"""End-to-end serving driver: batched requests with KV caches, then the
mqr-KV sparse path (the paper's technique) on a longer context.

  PYTHONPATH=src python examples/serve_longcontext.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs import registry
from repro.launch.serve import serve


def main():
    # Batched requests, dense decode
    out = serve(arch="llama32_1b", smoke=True, batch=4, prompt_len=48, gen=16)
    print("dense decode outputs:", out[:, :8])

    # Same model, mqr-KV sparse decode: the index prunes KV blocks per head
    out_sparse = serve(arch="llama32_1b", smoke=True, batch=2, prompt_len=48,
                       gen=16, mqr_sparse=True)
    print("mqr-sparse outputs:  ", out_sparse[:, :8])

    # show the pruning: topk out of nb blocks touched per step
    cfg = registry.get_config("llama32_1b", smoke=True)
    nb = 64 // cfg.mqr_block
    print(f"\nmqr-KV touched {min(cfg.mqr_topk, nb)}/{nb} KV blocks per head "
          f"per step (block={cfg.mqr_block} tokens, levels={cfg.mqr_levels}).")
    print("At the long_500k production shape that is "
          f"{64}/{524288 // 128} blocks — a ~64x HBM-read reduction, the "
          "2026 analogue of the paper's disk-access table.")


if __name__ == "__main__":
    main()
