"""Quickstart: one `SpatialIndex` façade over every tree × backend path.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro import SpatialIndex
from repro.core import datasets, metrics, mqrtree, rtree


def main():
    # 1. The paper's structure on 1000 uniform 10x10 squares
    data = datasets.uniform_squares(1000, seed=0)
    mq = mqrtree.build(data)
    rt = rtree.build(data)
    m, r = metrics.compute_metrics(mq), metrics.compute_metrics(rt)
    print("index     nodes  height  coverage      overcov      overlap")
    for name, x in (("mqr-tree", m), ("r-tree", r)):
        print(f"{name:9s} {x.n_nodes:5d}  {x.height:2d}({x.avg_path:4.1f}) "
              f"{x.coverage:12.0f} {x.overcoverage:12.0f} {x.overlap:12.0f}")
    print(f"\nmqr overlap is {100 * (1 - m.overlap / r.overlap):.0f}% lower; "
          "on point data it is exactly ZERO (paper section 4).")

    # 2. One façade, every build/query path: same call shape for the host
    # pointer oracle, the jit'd lax sweep, and the fused Pallas kernel.
    qs = datasets.region_queries(data, 20, seed=1)
    host = SpatialIndex.build(data, structure="mqr", backend="host")
    rhost = SpatialIndex.build(data, structure="rtree", backend="host")
    ref = host.region(qs)
    vr = int(rhost.region(qs).visits.sum())
    print(f"\nregion search over 20 queries: mqr {int(ref.visits.sum())} node "
          f"visits (host oracle); r-tree {vr}")

    for backend in ("lax", "pallas", "serve"):
        idx = host.with_backend(backend)  # same build artifacts, new engine
        res = idx.region(qs)
        assert np.array_equal(res.hits, ref.hits)
        assert np.array_equal(res.visits_per_level, ref.visits_per_level)
        print(f"backend={backend:6s} identical hits + per-level disk "
              f"accesses ({idx.stats.node_accesses} total, "
              f"{idx.stats.launches} launches)")

    # 3. k-NN as a first-class query: host branch-and-bound oracle vs the
    # TPU expanding-radius schedule over the fused kernel.
    pts = np.random.default_rng(2).uniform(100, 900, (8, 2))
    kh = host.knn(pts, k=5)
    kd = host.with_backend("pallas").knn(pts, k=5)
    assert np.array_equal(kh.ids, kd.ids)
    print(f"\nknn(k=5) over 8 points: host and fused-kernel paths agree; "
          f"nearest of point 0: objects {kh.ids[0].tolist()} "
          f"(host {int(kh.visits.sum())} vs device {int(kd.visits.sum())} "
          f"accesses)")

    # 4. The bulk pyramid structure through the same façade.
    pidx = SpatialIndex.build(data, structure="pyramid", backend="pallas")
    print(f"pyramid backend=pallas: {pidx.count(qs).sum()} total hits over "
          f"{pidx.schedule.levels} levels, one kernel launch per batch")

    # 5. Where the time goes: build-time / query-time split per backend.
    # The device bulk build (DESIGN.md §7) replaces per-object host
    # insertion with one launch; precision="compact" streams uint16 MBR
    # tiles at half the bytes/query with bit-identical hits.
    print("\nbuild-time / query-time split (n=1000, 20 queries):")
    configs = [
        ("mqr", "host", {}),
        ("mqr", "pallas", {}),
        ("pyramid", "pallas", {"build": "device"}),
        ("pyramid", "pallas", {"build": "device", "precision": "compact"}),
    ]
    ref_hits = ref.hits
    for structure, backend, opts in configs:
        t0 = time.time()
        idx = SpatialIndex.build(data, structure=structure, backend=backend,
                                 **opts)
        idx.region(qs)  # lowering+compile at batch shape = build column
        t_build = time.time() - t0
        t0 = time.time()
        res = idx.region(qs)
        t_query = time.time() - t0
        if structure == "mqr":
            assert np.array_equal(res.hits, ref_hits)
        tag = " ".join(f"{k}={v}" for k, v in opts.items()) or "-"
        print(f"  {structure:8s} {backend:7s} {tag:38s} "
              f"build {t_build:6.3f}s  query {t_query * 1e3:6.1f}ms")

    # 6. Batch insertion: extend() buffers the batch in the live-update
    # subsystem (flush="always" = the legacy eager device re-build).
    didx = SpatialIndex.build(data, structure="pyramid", backend="pallas",
                              build="device")
    t0 = time.time()
    grown = didx.extend(datasets.uniform_squares(500, seed=9))
    t_ext = time.time() - t0
    print(f"\nextend(+500 objects): {didx.n_objects} -> {grown.n_objects} "
          f"objects in {t_ext:.3f}s (buffered; no rebuild)")

    # 7. Live updates (DESIGN.md §8): insert/delete/flush online — the
    # delta buffer rides the same fused launch, deletes are tombstones
    # masked in the scan epilogue, flush() compacts with ids preserved.
    live = SpatialIndex.build(data, structure="mqr", backend="pallas",
                              capacity=256)
    gids = live.insert(datasets.uniform_squares(100, seed=10))
    live.delete(gids[:10])
    live.delete(np.arange(25))          # tombstone 25 base objects too
    res = live.region(qs)
    assert not res.hits[:, :25].any() and not res.hits[:, gids[:10]].any()
    print(f"\nlive updates: +100 / -35 -> {live.n_objects} live objects, "
          f"{int(res.delta_visits.sum())} delta accesses over 20 queries "
          f"(buffer fill {live._updates.fill:.0%})")
    live.flush()
    post = live.region(qs)
    assert all(np.array_equal(res.ids(i), post.ids(i)) for i in range(20))
    print(f"flush(): merged into a fresh base build — hit sets identical, "
          f"{live.stats.flushes} merge(s), zero overlap preserved on point "
          f"data (live_metrics)")

    # 8. Durability (DESIGN.md §9): save -> kill -> recover. Every
    # mutation is fsync'd to a write-ahead log BEFORE it touches device
    # state, so a kill at any point (here: mid-workload, with a torn
    # half-written record at the WAL tail) recovers to the last durable
    # op — bit-identical hits on any backend.
    import shutil
    import tempfile

    from repro.checkpoint import DurableIndex, live_ids
    from repro.ft import FaultPlan, KillPoint

    root = tempfile.mkdtemp(prefix="mqr-durable-")
    try:
        plan = FaultPlan(kill_at_op=5, torn_write=True)  # die mid-append
        d = DurableIndex.create(data, root, backend="pallas",
                                capacity=64, fault_plan=plan)
        try:
            for i in range(8):
                d.insert(datasets.uniform_squares(3, seed=20 + i))
        except KillPoint as e:
            print(f"\ndurability: simulated crash — {e}")
        d.close()
        rec = DurableIndex.recover(root, backend="pallas")
        print(f"recover(): snapshot + {rec.recovered_ops} WAL ops replayed "
              f"(torn tail dropped: {rec.recovered_torn}) -> "
              f"{rec.n_objects} live objects")
        assert rec.ops_total == 5 and rec.n_objects == 1015
        twin = rec.index.with_backend("host")
        assert np.array_equal(rec.region(qs).hits, twin.region(qs).hits)
        assert live_ids(rec).size == rec.n_objects
        rec.checkpoint()  # rotate: fresh snapshot generation + empty WAL
        rec.close()
        print("recovered index answers bit-identically on pallas and host; "
              "checkpoint() rotated to a fresh generation")
    finally:
        shutil.rmtree(root, ignore_errors=True)

    # 9. Batch spatial join + the moving-object workload (DESIGN.md §10):
    # both trees sweep together in one fused launch, and the pair set is
    # bit-identical to the brute-force nested-loop oracle — even while a
    # churning delta buffer holds un-merged inserts and tombstones.
    from repro.launch.moving import MovingConfig, MovingWorkload

    w = MovingWorkload(
        MovingConfig(n_objects=64, moves_per_tick=8, query_every=5, seed=0),
        backend="pallas", capacity=96,
    )
    t0 = time.time()
    last = w.run(15)   # 15 ticks: 120 deletes + 120 inserts, 3 query ticks
    dt = time.time() - t0
    a = w.query_index._updates.mbr_table.astype(np.float32)
    z = np.asarray(w.zones.artifacts.mbrs, np.float32)
    brute = ((a[:, None, 0] <= z[None, :, 2]) & (z[None, :, 0] <= a[:, None, 2])
             & (a[:, None, 1] <= z[None, :, 3]) & (z[None, :, 1] <= a[:, None, 3]))
    brute &= w.query_index._updates.alive[:, None]
    assert np.array_equal(last.join.pairs, brute)
    print(f"\nmoving objects: 15 ticks in {dt:.2f}s "
          f"({w.query_index.stats.inserts} inserts, "
          f"{w.query_index.stats.deletes} deletes, "
          f"{w.query_index.stats.flushes} merges); final join: "
          f"{last.join.n_pairs} object×zone pairs from "
          f"{int(last.join.pair_visits.sum())} pair tests "
          f"(brute force: {brute.size}) — pair set identical to the "
          f"nested-loop oracle")

    # 10. Serving front end (DESIGN.md §11): single-request arrivals are
    # coalesced into deadline-bounded batches, admission-controlled per
    # SLO class, one tenant per declarative config — and every served
    # answer is bit-identical to calling the tenant's index directly.
    from repro.serve import ServerConfig, ServingFrontEnd

    cfg = ServerConfig.from_dict({
        "query_block": 8,
        "classes": [
            {"name": "interactive", "deadline_ms": 50, "overload": "shed",
             "max_queue": 64},
        ],
        "tenants": [
            {"name": "maps", "structure": "mqr", "backend": "serve"},
            {"name": "fleet", "structure": "mqr", "backend": "host",
             "capacity": 64},
        ],
    })
    front = ServingFrontEnd.build(cfg, {"maps": data, "fleet": data})
    tickets = [
        front.submit("maps", "region", q) for q in qs[:6].astype(np.float32)
    ]
    tickets.append(front.submit("maps", "knn", [5.0, 5.0], k=3))
    front.drain()
    direct = front.tenants["maps"].index.region(qs[:6].astype(np.float32))
    for i, t in enumerate(tickets[:6]):
        assert np.array_equal(front.result(t).hits, direct.hits[i])
    front.insert("fleet", data[:4] + 0.5)   # only fleet's epoch moves
    snap = front.telemetry.snapshot()
    print(f"\nserving front end: {snap['completed']} served in "
          f"{snap['batches']} coalesced batches (avg {snap['avg_batch']}), "
          f"p99 {snap['p99_ms']:.2f} ms, shed {snap['shed']} — every "
          "answer bit-identical to the direct index call")

    # 11. Hardware-limit knobs (DESIGN.md §12): Hilbert leaf ordering at
    # build time, HBM-streaming sweep, uint8 upper-level tiles, and the
    # tiling autotuner — four independent levers, zero answer movement.
    base = SpatialIndex.build(data, structure="mqr", backend="pallas")
    ref = base.region(qs.astype(np.float32))
    tuned = SpatialIndex.build(
        data, structure="mqr", backend="pallas", order="hilbert",
        backend_opts={"stream": True, "autotune": "off"},
    )
    res = tuned.region(qs.astype(np.float32))
    assert np.array_equal(res.hits, ref.hits)
    assert np.array_equal(res.visits_per_level, ref.visits_per_level)
    c8 = base.with_backend("pallas", precision="compact8").region(
        qs.astype(np.float32)
    )
    assert np.array_equal(c8.hits, ref.hits)
    print("\nhardware-limit knobs: hilbert ordering + HBM-streamed sweep "
          "+ uint8 upper tiles all bit-identical to the plain fused path "
          f"({int(ref.hits.sum())} hits; autotuner caches winners in "
          "BuildArtifacts.tuned)")

    # 12. Observability (DESIGN.md §13): trace spans + the per-launch
    # byte ledger + a metrics snapshot.  Tracing off costs one attribute
    # check; the ledger is opt-in and discloses the SAME numbers
    # bench_stream_scan computes, bit for bit.
    from repro.obs import counters, trace

    trace.enable()
    counters.collect_launch_reports(True)
    res = tuned.region(qs.astype(np.float32))
    rep = res.launch_report
    counters.collect_launch_reports(False)
    trace.get_tracer().export_chrome_trace("trace.json")
    trace.disable()
    prom = tuned.metrics(tenant="quickstart").to_prometheus()
    n_spans = sum(1 for e in trace.get_tracer().events() if e["ph"] == "X")
    print(f"\nobservability: {n_spans} spans -> trace.json (open in "
          f"Perfetto); launch ledger: {rep.bytes_streamed:.0f} B streamed "
          f"over {rep.tiles_fetched}/{rep.tiles_total} tiles "
          f"({rep.tiles_skipped} skipped dead); metrics snapshot "
          f"{len(prom.splitlines())} Prometheus lines, e.g.")
    for line in prom.splitlines():
        if line.startswith("repro_index_queries"):
            print(f"  {line}")


if __name__ == "__main__":
    main()
