"""Quickstart: build an mqr-tree, compare with the R-tree, run the JAX path.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import datasets, flat, metrics, mqrtree, rtree


def main():
    # 1. The paper's structure on 1000 uniform 10x10 squares
    data = datasets.uniform_squares(1000, seed=0)
    mq = mqrtree.build(data)
    rt = rtree.build(data)
    m, r = metrics.compute_metrics(mq), metrics.compute_metrics(rt)
    print("index     nodes  height  coverage      overcov      overlap")
    for name, x in (("mqr-tree", m), ("r-tree", r)):
        print(f"{name:9s} {x.n_nodes:5d}  {x.height:2d}({x.avg_path:4.1f}) "
              f"{x.coverage:12.0f} {x.overcoverage:12.0f} {x.overlap:12.0f}")
    print(f"\nmqr overlap is {100 * (1 - m.overlap / r.overlap):.0f}% lower; "
          "on point data it is exactly ZERO (paper section 4).")

    # 2. Region search: disk accesses
    qs = datasets.region_queries(data, 20, seed=1)
    vm = sum(mq.region_search(q)[1] for q in qs)
    vr = sum(rt.region_search(q)[1] for q in qs)
    print(f"\nregion search over 20 queries: mqr {vm} node visits, r-tree {vr}")

    # 3. The TPU-adapted path: levelized arrays + batched JAX search
    ft = flat.flatten(mq)
    hits, visits = flat.region_search_batch(ft, qs)
    host_hits = [set(mq.region_search(q)[0]) for q in qs]
    assert all(set(np.nonzero(hits[i])[0]) == host_hits[i] for i in range(len(qs)))
    print(f"JAX levelized search: identical results, visits match "
          f"({int(visits.sum())} == {vm})")

    # 4. The fused Pallas pipeline: the whole levelized sweep in ONE kernel
    # launch (DESIGN.md §3.3), same results and per-level disk accesses.
    from repro.kernels import ops
    sched = flat.level_schedule(ft)
    fhits, fvisits = ops.pyramid_scan(sched, qs)
    fhits, fvisits = np.asarray(fhits), np.asarray(fvisits)
    assert all(set(np.nonzero(fhits[i])[0]) == host_hits[i] for i in range(len(qs)))
    print(f"fused pyramid_scan: 1 launch for {sched.levels} levels, "
          f"identical results, accesses match ({int(fvisits.sum())} == {vm})")


if __name__ == "__main__":
    main()
