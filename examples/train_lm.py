"""End-to-end training driver: a ~100M-parameter llama-style model on the
synthetic pipeline with checkpoint/resume and straggler monitoring.

Full run (a few hundred steps of a ~100M model — several hours on 1 CPU;
minutes on any accelerator):
  PYTHONPATH=src python examples/train_lm.py --steps 300

Quick demo (2-layer 25M variant, ~2 min):
  PYTHONPATH=src python examples/train_lm.py --quick
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    if args.quick:
        # ~25M params: d_model=512, 2 layers, 128k vocab head dominates
        losses = train(arch="llama32_1b", smoke=True, steps=60, batch=8,
                       seq=128, d_model=512, n_layers=2, lr=1e-3,
                       ckpt_dir=args.ckpt_dir, ckpt_every=25, log_every=5)
    else:
        # ~100M params: d_model=768, 12 layers (llama3-style stack)
        losses = train(arch="llama32_1b", smoke=True, steps=args.steps,
                       batch=16, seq=256, d_model=768, n_layers=12, lr=6e-4,
                       ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10)
    print(f"final loss {losses[-5:].mean():.4f} (start {losses[:5].mean():.4f})")


if __name__ == "__main__":
    main()
