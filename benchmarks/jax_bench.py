"""TPU-adaptation benchmarks: vectorized search, kernels, mqr-KV serving.

``REPRO_BENCH_TINY=1`` shrinks every object count to smoke sizes so the
CI bench-smoke job can exercise the whole harness in seconds.
"""

from __future__ import annotations

import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import datasets, flat, kvindex, mqrtree
from repro.kernels import ops
from repro.obs import counters as obs_counters
from repro.obs import trace as obs_trace

TINY = os.environ.get("REPRO_BENCH_TINY", "0") == "1"


def _timeit(fn, *args, iters=5, warm=True):
    if warm:  # settle jit compilation; skip for pure-host one-pass timings
        fn(*args)
    t0 = time.time()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) / iters


def bench_flat_search():
    data = datasets.uniform_squares(300 if TINY else 2000, seed=1)
    tree = mqrtree.build(data)
    ft = flat.flatten(tree)
    qs = jnp.asarray(datasets.region_queries(data, 32, seed=2), jnp.float32)
    t_host = _timeit(
        lambda: [tree.region_search(np.asarray(q)) for q in qs], iters=2
    )
    t_jax = _timeit(lambda: flat.region_search_batch(ft, qs), iters=2)
    return [
        (t_host / 32, {"impl": "host-pointer", "queries": 32}),
        (t_jax / 32, {"impl": "jax-levelized", "queries": 32}),
    ]


def bench_pyramid_build():
    """Build throughput, host pointer insertion vs the device bulk build.

    Both pipelines end at a query-ready ``LevelSchedule`` (the host side
    pays build + flatten + level_schedule, the device side ONE launch of
    the bulk fixed point, DESIGN.md §7); objects/sec at every n sits in
    one derived dict per impl so the crossover reads off a single row.
    """
    ns = (200, 400) if TINY else (1_000, 10_000, 100_000)

    def host_build(data):
        return flat.level_schedule(flat.flatten(mqrtree.build(data)))

    rows = []
    for impl, build in (
        ("host-pointer-build", host_build),
        ("device-bulk-build", lambda d: ops.device_schedule(d)),
    ):
        objs_per_sec, t_last = {}, 0.0
        device = impl.startswith("device")
        for n in ns:
            data = datasets.uniform_squares(n, seed=3)
            # the host pointer build is O(minutes) at n=1e5: time ONE pass,
            # no warm call (nothing to compile on the pure-host path)
            iters = 3 if (device and n <= 10_000) else 1
            t_last = _timeit(build, data, iters=iters, warm=device)
            objs_per_sec[str(n)] = round(n / t_last)
        rows.append((t_last, {"impl": impl, "objects_per_sec": objs_per_sec,
                              "n_max": ns[-1]}))
    return rows


def bench_compact_scan():
    """Bytes-per-query of the fused sweep: float32 tiles vs conservative
    uint16 tiles (+ exact confirming pass).  Hit sets are asserted
    identical; the bytes ratio is the streamed (mbr tiles + parent rows)
    HBM traffic of one launch, which the compact path halves."""
    n, n_q = (256, 8) if TINY else (4096, 32)
    data = datasets.uniform_squares(n, seed=1)
    sched = ops.device_schedule(data)
    qsched = ops.quantize_schedule(sched)
    qs = datasets.region_queries(data, n_q, seed=2)

    t_f = _timeit(lambda: ops.pyramid_scan(sched, qs), iters=3)
    t_c = _timeit(lambda: ops.pyramid_scan_compact(qsched, qs), iters=3)
    hits_f, visits_f = ops.pyramid_scan(sched, qs)
    hits_c, visits_c = ops.pyramid_scan_compact(qsched, qs)
    assert np.array_equal(np.asarray(hits_f), np.asarray(hits_c))
    bytes_f = sched.mbr_cm.nbytes + sched.parent.nbytes
    bytes_c = qsched.streamed_bytes
    return [
        (t_f, {"impl": "float32-tiles", "q/s": round(n_q / t_f),
               "bytes/query": round(bytes_f / n_q),
               "accesses": int(np.asarray(visits_f).sum())}),
        (t_c, {"impl": "compact-uint16-tiles", "q/s": round(n_q / t_c),
               "bytes/query": round(bytes_c / n_q),
               "bytes_ratio": round(bytes_c / bytes_f, 3),
               "accesses": int(np.asarray(visits_c).sum())}),
    ]


def bench_mbr_scan_kernel():
    n = 512 if TINY else 8192
    lo = jnp.asarray(np.random.default_rng(0).uniform(0, 1000, (n, 2)), jnp.float32)
    mbrs = jnp.concatenate([lo, lo + 10.0], axis=1)
    qs = jnp.asarray(datasets.region_queries(np.asarray(mbrs), 8, seed=1), jnp.float32)
    t_k = _timeit(lambda: ops.mbr_scan(mbrs, qs), iters=3)
    t_r = _timeit(lambda: ops.mbr_scan_ref(mbrs, qs), iters=3)
    return [
        (t_k, {"impl": "pallas-interpret", "n": n}),
        (t_r, {"impl": "jnp-ref", "n": n}),
    ]


def bench_pyramid_scan():
    """The paper's Section 5 disk-access comparison, on-accelerator: fused
    single-launch level sweep vs one-kernel-per-level vs host pointers."""
    n, n_q = (300, 8) if TINY else (2000, 32)
    data = datasets.uniform_squares(n, seed=1)
    tree = mqrtree.build(data)
    sched = flat.level_schedule(flat.flatten(tree))
    qs = datasets.region_queries(data, n_q, seed=2)
    qj = jnp.asarray(qs, jnp.float32)

    t_fused = _timeit(lambda: ops.pyramid_scan(sched, qj), iters=3)
    t_level = _timeit(lambda: ops.per_level_region_search(sched, qj), iters=3)
    t_host = _timeit(
        lambda: [tree.region_search(np.asarray(q)) for q in qs], iters=2
    )
    _, visits = ops.pyramid_scan(sched, qj)
    accesses = int(jnp.sum(visits))
    _, _, launches = ops.per_level_region_search(sched, qj)
    return [
        (t_fused, {"impl": "pyramid-scan-fused", "launches": 1,
                   "q/s": round(n_q / t_fused), "accesses": accesses}),
        (t_level, {"impl": "per-level-mbr-scan", "launches": launches,
                   "q/s": round(n_q / t_level), "accesses": accesses}),
        (t_host, {"impl": "host-pointer", "launches": 0,
                  "q/s": round(n_q / t_host), "accesses": accesses}),
    ]


def bench_index_api():
    """Façade overhead: `SpatialIndex.region` vs calling the fused kernel
    directly must be <5%; plus a first-class knn row (DESIGN.md §6).

    Both sides deliver host-side numpy results (what a caller consumes);
    timing interleaves the two and keeps the per-impl minimum so container
    scheduling jitter does not swamp the microseconds of façade work.
    """
    from repro.index import SpatialIndex

    n, n_q, k = (300, 8, 4) if TINY else (2000, 32, 8)
    data = datasets.uniform_squares(n, seed=1)
    idx = SpatialIndex.build(data, structure="mqr", backend="pallas")
    sched = idx.schedule
    qs = datasets.region_queries(data, n_q, seed=2)

    # Apples-to-apples: both sides take the same numpy queries and deliver
    # host-side numpy results (what a caller consumes).
    def direct():
        hits, visits = ops.pyramid_scan(sched, qs)
        return np.asarray(hits), np.asarray(visits)

    def facade():
        return idx.region(qs).hits

    direct(), facade()  # warm / compile
    # Paired timing: each iteration measures both back-to-back, so the
    # slowly-drifting container noise cancels in the per-pair delta.
    ds, fs = [], []
    for _ in range(80):
        t0 = time.time()
        direct()
        t1 = time.time()
        facade()
        t2 = time.time()
        ds.append(t1 - t0)
        fs.append(t2 - t1)
    t_direct = float(np.median(ds))
    t_facade = float(np.median(fs))
    overhead = float(np.median(np.array(fs) - np.array(ds))) / t_direct

    pts = np.random.default_rng(3).uniform(100, 900, (n_q, 2))
    idx.knn(pts, k)  # warm the expanding-radius round shapes
    before = idx.stats.to_dict()
    t_knn = _timeit(lambda: idx.knn(pts, k).ids, iters=3)
    delta = idx.stats.diff(before)  # windowed deltas, not lifetime totals
    accesses = delta["node_accesses"] / max(delta["knn_queries"], 1)
    # Facade build throughput: `SpatialIndex.build(structure="pyramid",
    # build="device")` objects/sec across the crossover sizes, one row.
    build_ns = (200, 400) if TINY else (1_000, 10_000, 100_000)
    build_objs, t_build = {}, 0.0
    for bn in build_ns:
        bdata = datasets.uniform_squares(bn, seed=4)
        t_build = _timeit(
            lambda d=bdata: SpatialIndex.build(
                d, structure="pyramid", backend="pallas", build="device"
            ),
            iters=1,
        )
        build_objs[str(bn)] = round(bn / t_build)

    # precision="compact": identical hits through the facade, half the
    # streamed tile bytes (see kernel_compact_scan for the byte ledger).
    cidx = idx.with_backend("pallas", precision="compact")
    res_c = cidx.region(qs)
    assert np.array_equal(res_c.hits, idx.region(qs).hits)
    t_compact = _timeit(lambda: cidx.region(qs).hits, iters=3)

    return [
        (t_direct, {"impl": "pyramid-scan-direct", "q/s": round(n_q / t_direct)}),
        (t_facade, {"impl": "spatial-index-facade", "q/s": round(n_q / t_facade),
                    "overhead": f"{overhead:+.1%}"}),
        (t_compact, {"impl": "spatial-index-compact",
                     "q/s": round(n_q / t_compact)}),
        (t_build, {"impl": "spatial-index-build-device",
                   "objects_per_sec": build_objs, "n_max": build_ns[-1]}),
        (t_knn, {"impl": "spatial-index-knn", "k": k,
                 "q/s": round(n_q / t_knn),
                 "accesses/query": round(accesses, 1)}),
    ]


def bench_live_update():
    """Live-update subsystem (DESIGN.md §8): mutation throughput and the
    query rent of an un-merged delta buffer.

    Rows: inserts/sec and deletes/sec into the device-resident buffer,
    region q/s at ~10% and ~50% buffer fill (the flat delta levels ride
    the same fused launch), and q/s after the merge compacts everything
    back into a clean base build (flush wall-time reported alongside).
    """
    from repro.index import SpatialIndex

    n, capacity, n_q = (200, 64, 8) if TINY else (4000, 1024, 32)
    data = datasets.uniform_squares(n, seed=1)
    idx = SpatialIndex.build(
        data, structure="pyramid", backend="pallas",
        merge=dict(capacity=capacity, max_fill=1.0, max_tombstone_ratio=1.0),
    )
    qs = datasets.region_queries(data, n_q, seed=2)
    rng = np.random.default_rng(3)
    rows = []

    b = max(capacity // 10, 1)
    ins1 = datasets.uniform_squares(b, seed=4)
    t0 = time.time()
    idx.insert(ins1)
    t_ins = time.time() - t0
    rows.append((t_ins, {"impl": "live-insert", "batch": b,
                         "inserts_per_sec": round(b / t_ins)}))

    t10 = _timeit(lambda: idx.region(qs).hits, iters=3)
    rows.append((t10, {"impl": "live-query-10pct-fill",
                       "q/s": round(n_q / t10),
                       "fill": round(idx._updates.fill, 2)}))

    victims = rng.choice(
        np.nonzero(idx._updates.alive)[0], size=b, replace=False
    )
    t0 = time.time()
    idx.delete(victims)
    t_del = time.time() - t0
    rows.append((t_del, {"impl": "live-delete", "batch": b,
                         "deletes_per_sec": round(b / t_del)}))

    idx.insert(datasets.uniform_squares(int(capacity * 0.4), seed=5))
    t50 = _timeit(lambda: idx.region(qs).hits, iters=3)
    rows.append((t50, {"impl": "live-query-50pct-fill",
                       "q/s": round(n_q / t50),
                       "fill": round(idx._updates.fill, 2)}))

    t0 = time.time()
    idx.flush()
    t_flush = time.time() - t0
    tpf = _timeit(lambda: idx.region(qs).hits, iters=3)
    rows.append((tpf, {"impl": "live-query-post-flush",
                       "q/s": round(n_q / tpf),
                       "flush_ms": round(t_flush * 1e3, 1),
                       "n_live": idx.n_objects}))
    return rows


def bench_durability():
    """Durability subsystem (DESIGN.md §9): what fault tolerance costs.

    Rows: snapshot save/load throughput (MB/s over the npz payload), the
    WAL tax per mutation with and without fsync, and recovery wall-time
    (snapshot load + WAL tail replay) as the tail grows.
    """
    import shutil
    import tempfile

    from repro.checkpoint import DurableIndex, load_index, save_index
    from repro.index import SpatialIndex

    n, n_mut, tail = (300, 20, (5, 20)) if TINY else (8000, 200, (50, 400))
    data = datasets.uniform_squares(n, seed=7)
    idx = SpatialIndex.build(data, backend="pallas", capacity=max(n_mut, 64))
    idx.insert(datasets.uniform_squares(n_mut // 2, seed=8))
    root = pathlib.Path(tempfile.mkdtemp(prefix="repro-bench-durable-"))
    rows = []
    try:
        t_save = _timeit(lambda: save_index(idx, root / "snap"),
                         iters=3, warm=False)
        nbytes = (root / "snap" / "arrays.npz").stat().st_size
        rows.append((t_save, {"impl": "snapshot-save", "n": idx.n_objects,
                              "MB/s": round(nbytes / t_save / 2**20, 1)}))
        t_load = _timeit(lambda: load_index(root / "snap", backend="pallas"),
                         iters=3, warm=False)
        rows.append((t_load, {"impl": "snapshot-load", "n": idx.n_objects,
                              "MB/s": round(nbytes / t_load / 2**20, 1)}))

        for sync in (False, True):
            d = DurableIndex.create(
                data, root / f"wal-{sync}", backend="pallas", sync=sync,
                capacity=max(n_mut * 4, 64),
            )
            batches = [datasets.uniform_squares(1, seed=100 + i)
                       for i in range(n_mut)]
            t0 = time.time()
            for b in batches:
                d.insert(b)
            t_mut = (time.time() - t0) / n_mut
            d.close()
            rows.append((t_mut, {
                "impl": f"wal-insert-{'fsync' if sync else 'nosync'}",
                "mutations": n_mut, "us_per_op": round(t_mut * 1e6, 1),
            }))

        for n_tail in tail:
            troot = root / f"tail-{n_tail}"
            d = DurableIndex.create(
                data, troot, backend="pallas", sync=False,
                capacity=max(n_tail * 2, 64),
            )
            for i in range(n_tail):
                d.insert(datasets.uniform_squares(1, seed=200 + i))
            d.close()
            t_rec = _timeit(
                lambda r=troot: DurableIndex.recover(
                    r, backend="pallas", sync=False
                ).close(),
                iters=2, warm=False,
            )
            rows.append((t_rec, {"impl": "recover", "wal_ops": n_tail,
                                 "ms": round(t_rec * 1e3, 1)}))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows


def bench_mqr_sparse_vs_dense_decode():
    """The paper's payoff on the KV cache: pruned vs full decode attention."""
    key = jax.random.PRNGKey(0)
    s, d, bs, k = (2048, 64, 128, 4) if TINY else (16384, 64, 128, 16)
    nb = s // bs
    keys = jax.random.normal(key, (s, d))
    vals = jax.random.normal(jax.random.fold_in(key, 1), (s, d))
    probe = jax.random.normal(jax.random.fold_in(key, 2), (d,))
    q = jax.random.normal(jax.random.fold_in(key, 3), (d,))

    @jax.jit
    def dense(q, keys, vals):
        logits = keys @ q / jnp.sqrt(d)
        return jax.nn.softmax(logits) @ vals

    @jax.jit
    def sparse(q, keys, vals):
        idx = kvindex.build_kv_index(keys, probe, bs, 6)
        ids = kvindex.select_blocks(idx, kvindex.query_region(q, probe, s), k)
        kb = keys.reshape(nb, bs, d)[ids].reshape(-1, d)
        vb = vals.reshape(nb, bs, d)[ids].reshape(-1, d)
        logits = kb @ q / jnp.sqrt(d)
        return jax.nn.softmax(logits) @ vb

    t_d = _timeit(dense, q, keys, vals)
    t_s = _timeit(sparse, q, keys, vals)
    return [
        (t_d, {"impl": "dense-decode", "kv": s}),
        (t_s, {"impl": "mqr-sparse-decode", "kv": s, "blocks": f"{k}/{nb}"}),
    ]


def bench_join():
    """Tree-vs-tree spatial join (DESIGN.md §10) vs the nested-loop oracle.

    Rows: joins/sec for the levelized pair sweep on float32 and compact
    tiles (candidate pair counts alongside — the pruning is the point)
    against the brute-force O(n·m) host oracle on the same data.
    """
    from repro.index import SpatialIndex

    na, nb = (300, 200) if TINY else (2000, 1500)
    da = datasets.uniform_squares(na, seed=1)
    db = datasets.exponential_squares(nb, seed=2)
    left = SpatialIndex.build(da, structure="mqr", backend="pallas")
    right = SpatialIndex.build(db, structure="mqr", backend="pallas")
    compact = SpatialIndex.build(
        da, structure="mqr", backend="pallas", precision="compact"
    )

    res = left.join(right)
    a32, b32 = np.asarray(da, np.float32), np.asarray(db, np.float32)

    def brute():
        return (
            (a32[:, None, 0] <= b32[None, :, 2])
            & (b32[None, :, 0] <= a32[:, None, 2])
            & (a32[:, None, 1] <= b32[None, :, 3])
            & (b32[None, :, 1] <= a32[:, None, 3])
        )

    t_j = _timeit(lambda: left.join(right).pairs, iters=3)
    t_c = _timeit(lambda: compact.join(right).pairs, iters=3)
    t_b = _timeit(brute, iters=3)
    return [
        (t_j, {"impl": "join-pair-sweep", "n": f"{na}x{nb}",
               "joins_per_sec": round(1 / t_j, 2),
               "pairs": res.n_pairs,
               "pair_tests": int(res.pair_visits.sum())}),
        (t_c, {"impl": "join-pair-sweep-compact", "n": f"{na}x{nb}",
               "joins_per_sec": round(1 / t_c, 2)}),
        (t_b, {"impl": "join-brute-oracle", "n": f"{na}x{nb}",
               "joins_per_sec": round(1 / t_b, 2),
               "pair_tests": na * nb}),
    ]


def bench_moving():
    """Moving-object workload: delta-buffer churn vs naive rebuilds.

    Rows: ticks/sec for the live-update path (batch delete + insert per
    tick, continuous region + join queries) against the rebuild-per-tick
    baseline on the identical seeded motion.
    """
    from repro.launch.moving import MovingConfig, MovingWorkload

    ticks = 5 if TINY else 50
    cfg = MovingConfig(n_objects=64 if TINY else 256, moves_per_tick=8,
                       query_every=5, seed=1)
    live = MovingWorkload(cfg, backend="pallas", capacity=128)
    t0 = time.time()
    live.run(ticks)
    t_live = time.time() - t0

    base = MovingWorkload(cfg, backend="pallas", rebuild_per_tick=True)
    t0 = time.time()
    base.run(ticks)
    t_base = time.time() - t0
    live_stats = live.query_index.stats.to_dict()
    return [
        (t_live, {"impl": "moving-delta-buffer", "ticks": ticks,
                  "ticks_per_sec": round(ticks / t_live, 2),
                  "merges": live_stats["flushes"],
                  "joins": live_stats["joins"]}),
        (t_base, {"impl": "moving-rebuild-per-tick", "ticks": ticks,
                  "ticks_per_sec": round(ticks / t_base, 2),
                  "speedup_vs_rebuild": round(t_base / t_live, 2)}),
    ]


def bench_serving():
    """Latency under open-loop load through the serving front end.

    One row per offered-QPS level: p50/p99/p99.9 completion latency of
    single-request arrivals coalesced into ``query_block`` batches, plus
    shed / SLO-violation counters — the latency-vs-load curve the
    front end exists for (DESIGN.md §11).  Arrivals are Poisson and
    latency is measured from the SCHEDULED arrival, so the curve is
    free of coordinated omission.
    """
    from repro.launch.loadgen import demo_dataset
    from repro.serve import ServerConfig, ServingFrontEnd
    from repro.serve.loadgen import run_sweep

    levels = [25.0, 100.0, 400.0] if TINY else [50.0, 200.0, 800.0]
    duration = 0.4 if TINY else 2.0
    data = {"demo": demo_dataset(256 if TINY else 4096)}
    cfg = ServerConfig.from_dict({
        "tenants": [{"name": "demo", "backend": "serve"}],
        "query_block": 8 if TINY else 16,
    })

    def make_front():
        return ServingFrontEnd.build(cfg, data), "demo"

    rows = run_sweep(make_front, levels, duration=duration, seed=0)
    return [
        (row["mean_ms"] / 1e3,
         {"impl": "serve-frontend",
          "qps_offered": round(row["qps_offered"], 1),
          "qps_achieved": round(row["qps_achieved"], 1),
          "p50_ms": round(row["p50_ms"], 3),
          "p99_ms": round(row["p99_ms"], 3),
          "p999_ms": round(row["p999_ms"], 3),
          "shed": row["shed"],
          "slo_violations": row["slo_violations"],
          "avg_batch": row["avg_batch"],
          "deadline_launches": row["deadline_launches"]})
        for row in rows
    ]


def bench_stream_scan():
    """DESIGN.md §12 headline rows.

    1. streamed-vs-resident fused kernel: bit-identical hits, q/s both.
    2. bytes/query: uint16 compact baseline vs uint8-upper + Hilbert
       leaves, visited-tile accounting at 64-slot granularity (hit sets
       asserted bit-identical through the real kernels first).
    3. the capacity row: region search over n=1e7 objects on ONE chip via
       the memory-bounded streamed sweep — the VMEM-resident path cannot
       represent this schedule at all (mbr tiles alone are ~25x VMEM).
    """
    from repro.kernels import fallback

    rows = []

    # -- 1. streamed vs resident kernel -------------------------------
    n, n_q = (400, 8) if TINY else (4096, 16)
    data = datasets.uniform_squares(n, seed=1)
    sched = ops.device_schedule(data)
    qs = datasets.region_queries(data, n_q, seed=2)
    t_res = _timeit(lambda: ops.pyramid_scan(sched, qs), iters=3)
    t_str = _timeit(lambda: ops.pyramid_scan(sched, qs, stream=True), iters=3)
    h_r, v_r = ops.pyramid_scan(sched, qs)
    h_s, v_s = ops.pyramid_scan(sched, qs, stream=True)
    assert np.array_equal(np.asarray(h_s), np.asarray(h_r))
    assert np.array_equal(np.asarray(v_s), np.asarray(v_r))
    win_off, win_w = ops.parent_windows(sched.parent, sched.n_real,
                                        block_w=128)
    rows.append((t_res, {"impl": "vmem-resident", "n": n,
                         "q/s": round(n_q / t_res)}))
    rows.append((t_str, {"impl": "hbm-streamed", "n": n,
                         "q/s": round(n_q / t_str), "win_w": int(win_w),
                         "hits_identical": True}))

    # -- 2. bytes/query ------------------------------------------------
    # Headline: the resident uint16 compact path streams its FULL grid
    # HBM->VMEM every launch (each BlockSpec tile is DMA'd whether or not
    # any query can reach it — that is what pallas_call does); the
    # streamed sweep's dead-window skip only DMAs tiles whose parent
    # window still holds a survivor for some query in the batch.  Both
    # sides count mbr+parent tile traffic per query on the SAME uint16
    # grid, hit sets asserted bit-identical through the real kernels.
    nb, nqb = (400, 8) if TINY else (20_000, 8)
    data_b = datasets.uniform_squares(nb, seed=4)
    qs_b = datasets.region_queries(data_b, nqb, seed=5)
    plain = ops.device_schedule(data_b, engine="jnp")
    hil = ops.device_schedule(data_b, engine="jnp", order="hilbert")
    q16 = ops.quantize_schedule(plain, engine="jnp")
    q8h = ops.quantize_schedule(hil, engine="jnp", upper8=True)
    # hit sets through the real kernels: bit-identical across the board
    h16, _ = ops.pyramid_scan_compact(q16, qs_b)
    h16s, _ = ops.pyramid_scan_compact(q16, qs_b, stream=True)
    h8h, _ = ops.pyramid_scan_compact8(q8h, qs_b)
    assert np.array_equal(np.asarray(h16), np.asarray(h8h))
    assert np.array_equal(np.asarray(h16), np.asarray(h16s))

    # The ledger math lives in repro.obs.counters — the SAME functions
    # the kernel wrappers call to emit LaunchReports, so what the bench
    # discloses and what production telemetry discloses are one number.
    n_real = np.asarray(plain.n_real, np.int64)
    g16 = np.asarray(q16.mbr_q, np.int64)
    p16 = np.asarray(q16.parent_q, np.int64)
    qq16p = obs_counters.quantize_queries_grid(
        qs_b, q16.origin, q16.inv_cell, q16.cells)
    resident_bpq = q16.streamed_bytes / qs_b.shape[0]
    win_off, win_w = ops.parent_windows(p16, n_real, block_w=128)
    tile_b, mask_b, fetched, n_tiles, _ = obs_counters.stream_fetch_bytes(
        g16, p16, qq16p, win_off, win_w, block_w=128,
        root_unconditional=plain.root_unconditional,
    )
    rows.append((0.0, {"impl": "bytes-compact-uint16-resident", "n": nb,
                       "bytes/query": round(resident_bpq)}))
    rows.append((0.0, {"impl": "bytes-streamed-skip-uint16", "n": nb,
                       "bytes/query": round(tile_b / nqb),
                       "bytes_ratio": round(tile_b / nqb / resident_bpq, 4),
                       "tiles_fetched": f"{fetched}/{n_tiles}",
                       "mask_bytes/query": round(mask_b / nqb),
                       "hits_identical": True}))

    # Context rows: the paper's visited-tile disk ledger (a tile charged
    # only when one of its real slots must be tested) — the floor of
    # this model is 384/640 = 0.6x, which uint8 upper tiles + Hilbert
    # leaf order approach; the coarse u8 grid really is what the upper
    # levels test, so the accounting mixes grids per level.
    bpq16 = obs_counters.tile_bytes_per_query(
        g16, p16, n_real, qq16p, split=0,
        root_unconditional=plain.root_unconditional,
    )
    mixed = np.asarray(q8h.mbr_q, np.int64).copy()
    if q8h.split:
        mixed[:q8h.split] = np.asarray(q8h.mbr_q8, np.int64)
    bpq8h = obs_counters.tile_bytes_per_query(
        mixed, np.asarray(q8h.parent_q, np.int64),
        np.asarray(hil.n_real, np.int64),
        obs_counters.quantize_queries_grid(
            qs_b, q8h.origin, q8h.inv_cell, q8h.cells),
        split=q8h.split,
        root_unconditional=hil.root_unconditional,
        qq8=obs_counters.quantize_queries_grid(
            qs_b, q8h.origin, q8h.inv_cell8, q8h.cells8),
    )
    rows.append((0.0, {"impl": "bytes-visited-uint16", "n": nb,
                       "bytes/query": round(bpq16)}))
    rows.append((0.0, {"impl": "bytes-compact8-hilbert", "n": nb,
                       "bytes/query": round(bpq8h),
                       "bytes_ratio": round(bpq8h / bpq16, 3),
                       "hits_identical": True}))

    # -- 3. the 1e7 capacity row (streamed twin; VMEM path impossible) -
    n_big = 5_000 if TINY else 10_000_000
    data_big = datasets.uniform_points(n_big, seed=3)
    sched_big = ops.device_schedule(data_big, engine="jnp")
    qs_big = datasets.region_queries(data_big, 4, seed=6).astype(np.float32)
    t_big = _timeit(
        lambda: fallback.fused_search_np(
            qs_big, sched_big.mbr_cm, sched_big.parent, sched_big.obj_mbr,
            sched_big.obj_level, sched_big.obj_slot, sched_big.obj_id,
            n_objects=sched_big.n_objects,
            root_unconditional=sched_big.root_unconditional,
            test_object_mbr=sched_big.test_object_mbr,
            stream=True,
        ),
        iters=1, warm=False,
    )
    mbr_mb = sched_big.mbr_cm.nbytes / 2**20
    rows.append((t_big, {"impl": "streamed-twin-1e7", "n": n_big,
                         "q/s": round(4 / t_big, 2),
                         "levels": int(sched_big.parent.shape[0]),
                         "mbr_mb": round(mbr_mb, 1),
                         # ~16 MB VMEM/core: the resident kernel cannot
                         # even bind this schedule; streaming holds one
                         # (4, block_w) tile pair + two mask windows
                         "fits_vmem": bool(mbr_mb < 16)}))
    return rows


def bench_autotune():
    """Autotuned tiling vs the historical fixed block_w=128 (DESIGN.md
    §12).  Interpreted, larger tiles mean fewer Python kernel-body
    invocations per launch, so the tuner's win is visible on CPU too;
    natively it tracks VMEM/lane utilisation instead.  Hits are asserted
    bit-identical — the tuner only ever changes WHICH config runs."""
    from repro.index import SpatialIndex

    n, n_q = (640, 8) if TINY else (4096, 32)
    data = datasets.uniform_squares(n, seed=1)
    qs = datasets.region_queries(data, n_q, seed=2).astype(np.float32)
    fixed = SpatialIndex.build(data, structure="pyramid", backend="pallas",
                               build="device",
                               backend_opts={"autotune": "off"})
    tuned = fixed.with_backend("pallas", autotune="on")
    ref = fixed.region(qs)          # fixed 128-wide tiles
    res = tuned.region(qs)          # tunes on first batch, then cached
    assert np.array_equal(res.hits, ref.hits)
    t_fixed = _timeit(lambda: fixed.region(qs), iters=3)
    t_tuned = _timeit(lambda: tuned.region(qs), iters=3)
    (key, cfg), = tuned.artifacts.tuned.items()
    return [
        (t_fixed, {"impl": "fixed-block-128", "n": n,
                   "q/s": round(n_q / t_fixed, 1)}),
        (t_tuned, {"impl": "autotuned", "n": n,
                   "q/s": round(n_q / t_tuned, 1),
                   "block_w": cfg.block_w,
                   "query_block": cfg.query_block,
                   "levels_in_grid": cfg.levels_in_grid,
                   "speedup": round(t_fixed / t_tuned, 2),
                   "hits_identical": True}),
    ]


def bench_obs():
    """Observability tax (DESIGN.md §13): the <2% guarantee, measured.

    Rows: fused region q/s with tracing disabled vs enabled, plus the
    analytic overhead of the disabled fast path — per-call cost of a
    no-op ``span()`` (one enabled check returning the shared null span)
    times the two spans every ``region()`` call opens (facade +
    backend), as a percent of one region call.  The CI guard checks the
    analytic number: a direct A/B at smoke sizes is swamped by
    scheduler noise, the per-span cost is not.
    """
    from repro.index import SpatialIndex

    n, n_q = (640, 8) if TINY else (4096, 32)
    data = datasets.uniform_squares(n, seed=1)
    qs = datasets.region_queries(data, n_q, seed=2).astype(np.float32)
    idx = SpatialIndex.build(data, structure="pyramid", backend="pallas",
                             build="device",
                             backend_opts={"autotune": "off"})
    obs_trace.disable()
    t_off = _timeit(lambda: idx.region(qs).hits, iters=3)
    obs_trace.enable(capacity=1 << 16)
    try:
        t_on = _timeit(lambda: idx.region(qs).hits, iters=3)
    finally:
        obs_trace.disable()

    # per-span cost of the disabled fast path, amortized over K spans
    k = 20_000

    def noop_spans():
        for _ in range(k):
            with obs_trace.span("bench.noop"):
                pass

    t_span = _timeit(noop_spans, iters=3) / k
    spans_per_region = 2  # index.region + backend.<name>
    overhead_pct = 100.0 * spans_per_region * t_span / t_off
    return [
        (t_off, {"impl": "fused-tracing-off", "n": n,
                 "q/s": round(n_q / t_off, 1)}),
        (t_on, {"impl": "fused-tracing-on", "n": n,
                "q/s": round(n_q / t_on, 1),
                "qs_ratio": round((n_q / t_on) / (n_q / t_off), 4)}),
        (t_span * spans_per_region,
         {"impl": "disabled-span-tax",
          "per_span_ns": round(t_span * 1e9, 1),
          "spans_per_region": spans_per_region,
          "overhead_pct": round(overhead_pct, 4)}),
    ]


JAX_BENCHES = {
    "jax_flat_search": bench_flat_search,
    "jax_pyramid_build": bench_pyramid_build,
    "kernel_mbr_scan": bench_mbr_scan_kernel,
    "kernel_pyramid_scan": bench_pyramid_scan,
    "kernel_compact_scan": bench_compact_scan,
    "bench_stream_scan": bench_stream_scan,
    "bench_autotune": bench_autotune,
    "bench_obs": bench_obs,
    "index_api": bench_index_api,
    "live_update": bench_live_update,
    "durability": bench_durability,
    "join": bench_join,
    "moving": bench_moving,
    "serving": bench_serving,
    "mqr_sparse_vs_dense_decode": bench_mqr_sparse_vs_dense_decode,
}
