"""TPU-adaptation benchmarks: vectorized search, kernels, mqr-KV serving.

``REPRO_BENCH_TINY=1`` shrinks every object count to smoke sizes so the
CI bench-smoke job can exercise the whole harness in seconds.
"""

from __future__ import annotations

import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import datasets, flat, kvindex, mqrtree
from repro.kernels import ops

TINY = os.environ.get("REPRO_BENCH_TINY", "0") == "1"


def _timeit(fn, *args, iters=5, warm=True):
    if warm:  # settle jit compilation; skip for pure-host one-pass timings
        fn(*args)
    t0 = time.time()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) / iters


def bench_flat_search():
    data = datasets.uniform_squares(300 if TINY else 2000, seed=1)
    tree = mqrtree.build(data)
    ft = flat.flatten(tree)
    qs = jnp.asarray(datasets.region_queries(data, 32, seed=2), jnp.float32)
    t_host = _timeit(
        lambda: [tree.region_search(np.asarray(q)) for q in qs], iters=2
    )
    t_jax = _timeit(lambda: flat.region_search_batch(ft, qs), iters=2)
    return [
        (t_host / 32, {"impl": "host-pointer", "queries": 32}),
        (t_jax / 32, {"impl": "jax-levelized", "queries": 32}),
    ]


def bench_pyramid_build():
    """Build throughput, host pointer insertion vs the device bulk build.

    Both pipelines end at a query-ready ``LevelSchedule`` (the host side
    pays build + flatten + level_schedule, the device side ONE launch of
    the bulk fixed point, DESIGN.md §7); objects/sec at every n sits in
    one derived dict per impl so the crossover reads off a single row.
    """
    ns = (200, 400) if TINY else (1_000, 10_000, 100_000)

    def host_build(data):
        return flat.level_schedule(flat.flatten(mqrtree.build(data)))

    rows = []
    for impl, build in (
        ("host-pointer-build", host_build),
        ("device-bulk-build", lambda d: ops.device_schedule(d)),
    ):
        objs_per_sec, t_last = {}, 0.0
        device = impl.startswith("device")
        for n in ns:
            data = datasets.uniform_squares(n, seed=3)
            # the host pointer build is O(minutes) at n=1e5: time ONE pass,
            # no warm call (nothing to compile on the pure-host path)
            iters = 3 if (device and n <= 10_000) else 1
            t_last = _timeit(build, data, iters=iters, warm=device)
            objs_per_sec[str(n)] = round(n / t_last)
        rows.append((t_last, {"impl": impl, "objects_per_sec": objs_per_sec,
                              "n_max": ns[-1]}))
    return rows


def bench_compact_scan():
    """Bytes-per-query of the fused sweep: float32 tiles vs conservative
    uint16 tiles (+ exact confirming pass).  Hit sets are asserted
    identical; the bytes ratio is the streamed (mbr tiles + parent rows)
    HBM traffic of one launch, which the compact path halves."""
    n, n_q = (256, 8) if TINY else (4096, 32)
    data = datasets.uniform_squares(n, seed=1)
    sched = ops.device_schedule(data)
    qsched = ops.quantize_schedule(sched)
    qs = datasets.region_queries(data, n_q, seed=2)

    t_f = _timeit(lambda: ops.pyramid_scan(sched, qs), iters=3)
    t_c = _timeit(lambda: ops.pyramid_scan_compact(qsched, qs), iters=3)
    hits_f, visits_f = ops.pyramid_scan(sched, qs)
    hits_c, visits_c = ops.pyramid_scan_compact(qsched, qs)
    assert np.array_equal(np.asarray(hits_f), np.asarray(hits_c))
    bytes_f = sched.mbr_cm.nbytes + sched.parent.nbytes
    bytes_c = qsched.streamed_bytes
    return [
        (t_f, {"impl": "float32-tiles", "q/s": round(n_q / t_f),
               "bytes/query": round(bytes_f / n_q),
               "accesses": int(np.asarray(visits_f).sum())}),
        (t_c, {"impl": "compact-uint16-tiles", "q/s": round(n_q / t_c),
               "bytes/query": round(bytes_c / n_q),
               "bytes_ratio": round(bytes_c / bytes_f, 3),
               "accesses": int(np.asarray(visits_c).sum())}),
    ]


def bench_mbr_scan_kernel():
    n = 512 if TINY else 8192
    lo = jnp.asarray(np.random.default_rng(0).uniform(0, 1000, (n, 2)), jnp.float32)
    mbrs = jnp.concatenate([lo, lo + 10.0], axis=1)
    qs = jnp.asarray(datasets.region_queries(np.asarray(mbrs), 8, seed=1), jnp.float32)
    t_k = _timeit(lambda: ops.mbr_scan(mbrs, qs), iters=3)
    t_r = _timeit(lambda: ops.mbr_scan_ref(mbrs, qs), iters=3)
    return [
        (t_k, {"impl": "pallas-interpret", "n": n}),
        (t_r, {"impl": "jnp-ref", "n": n}),
    ]


def bench_pyramid_scan():
    """The paper's Section 5 disk-access comparison, on-accelerator: fused
    single-launch level sweep vs one-kernel-per-level vs host pointers."""
    n, n_q = (300, 8) if TINY else (2000, 32)
    data = datasets.uniform_squares(n, seed=1)
    tree = mqrtree.build(data)
    sched = flat.level_schedule(flat.flatten(tree))
    qs = datasets.region_queries(data, n_q, seed=2)
    qj = jnp.asarray(qs, jnp.float32)

    t_fused = _timeit(lambda: ops.pyramid_scan(sched, qj), iters=3)
    t_level = _timeit(lambda: ops.per_level_region_search(sched, qj), iters=3)
    t_host = _timeit(
        lambda: [tree.region_search(np.asarray(q)) for q in qs], iters=2
    )
    _, visits = ops.pyramid_scan(sched, qj)
    accesses = int(jnp.sum(visits))
    _, _, launches = ops.per_level_region_search(sched, qj)
    return [
        (t_fused, {"impl": "pyramid-scan-fused", "launches": 1,
                   "q/s": round(n_q / t_fused), "accesses": accesses}),
        (t_level, {"impl": "per-level-mbr-scan", "launches": launches,
                   "q/s": round(n_q / t_level), "accesses": accesses}),
        (t_host, {"impl": "host-pointer", "launches": 0,
                  "q/s": round(n_q / t_host), "accesses": accesses}),
    ]


def bench_index_api():
    """Façade overhead: `SpatialIndex.region` vs calling the fused kernel
    directly must be <5%; plus a first-class knn row (DESIGN.md §6).

    Both sides deliver host-side numpy results (what a caller consumes);
    timing interleaves the two and keeps the per-impl minimum so container
    scheduling jitter does not swamp the microseconds of façade work.
    """
    from repro.index import SpatialIndex

    n, n_q, k = (300, 8, 4) if TINY else (2000, 32, 8)
    data = datasets.uniform_squares(n, seed=1)
    idx = SpatialIndex.build(data, structure="mqr", backend="pallas")
    sched = idx.schedule
    qs = datasets.region_queries(data, n_q, seed=2)

    # Apples-to-apples: both sides take the same numpy queries and deliver
    # host-side numpy results (what a caller consumes).
    def direct():
        hits, visits = ops.pyramid_scan(sched, qs)
        return np.asarray(hits), np.asarray(visits)

    def facade():
        return idx.region(qs).hits

    direct(), facade()  # warm / compile
    # Paired timing: each iteration measures both back-to-back, so the
    # slowly-drifting container noise cancels in the per-pair delta.
    ds, fs = [], []
    for _ in range(80):
        t0 = time.time()
        direct()
        t1 = time.time()
        facade()
        t2 = time.time()
        ds.append(t1 - t0)
        fs.append(t2 - t1)
    t_direct = float(np.median(ds))
    t_facade = float(np.median(fs))
    overhead = float(np.median(np.array(fs) - np.array(ds))) / t_direct

    pts = np.random.default_rng(3).uniform(100, 900, (n_q, 2))
    idx.knn(pts, k)  # warm the expanding-radius round shapes
    before = (idx.stats.node_accesses, idx.stats.knn_queries)
    t_knn = _timeit(lambda: idx.knn(pts, k).ids, iters=3)
    accesses = (idx.stats.node_accesses - before[0]) / (
        idx.stats.knn_queries - before[1]
    )
    # Facade build throughput: `SpatialIndex.build(structure="pyramid",
    # build="device")` objects/sec across the crossover sizes, one row.
    build_ns = (200, 400) if TINY else (1_000, 10_000, 100_000)
    build_objs, t_build = {}, 0.0
    for bn in build_ns:
        bdata = datasets.uniform_squares(bn, seed=4)
        t_build = _timeit(
            lambda d=bdata: SpatialIndex.build(
                d, structure="pyramid", backend="pallas", build="device"
            ),
            iters=1,
        )
        build_objs[str(bn)] = round(bn / t_build)

    # precision="compact": identical hits through the facade, half the
    # streamed tile bytes (see kernel_compact_scan for the byte ledger).
    cidx = idx.with_backend("pallas", precision="compact")
    res_c = cidx.region(qs)
    assert np.array_equal(res_c.hits, idx.region(qs).hits)
    t_compact = _timeit(lambda: cidx.region(qs).hits, iters=3)

    return [
        (t_direct, {"impl": "pyramid-scan-direct", "q/s": round(n_q / t_direct)}),
        (t_facade, {"impl": "spatial-index-facade", "q/s": round(n_q / t_facade),
                    "overhead": f"{overhead:+.1%}"}),
        (t_compact, {"impl": "spatial-index-compact",
                     "q/s": round(n_q / t_compact)}),
        (t_build, {"impl": "spatial-index-build-device",
                   "objects_per_sec": build_objs, "n_max": build_ns[-1]}),
        (t_knn, {"impl": "spatial-index-knn", "k": k,
                 "q/s": round(n_q / t_knn),
                 "accesses/query": round(accesses, 1)}),
    ]


def bench_live_update():
    """Live-update subsystem (DESIGN.md §8): mutation throughput and the
    query rent of an un-merged delta buffer.

    Rows: inserts/sec and deletes/sec into the device-resident buffer,
    region q/s at ~10% and ~50% buffer fill (the flat delta levels ride
    the same fused launch), and q/s after the merge compacts everything
    back into a clean base build (flush wall-time reported alongside).
    """
    from repro.index import SpatialIndex

    n, capacity, n_q = (200, 64, 8) if TINY else (4000, 1024, 32)
    data = datasets.uniform_squares(n, seed=1)
    idx = SpatialIndex.build(
        data, structure="pyramid", backend="pallas",
        merge=dict(capacity=capacity, max_fill=1.0, max_tombstone_ratio=1.0),
    )
    qs = datasets.region_queries(data, n_q, seed=2)
    rng = np.random.default_rng(3)
    rows = []

    b = max(capacity // 10, 1)
    ins1 = datasets.uniform_squares(b, seed=4)
    t0 = time.time()
    idx.insert(ins1)
    t_ins = time.time() - t0
    rows.append((t_ins, {"impl": "live-insert", "batch": b,
                         "inserts_per_sec": round(b / t_ins)}))

    t10 = _timeit(lambda: idx.region(qs).hits, iters=3)
    rows.append((t10, {"impl": "live-query-10pct-fill",
                       "q/s": round(n_q / t10),
                       "fill": round(idx._updates.fill, 2)}))

    victims = rng.choice(
        np.nonzero(idx._updates.alive)[0], size=b, replace=False
    )
    t0 = time.time()
    idx.delete(victims)
    t_del = time.time() - t0
    rows.append((t_del, {"impl": "live-delete", "batch": b,
                         "deletes_per_sec": round(b / t_del)}))

    idx.insert(datasets.uniform_squares(int(capacity * 0.4), seed=5))
    t50 = _timeit(lambda: idx.region(qs).hits, iters=3)
    rows.append((t50, {"impl": "live-query-50pct-fill",
                       "q/s": round(n_q / t50),
                       "fill": round(idx._updates.fill, 2)}))

    t0 = time.time()
    idx.flush()
    t_flush = time.time() - t0
    tpf = _timeit(lambda: idx.region(qs).hits, iters=3)
    rows.append((tpf, {"impl": "live-query-post-flush",
                       "q/s": round(n_q / tpf),
                       "flush_ms": round(t_flush * 1e3, 1),
                       "n_live": idx.n_objects}))
    return rows


def bench_durability():
    """Durability subsystem (DESIGN.md §9): what fault tolerance costs.

    Rows: snapshot save/load throughput (MB/s over the npz payload), the
    WAL tax per mutation with and without fsync, and recovery wall-time
    (snapshot load + WAL tail replay) as the tail grows.
    """
    import shutil
    import tempfile

    from repro.checkpoint import DurableIndex, load_index, save_index
    from repro.index import SpatialIndex

    n, n_mut, tail = (300, 20, (5, 20)) if TINY else (8000, 200, (50, 400))
    data = datasets.uniform_squares(n, seed=7)
    idx = SpatialIndex.build(data, backend="pallas", capacity=max(n_mut, 64))
    idx.insert(datasets.uniform_squares(n_mut // 2, seed=8))
    root = pathlib.Path(tempfile.mkdtemp(prefix="repro-bench-durable-"))
    rows = []
    try:
        t_save = _timeit(lambda: save_index(idx, root / "snap"),
                         iters=3, warm=False)
        nbytes = (root / "snap" / "arrays.npz").stat().st_size
        rows.append((t_save, {"impl": "snapshot-save", "n": idx.n_objects,
                              "MB/s": round(nbytes / t_save / 2**20, 1)}))
        t_load = _timeit(lambda: load_index(root / "snap", backend="pallas"),
                         iters=3, warm=False)
        rows.append((t_load, {"impl": "snapshot-load", "n": idx.n_objects,
                              "MB/s": round(nbytes / t_load / 2**20, 1)}))

        for sync in (False, True):
            d = DurableIndex.create(
                data, root / f"wal-{sync}", backend="pallas", sync=sync,
                capacity=max(n_mut * 4, 64),
            )
            batches = [datasets.uniform_squares(1, seed=100 + i)
                       for i in range(n_mut)]
            t0 = time.time()
            for b in batches:
                d.insert(b)
            t_mut = (time.time() - t0) / n_mut
            d.close()
            rows.append((t_mut, {
                "impl": f"wal-insert-{'fsync' if sync else 'nosync'}",
                "mutations": n_mut, "us_per_op": round(t_mut * 1e6, 1),
            }))

        for n_tail in tail:
            troot = root / f"tail-{n_tail}"
            d = DurableIndex.create(
                data, troot, backend="pallas", sync=False,
                capacity=max(n_tail * 2, 64),
            )
            for i in range(n_tail):
                d.insert(datasets.uniform_squares(1, seed=200 + i))
            d.close()
            t_rec = _timeit(
                lambda r=troot: DurableIndex.recover(
                    r, backend="pallas", sync=False
                ).close(),
                iters=2, warm=False,
            )
            rows.append((t_rec, {"impl": "recover", "wal_ops": n_tail,
                                 "ms": round(t_rec * 1e3, 1)}))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows


def bench_mqr_sparse_vs_dense_decode():
    """The paper's payoff on the KV cache: pruned vs full decode attention."""
    key = jax.random.PRNGKey(0)
    s, d, bs, k = (2048, 64, 128, 4) if TINY else (16384, 64, 128, 16)
    nb = s // bs
    keys = jax.random.normal(key, (s, d))
    vals = jax.random.normal(jax.random.fold_in(key, 1), (s, d))
    probe = jax.random.normal(jax.random.fold_in(key, 2), (d,))
    q = jax.random.normal(jax.random.fold_in(key, 3), (d,))

    @jax.jit
    def dense(q, keys, vals):
        logits = keys @ q / jnp.sqrt(d)
        return jax.nn.softmax(logits) @ vals

    @jax.jit
    def sparse(q, keys, vals):
        idx = kvindex.build_kv_index(keys, probe, bs, 6)
        ids = kvindex.select_blocks(idx, kvindex.query_region(q, probe, s), k)
        kb = keys.reshape(nb, bs, d)[ids].reshape(-1, d)
        vb = vals.reshape(nb, bs, d)[ids].reshape(-1, d)
        logits = kb @ q / jnp.sqrt(d)
        return jax.nn.softmax(logits) @ vb

    t_d = _timeit(dense, q, keys, vals)
    t_s = _timeit(sparse, q, keys, vals)
    return [
        (t_d, {"impl": "dense-decode", "kv": s}),
        (t_s, {"impl": "mqr-sparse-decode", "kv": s, "blocks": f"{k}/{nb}"}),
    ]


def bench_join():
    """Tree-vs-tree spatial join (DESIGN.md §10) vs the nested-loop oracle.

    Rows: joins/sec for the levelized pair sweep on float32 and compact
    tiles (candidate pair counts alongside — the pruning is the point)
    against the brute-force O(n·m) host oracle on the same data.
    """
    from repro.index import SpatialIndex

    na, nb = (300, 200) if TINY else (2000, 1500)
    da = datasets.uniform_squares(na, seed=1)
    db = datasets.exponential_squares(nb, seed=2)
    left = SpatialIndex.build(da, structure="mqr", backend="pallas")
    right = SpatialIndex.build(db, structure="mqr", backend="pallas")
    compact = SpatialIndex.build(
        da, structure="mqr", backend="pallas", precision="compact"
    )

    res = left.join(right)
    a32, b32 = np.asarray(da, np.float32), np.asarray(db, np.float32)

    def brute():
        return (
            (a32[:, None, 0] <= b32[None, :, 2])
            & (b32[None, :, 0] <= a32[:, None, 2])
            & (a32[:, None, 1] <= b32[None, :, 3])
            & (b32[None, :, 1] <= a32[:, None, 3])
        )

    t_j = _timeit(lambda: left.join(right).pairs, iters=3)
    t_c = _timeit(lambda: compact.join(right).pairs, iters=3)
    t_b = _timeit(brute, iters=3)
    return [
        (t_j, {"impl": "join-pair-sweep", "n": f"{na}x{nb}",
               "joins_per_sec": round(1 / t_j, 2),
               "pairs": res.n_pairs,
               "pair_tests": int(res.pair_visits.sum())}),
        (t_c, {"impl": "join-pair-sweep-compact", "n": f"{na}x{nb}",
               "joins_per_sec": round(1 / t_c, 2)}),
        (t_b, {"impl": "join-brute-oracle", "n": f"{na}x{nb}",
               "joins_per_sec": round(1 / t_b, 2),
               "pair_tests": na * nb}),
    ]


def bench_moving():
    """Moving-object workload: delta-buffer churn vs naive rebuilds.

    Rows: ticks/sec for the live-update path (batch delete + insert per
    tick, continuous region + join queries) against the rebuild-per-tick
    baseline on the identical seeded motion.
    """
    from repro.launch.moving import MovingConfig, MovingWorkload

    ticks = 5 if TINY else 50
    cfg = MovingConfig(n_objects=64 if TINY else 256, moves_per_tick=8,
                       query_every=5, seed=1)
    live = MovingWorkload(cfg, backend="pallas", capacity=128)
    t0 = time.time()
    live.run(ticks)
    t_live = time.time() - t0

    base = MovingWorkload(cfg, backend="pallas", rebuild_per_tick=True)
    t0 = time.time()
    base.run(ticks)
    t_base = time.time() - t0
    return [
        (t_live, {"impl": "moving-delta-buffer", "ticks": ticks,
                  "ticks_per_sec": round(ticks / t_live, 2),
                  "merges": live.query_index.stats.flushes,
                  "joins": live.query_index.stats.joins}),
        (t_base, {"impl": "moving-rebuild-per-tick", "ticks": ticks,
                  "ticks_per_sec": round(ticks / t_base, 2),
                  "speedup_vs_rebuild": round(t_base / t_live, 2)}),
    ]


def bench_serving():
    """Latency under open-loop load through the serving front end.

    One row per offered-QPS level: p50/p99/p99.9 completion latency of
    single-request arrivals coalesced into ``query_block`` batches, plus
    shed / SLO-violation counters — the latency-vs-load curve the
    front end exists for (DESIGN.md §11).  Arrivals are Poisson and
    latency is measured from the SCHEDULED arrival, so the curve is
    free of coordinated omission.
    """
    from repro.launch.loadgen import demo_dataset
    from repro.serve import ServerConfig, ServingFrontEnd
    from repro.serve.loadgen import run_sweep

    levels = [25.0, 100.0, 400.0] if TINY else [50.0, 200.0, 800.0]
    duration = 0.4 if TINY else 2.0
    data = {"demo": demo_dataset(256 if TINY else 4096)}
    cfg = ServerConfig.from_dict({
        "tenants": [{"name": "demo", "backend": "serve"}],
        "query_block": 8 if TINY else 16,
    })

    def make_front():
        return ServingFrontEnd.build(cfg, data), "demo"

    rows = run_sweep(make_front, levels, duration=duration, seed=0)
    return [
        (row["mean_ms"] / 1e3,
         {"impl": "serve-frontend",
          "qps_offered": round(row["qps_offered"], 1),
          "qps_achieved": round(row["qps_achieved"], 1),
          "p50_ms": round(row["p50_ms"], 3),
          "p99_ms": round(row["p99_ms"], 3),
          "p999_ms": round(row["p999_ms"], 3),
          "shed": row["shed"],
          "slo_violations": row["slo_violations"],
          "avg_batch": row["avg_batch"],
          "deadline_launches": row["deadline_launches"]})
        for row in rows
    ]


JAX_BENCHES = {
    "jax_flat_search": bench_flat_search,
    "jax_pyramid_build": bench_pyramid_build,
    "kernel_mbr_scan": bench_mbr_scan_kernel,
    "kernel_pyramid_scan": bench_pyramid_scan,
    "kernel_compact_scan": bench_compact_scan,
    "index_api": bench_index_api,
    "live_update": bench_live_update,
    "durability": bench_durability,
    "join": bench_join,
    "moving": bench_moving,
    "serving": bench_serving,
    "mqr_sparse_vs_dense_decode": bench_mqr_sparse_vs_dense_decode,
}
