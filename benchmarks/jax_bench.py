"""TPU-adaptation benchmarks: vectorized search, kernels, mqr-KV serving."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bulk, datasets, flat, kvindex, mqrtree
from repro.kernels import ops


def _timeit(fn, *args, iters=5):
    fn(*args)  # warm / compile
    t0 = time.time()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) / iters


def bench_flat_search():
    data = datasets.uniform_squares(2000, seed=1)
    tree = mqrtree.build(data)
    ft = flat.flatten(tree)
    qs = jnp.asarray(datasets.region_queries(data, 32, seed=2), jnp.float32)
    t_host = _timeit(
        lambda: [tree.region_search(np.asarray(q)) for q in qs], iters=2
    )
    t_jax = _timeit(lambda: flat.region_search_batch(ft, qs), iters=2)
    return [
        (t_host / 32, {"impl": "host-pointer", "queries": 32}),
        (t_jax / 32, {"impl": "jax-levelized", "queries": 32}),
    ]


def bench_pyramid_build():
    pts = jnp.asarray(datasets.uniform_points(4096, seed=3), jnp.float32)
    f = jax.jit(lambda m: bulk.build_pyramid(m, levels=7).group_mbr)
    return [(_timeit(f, pts), {"n": 4096, "levels": 7})]


def bench_mbr_scan_kernel():
    lo = jnp.asarray(np.random.default_rng(0).uniform(0, 1000, (8192, 2)), jnp.float32)
    mbrs = jnp.concatenate([lo, lo + 10.0], axis=1)
    qs = jnp.asarray(datasets.region_queries(np.asarray(mbrs), 8, seed=1), jnp.float32)
    t_k = _timeit(lambda: ops.mbr_scan(mbrs, qs), iters=3)
    t_r = _timeit(lambda: ops.mbr_scan_ref(mbrs, qs), iters=3)
    return [
        (t_k, {"impl": "pallas-interpret", "n": 8192}),
        (t_r, {"impl": "jnp-ref", "n": 8192}),
    ]


def bench_pyramid_scan():
    """The paper's Section 5 disk-access comparison, on-accelerator: fused
    single-launch level sweep vs one-kernel-per-level vs host pointers."""
    n, n_q = 2000, 32
    data = datasets.uniform_squares(n, seed=1)
    tree = mqrtree.build(data)
    sched = flat.level_schedule(flat.flatten(tree))
    qs = datasets.region_queries(data, n_q, seed=2)
    qj = jnp.asarray(qs, jnp.float32)

    t_fused = _timeit(lambda: ops.pyramid_scan(sched, qj), iters=3)
    t_level = _timeit(lambda: ops.per_level_region_search(sched, qj), iters=3)
    t_host = _timeit(
        lambda: [tree.region_search(np.asarray(q)) for q in qs], iters=2
    )
    _, visits = ops.pyramid_scan(sched, qj)
    accesses = int(jnp.sum(visits))
    _, _, launches = ops.per_level_region_search(sched, qj)
    return [
        (t_fused, {"impl": "pyramid-scan-fused", "launches": 1,
                   "q/s": round(n_q / t_fused), "accesses": accesses}),
        (t_level, {"impl": "per-level-mbr-scan", "launches": launches,
                   "q/s": round(n_q / t_level), "accesses": accesses}),
        (t_host, {"impl": "host-pointer", "launches": 0,
                  "q/s": round(n_q / t_host), "accesses": accesses}),
    ]


def bench_index_api():
    """Façade overhead: `SpatialIndex.region` vs calling the fused kernel
    directly must be <5%; plus a first-class knn row (DESIGN.md §6).

    Both sides deliver host-side numpy results (what a caller consumes);
    timing interleaves the two and keeps the per-impl minimum so container
    scheduling jitter does not swamp the microseconds of façade work.
    """
    from repro.index import SpatialIndex

    n, n_q, k = 2000, 32, 8
    data = datasets.uniform_squares(n, seed=1)
    idx = SpatialIndex.build(data, structure="mqr", backend="pallas")
    sched = idx.schedule
    qs = datasets.region_queries(data, n_q, seed=2)

    # Apples-to-apples: both sides take the same numpy queries and deliver
    # host-side numpy results (what a caller consumes).
    def direct():
        hits, visits = ops.pyramid_scan(sched, qs)
        return np.asarray(hits), np.asarray(visits)

    def facade():
        return idx.region(qs).hits

    direct(), facade()  # warm / compile
    # Paired timing: each iteration measures both back-to-back, so the
    # slowly-drifting container noise cancels in the per-pair delta.
    ds, fs = [], []
    for _ in range(80):
        t0 = time.time()
        direct()
        t1 = time.time()
        facade()
        t2 = time.time()
        ds.append(t1 - t0)
        fs.append(t2 - t1)
    t_direct = float(np.median(ds))
    t_facade = float(np.median(fs))
    overhead = float(np.median(np.array(fs) - np.array(ds))) / t_direct

    pts = np.random.default_rng(3).uniform(100, 900, (n_q, 2))
    idx.knn(pts, k)  # warm the expanding-radius round shapes
    before = (idx.stats.node_accesses, idx.stats.knn_queries)
    t_knn = _timeit(lambda: idx.knn(pts, k).ids, iters=3)
    accesses = (idx.stats.node_accesses - before[0]) / (
        idx.stats.knn_queries - before[1]
    )
    return [
        (t_direct, {"impl": "pyramid-scan-direct", "q/s": round(n_q / t_direct)}),
        (t_facade, {"impl": "spatial-index-facade", "q/s": round(n_q / t_facade),
                    "overhead": f"{overhead:+.1%}"}),
        (t_knn, {"impl": "spatial-index-knn", "k": k,
                 "q/s": round(n_q / t_knn),
                 "accesses/query": round(accesses, 1)}),
    ]


def bench_mqr_sparse_vs_dense_decode():
    """The paper's payoff on the KV cache: pruned vs full decode attention."""
    key = jax.random.PRNGKey(0)
    s, d, bs, k = 16384, 64, 128, 16
    nb = s // bs
    keys = jax.random.normal(key, (s, d))
    vals = jax.random.normal(jax.random.fold_in(key, 1), (s, d))
    probe = jax.random.normal(jax.random.fold_in(key, 2), (d,))
    q = jax.random.normal(jax.random.fold_in(key, 3), (d,))

    @jax.jit
    def dense(q, keys, vals):
        logits = keys @ q / jnp.sqrt(d)
        return jax.nn.softmax(logits) @ vals

    @jax.jit
    def sparse(q, keys, vals):
        idx = kvindex.build_kv_index(keys, probe, bs, 6)
        ids = kvindex.select_blocks(idx, kvindex.query_region(q, probe, s), k)
        kb = keys.reshape(nb, bs, d)[ids].reshape(-1, d)
        vb = vals.reshape(nb, bs, d)[ids].reshape(-1, d)
        logits = kb @ q / jnp.sqrt(d)
        return jax.nn.softmax(logits) @ vb

    t_d = _timeit(dense, q, keys, vals)
    t_s = _timeit(sparse, q, keys, vals)
    return [
        (t_d, {"impl": "dense-decode", "kv": s}),
        (t_s, {"impl": "mqr-sparse-decode", "kv": s, "blocks": f"{k}/{nb}"}),
    ]


JAX_BENCHES = {
    "jax_flat_search": bench_flat_search,
    "jax_pyramid_build": bench_pyramid_build,
    "kernel_mbr_scan": bench_mbr_scan_kernel,
    "kernel_pyramid_scan": bench_pyramid_scan,
    "index_api": bench_index_api,
    "mqr_sparse_vs_dense_decode": bench_mqr_sparse_vs_dense_decode,
}
