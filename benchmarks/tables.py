"""Paper-table benchmarks (Tables 1-8: insertion quality; 9-12: search).

Scaled for single-CPU runtime: default object counts and tree counts are
reduced; set REPRO_FULL=1 for counts closer to the paper's.
Each function returns (name, seconds_per_build_or_query, derived_dict).
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import datasets, metrics, mqrtree, rtree

FULL = os.environ.get("REPRO_FULL", "0") == "1"
TINY = os.environ.get("REPRO_BENCH_TINY", "0") == "1"
SIZES = (120,) if TINY else (500, 1000, 5000) if FULL else (500, 1000)
N_TREES = 1 if TINY else 5 if FULL else 2


def _build_compare(gen, sizes=SIZES, n_trees=N_TREES, seed0=0):
    rows = []
    for n in sizes:
        for index, builder in (("mqr-tree", mqrtree.build), ("r-tree", rtree.build)):
            ms, t_build = [], 0.0
            for k in range(n_trees):
                data = gen(n, seed=seed0 + 17 * k)
                order = np.random.default_rng(k).permutation(n)
                t0 = time.time()
                t = builder(data[order])
                t_build += time.time() - t0
                ms.append(metrics.compute_metrics(t))
            agg = {
                "n": n, "index": index,
                "nodes": np.mean([m.n_nodes for m in ms]),
                "height": np.mean([m.height for m in ms]),
                "avg_path": np.mean([m.avg_path for m in ms]),
                "coverage": np.mean([m.coverage for m in ms]),
                "overcoverage": np.mean([m.overcoverage for m in ms]),
                "overlap": np.mean([m.overlap for m in ms]),
                "util": np.mean([m.space_utilization for m in ms]),
            }
            rows.append((t_build / n_trees, agg))
    return rows


def _search_compare(gen, query_fn, sizes=SIZES, seed0=0):
    rows = []
    for n in sizes:
        data = gen(n, seed=seed0)
        qs = query_fn(data)
        for index, builder in (("mqr-tree", mqrtree.build), ("r-tree", rtree.build)):
            t = builder(data)
            t0 = time.time()
            found, visits = 0, 0
            for q in qs:
                f, v = t.region_search(q)
                found += len(f)
                visits += v
            rows.append(
                (
                    (time.time() - t0) / len(qs),
                    {
                        "n": n, "index": index,
                        "found": found / len(qs),
                        "diskhits": visits / len(qs),
                    },
                )
            )
    return rows


TABLES = {
    "table1_uniform_objects": lambda: _build_compare(datasets.uniform_squares),
    "table2_uniform_points": lambda: _build_compare(datasets.uniform_points),
    "table3_exponential_objects": lambda: _build_compare(datasets.exponential_squares),
    "table4_exponential_points": lambda: _build_compare(datasets.exponential_points),
    "table5_roadlike_lines": lambda: _build_compare(
        datasets.roadlike_lines, sizes=SIZES if TINY else (2000, 5000) if FULL else (2000,)
    ),
    "table6_hv_lines": lambda: _build_compare(datasets.hv_lines),
    "table7_sloped_lines": lambda: _build_compare(datasets.sloped_lines),
    "table8_mixed_lines": lambda: _build_compare(datasets.mixed_lines),
    "table9_search_uniform_objects": lambda: _search_compare(
        datasets.uniform_squares,
        lambda d: datasets.region_queries(d, 20, seed=3),
        sizes=SIZES if TINY else (2000,) if not FULL else (2000, 5000),
    ),
    "table10_search_uniform_points": lambda: _search_compare(
        datasets.uniform_points,
        lambda d: datasets.region_queries(d, 20, seed=4, target_found=1.0),
        sizes=SIZES if TINY else (2000,) if not FULL else (2000, 5000),
    ),
    "table11_search_exponential_objects": lambda: _search_compare(
        datasets.exponential_squares,
        lambda d: datasets.dense_region_queries(20, seed=5),
    ),
    "table12_search_exponential_points": lambda: _search_compare(
        datasets.exponential_points,
        lambda d: datasets.dense_region_queries(20, seed=6),
    ),
}
