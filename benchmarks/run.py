"""Benchmark harness: one entry per paper table + TPU-adaptation benches.

Prints ``name,us_per_call,derived`` CSV (one row per measurement).
"""

from __future__ import annotations

import json
import sys


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks.tables import TABLES
    from benchmarks.jax_bench import JAX_BENCHES

    print("name,us_per_call,derived")
    for name, fn in {**TABLES, **JAX_BENCHES}.items():
        try:
            for seconds, derived in fn():
                print(f"{name},{seconds * 1e6:.1f},{json.dumps(derived, default=float)!r}")
        except Exception as e:  # noqa: BLE001
            print(f"{name},-1,'ERROR: {e!r}'")


if __name__ == "__main__":
    main()
