"""Benchmark harness: one entry per paper table + TPU-adaptation benches.

Prints ``name,us_per_call,derived`` CSV (one row per measurement) and
persists the same rows to ``BENCH_<UTC-date>.json`` next to the working
directory, so the perf trajectory is recorded run over run (build
throughput, bytes/query, q/s — see benchmarks/jax_bench.py).

Set ``REPRO_BENCH_TINY=1`` to run every bench at smoke sizes (used by the
CI bench-smoke job to keep the JSON plumbing honest).
"""

from __future__ import annotations

import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    # Anchor on the repo root so the harness runs the same from any CWD
    # (`python benchmarks/run.py`, `python -m benchmarks.run`, CI).
    for p in (_ROOT, os.path.join(_ROOT, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)
    from benchmarks.tables import TABLES
    from benchmarks.jax_bench import JAX_BENCHES

    rows = []
    print("name,us_per_call,derived")
    for name, fn in {**TABLES, **JAX_BENCHES}.items():
        try:
            for seconds, derived in fn():
                print(f"{name},{seconds * 1e6:.1f},{json.dumps(derived, default=float)!r}")
                rows.append(
                    {"name": name, "us_per_call": seconds * 1e6,
                     "derived": derived}
                )
        except Exception as e:  # noqa: BLE001
            print(f"{name},-1,'ERROR: {e!r}'")
            rows.append(
                {"name": name, "us_per_call": -1,
                 "derived": {"error": repr(e)}}
            )

    date = time.strftime("%Y-%m-%d", time.gmtime())
    # always lands at the repo root, wherever the harness was invoked from
    path = os.path.join(_ROOT, f"BENCH_{date}.json")
    with open(path, "w") as f:
        json.dump({"date": date, "rows": rows}, f, indent=1, default=float)
        f.write("\n")
    print(f"# wrote {path} ({len(rows)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main()
