"""R-tree baseline: structural invariants + search correctness."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import datasets, rtree
from repro.core import mbr as M


@given(st.integers(0, 300), st.integers(5, 80))
@settings(max_examples=20, deadline=None)
def test_rtree_valid_and_complete(seed, n):
    rng = np.random.default_rng(seed)
    ll = rng.uniform(0, 100, (n, 2))
    mbrs = np.concatenate([ll, ll + rng.uniform(0.1, 10, (n, 2))], axis=1)
    t = rtree.build(mbrs)
    t.validate()
    # every object findable
    for i in range(0, n, 7):
        found, _ = t.region_search(mbrs[i])
        assert i in found


def test_search_matches_bruteforce():
    data = datasets.uniform_squares(500, seed=1)
    t = rtree.build(data)
    qs = datasets.region_queries(data, 20, seed=2)
    for q in qs:
        found, visits = t.region_search(q)
        brute = set(np.nonzero(M.overlaps(data, q))[0])
        assert set(found) == brute
        assert visits >= 1
