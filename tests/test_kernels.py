"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops


@pytest.mark.parametrize("n", [17, 256, 1000])
@pytest.mark.parametrize("q", [1, 5])
def test_mbr_scan_sweep(n, q):
    key = jax.random.PRNGKey(n * 31 + q)
    lo = jax.random.uniform(key, (n, 2)) * 100
    mbrs = jnp.concatenate([lo, lo + jax.random.uniform(key, (n, 2)) * 10], axis=1)
    qs = jnp.concatenate(
        [jax.random.uniform(jax.random.fold_in(key, 1), (q, 2)) * 100] * 2, axis=1
    ) + jnp.array([0.0, 0.0, 20.0, 20.0])
    got = ops.mbr_scan(mbrs, qs)
    want = ops.mbr_scan_ref(mbrs, qs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,s,d", [(2, 128, 64), (4, 256, 128), (1, 384, 128)])
def test_flash_attention_sweep(dtype, bh, s, d):
    key = jax.random.PRNGKey(bh * s + d)
    q = jax.random.normal(key, (bh, s, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (bh, s, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, s, d), dtype)
    got = ops.flash_attention(q, k, v, block_q=128, block_k=128)
    want = ops.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,nb,bs,d,k", [(2, 8, 128, 64, 3), (4, 16, 128, 128, 8)])
def test_mqr_sparse_attention_sweep(dtype, bh, nb, bs, d, k):
    key = jax.random.PRNGKey(nb * bs + d)
    kb = jax.random.normal(key, (bh, nb, bs, d), dtype)
    vb = jax.random.normal(jax.random.fold_in(key, 1), (bh, nb, bs, d), dtype)
    q = jax.random.normal(jax.random.fold_in(key, 2), (bh, d), dtype)
    ids = jnp.stack(
        [
            jax.random.permutation(jax.random.fold_in(key, 3 + i), nb)[:k]
            for i in range(bh)
        ]
    ).astype(jnp.int32)
    pos = jnp.asarray(nb * bs // 2, jnp.int32)
    got = ops.mqr_sparse_attention(q, kb, vb, ids, pos)
    want = ops.mqr_sparse_attention_ref(q, kb, vb, ids, pos)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("r,d", [(64, 128), (300, 256), (1, 512)])
def test_rmsnorm_sweep(dtype, r, d):
    key = jax.random.PRNGKey(r + d)
    x = jax.random.normal(key, (r, d), dtype)
    s = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32)
    got = ops.rmsnorm(x, s)
    want = ops.rmsnorm_ref(x, s)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_matches_model_attention_path():
    """The Pallas kernel and the model's portable flash path agree."""
    from repro.models.attention import flash_attention_jnp

    key = jax.random.PRNGKey(0)
    b, s, h, dh = 2, 256, 4, 64
    q = jax.random.normal(key, (b, s, h, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    want = flash_attention_jnp(q, k, v, positions, positions, chunk=128)
    got = ops.flash_attention(
        jnp.moveaxis(q, 2, 1).reshape(b * h, s, dh),
        jnp.moveaxis(k, 2, 1).reshape(b * h, s, dh),
        jnp.moveaxis(v, 2, 1).reshape(b * h, s, dh),
    ).reshape(b, h, s, dh)
    got = jnp.moveaxis(got, 1, 2)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3
    )
