"""Graceful kernel degradation (DESIGN.md §9): the pallas → lax → host
health ladder changes latency, never answers.

Every rung runs the same sweep semantics — the fused Pallas kernel, its
plain-XLA twin, and its numpy twin — so with ALL Pallas launches forced
to fail the server still returns bit-identical hit sets via the lax (or
host) rung while reporting the degradation in its stats.
"""

import warnings

import numpy as np
import pytest

from repro.core import datasets, flat, mqrtree
from repro.ft import FaultPlan
from repro.index import SpatialIndex
from repro.launch.spatial_serve import LADDER, SpatialServer
from repro.update import oracle


def _server(plan=None, **kw):
    data = datasets.uniform_squares(240, seed=21)
    sched = flat.level_schedule(flat.flatten(mqrtree.build(data)))
    kw.setdefault("query_block", 4)
    kw.setdefault("cache_size", 0)
    kw.setdefault("backoff", 0.0)
    server = SpatialServer(sched, fault_plan=plan, **kw)
    queries = datasets.region_queries(data, 10, seed=22)
    return server, queries


class TestLadder:
    def test_healthy_server_stays_on_pallas(self):
        server, queries = _server()
        server.search(queries)
        h = server.drain_health()
        assert h["rung"] == "pallas"
        assert h["rung_dispatches"]["pallas"] > 0
        assert h["degraded_batches"] == 0 and h["retries"] == 0

    def test_retry_recovers_without_degrading(self):
        # one failure, then the retry on the SAME rung succeeds
        plan = FaultPlan(fail_launches=1, fail_rungs=("pallas",))
        server, queries = _server(plan)
        ref_hits, _ = _server()[0].search(queries)
        hits, _ = server.search(queries)
        assert np.array_equal(hits, ref_hits)
        h = server.drain_health()
        assert h["retries"] == 1 and h["degraded_batches"] == 0
        assert server.current_rung == "pallas"

    def test_all_pallas_failures_fall_to_lax_with_parity(self):
        healthy, queries = _server()
        ref_hits, ref_visits = healthy.search(queries)
        plan = FaultPlan(fail_launches=10**9, fail_rungs=("pallas",))
        server, _ = _server(plan)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            hits, visits = server.search(queries)
        assert np.array_equal(hits, ref_hits)
        assert np.array_equal(visits, ref_visits)
        h = server.drain_health()
        assert h["rung"] == "lax"
        assert h["degraded_batches"] > 0
        assert h["rung_failures"]["pallas"] > 0
        assert h["rung_dispatches"]["lax"] > 0
        assert h["rung_dispatches"]["pallas"] == 0

    def test_pallas_and_lax_failures_fall_to_host(self):
        healthy, queries = _server()
        ref_hits, ref_visits = healthy.search(queries)
        plan = FaultPlan(fail_launches=10**9, fail_rungs=("pallas", "lax"))
        server, _ = _server(plan)
        before = server.stats.kernel_launches
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            hits, visits = server.search(queries)
        assert np.array_equal(hits, ref_hits)
        assert np.array_equal(visits, ref_visits)
        assert server.current_rung == "host"
        assert server.stats.kernel_launches == before  # host launches nothing
        h = server.drain_health()
        assert h["rung_dispatches"]["host"] > 0

    def test_floor_is_sticky_then_resettable(self):
        plan = FaultPlan(fail_launches=3, fail_rungs=("pallas",))
        server, queries = _server(plan)  # max_retries=2 → 3 tries burn all
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            server.search(queries)
        assert server.current_rung == "lax"
        server.search(queries[:2])  # sticky: pallas is not re-probed
        assert plan.launch_failures == 3
        assert server.current_rung == "lax"
        server.reset_health()
        assert server.current_rung == "pallas"
        server.search(queries[:2])  # healthy again (countdown exhausted)
        assert server.drain_health()["rung"] == "pallas"

    def test_degradation_warns(self):
        plan = FaultPlan(fail_launches=10**9, fail_rungs=("pallas",))
        server, queries = _server(plan)
        with pytest.warns(RuntimeWarning, match="degrading"):
            server.search(queries)

    def test_exhausted_ladder_raises(self):
        plan = FaultPlan(fail_launches=10**9, fail_rungs=("pallas",))
        server, queries = _server(plan, ladder=("pallas",))
        with pytest.raises(RuntimeError, match="every ladder rung"):
            server.search(queries)

    def test_compact_precision_ladder_parity(self):
        healthy, queries = _server(precision="compact")
        ref_hits, _ = healthy.search(queries)
        plan = FaultPlan(fail_launches=10**9, fail_rungs=("pallas", "lax"))
        server, _ = _server(plan, precision="compact")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            hits, _ = server.search(queries)
        assert np.array_equal(hits, ref_hits)
        assert server.current_rung == "host"

    def test_bad_ladder_rejected(self):
        with pytest.raises(ValueError, match="ladder"):
            _server(ladder=("pallas", "gpu"))
        with pytest.raises(ValueError, match="ladder"):
            _server(ladder=())


class TestFacadeDegradation:
    """The acceptance path: a serve-backend SpatialIndex keeps answering
    correctly under total Pallas failure, and AccessStats says so."""

    def _pair(self, plan, *, mutate=False):
        data = datasets.uniform_squares(200, seed=31)
        queries = datasets.region_queries(data, 8, seed=32)
        kw = dict(query_block=4, cache_size=0, backoff=0.0)
        idx = SpatialIndex.build(
            data, backend="serve", fault_plan=plan, capacity=16, **kw
        )
        if mutate:
            idx.insert(datasets.uniform_squares(5, seed=33))
            idx.delete([3, 17, 201])
        return idx, queries

    def test_pristine_serve_degrades_and_reports(self):
        plan = FaultPlan(fail_launches=10**9, fail_rungs=("pallas",))
        idx, queries = self._pair(plan)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            res = idx.region(queries)
        ref = oracle.hits_mask(idx, queries, idx.id_space)
        assert np.array_equal(res.hits, ref)
        stats = idx.stats
        assert stats.degraded and stats.degraded_batches > 0
        assert stats.launch_failures > 0
        assert stats.rung_dispatches.get("lax", 0) > 0
        assert stats.rung_dispatches.get("pallas", 0) == 0

    def test_live_serve_degrades_and_reports(self):
        # the live fused sweep (delta buffer + tombstones) has lax/host
        # twins too: mutate first, then fail every pallas launch
        plan = FaultPlan(fail_launches=10**9, fail_rungs=("pallas", "lax"))
        idx, queries = self._pair(plan, mutate=True)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            res = idx.region(queries)
        twin = idx.with_backend("host")
        assert np.array_equal(res.hits, twin.region(queries).hits)
        ref = oracle.hits_mask(idx, queries, idx.id_space)
        assert np.array_equal(res.hits, ref)
        assert idx.stats.degraded
        assert idx.stats.rung_dispatches.get("host", 0) > 0

    def test_healthy_serve_reports_no_degradation(self):
        idx, queries = self._pair(None)
        idx.region(queries)
        assert not idx.stats.degraded
        assert idx.stats.rung_dispatches.get("pallas", 0) > 0


def test_ladder_constant_order():
    assert LADDER == ("pallas", "lax", "host")
