"""decode_step with caches must reproduce the full forward logits."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import transformer as T

ARCHS = ["llama32_1b", "mamba2_2p7b", "recurrentgemma_9b", "deepseek_v3_671b",
         "granite_moe_1b", "musicgen_large"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = registry.get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 1, 32
    if cfg.frontend == "audio_codebooks":
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, s, cfg.n_codebooks),
                                  0, cfg.vocab_size, jnp.int32)
    else:
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                  cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    x, positions, _ = T.embed_inputs(params, cfg, batch)
    hidden, _ = T.forward_hidden(params, cfg, x, positions)
    full_logits = T.logits_fn(params, cfg, hidden)

    caches = T.init_caches(cfg, b, s)
    step = jax.jit(lambda p, t, c, pos: T.decode_step(p, cfg, t, c, pos))
    outs = []
    for t in range(s):
        tok_t = toks[:, t : t + 1]
        lg, caches = step(params, tok_t, caches, t)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    err = float(
        jnp.max(jnp.abs(dec.astype(jnp.float32) - full_logits.astype(jnp.float32)))
    )
    assert err < 0.25, (arch, err)  # bf16 accumulation tolerance


def test_mqr_sparse_decode_runs_and_is_close():
    """With topk == all blocks, the mqr path must equal dense decode."""
    import dataclasses

    cfg = registry.get_config("llama32_1b", smoke=True)
    cfg = dataclasses.replace(cfg, mqr_block=16, mqr_topk=4, mqr_levels=4)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 1, 64  # 4 blocks of 16 -> topk=4 covers everything
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size,
                              jnp.int32)
    caches_d = T.init_caches(cfg, b, s)
    caches_s = T.init_caches(cfg, b, s)
    for t in range(s):
        tok_t = toks[:, t : t + 1]
        lg_d, caches_d = T.decode_step(params, cfg, tok_t, caches_d, t)
        lg_s, caches_s = T.decode_step(params, cfg, tok_t, caches_s, t,
                                       mqr_sparse=True)
    err = float(jnp.max(jnp.abs(lg_d.astype(jnp.float32) - lg_s.astype(jnp.float32))))
    assert err < 0.05, err


def test_mqr_incremental_index_matches_dense():
    """Cache-resident incremental index (§Perf optimization): with topk
    covering all blocks it must equal dense decode exactly."""
    import dataclasses

    cfg = registry.get_config("llama32_1b", smoke=True)
    cfg_i = dataclasses.replace(cfg, mqr_block=16, mqr_topk=4, mqr_levels=4,
                                mqr_incremental=True)
    cfg_d = dataclasses.replace(cfg_i, mqr_incremental=False)
    params = T.init_params(jax.random.PRNGKey(0), cfg_i)
    b, s = 1, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size, jnp.int32)
    cd, ci = T.init_caches(cfg_d, b, s), T.init_caches(cfg_i, b, s)
    for t in range(s):
        tok = toks[:, t : t + 1]
        ld, cd = T.decode_step(params, cfg_d, tok, cd, t)
        li, ci = T.decode_step(params, cfg_i, tok, ci, t, mqr_sparse=True)
    err = float(jnp.max(jnp.abs(ld.astype(jnp.float32) - li.astype(jnp.float32))))
    assert err < 0.05, err
