"""The paper's Section 4 properties, enforced as tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import datasets, metrics, mqrtree
from repro.core import mbr as M


def object_level_check(t):
    """Property 2: every object under location li is in quadrant li."""
    def objs(e):
        if not e.is_node:
            return [e]
        out = []
        for _, ee in e.node.entries():
            out.extend(objs(ee))
        return out

    for node, _ in t.iter_nodes():
        if node.ntype != mqrtree.NORMAL or node.mbr is None:
            continue
        ncx, ncy = M.centroid(node.mbr)
        for li, e in node.entries():
            for oe in objs(e):
                q = mqrtree.quad_of_point(*M.centroid(oe.mbr), ncx, ncy)
                assert q == li, (li, oe.obj, q)


def shape_sig(t):
    sig = []

    def walk(node, path):
        for li, e in sorted(node.entries(), key=lambda x: x[0]):
            if e.is_node:
                walk(e.node, path + (li,))
            else:
                sig.append((path + (li,), e.obj))

    walk(t.root, ())
    return tuple(sorted(sig))


def test_fig2_orientation_table():
    # Fig. 2 rows, (A, B) -> placement
    NE, NW, SW, SE, EQ = range(5)
    cases = [
        ((0, 0), (1, 1), SW),   # A west & south of B
        ((0, 1), (1, 1), SW),   # due west -> SW
        ((0, 2), (1, 1), NW),   # northwest
        ((1, 2), (1, 1), NW),   # due north -> NW
        ((2, 0), (1, 1), SE),   # southeast
        ((1, 0), (1, 1), SE),   # due south -> SE
        ((2, 2), (1, 1), NE),   # northeast
        ((2, 1), (1, 1), NE),   # due east -> NE
        ((1, 1), (1, 1), EQ),
    ]
    for (ax, ay), (bx, by), want in cases:
        assert mqrtree.quad_of_point(ax, ay, bx, by) == want


@pytest.mark.parametrize("kind", ["uniform_points", "exponential_points"])
def test_zero_overlap_for_points(kind):
    data = datasets.REGISTRY[kind](400, seed=3)
    t = mqrtree.build(data)
    t.validate()
    m = metrics.compute_metrics(t)
    assert m.overlap == 0.0  # paper section 4, property 4


@given(st.integers(0, 1000), st.integers(5, 60))
@settings(max_examples=25, deadline=None)
def test_insertion_order_independence(seed, n):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 100, (n, 2))
    mbrs = np.concatenate([pts, pts], axis=1)
    ref = None
    for s in range(3):
        perm = np.random.default_rng(seed * 7 + s).permutation(n)
        t = mqrtree.MQRTree()
        for i in perm:
            t.insert(int(i), mbrs[i])
        t.validate()
        object_level_check(t)
        sig = shape_sig(t)
        if ref is None:
            ref = sig
        assert sig == ref


@given(st.integers(0, 500), st.integers(5, 50))
@settings(max_examples=25, deadline=None)
def test_validity_and_completeness_objects(seed, n):
    rng = np.random.default_rng(seed)
    ll = rng.uniform(0, 100, (n, 2))
    wh = rng.uniform(0.1, 20, (n, 2))
    mbrs = np.concatenate([ll, ll + wh], axis=1)
    t = mqrtree.build(mbrs)
    t.validate()
    got = sorted(o for o, _ in t.all_objects())
    assert got == list(range(n))


def test_duplicate_centroids_center_nodes():
    # many objects sharing one centroid exercise the CENTER chain
    base = np.array([50.0, 50.0, 60.0, 60.0])
    mbrs = np.stack([base + np.array([-k, -k, k, k]) for k in range(8)])
    t = mqrtree.build(mbrs)
    t.validate()
    assert sorted(o for o, _ in t.all_objects()) == list(range(8))
    found, _ = t.region_search(np.array([54, 54, 56, 56.0]))
    assert sorted(found) == list(range(8))


def test_entry_half_area_in_quadrant_points():
    """Property 3 (weak form checked on points where it is exact)."""
    data = datasets.uniform_points(200, seed=9)
    t = mqrtree.build(data)
    m = metrics.compute_metrics(t)
    assert m.overlap == 0.0


def test_height_vs_paper_scale():
    data = datasets.uniform_squares(1000, seed=4)
    t = mqrtree.build(data)
    m = metrics.compute_metrics(t)
    # paper table 1 at 1000 objects: worst-case height 8, avg 6 — allow slack
    assert m.height <= 12
    assert m.avg_path <= m.height
    assert m.avg_path >= 2


def test_point_search_single_path_for_points():
    """Paper §5.5: zero overlap on point data => point queries follow at
    most one path (visits <= max height)."""
    data = datasets.uniform_points(500, seed=21)
    t = mqrtree.build(data)
    m = metrics.compute_metrics(t)
    for i in range(0, 500, 23):
        p = data[i, :2]
        found, visits = mqrtree.point_search(t, p)
        assert i in found
        assert visits <= m.height, (visits, m.height)  # ONE path


def test_knn_matches_bruteforce():
    data = datasets.uniform_points(300, seed=22)
    t = mqrtree.build(data)
    pts = data[:, :2]
    rng = np.random.default_rng(0)
    for _ in range(5):
        q = rng.uniform(0, 1000, 2)
        ids, visits = mqrtree.knn_search(t, q, k=5)
        d2 = ((pts - q) ** 2).sum(axis=1)
        brute = set(np.argsort(d2)[:5])
        assert set(ids) == brute
        assert visits < 300  # far fewer than all nodes
