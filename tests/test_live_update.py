"""Live-update subsystem: delta buffer, tombstones, merge policy (§8).

The acceptance contract: a mixed insert/delete workload (≥1e4 ops) keeps
region / point / knn hit sets bit-identical to the host mqr
insertion-rule oracle on EVERY backend, both mid-buffer and after a
merge; tombstoned ids never appear anywhere; buffer overflow merges
automatically with hit sets unchanged; and the batching server's LRU is
epoch-invalidated so it never serves stale results after a mutation.
"""

import numpy as np
import pytest

import conftest
from conftest import f32_exact
from repro.core import datasets
from repro.index import MergePolicy, SpatialIndex
from repro.update import oracle

BACKENDS = ("host", "lax", "pallas", "serve")


def assert_matches_oracle(idx, queries, *, structure=""):
    """Hit sets of every backend == the mqr insertion-rule oracle, and
    hits + per-level visits identical across the float32 backends."""
    ref = oracle.hits_mask(idx, queries, idx.id_space)
    first = None
    for backend in BACKENDS:
        res = idx.with_backend(backend).region(queries)
        assert np.array_equal(res.hits, ref), f"{structure}×{backend} vs oracle"
        if first is None:
            first = res
        else:
            assert np.array_equal(
                res.visits_per_level, first.visits_per_level
            ), f"{structure}×{backend} visit parity"
    compact = idx.with_backend("pallas", precision="compact").region(queries)
    assert np.array_equal(compact.hits, ref), f"{structure}×compact vs oracle"
    return first


# ---------------------------------------------------------------------------
# The acceptance workload: >= 1e4 mixed ops on the pyramid structure
# ---------------------------------------------------------------------------


def test_mixed_workload_matches_oracle_on_every_backend():
    rng = np.random.default_rng(0)
    data = f32_exact(conftest.mbr_dataset("test_live_update",
                                          "uniform_squares", 400))
    # tombstone trigger relaxed so checkpoints land mid-buffer; merges
    # still happen through buffer/id-space overflow every few rounds
    idx = SpatialIndex.build(
        data, structure="pyramid", backend="pallas",
        merge=dict(capacity=1024, max_tombstone_ratio=0.95),
    )
    log = idx._ensure_log()

    ops_done = 0
    rounds = 30
    checkpoints = {10, 20}
    midbuffer_checks = 0
    for r in range(rounds):
        batch = f32_exact(datasets.uniform_squares(250, seed=1000 + r))
        idx.insert(batch)
        ops_done += 250
        if r in checkpoints:
            # an insert that exhausts the id headroom merges directly
            # (empty buffer); with 250 fresh ids per round against 1024
            # of headroom that happens on rounds ≡ 4 (mod 5), so both
            # checkpoints land mid-buffer — counted, not assumed
            if log.n_delta > 0:
                midbuffer_checks += 1
            qs = datasets.region_queries(
                idx._updates.mbr_table[log.alive], 4, seed=50 + r
            ).astype(np.float32)
            assert_matches_oracle(idx, qs, structure="pyramid")
        live = np.nonzero(log.alive)[0]
        victims = rng.choice(live, size=200, replace=False)
        idx.delete(victims)
        ops_done += 200
    assert ops_done >= 10_000
    assert midbuffer_checks >= 1, "no checkpoint landed mid-buffer"
    assert idx.stats.inserts == rounds * 250
    assert idx.stats.deletes == rounds * 200
    assert idx.stats.flushes > 0, "workload must have exercised the merge"

    # knn parity at the end, mid-buffer: oracle tree vs host vs device
    pts = rng.uniform(100.0, 900.0, (6, 2))
    k = 5
    from repro.index.knn import knn_pointer

    oracle_ids, oracle_d, _ = knn_pointer(oracle.live_tree(idx), pts, k)
    srt = np.sort(oracle_d, axis=1)
    assert (np.diff(srt, axis=1) > 0).all(), "degenerate knn fixture"
    for backend in ("host", "lax", "pallas"):
        res = idx.with_backend(backend).knn(pts, k)
        assert np.array_equal(res.ids, oracle_ids), f"knn {backend}"

    # post-merge: same hit-id sets, still oracle-identical everywhere
    qs = datasets.region_queries(
        idx._updates.mbr_table[log.alive], 4, seed=99
    ).astype(np.float32)
    pre = idx.region(qs)
    assert idx.flush()
    assert log.n_delta == 0 and log.dead_base == 0
    post = idx.region(qs)
    for i in range(qs.shape[0]):
        assert np.array_equal(pre.ids(i), post.ids(i)), "merge changed hits"
    assert_matches_oracle(idx, qs, structure="pyramid-post-flush")
    for backend in ("host", "pallas"):
        res = idx.with_backend(backend).knn(pts, k)
        assert np.array_equal(res.ids, oracle_ids), f"post-flush knn {backend}"


# ---------------------------------------------------------------------------
# Tombstones
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("structure", ("mqr", "pyramid"))
def test_tombstoned_ids_never_hit_anywhere(structure):
    data = f32_exact(datasets.uniform_squares(160, seed=3))
    idx = SpatialIndex.build(data, structure=structure, backend="pallas",
                             capacity=32)
    gids = idx.insert(f32_exact(datasets.uniform_squares(20, seed=4)))
    dead = [0, 7, 11, int(gids[0]), int(gids[5])]  # base + delta victims
    idx.delete(dead)
    centers = np.stack(
        [(data[:, 0] + data[:, 2]) / 2, (data[:, 1] + data[:, 3]) / 2], 1
    )[:8]
    huge = np.array([[-1e6, -1e6, 1e6, 1e6]], np.float32)  # hits everything
    for backend in BACKENDS:
        tw = idx.with_backend(backend)
        r = tw.region(huge)
        assert not r.hits[:, dead].any(), f"{backend} region leaked a tombstone"
        assert r.hits.sum() == idx.n_objects, f"{backend} missed live objects"
        p = tw.point(centers)
        assert not p.hits[:, dead].any(), f"{backend} point leaked a tombstone"
        knn = tw.knn(centers[:3], k=idx.n_objects)
        assert not np.isin(dead, knn.ids).any(), f"{backend} knn ranked a tombstone"
    compact = idx.with_backend("pallas", precision="compact").region(huge)
    assert not compact.hits[:, dead].any(), "compact path leaked a tombstone"


def test_delete_then_reinsert_roundtrips():
    data = f32_exact(datasets.uniform_squares(100, seed=5))
    idx = SpatialIndex.build(data, structure="mqr", backend="pallas",
                             capacity=16)
    box = data[3]
    q = np.asarray(box, np.float32)[None, :]
    assert idx.region(q).hits[0, 3]
    idx.delete([3])
    assert not idx.region(q).hits[0, 3]
    (new_gid,) = idx.insert(box[None, :])
    assert new_gid == 100  # ids never recycle
    res = idx.region(q)
    assert res.hits[0, new_gid] and not res.hits[0, 3]
    assert idx.n_objects == 100
    # survives a merge with the same identity
    idx.flush()
    res = idx.region(q)
    assert res.hits[0, new_gid] and not res.hits[0, 3]
    assert_matches_oracle(idx, q, structure="reinsert")


# ---------------------------------------------------------------------------
# Merge policy and overflow
# ---------------------------------------------------------------------------


def test_buffer_overflow_merges_automatically_bit_identical():
    data = f32_exact(datasets.uniform_squares(120, seed=6))
    idx = SpatialIndex.build(
        data, structure="pyramid", backend="pallas",
        merge=dict(capacity=24, max_fill=1.0),
    )
    qs = datasets.region_queries(data, 5, seed=7).astype(np.float32)
    seen = []
    for i in range(4):  # 4 × 10 inserts through a 24-slot buffer
        idx.insert(f32_exact(datasets.uniform_squares(10, seed=60 + i)))
        seen.append([set(idx.region(qs).ids(j)) for j in range(qs.shape[0])])
    assert idx.stats.flushes >= 1, "overflow must have merged"
    # every checkpoint stays a prefix-consistent superset: hit-id sets for
    # the SAME queries never lose base objects across automatic merges
    final = [set(idx.region(qs).ids(j)) for j in range(qs.shape[0])]
    assert final == seen[-1]
    assert_matches_oracle(idx, qs, structure="overflow")
    # oversized batch (> capacity) merges directly, ids still dense
    gids = idx.insert(f32_exact(datasets.uniform_squares(40, seed=70)))
    assert gids.shape == (40,) and idx._updates.n_delta == 0
    assert_matches_oracle(idx, qs, structure="oversized-batch")


def test_merge_policy_triggers_and_manual_mode():
    data = f32_exact(datasets.uniform_squares(80, seed=8))
    # fill trigger
    idx = SpatialIndex.build(
        data, structure="mqr", backend="host",
        merge=dict(capacity=10, max_fill=0.5),
    )
    idx.insert(f32_exact(datasets.uniform_squares(5, seed=9)))
    assert idx.stats.flushes == 1 and idx._updates.n_delta == 0
    # tombstone-ratio trigger
    idx = SpatialIndex.build(
        data, structure="mqr", backend="host",
        merge=dict(capacity=10, max_tombstone_ratio=0.1),
    )
    idx.delete(np.arange(8))
    assert idx.stats.flushes == 1 and idx._updates.dead_base == 0
    assert idx.n_objects == 72
    # manual mode: nothing auto-merges short of physical overflow
    idx = SpatialIndex.build(
        data, structure="mqr", backend="host",
        merge=MergePolicy(capacity=10, max_fill=0.5, auto=False),
    )
    idx.insert(f32_exact(datasets.uniform_squares(9, seed=10)))
    idx.delete(np.arange(40))
    assert idx.stats.flushes == 0 and idx._updates.pending
    assert idx.flush() and not idx._updates.pending
    assert not idx.flush()  # nothing pending: no-op


def test_update_option_routing_and_validation():
    data = f32_exact(datasets.uniform_squares(40, seed=11))
    with pytest.raises(ValueError, match="capacity"):
        SpatialIndex.build(data, capacity=0)
    with pytest.raises(ValueError, match="max_fill"):
        SpatialIndex.build(data, merge=dict(max_fill=1.5))
    with pytest.raises(TypeError, match="MergePolicy"):
        SpatialIndex.build(data, merge=42)
    # capacity is a build-level option, not a backend option
    with pytest.raises(TypeError):
        SpatialIndex.build(data).with_backend("pallas", capacity=8)
    idx = SpatialIndex.build(data, structure="mqr", backend="host")
    # empty batches are true no-ops: no live-update state, no epoch bump
    assert idx.insert(np.zeros((0, 4))).size == 0
    idx.delete(np.zeros((0,), np.int64))
    assert idx._updates is None and idx.id_space == 40
    with pytest.raises(KeyError, match="not live"):
        idx.delete([40])
    idx.delete([0])
    epoch = idx._updates.epoch
    idx.delete(np.zeros((0,), np.int64))
    assert idx._updates.epoch == epoch  # still no epoch bump
    with pytest.raises(KeyError, match="not live"):
        idx.delete([0])  # already dead
    with pytest.raises(KeyError, match="duplicate"):
        idx.delete([1, 1])
    with pytest.raises(ValueError, match="no live objects"):
        idx.delete(np.arange(1, 40))
        idx.flush()
    # ...but INSERTING into a fully-deleted index works: the batch folds
    # straight into the merge instead of flushing an empty live set
    gids = idx.insert(f32_exact(datasets.uniform_squares(3, seed=99)))
    assert idx.n_objects == 3
    huge = np.array([[-1e6, -1e6, 1e6, 1e6]], np.float32)
    assert np.array_equal(idx.region(huge).ids(0), gids)


def test_with_backend_shares_live_state():
    data = f32_exact(datasets.uniform_squares(60, seed=12))
    idx = SpatialIndex.build(data, structure="mqr", backend="pallas",
                             capacity=16)
    twin = idx.with_backend("lax")
    gids = idx.insert(f32_exact(datasets.uniform_squares(4, seed=13)))
    twin.delete([gids[0], 2])  # mutate through the twin
    huge = np.array([[-1e6, -1e6, 1e6, 1e6]], np.float32)
    a, b = idx.region(huge), twin.region(huge)
    assert np.array_equal(a.hits, b.hits)
    assert np.array_equal(a.visits_per_level, b.visits_per_level)
    # a merge through one twin is picked up lazily by the other; the id
    # space may widen at the merge, hit-id sets never change
    idx.flush()
    b2 = twin.region(huge)
    assert np.array_equal(b2.ids(0), a.ids(0))


# ---------------------------------------------------------------------------
# Serve: cache correctness under mutation
# ---------------------------------------------------------------------------


def test_serve_cache_is_epoch_invalidated():
    data = f32_exact(datasets.uniform_squares(90, seed=14))
    idx = SpatialIndex.build(data, structure="mqr", backend="serve",
                             capacity=32)
    idx.insert(f32_exact(datasets.uniform_squares(5, seed=15)))
    qs = datasets.region_queries(data, 4, seed=16).astype(np.float32)
    a = idx.region(qs)
    server = idx._live()._serve[1]
    hits_before = server.stats.cache_hits
    b = idx.region(qs)  # same epoch: served from the LRU
    assert server.stats.cache_hits > hits_before
    assert np.array_equal(a.hits, b.hits)
    victim = int(a.ids(0)[0])
    idx.delete([victim])
    c = idx.region(qs)  # new epoch: cached entries must not be served
    assert not c.hits[:, victim].any()
    assert np.array_equal(c.hits, oracle.hits_mask(idx, qs, idx.id_space))
    # pre-mutation entries were dropped, post-mutation caching works again
    hits_before = server.stats.cache_hits
    d = idx.region(qs)
    assert server.stats.cache_hits > hits_before
    assert np.array_equal(c.hits, d.hits)


def test_access_stats_delta_ledger():
    data = f32_exact(datasets.uniform_squares(70, seed=17))
    idx = SpatialIndex.build(data, structure="pyramid", backend="pallas",
                             capacity=16)
    idx.insert(f32_exact(datasets.uniform_squares(6, seed=18)))
    huge = np.array([[-1e6, -1e6, 1e6, 1e6]], np.float32)
    res = idx.region(huge)
    assert res.base_levels == idx.schedule.levels
    assert int(res.delta_visits[0]) == 6  # every valid slot was accessed
    assert idx.stats.delta_accesses == 6
    assert idx.stats.node_accesses == int(res.visits_per_level.sum())
    idx.flush()
    res = idx.region(huge)
    assert int(res.delta_visits[0]) == 0  # buffer empty after the merge
