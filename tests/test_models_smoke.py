"""Per-arch smoke: reduced config, one forward/train step, shapes + no NaN."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer as T


def make_batch(cfg, b=2, s=64, key=None):
    key = key or jax.random.PRNGKey(0)
    kt, kl, kv = jax.random.split(key, 3)
    if cfg.frontend == "audio_codebooks":
        return {
            "tokens": jax.random.randint(kt, (b, s, cfg.n_codebooks), 0, cfg.vocab_size, jnp.int32),
            "labels": jax.random.randint(kl, (b, s, cfg.n_codebooks), 0, cfg.vocab_size, jnp.int32),
        }
    if cfg.frontend == "vision_patches":
        st_ = s - cfg.n_patches
        return {
            "tokens": jax.random.randint(kt, (b, st_), 0, cfg.vocab_size, jnp.int32),
            "labels": jax.random.randint(kl, (b, st_), 0, cfg.vocab_size, jnp.int32),
            "vision_embeds": jax.random.normal(kv, (b, cfg.n_patches, cfg.d_model), jnp.bfloat16),
        }
    return {
        "tokens": jax.random.randint(kt, (b, s), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(kl, (b, s), 0, cfg.vocab_size, jnp.int32),
    }


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_arch_smoke_loss_and_grad(arch):
    cfg = registry.get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    loss, aux = jax.jit(lambda p, b: T.loss_and_aux(p, cfg, b))(params, batch)
    assert jnp.isfinite(loss), arch
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 2.5
    g = jax.jit(jax.grad(lambda p, b: T.loss_and_aux(p, cfg, b)[0]))(params, batch)
    gnorm = float(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(g))
        ** 0.5
    )
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_arch_param_count_matches_analytic(arch):
    cfg = registry.get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    actual = sum(int(x.size) for x in jax.tree.leaves(params))
    analytic = cfg.param_count()
    # analytic uses the unpadded vocab and skips tiny scalars; 15% slack
    assert abs(actual - analytic) / analytic < 0.4, (arch, actual, analytic)


def test_full_configs_match_assignment():
    cases = {
        "mamba2_2p7b": dict(n_layers=64, d_model=2560, vocab_size=50280, ssm_state=128),
        "granite_moe_1b": dict(n_layers=24, d_model=1024, n_experts=32, experts_per_tok=8),
        "deepseek_v3_671b": dict(n_layers=61, d_model=7168, n_heads=128, n_experts=256),
        "recurrentgemma_9b": dict(d_model=4096, n_kv_heads=1, d_ff=12288),
        "gemma_2b": dict(n_layers=18, d_model=2048, n_kv_heads=1, d_ff=16384, vocab_size=256000),
        "command_r_35b": dict(n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22528),
        "granite_8b": dict(n_layers=36, d_model=4096, n_heads=32, d_ff=14336, vocab_size=49152),
        "llama32_1b": dict(n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, vocab_size=128256),
        "musicgen_large": dict(n_layers=48, d_model=2048, n_heads=32, vocab_size=2048, n_codebooks=4),
        "internvl2_2b": dict(n_layers=24, d_model=2048, n_heads=16, vocab_size=92553),
    }
    for arch, fields in cases.items():
        cfg = registry.get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    # deepseek param budget sanity: ~671B total, ~37B active
    ds = registry.get_config("deepseek_v3_671b")
    assert 550e9 < ds.param_count() < 750e9, ds.param_count()
    assert 25e9 < ds.active_param_count() < 50e9, ds.active_param_count()
