"""mqr-KV index: block selection quality + jit-ability."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kvindex


def test_select_blocks_static_and_jits():
    key = jax.random.PRNGKey(0)
    keys = jax.random.normal(key, (2048, 64))
    probe = jax.random.normal(jax.random.fold_in(key, 1), (64,))

    @jax.jit
    def run(kk, qq):
        idx = kvindex.build_kv_index(kk, probe, 128, 5)
        region = kvindex.query_region(qq, probe, 2048)
        return kvindex.select_blocks(idx, region, 8)

    q = jax.random.normal(jax.random.fold_in(key, 2), (64,))
    ids = run(keys, q)
    assert ids.shape == (8,)
    assert int(ids.min()) >= 0 and int(ids.max()) < 16


def test_selected_blocks_cover_high_score_keys():
    """The block holding the single highest q-aligned key must be selected."""
    key = jax.random.PRNGKey(3)
    keys = jax.random.normal(key, (1024, 32)) * 0.1
    probe = jax.random.normal(jax.random.fold_in(key, 1), (32,))
    q = probe / jnp.linalg.norm(probe)  # query aligned with the probe
    # plant a strongly q-aligned key in block 5
    keys = keys.at[5 * 128 + 7].set(3.0 * probe / jnp.linalg.norm(probe))
    idx = kvindex.build_kv_index(keys, probe, 128, 5)
    region = kvindex.query_region(q, probe, 1024)
    ids = np.asarray(kvindex.select_blocks(idx, region, 4))
    assert 5 in ids, ids
