"""`SpatialIndex` façade: cross-backend parity, k-NN exactness, API hygiene.

The acceptance contract of DESIGN.md §6: every (structure × backend) pair
the registry advertises returns bit-identical hits AND per-level access
counts to the host pointer search, `knn` matches brute-force nearest
neighbours exactly on ≥3 dataset shapes, and no module outside `kernels/`
imports a `_`-prefixed kernel symbol.
"""
import functools
import pathlib
import re

import numpy as np
import pytest

import conftest
from repro.core import mbr as M
from repro.index import SpatialIndex, advertised_pairs, backend_names, get_backend
from repro.index.knn import _mindist_np

# shared builders live in tests/conftest.py; sizes are this module's own
DATASETS = {
    "uniform_squares": 250,
    # the paper's zero-overlap case: degenerate point MBRs (§4)
    "uniform_points": 220,
    "exponential_squares": 200,
}
STRUCTURES = ("mqr", "rtree", "pyramid")
BACKENDS = ("host", "lax", "pallas", "serve")


def _data(name: str) -> np.ndarray:
    return conftest.mbr_dataset("test_index_api", name, DATASETS[name])


@functools.lru_cache(maxsize=None)
def _host_index(structure: str, ds: str) -> SpatialIndex:
    return SpatialIndex.build(_data(ds), structure=structure, backend="host")


def _queries(ds: str) -> np.ndarray:
    return conftest.dataset_queries("test_index_api", ds, DATASETS[ds])


@functools.lru_cache(maxsize=None)
def _host_region(structure: str, ds: str):
    return _host_index(structure, ds).region(_queries(ds))


# ---------------------------------------------------------------------------
# The parity matrix: structures × backends × dataset shapes
# ---------------------------------------------------------------------------


def test_registry_advertises_full_matrix():
    pairs = advertised_pairs()
    for structure in STRUCTURES:
        for backend in BACKENDS:
            assert (structure, backend) in pairs
    assert set(backend_names()) == set(BACKENDS)
    with pytest.raises(ValueError, match="unknown backend"):
        get_backend("gpu-of-theseus")


@pytest.mark.parametrize("ds", sorted(DATASETS))
@pytest.mark.parametrize("structure", STRUCTURES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_region_parity_matrix(ds, structure, backend):
    """Identical hit sets and per-level access counts on every advertised
    (structure × backend) pair, for 3 dataset shapes."""
    if (structure, backend) not in advertised_pairs():
        pytest.skip(f"{backend} does not advertise {structure}")
    ref = _host_region(structure, ds)
    idx = _host_index(structure, ds).with_backend(backend)
    res = idx.region(_queries(ds))
    assert np.array_equal(res.hits, ref.hits)
    assert np.array_equal(res.visits_per_level, ref.visits_per_level), (
        f"per-level access counts diverge on {structure}×{backend}"
    )
    # the AccessStats ledger reports the same accounting everywhere
    assert idx.stats.queries == _queries(ds).shape[0]
    assert idx.stats.node_accesses == int(ref.visits_per_level.sum())


@pytest.mark.parametrize("structure", ("mqr", "rtree"))
def test_host_backend_is_the_pointer_search(structure):
    """The host backend's numbers ARE the pointer implementation's."""
    ds = "uniform_squares"
    idx = _host_index(structure, ds)
    res = idx.region(_queries(ds))
    tree = idx.artifacts.pointer_tree
    for i, q in enumerate(_queries(ds)):
        found, v = tree.region_search(np.asarray(q, np.float64))
        assert set(res.ids(i)) == set(found)
        assert int(res.visits[i]) == v


def test_point_and_count_fast_paths():
    ds = "uniform_squares"
    data = _data(ds)
    idx = _host_index("mqr", ds)
    centers = np.stack(
        [(data[:5, 0] + data[:5, 2]) / 2, (data[:5, 1] + data[:5, 3]) / 2], 1
    )
    res = idx.point(centers)
    for i, p in enumerate(centers):
        expect = set(np.nonzero(M.contains_point(data, p))[0])
        assert set(res.ids(i)) == expect
    assert np.array_equal(idx.count(_queries(ds)), _host_region("mqr", ds).counts)
    # point parity across a device backend too (degenerate rectangles)
    dev = idx.with_backend("pallas").point(centers)
    assert np.array_equal(dev.hits, res.hits)
    assert np.array_equal(dev.visits_per_level, res.visits_per_level)


# ---------------------------------------------------------------------------
# k-NN: first-class, exact on every path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ds", sorted(DATASETS))
@pytest.mark.parametrize("structure", STRUCTURES)
def test_knn_matches_brute_force(ds, structure):
    data = _data(ds)
    pts = np.random.default_rng(11).uniform(50.0, 950.0, (7, 2))
    k = 6
    brute_d = _mindist_np(pts, np.asarray(data, np.float64))
    brute_ids = np.argsort(brute_d, axis=1, kind="stable")[:, :k]
    # distances strictly separate at the k boundary -> ids are unambiguous
    srt = np.sort(brute_d, axis=1)
    assert (srt[:, k] > srt[:, k - 1]).all(), "degenerate test fixture"

    host = _host_index(structure, ds)
    for backend in ("host", "lax", "pallas"):
        res = host.with_backend(backend).knn(pts, k)
        assert np.array_equal(res.ids, brute_ids), f"{structure}×{backend}"
        assert np.allclose(
            res.dists, np.take_along_axis(brute_d, brute_ids, 1), atol=1e-4
        )
        assert res.visits.shape == (7,)


def test_knn_accounting_and_bounds():
    ds = "uniform_squares"
    idx = _host_index("mqr", ds).with_backend("pallas")
    pts = np.random.default_rng(3).uniform(100, 900, (4, 2))
    res = idx.knn(pts, 3)
    assert idx.stats.knn_queries == 4
    assert idx.stats.knn_rounds >= 2  # at least one probe + confirm round
    assert idx.stats.node_accesses == int(res.visits.sum())
    with pytest.raises(ValueError, match="outside"):
        idx.knn(pts, 0)
    with pytest.raises(ValueError, match="outside"):
        idx.knn(pts, idx.n_objects + 1)


@pytest.mark.parametrize("structure", STRUCTURES)
def test_knn_tie_breaking_consistent_across_engines(structure):
    """Equal distances resolve by lowest object id on EVERY engine —
    co-centred squares give distance-0 ties at the shared centroid."""
    n, k = 40, 5
    s = np.arange(1, n + 1, dtype=np.float64)[:, None]
    data = np.concatenate([500 - s, 500 - s, 500 + s, 500 + s], axis=1)
    pts = np.array([[500.0, 500.0], [495.0, 505.0], [200.0, 200.0]])
    idx = SpatialIndex.build(data, structure=structure, backend="host")
    ref = idx.knn(pts, k)
    # point 0 is inside every square -> ids 0..k-1; point 1 is inside all
    # squares with half-side >= 5 -> ids 4..8; point 2 has distinct dists
    assert np.array_equal(ref.ids[0], np.arange(k))
    assert np.array_equal(ref.ids[1], np.arange(4, 4 + k))
    assert np.array_equal(ref.ids[2], np.arange(n - 1, n - 1 - k, -1))
    for backend in ("lax", "pallas"):
        res = idx.with_backend(backend).knn(pts, k)
        assert np.array_equal(res.ids, ref.ids), f"{structure}×{backend}"
        assert np.allclose(res.dists, ref.dists, atol=1e-4)


# ---------------------------------------------------------------------------
# API hygiene
# ---------------------------------------------------------------------------


def test_unknown_backend_option_raises():
    """Options a backend does not support must fail loudly, not be
    silently swallowed (typos, or a documented option of another backend)."""
    with pytest.raises(TypeError):
        SpatialIndex.build(
            _data("uniform_squares"), structure="mqr", backend="pallas",
            cache_size=8,  # a serve-only option
        )
    with pytest.raises(TypeError):
        _host_index("mqr", "uniform_squares").with_backend("lax", block_w=64)
    # build options are structure-strict too
    with pytest.raises(TypeError, match="does not accept"):
        SpatialIndex.build(
            _data("uniform_squares"), structure="mqr", backend="host",
            levels=4,  # a pyramid-only option
        )
    with pytest.raises(TypeError, match="does not accept"):
        SpatialIndex.build(
            _data("uniform_squares"), structure="pyramid", backend="host",
            max_entries=8,  # an rtree-only option
        )


def test_custom_backend_registration_never_masks_builtins():
    """Regression: registering a user backend before the first built-in
    lookup must not stop the built-ins from loading."""
    import sys

    from repro.index import registry

    import repro.index as index_pkg

    saved_registry = dict(registry._REGISTRY)
    saved_flag = registry._BUILTINS_LOADED
    # simulate a fresh process: built-ins neither imported nor registered
    # (`from . import backends` short-circuits to an existing package attr)
    saved_mod = sys.modules.pop("repro.index.backends", None)
    saved_attr = index_pkg.__dict__.pop("backends", None)
    registry._REGISTRY.clear()
    registry._BUILTINS_LOADED = False
    try:

        @registry.register_backend(
            "dummy", structures=("mqr",), artifact="schedule"
        )
        class Dummy:
            def __init__(self, artifacts):
                pass

        assert registry.get_backend("host").name == "host"
        assert "dummy" in registry.backend_names()
    finally:
        registry._REGISTRY.clear()
        registry._REGISTRY.update(saved_registry)
        registry._BUILTINS_LOADED = saved_flag
        if saved_mod is not None:
            sys.modules["repro.index.backends"] = saved_mod
        if saved_attr is not None:
            index_pkg.backends = saved_attr


def test_structure_backend_validation():
    with pytest.raises(ValueError, match="unknown structure"):
        SpatialIndex.build(_data("uniform_squares"), structure="kd")
    idx = _host_index("pyramid", "uniform_squares")
    with pytest.raises(ValueError, match="no pointer tree"):
        _ = idx.artifacts.flat


def test_top_level_reexport():
    import repro

    assert repro.SpatialIndex is SpatialIndex
    assert "SpatialIndex" in dir(repro)


def test_no_private_kernel_imports_outside_kernels():
    """No module outside kernels/ may touch a `_`-prefixed kernel symbol —
    the public surface is `repro.kernels.ops` (fused_search,
    interpret_default, pyramid_scan, ...)."""
    root = pathlib.Path(__file__).resolve().parents[1]
    kernel_mods = (
        "kernels", "ops", "mbr_scan", "pyramid_scan", "flash_attention",
        "mqr_sparse_attention", "rmsnorm",
    )
    import_pat = re.compile(
        r"from\s+(?:repro\.)?kernels(?:\.\w+)?\s+import\s+[^\n]*\b_\w+"
    )
    attr_pat = re.compile(r"\b(?:%s)\._\w+" % "|".join(kernel_mods))
    offenders = []
    for sub in ("src", "tests", "benchmarks", "examples"):
        for f in sorted((root / sub).rglob("*.py")):
            if "kernels" in f.parts:
                continue  # inside the kernel package, private use is fine
            text = f.read_text()
            for pat in (import_pat, attr_pat):
                for m in pat.finditer(text):
                    offenders.append(f"{f.relative_to(root)}: {m.group(0)}")
    assert not offenders, "\n".join(offenders)


def test_kernel_entry_points_have_one_public_home():
    """`repro.kernels.ops` is the ONE public home of the kernel entry
    points (device_schedule, quantize_schedule, pyramid_scan*,
    level_sweep, build_levels_*, hilbert_*, parent_windows, ...).
    Outside kernels/, importing a kernel SUBMODULE other than the public
    trio (`ops`, `fallback`, `autotune`) is forbidden — re-export shims
    must not grow back (DESIGN.md §12)."""
    root = pathlib.Path(__file__).resolve().parents[1]
    allowed = {"ops", "fallback", "autotune"}
    from_pat = re.compile(r"from\s+repro\.kernels\.(\w+)\s+import")
    import_pat = re.compile(r"^\s*import\s+repro\.kernels\.(\w+)", re.M)
    offenders = []
    for sub in ("src", "tests", "benchmarks", "examples"):
        for f in sorted((root / sub).rglob("*.py")):
            if "kernels" in f.parts:
                continue  # inside the kernel package, cross-imports are fine
            text = f.read_text()
            for pat in (from_pat, import_pat):
                for m in pat.finditer(text):
                    if m.group(1) not in allowed:
                        offenders.append(
                            f"{f.relative_to(root)}: {m.group(0)}"
                        )
    assert not offenders, "\n".join(offenders)
