"""Scaled-down versions of the paper's Section 5 comparisons (trends)."""
import numpy as np
import pytest

from repro.core import datasets, metrics, mqrtree, rtree


def build_both(data):
    return mqrtree.build(data), rtree.build(data)


@pytest.fixture(scope="module")
def uniform_squares():
    data = datasets.uniform_squares(800, seed=11)
    return data, *build_both(data)


@pytest.fixture(scope="module")
def uniform_points():
    data = datasets.uniform_points(800, seed=12)
    return data, *build_both(data)


def test_table1_style_objects(uniform_squares):
    """Uniform objects: mqr lower overcoverage+overlap, more nodes."""
    _, mt, rt = uniform_squares
    m, r = metrics.compute_metrics(mt), metrics.compute_metrics(rt)
    assert m.overlap < r.overlap * 0.6          # paper: 49-87% decrease
    assert m.overcoverage < r.overcoverage      # paper: 33-80% decrease
    assert m.n_nodes > r.n_nodes                # paper: 45-50% more nodes
    assert m.n_nodes < 2.2 * r.n_nodes
    assert 0.4 < m.space_utilization < 0.65     # paper: 50-55%
    assert 0.6 < r.space_utilization < 0.85     # paper: 70-74%


def test_table2_style_points(uniform_points):
    """Uniform points: ZERO overlap for mqr, nonzero for R-tree."""
    _, mt, rt = uniform_points
    m, r = metrics.compute_metrics(mt), metrics.compute_metrics(rt)
    assert m.overlap == 0.0
    assert r.overlap > 0.0
    assert m.coverage < r.coverage              # paper: 21-60% decrease


def test_table9_style_search_uniform():
    """Uniform objects: mqr needs fewer disk accesses on region search.

    As in the paper (Table 9), the mqr advantage GROWS with object count —
    near-tied at 500-800 objects, clearly ahead by 2000."""
    data = datasets.uniform_squares(2000, seed=11)
    mt, rt = build_both(data)
    qs = datasets.region_queries(data, 20, seed=13)
    vm = sum(mt.region_search(q)[1] for q in qs)
    vr = sum(rt.region_search(q)[1] for q in qs)
    assert vm < vr, (vm, vr)


def test_table11_style_exponential_objects_exception():
    """Paper: for exponentially-distributed OBJECTS the R-tree wins on disk
    accesses (its exception case) — verify the same sign at small scale."""
    data = datasets.exponential_squares(800, seed=14)
    mt, rt = build_both(data)
    qs = datasets.dense_region_queries(20, seed=15)
    vm = sum(mt.region_search(q)[1] for q in qs)
    vr = sum(rt.region_search(q)[1] for q in qs)
    found_m = sum(len(mt.region_search(q)[0]) for q in qs)
    found_r = sum(len(rt.region_search(q)[0]) for q in qs)
    assert found_m == found_r          # same results either way
    assert vr < vm * 1.5               # R-tree competitive-or-better here


def test_zero_overlap_preserved_under_live_updates():
    """Section 4 property: ZERO overlap for point data — and it must
    survive a mixed insert/delete workload through the live-update path
    (DESIGN.md §8), both mid-buffer and after the merge compacts the
    buffer into a fresh base build."""
    from repro.index import SpatialIndex

    rng = np.random.default_rng(21)
    data = np.float64(np.float32(datasets.uniform_points(500, seed=21)))
    idx = SpatialIndex.build(
        data, structure="mqr", backend="pallas",
        merge=dict(capacity=128, max_tombstone_ratio=0.9),
    )
    assert idx.live_metrics().overlap == 0.0  # pristine baseline
    for r in range(4):
        idx.insert(np.float64(np.float32(
            datasets.uniform_points(100, seed=100 + r)
        )))
        live = np.nonzero(idx._updates.alive)[0]
        idx.delete(rng.choice(live, size=60, replace=False))
        # mid-buffer: the insertion-rule tree over the live set stays
        # overlap-free (the paper's Table 2 claim, under mutation)
        m = idx.live_metrics()
        assert m.overlap == 0.0, f"round {r}: overlap {m.overlap}"
    assert idx.stats.inserts == 400 and idx.stats.deletes == 240
    idx.flush()
    m = idx.live_metrics()
    assert m.overlap == 0.0
    assert m.overcoverage >= 0.0  # reported through the same path
    # contrast: an R-tree over the same live objects does overlap
    live_mbrs = idx._updates.mbr_table[idx._updates.alive]
    assert metrics.compute_metrics(rtree.build(live_mbrs)).overlap > 0.0


def test_roadlike_near_zero_overlap():
    """Table 7 trend: road-like line data gives mqr ~zero overlap."""
    data = datasets.roadlike_lines(2000, seed=16)
    mt, rt = build_both(data)
    m, r = metrics.compute_metrics(mt), metrics.compute_metrics(rt)
    assert m.overlap < 0.05 * r.overlap, (m.overlap, r.overlap)
