"""Observability layer (DESIGN.md §13): spans, ledger parity, metrics.

The contract under test:

1. spans nest and ALWAYS close — normal exit, exceptions, and the
   fault harness's BaseException kills all leave a complete ("X") event
   with the error type stamped in args;
2. the exported trace.json is Perfetto-loadable: valid Chrome trace
   schema, facade -> backend spans contained per thread, degradation
   rung transitions visible as instants;
3. the per-launch counter ledger discloses EXACTLY the numbers the §12
   bench computes — ``RegionResult.launch_report.bytes_streamed`` is
   bit-for-bit the bench's "bytes-streamed-skip-uint16" row;
4. the metrics registry renders well-formed Prometheus text and JSON,
   with per-tenant latency quantiles.
"""

from __future__ import annotations

import json
import re
import warnings

import numpy as np
import pytest

from repro.core import datasets
from repro.ft import FaultPlan, KillPoint
from repro.index import SpatialIndex
from repro.kernels import ops
from repro.obs import counters as obs_counters
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve import ServerConfig, ServingFrontEnd
from repro.serve.telemetry import LatencyHistogram


@pytest.fixture
def tracer():
    """A fresh, enabled process tracer; the previous one is restored."""
    old = obs_trace.get_tracer()
    t = obs_trace.set_tracer(obs_trace.Tracer())
    t.enabled = True
    yield t
    obs_trace.set_tracer(old)


@pytest.fixture
def ledger():
    obs_counters.collect_launch_reports(True)
    yield
    obs_counters.collect_launch_reports(False)


def _index(**backend_opts):
    data = datasets.uniform_squares(220, seed=41)
    queries = datasets.region_queries(data, 8, seed=42).astype(np.float32)
    idx = SpatialIndex.build(data, structure="pyramid", backend="pallas",
                             build="device", backend_opts=backend_opts)
    return idx, queries


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


class TestSpans:
    def test_disabled_tracing_returns_shared_null_span(self):
        old = obs_trace.get_tracer()
        t = obs_trace.set_tracer(obs_trace.Tracer())
        try:
            assert t.enabled is False
            assert obs_trace.span("x") is obs_trace.NULL_SPAN
            assert t.span("x") is obs_trace.NULL_SPAN
            with obs_trace.span("x", a=1) as s:
                s.annotate(b=2)
                s.event("inner")
            obs_trace.instant("i")
            obs_trace.counter("c", v=1)
            assert t.events() == []
        finally:
            obs_trace.set_tracer(old)

    def test_spans_nest_by_containment(self, tracer):
        with obs_trace.span("outer"):
            with obs_trace.span("inner"):
                pass
        ev = {e["name"]: e for e in tracer.events()}
        out, inn = ev["outer"], ev["inner"]
        assert out["ph"] == inn["ph"] == "X"
        assert out["tid"] == inn["tid"]
        assert out["ts"] <= inn["ts"]
        assert inn["ts"] + inn["dur"] <= out["ts"] + out["dur"]

    def test_span_closes_under_exception_and_records_error(self, tracer):
        with pytest.raises(ValueError):
            with obs_trace.span("boom", n=3):
                raise ValueError("nope")
        (e,) = tracer.events()
        assert e["name"] == "boom" and e["ph"] == "X"
        assert e["args"]["error"] == "ValueError"
        assert e["args"]["n"] == 3

    def test_span_closes_under_base_exception_kill(self, tracer):
        # the fault harness's KillPoint subclasses BaseException
        with pytest.raises(KillPoint):
            with obs_trace.span("killed"):
                raise KillPoint("simulated crash")
        (e,) = tracer.events()
        assert e["args"]["error"] == "KillPoint"

    def test_ring_buffer_bounds_and_counts_drops(self):
        t = obs_trace.Tracer(capacity=4)
        t.enabled = True
        for i in range(10):
            t.instant(f"e{i}")
        ev = t.events()
        assert len(ev) == 4
        assert [e["name"] for e in ev] == ["e6", "e7", "e8", "e9"]
        assert t.dropped == 6

    def test_annotate_and_nested_instant(self, tracer):
        with obs_trace.span("s") as s:
            s.annotate(rows=7)
            s.event("mark", k=1)
        names = {e["name"]: e for e in tracer.events()}
        assert names["s"]["args"]["rows"] == 7
        assert names["mark"]["ph"] == "i"
        assert names["mark"]["args"] == {"k": 1}


# ---------------------------------------------------------------------------
# Perfetto export + instrumented facade
# ---------------------------------------------------------------------------


def _validate_chrome_trace(doc):
    assert set(doc) >= {"traceEvents", "displayTimeUnit", "metadata"}
    for e in doc["traceEvents"]:
        assert {"name", "ph", "ts", "pid"} <= set(e), e
        assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
        if e["ph"] == "X":
            assert e["dur"] >= 0 and "tid" in e
        elif e["ph"] == "i":
            assert e["s"] == "t" and "tid" in e
        else:
            assert e["ph"] == "C", e


class TestPerfettoExport:
    def test_facade_trace_nests_and_exports(self, tracer, tmp_path):
        idx, queries = _index(autotune="off")
        idx.region(queries)
        idx.knn(queries[:, :2][:4], 3)
        path = tmp_path / "trace.json"
        tracer.export_chrome_trace(path)
        doc = json.loads(path.read_text())
        _validate_chrome_trace(doc)
        assert doc["metadata"]["dropped_events"] == 0
        by_name = {}
        for e in doc["traceEvents"]:
            by_name.setdefault(e["name"], []).append(e)
        assert "index.region" in by_name and "backend.pallas" in by_name
        assert "index.knn" in by_name
        region = by_name["index.region"][0]
        backend = by_name["backend.pallas"][0]
        # facade span contains the backend span on the same thread
        assert region["tid"] == backend["tid"]
        assert region["ts"] <= backend["ts"]
        assert (backend["ts"] + backend["dur"]
                <= region["ts"] + region["dur"] + 1e-6)
        assert region["args"]["backend"] == "pallas"

    def test_degradation_rungs_appear_as_span_errors_and_instants(
            self, tracer):
        data = datasets.uniform_squares(200, seed=31)
        queries = datasets.region_queries(data, 8, seed=32)
        plan = FaultPlan(fail_launches=10**9, fail_rungs=("pallas",))
        idx = SpatialIndex.build(
            data, backend="serve", fault_plan=plan,
            query_block=4, cache_size=0, backoff=0.0,
        )
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            idx.region(queries)
        ev = tracer.events()
        failed = [e for e in ev if e["name"] == "serve.rung"
                  and e["args"].get("error") == "InjectedFailure"]
        assert failed and all(e["ph"] == "X" for e in failed)
        assert all(e["args"]["rung"] == "pallas" for e in failed)
        degrades = [e for e in ev if e["name"] == "serve.degrade"]
        assert degrades and degrades[0]["ph"] == "i"
        assert degrades[0]["args"]["from"] == "pallas"
        assert degrades[0]["args"]["to"] == "lax"
        # the lax rung then answered: a clean serve.rung span exists
        ok = [e for e in ev if e["name"] == "serve.rung"
              and "error" not in e["args"] and e["args"]["rung"] == "lax"]
        assert ok


# ---------------------------------------------------------------------------
# the counter ledger: production == bench, bit for bit
# ---------------------------------------------------------------------------


class TestLaunchLedger:
    def test_region_report_matches_bench_disclosure_bit_for_bit(
            self, ledger):
        idx, queries = _index(autotune="off", stream=True,
                              precision="compact")
        res = idx.region(queries)
        rep = res.launch_report
        assert rep is not None and rep.kind == "compact" and rep.stream
        assert rep.backend == "pallas"

        # the bench's computation, reproduced independently from the
        # SAME artifacts (benchmarks/jax_bench.py::bench_stream_scan)
        q16 = idx.artifacts.quantized
        sched = idx.artifacts.schedule
        g16 = np.asarray(q16.mbr_q, np.int64)
        p16 = np.asarray(q16.parent_q, np.int64)
        qq = obs_counters.quantize_queries_grid(
            queries, q16.origin, q16.inv_cell, q16.cells)
        win_off, win_w = ops.parent_windows(
            p16, np.asarray(sched.n_real, np.int64), block_w=128)
        tile_b, mask_b, fetched, n_tiles, surv = \
            obs_counters.stream_fetch_bytes(
                g16, p16, qq, win_off, win_w, block_w=128,
                root_unconditional=sched.root_unconditional,
            )
        assert rep.bytes_streamed == tile_b          # bit for bit
        assert rep.mask_bytes == mask_b
        assert rep.tiles_fetched == fetched
        assert rep.tiles_total == n_tiles
        assert rep.survivors_per_level == surv
        assert rep.queries == queries.shape[0]
        # the survivors ledger IS the kernel's own visit accounting
        assert surv == tuple(int(x) for x in
                             np.asarray(res.visits_per_level).sum(axis=0))

    def test_reports_fold_into_access_stats(self, ledger):
        idx, queries = _index(autotune="off", stream=True,
                              precision="compact")
        per_call = idx.region(queries).launch_report
        idx.region(queries)
        s = idx.stats
        assert s.launch_reports == 2
        assert s.bytes_streamed == 2 * per_call.bytes_streamed
        assert s.mask_bytes == 2 * per_call.mask_bytes
        assert s.tiles_fetched == 2 * per_call.tiles_fetched
        assert s.tiles_skipped == 2 * per_call.tiles_skipped

    def test_no_collection_no_report(self):
        obs_counters.collect_launch_reports(False)
        idx, queries = _index(autotune="off", stream=True,
                              precision="compact")
        res = idx.region(queries)
        assert res.launch_report is None
        assert idx.stats.launch_reports == 0

    def test_merge_reports_sums_and_adds_survivors(self):
        a = obs_counters.LaunchReport("compact", True, 4, 128, 100.0,
                                      mask_bytes=10.0, tiles_fetched=3,
                                      tiles_total=8,
                                      survivors_per_level=(1, 2))
        b = obs_counters.LaunchReport("compact", True, 4, 128, 50.0,
                                      mask_bytes=5.0, tiles_fetched=2,
                                      tiles_total=8,
                                      survivors_per_level=(3, 4))
        m = obs_counters.merge_reports([a, b])
        assert m.queries == 8 and m.launches == 2
        assert m.bytes_streamed == 150.0 and m.mask_bytes == 15.0
        assert m.tiles_fetched == 5 and m.tiles_total == 16
        assert m.tiles_skipped == 11
        assert m.survivors_per_level == (4, 6)
        assert obs_counters.merge_reports([]) is None
        d = m.to_dict()
        assert d["tiles_skipped"] == 11
        assert d["survivors_per_level"] == [4, 6]


# ---------------------------------------------------------------------------
# AccessStats snapshots / deltas
# ---------------------------------------------------------------------------


class TestAccessStatsDict:
    def test_to_dict_and_diff(self):
        idx, queries = _index(autotune="off")
        idx.region(queries)
        before = idx.stats.to_dict()
        assert before["queries"] == queries.shape[0]
        assert isinstance(before["rung_dispatches"], dict)
        idx.region(queries)
        delta = idx.stats.diff(before)
        assert delta["queries"] == queries.shape[0]
        assert delta["node_accesses"] > 0
        # diff accepts the live object too
        assert idx.stats.diff(idx.stats)["queries"] == 0


# ---------------------------------------------------------------------------
# histograms
# ---------------------------------------------------------------------------


class TestLatencyHistogram:
    def test_quantile_clamps_out_of_range_q(self):
        h = LatencyHistogram()
        assert h.quantile(0.5) == 0.0  # empty
        for v in (0.001, 0.002, 0.004, 0.008):
            h.record(v)
        assert h.quantile(-1.0) == h.quantile(0.0)
        assert h.quantile(1.0) == h.max
        assert h.quantile(2.0) == h.max

    def test_merge_and_to_dict_roundtrip_counts(self):
        a, b = LatencyHistogram(), LatencyHistogram()
        for v in (0.001, 0.004):
            a.record(v)
        for v in (0.002, 0.064):
            b.record(v)
        out = a.merge(b)
        assert out is a
        assert a.n == 4
        assert a.max == pytest.approx(0.064)
        assert a.total == pytest.approx(0.071)
        d = a.to_dict()
        assert d["n"] == 4
        assert sum(d["counts"].values()) == 4

    def test_merge_rejects_mismatched_buckets(self):
        a = LatencyHistogram()
        b = LatencyHistogram(lo=1e-3)
        with pytest.raises(ValueError, match="merge"):
            a.merge(b)


# ---------------------------------------------------------------------------
# metrics registry + exposition
# ---------------------------------------------------------------------------

_PROM_SAMPLE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? -?[0-9].*$")


def _check_prometheus(text):
    seen_type = set()
    for line in text.strip().splitlines():
        if line.startswith("# HELP"):
            continue
        if line.startswith("# TYPE"):
            _, _, fam, mtype = line.split(maxsplit=3)
            assert mtype in ("counter", "gauge", "summary"), line
            seen_type.add(fam)
            continue
        assert _PROM_SAMPLE.match(line), f"malformed sample: {line!r}"
        fam = re.split(r"[{ ]", line)[0]
        base = re.sub(r"_(sum|count)$", "", fam)
        assert fam in seen_type or base in seen_type, \
            f"sample before TYPE: {line!r}"


class TestMetrics:
    def test_registry_families_and_escaping(self):
        reg = obs_metrics.MetricsRegistry()
        reg.counter("ops total", 3, labels={"tenant": 'a"b\\c'},
                    help="ops")
        reg.gauge("depth", 2.5)
        text = reg.to_prometheus()
        _check_prometheus(text)
        assert 'repro_ops_total{tenant="a\\"b\\\\c"} 3' in text
        assert "repro_depth 2.5" in text
        with pytest.raises(ValueError, match="registered as"):
            reg.gauge("ops total", 1)

    def test_index_metrics_snapshot(self):
        idx, queries = _index(autotune="off")
        idx.region(queries)
        reg = idx.metrics(tenant="t0")
        text = reg.to_prometheus()
        _check_prometheus(text)
        assert 'repro_index_queries{tenant="t0"} 8' in text
        assert 'repro_index_launches{tenant="t0"} 1' in text
        doc = reg.to_json()
        names = {m["name"] for m in doc["metrics"]}
        assert "repro_index_queries" in names

    def test_front_end_metrics_with_per_tenant_quantiles(self):
        data = np.asarray(
            datasets.uniform_squares(160, seed=51), np.float32)
        cfg = ServerConfig.from_dict({
            "tenants": [{"name": "a", "backend": "host"},
                        {"name": "b", "backend": "host"}],
            "classes": [{"name": "interactive", "deadline_ms": 50.0,
                         "overload": "shed", "max_queue": 64}],
            "query_block": 4,
        })
        front = ServingFrontEnd.build(cfg, {"a": data, "b": data})
        rect = np.array([0.0, 0.0, 50.0, 50.0], np.float32)
        for tenant in ("a", "b"):
            for _ in range(4):
                front.submit(tenant, "region", rect)
        front.drain()
        text = front.metrics().to_prometheus()
        _check_prometheus(text)
        assert "repro_serve_submitted 8" in text
        assert "repro_serve_completed 8" in text
        for tenant in ("a", "b"):
            for q in ("0.5", "0.99", "0.999"):
                assert (f'repro_serve_tenant_latency_seconds{{'
                        f'quantile="{q}",tenant="{tenant}"}}') in text
            assert (f'repro_index_queries{{tenant="{tenant}"}} 4'
                    in text)
        assert 'slo_class="interactive"' in text
