"""Checkpoint: roundtrip, atomicity, keep-k, resume metadata."""
import numpy as np
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager


def tree():
    return {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16), "step": jnp.asarray(3)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    t = tree()
    mgr.save(7, t, {"loss": 1.5})
    assert mgr.latest_step() == 7
    r = mgr.restore(7, t)
    np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(t["a"]))
    assert r["nested"]["b"].dtype == jnp.bfloat16
    assert mgr.metadata(7)["loss"] == 1.5


def test_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree())
    assert sorted(mgr.all_steps()) == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, tree())
    mgr.wait()
    assert mgr.latest_step() == 1


def test_no_tmp_dirs_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    mgr.save(5, tree())
    leftovers = [p for p in tmp_path.iterdir() if p.name.startswith("tmp.")]
    assert not leftovers
