"""End-to-end behaviour: training converges; serving generates; the
mqr-sparse serve path works; the mini dry-run compiles on 8 virtual devices."""
import subprocess
import sys


def test_training_loss_decreases():
    from repro.launch.train import train

    losses = train(arch="llama32_1b", smoke=True, steps=60, batch=8, seq=64,
                   log_every=0, lr=2e-3, d_model=128, n_layers=2)
    first, last = losses[:10].mean(), losses[-10:].mean()
    assert last < first - 0.5, (first, last)


def test_serve_generates():
    from repro.launch.serve import serve

    out = serve(arch="llama32_1b", smoke=True, batch=2, prompt_len=16, gen=8)
    assert out.shape == (2, 8)


def test_serve_mqr_sparse_path():
    from repro.launch.serve import serve

    out = serve(arch="llama32_1b", smoke=True, batch=1, prompt_len=16, gen=8,
                mqr_sparse=True)
    assert out.shape == (1, 8)


def test_mini_dryrun_8_devices():
    """Production-mesh machinery on an 8-device host mesh (subprocess so the
    forced device count cannot leak into this test process)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import registry
from repro.launch import steps
from repro.optim import adamw
from repro.sharding import rules
import dataclasses

cfg = registry.get_config("llama32_1b", smoke=True)
cfg = dataclasses.replace(cfg, remat=False)
mesh = jax.make_mesh((4, 2), ("data", "model"))
params_abs = steps.abstract_params(cfg)
params_sh = rules.param_shardings(params_abs, mesh)
opt_cfg = adamw.AdamWConfig()
opt_abs = steps.abstract_opt_state(params_abs, opt_cfg)
opt_sh = adamw.AdamWState(step=NamedSharding(mesh, P()),
    m=rules.param_shardings(params_abs, mesh),
    v=rules.param_shardings(params_abs, mesh))
import jax.numpy as jnp
batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
batch_sh = rules.batch_shardings(batch, mesh)
fn = steps.make_train_step(cfg, opt_cfg)
with mesh:
    compiled = jax.jit(fn, in_shardings=(params_sh, opt_sh, batch_sh)).lower(
        params_abs, opt_abs, batch).compile()
assert compiled.memory_analysis() is not None
print("MINI-DRYRUN-OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={**__import__('os').environ,
                            "PYTHONPATH": "src"})
    assert "MINI-DRYRUN-OK" in r.stdout, r.stderr[-2000:]
