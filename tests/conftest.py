import functools
import os
import sys
import zlib

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import datasets  # noqa: E402

# ---------------------------------------------------------------------------
# Shared random-MBR dataset builders (one copy for every suite).
#
# Seeds derive from (module, kind, salt) so each consuming module gets its
# own deterministic stream: suites no longer share module-level RNG state
# or silently reuse one another's arrays, and adding a dataset to one
# module cannot reorder another's data.  ``salt`` is for CI matrix legs
# (e.g. REPRO_JOIN_SEED) that want whole fresh datasets per leg.
# ---------------------------------------------------------------------------

DATASET_KINDS = ("exponential_squares", "uniform_points", "uniform_squares")


def derived_seed(module: str, tag: str, salt: int = 0) -> int:
    """Deterministic per-(module, tag, salt) seed, stable across runs."""
    return zlib.crc32(f"{module}:{tag}:{salt}".encode()) % (2 ** 31)


@functools.lru_cache(maxsize=None)
def mbr_dataset(module: str, kind: str, n: int, salt: int = 0) -> np.ndarray:
    """Build (and cache) one of the canonical random-MBR datasets —
    ``kind`` is a ``repro.core.datasets`` builder name."""
    return getattr(datasets, kind)(n, seed=derived_seed(module, kind, salt))


@functools.lru_cache(maxsize=None)
def dataset_queries(module: str, kind: str, n: int, n_queries: int = 6,
                    salt: int = 0) -> np.ndarray:
    """Region queries targeted at the matching cached dataset."""
    return datasets.region_queries(
        mbr_dataset(module, kind, n, salt), n_queries,
        seed=derived_seed(module, f"{kind}/queries", salt),
    ).astype(np.float32)


def f32_exact(a) -> np.ndarray:
    """Snap coordinates to float32-representable values so host (f64)
    and device (f32) comparisons agree bit-for-bit at box boundaries."""
    return np.float64(np.float32(a))
