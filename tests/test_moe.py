"""MoE: einsum vs scatter dispatch parity, capacity, load stats."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import moe as moe_mod


def _cfg(**kw):
    cfg = registry.get_config("granite_moe_1b", smoke=True)
    return dataclasses.replace(cfg, **kw)


def test_einsum_vs_scatter_dispatch_parity():
    cfg_e = _cfg(moe_dispatch="einsum")
    cfg_s = _cfg(moe_dispatch="scatter")
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg_e, cfg_e.d_model)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg_e.d_model), jnp.float32)
    # high capacity so no token drops differ
    y_e, aux_e = moe_mod.moe_ffn(params, cfg_e, x, capacity_factor=4.0)
    y_s, aux_s = moe_mod.moe_ffn(params, cfg_s, x, capacity_factor=4.0)
    np.testing.assert_allclose(
        np.asarray(y_e, np.float32), np.asarray(y_s, np.float32), atol=2e-2, rtol=2e-2
    )
    np.testing.assert_allclose(
        np.asarray(aux_e["expert_load"]), np.asarray(aux_s["expert_load"]), atol=1e-6
    )


def test_load_stats_sum_to_topk_fraction():
    cfg = _cfg()
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model), jnp.float32)
    _, aux = moe_mod.moe_ffn(params, cfg, x, capacity_factor=8.0)
    total = float(aux["expert_load"].sum())
    assert abs(total - cfg.experts_per_tok) < 0.05, total


def test_capacity_drops_tokens_but_stays_finite():
    cfg = _cfg()
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, cfg.d_model), jnp.float32)
    y, _ = moe_mod.moe_ffn(params, cfg, x, capacity_factor=0.25)
    assert bool(jnp.isfinite(y).all())


def test_sigmoid_router_deepseek_flavour():
    cfg = _cfg(router_kind="sigmoid", n_shared_experts=1)
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 32, cfg.d_model), jnp.float32)
    y, aux = moe_mod.moe_ffn(params, cfg, x)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
