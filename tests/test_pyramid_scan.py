"""Fused pyramid_scan kernel == host pointer search, exactly.

The acceptance contract of DESIGN.md §3.3: the single-launch fused sweep
returns bit-identical object result sets AND per-level access counts to
the host pointer search (`MQRTree.region_search` / `RTree.region_search`)
and to the levelized JAX search (`flat.region_search_batch`), across
dataset shapes including the paper's zero-overlap point-data case.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import bulk, datasets, flat, mqrtree, rtree
from repro.core import mbr as M
from repro.kernels import ops
from repro.kernels.ops import level_sweep


def host_search_by_level(tree, query, levels):
    """Pointer search, recording visits per depth (root = level 0)."""
    counts = np.zeros(levels, np.int64)
    found = []
    stack = [(tree.root, 0)]
    while stack:
        node, d = stack.pop()
        node_mbr = node.mbr if not callable(node.mbr) else node.mbr()
        if node_mbr is None:
            continue
        counts[d] += 1
        entries = (
            [(e.mbr, e.node, e.obj) for _, e in node.entries()]
            if hasattr(node, "locs")
            else [(e.mbr, e.child, e.obj) for e in node.entries]
        )
        for embr, child, obj in entries:
            if not M.overlaps(embr, query):
                continue
            if child is not None:
                stack.append((child, d + 1))
            else:
                found.append(obj)
    return found, counts


DATASETS = {
    "uniform_squares": lambda: datasets.uniform_squares(300, seed=5),
    # the paper's zero-overlap case: point data never overlaps (§4)
    "uniform_points": lambda: datasets.uniform_points(256, seed=2),
    "exponential_squares": lambda: datasets.exponential_squares(250, seed=9),
}


@pytest.mark.parametrize("name", sorted(DATASETS))
@pytest.mark.parametrize("builder", [mqrtree.build, rtree.build])
def test_fused_matches_host_pointer_search(name, builder):
    data = DATASETS[name]()
    tree = builder(data)
    sched = flat.level_schedule(flat.flatten(tree))
    qs = datasets.region_queries(data, 8, seed=6)
    hits, visits = ops.pyramid_scan(sched, qs)
    hits, visits = np.asarray(hits), np.asarray(visits)
    for i, q in enumerate(qs):
        found, per_level = host_search_by_level(tree, q, sched.levels)
        assert set(np.nonzero(hits[i])[0]) == set(found)
        assert np.array_equal(per_level, visits[i]), (
            f"per-level access counts diverge: {per_level} vs {visits[i]}"
        )
        # total accesses also match the tree's own accounting
        found2, total = tree.region_search(q)
        assert set(found2) == set(found) and total == visits[i].sum()


def test_fused_matches_levelized_jax_search():
    data = datasets.uniform_squares(300, seed=5)
    tree = mqrtree.build(data)
    ft = flat.flatten(tree)
    sched = flat.level_schedule(ft)
    qs = datasets.region_queries(data, 8, seed=6)
    hits_a, visits_a = ops.pyramid_scan(sched, qs)
    hits_b, visits_b = flat.region_search_batch(ft, qs)
    assert np.array_equal(np.asarray(hits_a), hits_b)
    assert np.array_equal(np.asarray(visits_a).sum(axis=1), visits_b)


def test_per_level_baseline_parity_and_launch_count():
    data = datasets.uniform_squares(300, seed=7)
    tree = mqrtree.build(data)
    sched = flat.level_schedule(flat.flatten(tree))
    qs = datasets.region_queries(data, 8, seed=8)
    hits_f, visits_f = ops.pyramid_scan(sched, qs)
    hits_l, visits_l, launches = ops.per_level_region_search(sched, qs)
    assert np.array_equal(np.asarray(hits_f), hits_l)
    assert np.array_equal(np.asarray(visits_f), visits_l)
    # the fused kernel replaces one launch per level with a single launch
    assert launches == sched.levels >= 2


def test_pyramid_schedule_matches_bulk_search():
    pts = datasets.uniform_points(256, seed=2)
    pyr = bulk.build_pyramid(jnp.asarray(pts, jnp.float32), levels=6)
    sched = flat.pyramid_schedule(pyr, pts)
    qs = datasets.region_queries(pts, 6, seed=3)
    hits, _ = ops.pyramid_scan(sched, qs)
    hits = np.asarray(hits)
    for i, q in enumerate(qs):
        ref = np.asarray(bulk.pyramid_search(pyr, jnp.asarray(q, jnp.float32)))
        assert np.array_equal(hits[i], ref)


def test_onehot_gather_matches_column_gather():
    """The MXU one-hot parent gather (TPU path) and the interpreter's
    column gather must produce the same sweep."""
    data = datasets.uniform_squares(200, seed=11)
    sched = flat.level_schedule(flat.flatten(mqrtree.build(data)))
    qs = jnp.asarray(datasets.region_queries(data, 4, seed=12), jnp.float32)
    mb, pa = jnp.asarray(sched.mbr_cm), jnp.asarray(sched.parent)
    a = level_sweep(qs, mb, pa, interpret=True, onehot_gather=True)
    b = level_sweep(qs, mb, pa, interpret=True, onehot_gather=False)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_spatial_server_transparent_and_caching():
    from repro.launch.spatial_serve import SpatialServer

    data = datasets.uniform_squares(300, seed=13)
    tree = mqrtree.build(data)
    sched = flat.level_schedule(flat.flatten(tree))
    server = SpatialServer(sched, query_block=4, cache_size=64)
    qs = datasets.region_queries(data, 6, seed=14)
    # repeated regions in the stream exercise the cache + padding paths
    stream = np.concatenate([qs, qs[:3], qs[1:2]])
    hits, visits = server.search(stream)
    ref_hits, ref_visits = ops.pyramid_scan(sched, stream)
    assert np.array_equal(hits, np.asarray(ref_hits))
    assert np.array_equal(visits, np.asarray(ref_visits))
    assert server.stats.dedup_hits == 4      # repeats within the one batch
    assert server.stats.cache_hits == 0
    assert server.stats.queries_served == 10
    # second pass: fully served from cache, no new launches
    launches = server.stats.kernel_launches
    hits2, _ = server.search(qs)
    assert np.array_equal(hits2, hits[:6])
    assert server.stats.kernel_launches == launches
    assert server.stats.cache_hits == 6


def test_spatial_server_eviction_and_disabled_cache():
    from repro.launch.spatial_serve import SpatialServer

    data = datasets.uniform_squares(200, seed=15)
    sched = flat.level_schedule(flat.flatten(mqrtree.build(data)))
    qs = datasets.region_queries(data, 16, seed=16)
    ref_hits, _ = ops.pyramid_scan(sched, qs)
    # more distinct misses than cache slots: results must not depend on
    # what the LRU evicted mid-batch
    tiny = SpatialServer(sched, query_block=4, cache_size=4)
    hits, _ = tiny.search(qs)
    assert np.array_equal(hits, np.asarray(ref_hits))
    assert len(tiny._cache) == 4
    # cache_size=0 disables caching entirely
    off = SpatialServer(sched, query_block=4, cache_size=0)
    hits0, _ = off.search(qs)
    assert np.array_equal(hits0, np.asarray(ref_hits))
    assert len(off._cache) == 0
