"""Fault tolerance: stragglers, elastic re-mesh plans, failure/resume."""
import numpy as np
import pytest

from repro.ft import StragglerMonitor, plan_mesh
from repro.ft.failures import InjectedFailure


def test_straggler_flags_outliers():
    mon = StragglerMonitor(window=20, ratio_threshold=2.0, min_samples=5)
    rng = np.random.default_rng(0)
    flags = 0
    for s in range(100):
        t = 0.1 + rng.normal(0, 0.005)
        if s in (50, 80):
            t = 0.5  # injected straggler
        flags += bool(mon.observe(s, t))
    assert flags == 2
    assert len(mon.events) == 2
    assert mon.events[0].step == 50


def test_straggler_does_not_poison_window():
    mon = StragglerMonitor(window=10, ratio_threshold=2.0, min_samples=5)
    for s in range(20):
        mon.observe(s, 0.1)
    assert mon.observe(20, 1.0)
    assert mon.observe(21, 1.0)  # still flagged: median unchanged


@pytest.mark.parametrize(
    "avail,shape", [(512, (2, 16, 16)), (256, (16, 16)), (496, (31, 16)), (130, (8, 16))]
)
def test_elastic_plan(avail, shape):
    plan = plan_mesh(avail, model_parallel=16)
    assert plan.shape == shape
    assert plan.n_devices == np.prod(shape)
    assert plan.dropped == avail - plan.n_devices


def test_elastic_plan_too_small():
    with pytest.raises(ValueError):
        plan_mesh(7, model_parallel=16)


def test_failure_injection_and_training_resume(tmp_path):
    """Train crashes at an injected step, restarts, and resumes from ckpt."""
    from repro.launch.train import train

    with pytest.raises(InjectedFailure):
        train(arch="llama32_1b", smoke=True, steps=30, batch=2, seq=32,
              ckpt_dir=str(tmp_path), ckpt_every=10, log_every=0,
              fail_at_step=15, d_model=64, n_layers=2)
    # restart: resumes from step 10 and completes
    losses = train(arch="llama32_1b", smoke=True, steps=30, batch=2, seq=32,
                   ckpt_dir=str(tmp_path), ckpt_every=10, log_every=0,
                   d_model=64, n_layers=2)
    assert len(losses) == 20  # 30 - resumed 10
