"""Device bulk build == host pyramid_schedule, bit for bit.

The acceptance contract of DESIGN.md §7: the one-launch Pallas build
kernel (and its jit'd jnp engine) emits a ``LevelSchedule`` identical to
the host ``flat.pyramid_schedule(bulk.build_pyramid(...))`` lowering on
every parity-matrix dataset shape — so the fused scan's hit sets AND
per-level access counts are unchanged, only where the build runs moves.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import bulk, datasets, flat
from repro.index import SpatialIndex
from repro.kernels import ops
from repro.kernels.ops import build_levels_pallas

DATASETS = {
    "uniform_squares": lambda: datasets.uniform_squares(300, seed=5),
    # the paper's zero-overlap case: degenerate point MBRs (§4)
    "uniform_points": lambda: datasets.uniform_points(256, seed=2),
    "exponential_squares": lambda: datasets.exponential_squares(250, seed=9),
}

SCHEDULE_FIELDS = (
    "mbr_cm", "parent", "n_real", "obj_mbr", "obj_level", "obj_slot", "obj_id"
)


def host_schedule(data, levels):
    pyr = bulk.build_pyramid(jnp.asarray(data, jnp.float32), levels=levels)
    return flat.pyramid_schedule(pyr, np.asarray(data, np.float32))


@pytest.mark.parametrize("name", sorted(DATASETS))
@pytest.mark.parametrize("engine", ["jnp", "pallas"])
def test_device_schedule_matches_host_lowering(name, engine):
    data = DATASETS[name]()
    levels = bulk.default_levels(data.shape[0])
    host = host_schedule(data, levels)
    dev = ops.device_schedule(data, levels=levels, engine=engine,
                              interpret=True)
    for f in SCHEDULE_FIELDS:
        assert np.array_equal(getattr(host, f), getattr(dev, f)), (
            f"device build field {f} diverges from host lowering ({engine})"
        )
    assert dev.n_objects == host.n_objects
    assert dev.root_unconditional == host.root_unconditional is False
    assert dev.test_object_mbr == host.test_object_mbr is False


@pytest.mark.parametrize("name", sorted(DATASETS))
def test_device_schedule_scan_parity(name):
    """Fused-scan hit sets and per-level access counts over the device
    schedule are bit-identical to the host pyramid path."""
    data = DATASETS[name]()
    levels = bulk.default_levels(data.shape[0])
    qs = datasets.region_queries(data, 8, seed=6)
    h_hits, h_visits = ops.pyramid_scan(host_schedule(data, levels), qs)
    d_hits, d_visits = ops.pyramid_scan(
        ops.device_schedule(data, levels=levels), qs
    )
    assert np.array_equal(np.asarray(h_hits), np.asarray(d_hits))
    assert np.array_equal(np.asarray(h_visits), np.asarray(d_visits))


def test_build_kernel_onehot_matches_gather():
    """The MXU one-hot segment/densify path (TPU lowering) and the
    interpreter's gather path must emit the same build."""
    data = datasets.uniform_squares(300, seed=5).astype(np.float32)
    a = build_levels_pallas(jnp.asarray(data), levels=6, interpret=True,
                            onehot_gather=True)
    b = build_levels_pallas(jnp.asarray(data), levels=6, interpret=True,
                            onehot_gather=False)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("n", [1, 2, 130, 257])
def test_build_kernel_edge_sizes(n):
    """Non-lane-multiple and degenerate object counts stay bit-identical
    across engines (padding lanes must never leak into the schedule)."""
    data = datasets.uniform_points(n, seed=1)
    a = ops.device_schedule(data, engine="pallas", interpret=True)
    b = ops.device_schedule(data, engine="jnp")
    for f in SCHEDULE_FIELDS:
        assert np.array_equal(getattr(a, f), getattr(b, f)), (n, f)


def test_facade_device_build_parity_and_strictness():
    data = DATASETS["uniform_squares"]()
    qs = datasets.region_queries(data, 6, seed=6)
    ref = SpatialIndex.build(data, structure="pyramid", backend="host")
    refr = ref.region(qs)
    for backend in ("host", "lax", "pallas", "serve"):
        idx = SpatialIndex.build(
            data, structure="pyramid", backend=backend, build="device"
        )
        res = idx.region(qs)
        assert np.array_equal(res.hits, refr.hits), backend
        assert np.array_equal(res.visits_per_level, refr.visits_per_level)
    # device build is a pyramid-only option; pointer structures refuse it
    with pytest.raises(TypeError, match="does not accept"):
        SpatialIndex.build(data, structure="mqr", build="device")
    with pytest.raises(ValueError, match="unknown build"):
        SpatialIndex.build(data, structure="pyramid", build="gpu")


def test_extend_flush_always_is_the_legacy_rebuild():
    """flush="always" on a never-mutated index reproduces the old eager
    re-build bit-for-bit: fresh artifacts, no live-update state."""
    base = datasets.uniform_squares(200, seed=5)
    more = datasets.uniform_squares(80, seed=77)
    qs = datasets.region_queries(np.concatenate([base, more]), 6, seed=6)
    idx = SpatialIndex.build(
        base, structure="pyramid", backend="pallas", build="device"
    )
    ext = idx.extend(more, flush="always")
    assert ext.n_objects == 280
    assert ext.backend == "pallas" and ext.structure == "pyramid"
    assert ext._updates is None  # pristine: no update log attached
    fresh = SpatialIndex.build(
        np.concatenate([base, more]), structure="pyramid",
        backend="pallas", build="device",
    )
    a, b = ext.region(qs), fresh.region(qs)
    assert np.array_equal(a.hits, b.hits)
    assert np.array_equal(a.visits_per_level, b.visits_per_level)
    # the original index is untouched
    assert idx.n_objects == 200
    # extend works on pointer structures too (host re-build)
    mq = SpatialIndex.build(base, structure="mqr", backend="pallas")
    mq2 = mq.extend(more, flush="always")
    assert mq2.n_objects == 280
    ref = SpatialIndex.build(
        np.concatenate([base, more]), structure="mqr", backend="host"
    ).region(qs)
    assert np.array_equal(mq2.region(qs).hits, ref.hits)
    with pytest.raises(ValueError, match="unknown flush"):
        idx.extend(more, flush="eventually")


def test_extend_default_routes_through_the_delta_buffer():
    """Default extend buffers the batch (no rebuild) yet answers the same
    hit-id sets as a fresh build over the concatenated objects."""
    base = datasets.uniform_squares(200, seed=5)
    more = datasets.uniform_squares(80, seed=77)
    qs = datasets.region_queries(np.concatenate([base, more]), 6, seed=6)
    idx = SpatialIndex.build(
        base, structure="pyramid", backend="pallas", build="device"
    )
    ext = idx.extend(more)
    assert ext.n_objects == 280
    assert idx.n_objects == 200 and idx._updates is None  # source untouched
    assert ext._updates is not None and ext._updates.n_delta == 80
    assert ext._updates.flushes == 0  # buffered, not rebuilt
    fresh = SpatialIndex.build(
        np.concatenate([base, more]), structure="pyramid",
        backend="pallas", build="device",
    )
    a, b = ext.region(qs), fresh.region(qs)
    for i in range(qs.shape[0]):
        assert np.array_equal(a.ids(i), b.ids(i))
    # per-query delta-side accesses are reported separately
    assert a.base_levels == idx.schedule.levels
    assert int(a.delta_visits.sum()) == int(ext.stats.delta_accesses)
