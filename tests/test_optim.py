"""AdamW / schedule / clipping / EF-int8 compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamWConfig, adamw
from repro.optim.compress import ef_int8_compress, ef_int8_state


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=200)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init_state(params, cfg)
    loss = lambda p: jnp.sum((p["w"] - jnp.array([1.0, 2.0])) ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply_updates(params, g, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 2.0], atol=0.05)


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, gnorm = adamw.clip_by_global_norm(g, 1.0)
    assert float(gnorm) > 100
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-4


def test_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(adamw.lr_schedule(cfg, s)) for s in range(101)]
    assert lrs[0] < lrs[9] <= lrs[10] * 1.001
    assert max(lrs) <= 1e-3 + 1e-9
    assert abs(lrs[100] - 1e-4) < 1e-6


def test_ef_int8_error_feedback_is_lossless_over_time():
    """Sum of dequantized grads + final residual == sum of true grads."""
    key = jax.random.PRNGKey(0)
    grads = [
        {"w": jax.random.normal(jax.random.fold_in(key, i), (64,)) * (i + 1)}
        for i in range(10)
    ]
    ef = ef_int8_state(grads[0])
    total_sent = jnp.zeros((64,))
    for g in grads:
        sent, ef = ef_int8_compress(g, ef)
        total_sent = total_sent + sent["w"]
    total_true = sum(g["w"] for g in grads)
    drift = total_sent + ef["w"] - total_true
    np.testing.assert_allclose(np.asarray(drift), 0.0, atol=1e-3)
    # compression is coarse per step but bounded
    assert float(jnp.abs(ef["w"]).max()) < 0.2
