"""HBM-streaming fused sweep == VMEM-resident sweep, bit for bit.

DESIGN.md §12's contract: the double-buffered streaming schedule of
``pyramid_scan(..., stream=True)`` — MBR tiles DMA'd HBM→VMEM two slots
deep while the previous tile computes, survivor masks ping-ponged through
HBM scratch windows — changes WHERE bytes live, never WHAT the sweep
computes.  Hits AND per-level visit counts stay bit-identical to the
VMEM path on every dataset shape × structure × engine rung (fused kernel,
lax twin, numpy twin), including Hilbert-permuted schedules (which
exercise the conservative full-width window fallback) and live delta
levels on the memory-bounded twins.
"""
import numpy as np
import pytest

import jax.numpy as jnp

import conftest
from repro.index import SpatialIndex
from repro.kernels import fallback, ops

_SIZES = {
    "uniform_squares": 300,
    # the paper's zero-overlap case: degenerate point MBRs (§4)
    "uniform_points": 256,
    "exponential_squares": 250,
}
STRUCTURES = ("mqr", "rtree", "pyramid")


def _data(name):
    return conftest.mbr_dataset("test_stream_scan", name, _SIZES[name])


def _queries(name):
    return conftest.dataset_queries("test_stream_scan", name, _SIZES[name])


def _schedule(name, structure):
    idx = SpatialIndex.build(_data(name), structure=structure, backend="pallas")
    return idx.artifacts.schedule


# ---------------------------------------------------------------------------
# The fused kernel: streamed == VMEM on the full matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(_SIZES))
@pytest.mark.parametrize("structure", STRUCTURES)
def test_stream_kernel_bit_identical(name, structure):
    sched = _schedule(name, structure)
    qs = _queries(name)
    hits, visits = ops.pyramid_scan(sched, qs, interpret=True)
    s_hits, s_visits = ops.pyramid_scan(sched, qs, interpret=True, stream=True)
    assert np.array_equal(np.asarray(s_hits), np.asarray(hits))
    assert np.array_equal(np.asarray(s_visits), np.asarray(visits))


@pytest.mark.parametrize("block_w", [64, 256])
def test_stream_kernel_block_w_invariant(block_w):
    """Tile width changes the DMA schedule (number of steps, window
    rounding), never the answers."""
    sched = _schedule("uniform_squares", "mqr")
    qs = _queries("uniform_squares")
    hits, visits = ops.pyramid_scan(sched, qs, interpret=True)
    s_hits, s_visits = ops.pyramid_scan(
        sched, qs, interpret=True, stream=True, block_w=block_w
    )
    assert np.array_equal(np.asarray(s_hits), np.asarray(hits))
    assert np.array_equal(np.asarray(s_visits), np.asarray(visits))


@pytest.mark.parametrize("structure", STRUCTURES)
def test_stream_compact_bit_identical(structure):
    """Streaming composes with the uint16 compact form: same integer
    sweep, tiles just arrive by DMA."""
    sched = _schedule("uniform_squares", structure)
    qs = _queries("uniform_squares")
    qsched = ops.quantize_schedule(sched, interpret=True)
    hits, visits = ops.pyramid_scan_compact(qsched, qs, interpret=True)
    s_hits, s_visits = ops.pyramid_scan_compact(
        qsched, qs, interpret=True, stream=True
    )
    assert np.array_equal(np.asarray(s_hits), np.asarray(hits))
    assert np.array_equal(np.asarray(s_visits), np.asarray(visits))


def test_stream_hilbert_full_width_window():
    """A Hilbert-permuted schedule scatters parents, forcing the streamed
    survivor window to its conservative full-width fallback — answers
    must still be bit-identical."""
    data = _data("uniform_squares")
    qs = _queries("uniform_squares")
    plain = SpatialIndex.build(data, structure="mqr", backend="pallas")
    hil = SpatialIndex.build(
        data, structure="mqr", backend="pallas", order="hilbert",
        backend_opts={"stream": True},
    )
    ref = plain.region(qs)
    res = hil.region(qs)
    assert np.array_equal(res.hits, ref.hits)
    assert np.array_equal(res.visits_per_level, ref.visits_per_level)


# ---------------------------------------------------------------------------
# parent_windows: the host-side window plan the DMA schedule trusts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("structure", STRUCTURES)
@pytest.mark.parametrize("block_w", [64, 128])
def test_parent_windows_cover_all_real_parents(structure, block_w):
    """Every real slot's parent row lies inside its tile's declared
    window — the invariant that makes the windowed survivor gather safe."""
    sched = _schedule("uniform_squares", structure)
    win_off, win_w = ops.parent_windows(
        sched.parent, sched.n_real, block_w=block_w
    )
    levels, width = sched.parent.shape
    n_tiles = win_off.shape[1]
    assert win_off.shape == (levels, n_tiles)
    for l in range(1, levels):
        nr = int(sched.n_real[l])
        for t in range(n_tiles):
            s0, s1 = t * block_w, min((t + 1) * block_w, nr)
            if s0 >= nr:
                continue
            parents = np.asarray(sched.parent[l, s0:s1], np.int64)
            off = int(win_off[l, t])
            assert (parents >= off).all() and (parents < off + win_w).all()


def test_stream_requires_windows_at_kernel_level():
    """The private sweep refuses stream=True without a window plan (the
    public wrappers always compute one)."""
    sched = _schedule("uniform_squares", "mqr")
    qs = _queries("uniform_squares")
    from repro.kernels.ops import level_sweep

    with pytest.raises(ValueError, match="win_off"):
        level_sweep(
            jnp.asarray(qs), jnp.asarray(sched.mbr_cm),
            jnp.asarray(sched.parent), interpret=True, stream=True,
        )


# ---------------------------------------------------------------------------
# Degradation twins: the memory-bounded streamed sweep (lax and numpy)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(_SIZES))
def test_twin_stream_parity_float32(name):
    sched = _schedule(name, "mqr")
    qs = _queries(name)
    args = (
        sched.mbr_cm, sched.parent, sched.obj_mbr, sched.obj_level,
        sched.obj_slot, sched.obj_id,
    )
    kwargs = dict(
        n_objects=sched.n_objects,
        root_unconditional=sched.root_unconditional,
        test_object_mbr=sched.test_object_mbr,
    )
    for fn in (fallback.fused_search_lax, fallback.fused_search_np):
        h0, v0 = fn(qs, *args, **kwargs)
        h1, v1 = fn(qs, *args, stream=True, **kwargs)
        assert np.array_equal(np.asarray(h1), np.asarray(h0))
        assert np.array_equal(np.asarray(v1), np.asarray(v0))


def test_twin_stream_parity_compact():
    sched = _schedule("uniform_squares", "pyramid")
    qs = _queries("uniform_squares")
    q = ops.quantize_schedule(sched, interpret=True)
    args = (
        q.mbr_q, q.parent_q, q.confirm_mbr, sched.obj_level, sched.obj_slot,
        sched.obj_id, q.origin, q.inv_cell,
    )
    kwargs = dict(
        n_objects=sched.n_objects, cells=q.cells,
        root_unconditional=sched.root_unconditional,
    )
    for fn in (fallback.fused_search_compact_lax, fallback.fused_search_compact_np):
        h0, v0 = fn(qs, *args, **kwargs)
        h1, v1 = fn(qs, *args, stream=True, **kwargs)
        assert np.array_equal(np.asarray(h1), np.asarray(h0))
        assert np.array_equal(np.asarray(v1), np.asarray(v0))


def test_twin_stream_parity_live_delta_levels():
    """Streamed twins honor the live layout: unconditional flat delta
    levels past base_levels, tombstone masking — same answers."""
    sched = _schedule("uniform_squares", "mqr")
    qs = _queries("uniform_squares")
    levels, width = sched.parent.shape
    n = sched.n_objects
    sent = np.array([np.inf, np.inf, -np.inf, -np.inf], np.float32)
    delta = np.broadcast_to(sent[None, :, None], (1, 4, width)).copy()
    delta[0, :, 0] = [0.0, 0.0, 1e9, 1e9]  # one delta row overlapping all
    mbr = np.concatenate([sched.mbr_cm, delta], 0)
    parent = np.concatenate([sched.parent, np.zeros((1, width), np.int32)], 0)
    obj_mbr = np.concatenate([sched.obj_mbr, delta[0][:, :1].T], 0)
    obj_level = np.concatenate([sched.obj_level, [levels]])
    obj_slot = np.concatenate([sched.obj_slot, [0]])
    obj_id = np.concatenate([sched.obj_id, [n]])
    alive = np.ones(n + 1, bool)
    alive[0] = False  # one tombstone
    kwargs = dict(
        n_objects=n + 1, base_levels=levels,
        root_unconditional=sched.root_unconditional,
        test_object_mbr=sched.test_object_mbr,
    )
    for fn in (fallback.fused_search_live_lax, fallback.fused_search_live_np):
        h0, v0 = fn(qs, mbr, parent, obj_mbr, obj_level, obj_slot, obj_id,
                    alive, **kwargs)
        h1, v1 = fn(qs, mbr, parent, obj_mbr, obj_level, obj_slot, obj_id,
                    alive, stream=True, **kwargs)
        assert np.array_equal(np.asarray(h1), np.asarray(h0))
        assert np.array_equal(np.asarray(v1), np.asarray(v0))
        h0 = np.asarray(h0)
        assert h0[:, n].all()      # the delta row hits every query
        assert not h0[:, 0].any()  # the tombstone never does


# ---------------------------------------------------------------------------
# Façade plumb: backend_opts carries the stream flag end to end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("structure", STRUCTURES)
@pytest.mark.parametrize("precision", ["float32", "compact"])
def test_facade_stream_matrix(structure, precision):
    data = _data("uniform_squares")
    qs = _queries("uniform_squares")
    idx = SpatialIndex.build(data, structure=structure, backend="pallas")
    ref = idx.region(qs)
    streamed = idx.with_backend(
        "pallas", stream=True, precision=precision
    ).region(qs)
    assert np.array_equal(streamed.hits, ref.hits)
    if precision == "float32":
        assert np.array_equal(streamed.visits_per_level, ref.visits_per_level)


def test_stream_compact8_rejected():
    data = _data("uniform_squares")
    with pytest.raises(ValueError, match="compact8"):
        SpatialIndex.build(
            data, backend="pallas",
            backend_opts={"stream": True, "precision": "compact8"},
        )
