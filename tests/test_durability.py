"""Durability: snapshots, the mutation WAL, and crash recovery (§9).

The acceptance contract: a process kill at ANY op index of a mixed
insert/delete/flush workload — before the WAL append, after it, after
the apply, mid-merge, or tearing the record itself — recovers via
"latest snapshot + WAL tail replay" to a live set bit-identical to a
fault-free run of the surviving op prefix, and to the host mqr oracle,
on every backend.  Exhaustive kill indices with REPRO_FT_EXHAUSTIVE=1;
sampled (seedable via REPRO_FT_SEED) otherwise.
"""

import os

import numpy as np
import pytest

from repro.checkpoint import (
    DurableIndex,
    SnapshotError,
    live_ids,
    mutation_workload,
)
from repro.core import datasets
from repro.ft import FaultPlan, KillPoint
from repro.index import SpatialIndex
from repro.update import (
    BufferFullError,
    WriteAheadLog,
    oracle,
    read_wal,
    recover_wal,
)

BACKENDS = ("host", "lax", "pallas", "serve")

EXHAUSTIVE = os.environ.get("REPRO_FT_EXHAUSTIVE") == "1"
FT_SEED = int(os.environ.get("REPRO_FT_SEED", "0"))
N_OPS = int(os.environ.get("REPRO_FT_OPS", "1000" if EXHAUSTIVE else "80"))


# ---------------------------------------------------------------------------
# WAL unit behavior
# ---------------------------------------------------------------------------


class TestWal:
    def test_roundtrip(self, tmp_path):
        p = tmp_path / "w.log"
        with WriteAheadLog(p) as w:
            w.append("insert", np.arange(8.0).reshape(2, 4))
            w.append("delete", [3, 1])
            w.append("flush")
        records, torn, _ = read_wal(p)
        assert not torn
        assert [op for op, _ in records] == ["insert", "delete", "flush"]
        assert np.array_equal(records[0][1], np.arange(8.0).reshape(2, 4))
        assert np.array_equal(records[1][1], [3, 1])
        assert records[2][1].size == 0

    def test_reopen_appends(self, tmp_path):
        p = tmp_path / "w.log"
        with WriteAheadLog(p) as w:
            w.append("delete", [1])
        with WriteAheadLog(p) as w:
            assert w.seq == 1
            w.append("delete", [2])
        records, torn, _ = read_wal(p)
        assert not torn and len(records) == 2

    def test_torn_tail_detected_and_repaired(self, tmp_path):
        p = tmp_path / "w.log"
        with WriteAheadLog(p) as w:
            w.append("insert", np.ones((1, 4)))
            w.append("delete", [0])
        whole = p.read_bytes()
        p.write_bytes(whole[:-3])  # tear the last record
        records, torn, valid_end = read_wal(p)
        assert torn and len(records) == 1
        wal, records, torn = recover_wal(p)
        wal.close()
        assert torn and len(records) == 1
        # after repair the tail is gone and appends extend cleanly
        with WriteAheadLog(p) as w:
            assert w.seq == 1
            w.append("flush")
        records, torn, _ = read_wal(p)
        assert not torn and len(records) == 2

    def test_corrupt_payload_stops_replay(self, tmp_path):
        p = tmp_path / "w.log"
        with WriteAheadLog(p) as w:
            w.append("delete", [7])
            off_ok = p.stat().st_size
            w.append("delete", [8])
        raw = bytearray(p.read_bytes())
        raw[off_ok + 10] ^= 0xFF  # flip a byte inside record 2's payload
        p.write_bytes(bytes(raw))
        records, torn, valid_end = read_wal(p)
        assert torn and len(records) == 1 and valid_end == off_ok

    def test_bad_magic_raises(self, tmp_path):
        from repro.update.wal import WalCorruption

        p = tmp_path / "w.log"
        p.write_bytes(b"NOTAWAL0" + b"x" * 32)
        with pytest.raises(WalCorruption):
            read_wal(p)

    def test_missing_file_is_empty_log(self, tmp_path):
        records, torn, _ = read_wal(tmp_path / "nope.log")
        assert records == [] and not torn

    def test_torn_write_injection(self, tmp_path):
        plan = FaultPlan(kill_at_op=0, torn_write=True)
        plan.op_event("pre-append", 0)
        w = WriteAheadLog(tmp_path / "w.log", fault_plan=plan)
        with pytest.raises(KillPoint):
            w.append("insert", np.ones((1, 4)))
        w.close()
        records, torn, _ = read_wal(tmp_path / "w.log")
        assert torn and records == []


# ---------------------------------------------------------------------------
# Snapshot save/load parity
# ---------------------------------------------------------------------------


class TestSnapshot:
    def test_save_load_parity_all_backends(self, tmp_path):
        data = datasets.uniform_squares(120, seed=0)
        queries = datasets.region_queries(data, 16, seed=1)
        pts = data[:6, :2] + 0.01
        idx = SpatialIndex.build(data, backend="pallas", capacity=24)
        idx.insert(datasets.uniform_squares(7, seed=3))
        idx.delete([2, 5, 121])
        ref = idx.region(queries)
        refk = idx.knn(pts, k=4)
        idx.save(tmp_path / "snap")
        for be in BACKENDS:
            r = SpatialIndex.load(tmp_path / "snap", backend=be)
            res = r.region(queries)
            assert np.array_equal(res.hits, ref.hits), be
            assert np.array_equal(
                res.visits_per_level, ref.visits_per_level
            ), be
            k = r.knn(pts, k=4)
            assert np.array_equal(k.ids, refk.ids), be
            assert r.n_objects == idx.n_objects
            assert r.id_space == idx.id_space

    def test_save_load_pristine_and_compact(self, tmp_path):
        data = datasets.uniform_squares(90, seed=2)
        queries = datasets.region_queries(data, 12, seed=4)
        idx = SpatialIndex.build(data, backend="pallas", precision="compact")
        ref = idx.region(queries)
        idx.save(tmp_path / "s")
        r = SpatialIndex.load(
            tmp_path / "s", backend="pallas", precision="compact"
        )
        # the quantized tiles were saved: load must not re-quantize
        assert r.artifacts._quantized is not None
        assert np.array_equal(r.region(queries).hits, ref.hits)
        assert np.array_equal(
            r.region(queries).visits_per_level, ref.visits_per_level
        )

    def test_mutation_continues_deterministically_after_load(self, tmp_path):
        data = datasets.uniform_squares(60, seed=5)
        idx = SpatialIndex.build(data, backend="host", capacity=16)
        idx.insert(datasets.uniform_squares(5, seed=6))
        idx.save(tmp_path / "s")
        r = SpatialIndex.load(tmp_path / "s", backend="host")
        batch = datasets.uniform_squares(4, seed=7)
        assert np.array_equal(idx.insert(batch), r.insert(batch))
        queries = datasets.region_queries(data, 8, seed=8)
        assert np.array_equal(
            idx.region(queries).hits, r.region(queries).hits
        )

    def test_unknown_version_rejected(self, tmp_path):
        import json

        data = datasets.uniform_squares(20, seed=0)
        SpatialIndex.build(data, backend="host").save(tmp_path / "s")
        meta = json.loads((tmp_path / "s" / "meta.json").read_text())
        meta["format_version"] = 99
        (tmp_path / "s" / "meta.json").write_text(json.dumps(meta))
        with pytest.raises(SnapshotError):
            SpatialIndex.load(tmp_path / "s", backend="host")

    def test_not_a_snapshot_rejected(self, tmp_path):
        with pytest.raises(SnapshotError):
            SpatialIndex.load(tmp_path / "empty", backend="host")


# ---------------------------------------------------------------------------
# Input hardening (every degenerate shape, build + insert, all backends)
# ---------------------------------------------------------------------------


DEGENERATE = {
    "nan": [0.1, 0.1, np.nan, 0.3],
    "posinf": [0.1, 0.1, np.inf, 0.3],
    "neginf": [-np.inf, 0.1, 0.2, 0.3],
    "inverted_x": [0.5, 0.1, 0.2, 0.3],
    "inverted_y": [0.1, 0.8, 0.2, 0.3],
}


class TestInputHardening:
    @pytest.mark.parametrize("shape", sorted(DEGENERATE))
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_build_rejects(self, shape, backend):
        data = datasets.uniform_squares(12, seed=0)
        bad = np.concatenate([data, [DEGENERATE[shape]]], axis=0)
        with pytest.raises(ValueError, match="non-finite|inverted"):
            SpatialIndex.build(bad, backend=backend)

    @pytest.mark.parametrize("shape", sorted(DEGENERATE))
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_insert_rejects(self, shape, backend):
        idx = SpatialIndex.build(
            datasets.uniform_squares(12, seed=0), backend=backend
        )
        before = idx.n_objects
        with pytest.raises(ValueError, match="non-finite|inverted"):
            idx.insert([DEGENERATE[shape]])
        assert idx.n_objects == before  # nothing half-applied

    def test_degenerate_point_is_valid(self):
        idx = SpatialIndex.build(
            datasets.uniform_squares(12, seed=0), backend="host"
        )
        idx.insert([[0.5, 0.5, 0.5, 0.5]])  # lo == hi: a point, accepted
        assert idx.n_objects == 13

    def test_wrong_shape_rejected(self):
        with pytest.raises(ValueError, match=r"\(n, 4\)"):
            SpatialIndex.build(np.zeros((5, 3)), backend="host")


# ---------------------------------------------------------------------------
# Buffer-full ergonomics and admission control
# ---------------------------------------------------------------------------


class TestAdmission:
    def _full_manual_index(self, backend="host"):
        data = datasets.uniform_squares(20, seed=0)
        idx = SpatialIndex.build(
            data, backend=backend, capacity=4, merge={"auto": False}
        )
        idx.insert(datasets.uniform_squares(4, seed=1))  # buffer now full
        return idx

    def test_manual_policy_overflow_raises_typed(self):
        idx = self._full_manual_index()
        with pytest.raises(BufferFullError, match="auto=False"):
            idx.insert(datasets.uniform_squares(1, seed=2))
        assert isinstance(BufferFullError("x"), RuntimeError)

    def test_flush_clears_the_condition(self):
        idx = self._full_manual_index()
        assert idx.flush()
        idx.insert(datasets.uniform_squares(1, seed=2))  # fits again
        assert idx.n_objects == 25

    def test_oversized_batch_still_merges(self):
        # larger-than-capacity batches take the documented bulk path even
        # under a manual policy: they can never fit a buffer
        idx = self._full_manual_index()
        idx.insert(datasets.uniform_squares(9, seed=3))
        assert idx.n_objects == 33

    def test_shed_admission_drops_and_counts(self):
        data = datasets.uniform_squares(20, seed=0)
        idx = SpatialIndex.build(
            data, backend="host", capacity=4, merge={"auto": False},
            admission="shed",
        )
        idx.insert(datasets.uniform_squares(4, seed=1))
        gids = idx.insert(datasets.uniform_squares(2, seed=2))
        assert gids.size == 0
        assert idx.stats.shed_mutations == 2
        assert idx.n_objects == 24  # shed batch is simply gone

    def test_unknown_admission_rejected(self):
        with pytest.raises(ValueError, match="admission"):
            SpatialIndex.build(
                datasets.uniform_squares(8, seed=0), backend="host",
                admission="reject",
            )

    def test_queue_admission_in_durable_index(self, tmp_path):
        d = DurableIndex.create(
            datasets.uniform_squares(20, seed=0), tmp_path / "d",
            backend="host", admission="queue", sync=False,
            capacity=4, merge={"auto": False},
        )
        assert d.insert(datasets.uniform_squares(4, seed=1)).applied
        res = d.insert(datasets.uniform_squares(2, seed=2))
        assert res.status == "queued" and d.pending == 2
        assert d.stats.queued_mutations == 2
        # queued batches are NOT durable: recovery sees only applied ops
        r = DurableIndex.recover(tmp_path / "d", backend="host", sync=False)
        assert r.n_objects == 24
        # a flush makes room and drains the queue durably
        d.flush()
        assert d.pending == 0 and d.n_objects == 26
        r = DurableIndex.recover(tmp_path / "d", backend="host", sync=False)
        assert np.array_equal(live_ids(r), live_ids(d))


# ---------------------------------------------------------------------------
# Crash recovery: the kill matrix vs a fault-free reference run
# ---------------------------------------------------------------------------


def _run_ops(d: DurableIndex, ops, *, upto=None):
    """Drive the shared workload; deletes target the lowest live ids so
    the sequence is a pure function of durable state."""
    applied = 0
    for op, arg in ops:
        if upto is not None and applied >= upto:
            break
        if op == "insert":
            d.insert(arg)
        elif op == "delete":
            lids = live_ids(d)
            if lids.size == 0:
                continue
            d.delete(lids[: min(arg, lids.size)])
        else:
            d.flush()
        applied += 1
    return applied


def _reference_state(tmp_path, base, ops, n_durable, tag):
    """Fault-free host-side run of the surviving prefix."""
    d = DurableIndex.create(
        base, tmp_path / f"ref-{tag}", backend="host", sync=False,
        capacity=12,
    )
    _run_ops(d, ops, upto=n_durable)
    d.close()
    return d


class TestCrashRecovery:
    def _kill_matrix(self):
        if EXHAUSTIVE:
            indices = list(range(N_OPS))
        else:
            rng = np.random.default_rng(FT_SEED)
            indices = sorted(
                set(
                    rng.integers(0, N_OPS, size=8).tolist()
                    + [0, N_OPS - 1]
                )
            )
        sites = ("pre-append", "post-append", "post-apply", "mid-merge")
        for k in indices:
            for site in sites:
                yield k, site, False
            yield k, "post-append", True  # torn write at op k

    def test_kill_anywhere_recovers_to_oracle(self, tmp_path):
        base, ops = mutation_workload(N_OPS, seed=FT_SEED + 7, base_n=32)
        queries = datasets.region_queries(base, 10, seed=9)
        for k, site, torn in self._kill_matrix():
            root = tmp_path / f"k{k}-{site}-{int(torn)}"
            plan = FaultPlan(kill_at_op=k, kill_site=site, torn_write=torn)
            d = DurableIndex.create(
                base, root, backend="host", sync=False, capacity=12,
                fault_plan=plan,
            )
            killed = False
            try:
                _run_ops(d, ops)
            except KillPoint:
                killed = True
            d.close()
            r = DurableIndex.recover(root, backend="host", sync=False)
            if killed:
                expect = k if (site == "pre-append" or torn) else k + 1
                assert r.ops_total == expect, (k, site, torn)
                assert r.recovered_torn == torn or not torn
            ref = _reference_state(
                tmp_path, base, ops, r.ops_total, f"{k}-{site}-{int(torn)}"
            )
            assert np.array_equal(live_ids(r), live_ids(ref)), (k, site, torn)
            assert np.array_equal(
                r.region(queries).hits, ref.region(queries).hits
            ), (k, site, torn)

    def test_recovered_state_matches_oracle_on_all_backends(self, tmp_path):
        base, ops = mutation_workload(40, seed=FT_SEED + 1, base_n=32)
        queries = datasets.region_queries(base, 10, seed=3)
        plan = FaultPlan(kill_at_op=23, kill_site="post-append")
        d = DurableIndex.create(
            base, tmp_path / "d", backend="host", sync=False, capacity=12,
            fault_plan=plan,
        )
        with pytest.raises(KillPoint):
            _run_ops(d, ops)
        d.close()
        r = DurableIndex.recover(tmp_path / "d", backend="pallas")
        ref = oracle.hits_mask(r.index, queries, r.id_space)
        for be in BACKENDS:
            got = r.index.with_backend(be).region(queries)
            assert np.array_equal(got.hits, ref), be

    def test_kill_mid_merge_replays_the_merge(self, tmp_path):
        base, ops = mutation_workload(60, seed=FT_SEED + 2, base_n=24)
        # find an op that actually merges by running fault-free first
        probe = DurableIndex.create(
            base, tmp_path / "probe", backend="host", sync=False, capacity=8
        )
        merge_ops = []
        applied = 0
        for op, arg in ops:
            before = probe.index.stats.flushes
            if op == "insert":
                probe.insert(arg)
            elif op == "delete":
                lids = live_ids(probe)
                if lids.size == 0:
                    continue
                probe.delete(lids[: min(arg, lids.size)])
            else:
                probe.flush()
            if probe.index.stats.flushes > before:
                merge_ops.append(applied)
            applied += 1
        probe.close()
        assert merge_ops, "workload never merged; widen it"
        k = merge_ops[len(merge_ops) // 2]
        plan = FaultPlan(kill_at_op=k, kill_site="mid-merge", slow_merge=0.001)
        d = DurableIndex.create(
            base, tmp_path / "d", backend="host", sync=False, capacity=8,
            fault_plan=plan,
        )
        with pytest.raises(KillPoint):
            _run_ops(d, ops)
        d.close()
        assert plan.kills == 1
        r = DurableIndex.recover(tmp_path / "d", backend="host", sync=False)
        assert r.ops_total == k + 1  # the record was durable; merge replayed
        ref = _reference_state(tmp_path, base, ops, k + 1, "midmerge")
        assert np.array_equal(live_ids(r), live_ids(ref))

    def test_checkpoint_rotation_and_gc(self, tmp_path):
        base, ops = mutation_workload(30, seed=FT_SEED + 3, base_n=24)
        d = DurableIndex.create(
            base, tmp_path / "d", backend="host", sync=False, capacity=12
        )
        applied = 0
        for op, arg in ops:
            if op == "insert":
                d.insert(arg)
            elif op == "delete":
                lids = live_ids(d)
                if lids.size == 0:
                    continue
                d.delete(lids[: min(arg, lids.size)])
            else:
                d.flush()
            applied += 1
            if applied % 10 == 0:
                d.checkpoint()
        assert d.generation == 3
        names = {p.name for p in (tmp_path / "d").iterdir()}
        assert "snap_3" in names and "wal_3.log" in names
        assert "snap_0" not in names and "wal_0.log" not in names  # GC'd
        assert "snap_2" in names  # keep=1 retains the previous generation
        r = DurableIndex.recover(tmp_path / "d", backend="host", sync=False)
        assert r.generation == 3 and r.ops_total == d.ops_total
        assert np.array_equal(live_ids(r), live_ids(d))

    def test_kill_between_snapshot_and_new_wal(self, tmp_path):
        # the rotation crash window: snap_<g+1> published, wal_<g+1>
        # never created — recovery must read it as an empty log
        base, _ = mutation_workload(1, seed=0, base_n=24)
        d = DurableIndex.create(
            base, tmp_path / "d", backend="host", sync=False, capacity=8
        )
        d.insert(datasets.uniform_squares(3, seed=1))
        d.checkpoint()
        d.close()
        (tmp_path / "d" / "wal_1.log").unlink()  # simulate the kill
        r = DurableIndex.recover(tmp_path / "d", backend="host", sync=False)
        assert r.generation == 1 and r.n_objects == 27
        assert r.recovered_ops == 0

    def test_recover_empty_root_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            DurableIndex.recover(tmp_path / "nothing", backend="host")


# ---------------------------------------------------------------------------
# Property test: arbitrary interleavings of mutate/crash/recover
# ---------------------------------------------------------------------------


def _check_interleaving(tmp_path, ops, kill_at, site, torn, seed):
    """The property: ANY interleaving of {insert, delete, flush} killed at
    op ``kill_at`` (site/torn variants) recovers bit-identical to a
    fault-free run of the surviving prefix AND to the host mqr oracle, on
    all four backends."""
    rng = np.random.default_rng(seed)
    base = datasets.uniform_squares(16, seed=seed)
    concrete = []
    for op, arg in ops:
        if op == "insert":
            concrete.append(
                ("insert", datasets.uniform_squares(
                    arg, seed=int(rng.integers(0, 2**31))
                ))
            )
        elif op == "delete":
            concrete.append(("delete", arg))
        else:
            concrete.append(("flush", None))
    plan = FaultPlan(kill_at_op=kill_at, kill_site=site, torn_write=torn)
    d = DurableIndex.create(
        base, tmp_path / "d", backend="host", sync=False, capacity=6,
        fault_plan=plan,
    )
    try:
        _run_ops(d, concrete)
    except KillPoint:
        pass
    d.close()
    r = DurableIndex.recover(tmp_path / "d", backend="host", sync=False)
    ref = _reference_state(tmp_path, base, concrete, r.ops_total, "h")
    assert np.array_equal(live_ids(r), live_ids(ref))
    queries = datasets.region_queries(base, 6, seed=seed)
    mask = oracle.hits_mask(r.index, queries, r.id_space)
    for be in BACKENDS:
        got = r.index.with_backend(be).region(queries)
        assert np.array_equal(got.hits, mask), be


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pip install -r requirements-dev.txt
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    _op = st.one_of(
        st.tuples(st.just("insert"), st.integers(1, 4)),
        st.tuples(st.just("delete"), st.integers(1, 3)),
        st.tuples(st.just("flush"), st.just(0)),
    )

    @settings(max_examples=12, deadline=None)
    @given(
        ops=st.lists(_op, min_size=1, max_size=24),
        kill_at=st.integers(0, 23),
        site=st.sampled_from(
            ("pre-append", "post-append", "post-apply", "mid-merge")
        ),
        torn=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    def test_any_interleaving_recovers_bit_identical(
        tmp_path_factory, ops, kill_at, site, torn, seed
    ):
        _check_interleaving(
            tmp_path_factory.mktemp("hyp"), ops, kill_at, site, torn, seed
        )

else:
    # hypothesis is optional in this image: cover the same property with
    # a fixed-seed random sweep so the invariant is still exercised.
    @pytest.mark.parametrize("case", range(8))
    def test_any_interleaving_recovers_bit_identical(tmp_path, case):
        rng = np.random.default_rng(1000 + case)
        n = int(rng.integers(4, 25))
        ops = []
        for _ in range(n):
            r = rng.random()
            if r < 0.55:
                ops.append(("insert", int(rng.integers(1, 5))))
            elif r < 0.85:
                ops.append(("delete", int(rng.integers(1, 4))))
            else:
                ops.append(("flush", 0))
        _check_interleaving(
            tmp_path,
            ops,
            kill_at=int(rng.integers(0, n)),
            site=("pre-append", "post-append", "post-apply", "mid-merge")[
                case % 4
            ],
            torn=bool(case % 2),
            seed=case,
        )
