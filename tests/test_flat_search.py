"""Levelized JAX search == pointer search (results AND disk accesses)."""
import sys

import numpy as np
import pytest

from repro.core import bulk, datasets, flat, mqrtree, rtree
from repro.core import mbr as M
import jax.numpy as jnp


@pytest.mark.parametrize("builder", [mqrtree.build, rtree.build])
def test_flat_parity(builder):
    data = datasets.uniform_squares(300, seed=5)
    t = builder(data)
    ft = flat.flatten(t)
    qs = datasets.region_queries(data, 8, seed=6)
    hits, visits = flat.region_search_batch(ft, qs)
    for i, q in enumerate(qs):
        found, v = t.region_search(q)
        assert set(np.nonzero(hits[i])[0]) == set(found)
        assert v == int(visits[i])


def test_pyramid_search_no_false_negatives():
    pts = datasets.uniform_points(256, seed=2)
    pyr = bulk.build_pyramid(jnp.asarray(pts, jnp.float32), levels=6)
    for seed in range(5):
        rng = np.random.default_rng(seed)
        lo = rng.uniform(0, 800, 2)
        region = jnp.asarray([*lo, *(lo + 250)], jnp.float32)
        surv = np.asarray(bulk.pyramid_search(pyr, region))
        brute = M.overlaps(pts, np.asarray(region))
        assert not (brute & ~surv).any(), "pyramid search missed an object"


def test_flatten_deep_center_chain_no_recursion_blowup():
    """Regression: `flatten` must not recurse — CENTER chains grow one node
    per ~4 co-centred objects (Section 3.4), so tree depth is unbounded and
    the old recursive assign() tripped Python's recursion limit on deep or
    degenerate datasets."""
    n = 1200  # concentric squares: identical centroids -> one CENTER chain
    s = np.arange(1, n + 1, dtype=np.float64)[:, None]
    mbrs = np.concatenate([500 - s, 500 - s, 500 + s, 500 + s], axis=1)
    tree = mqrtree.build(mbrs)
    depth = max(d for _, d in tree.iter_nodes())
    assert depth >= n // 5  # genuinely deep

    old = sys.getrecursionlimit()
    sys.setrecursionlimit(max(depth // 2, 120))  # recursion would blow here
    try:
        ft = flat.flatten(tree)
    finally:
        sys.setrecursionlimit(old)
    assert ft.n_objects == n

    sched = flat.level_schedule(ft)
    assert sched.levels == depth
    q = np.array([[499.0, 499.0, 501.0, 501.0]], np.float32)
    hits, visits = flat.region_search_batch(ft, q)
    found, v = tree.region_search(q[0].astype(np.float64))
    assert set(np.nonzero(hits[0])[0]) == set(found)
    assert int(visits[0]) == v == depth  # the query walks the whole chain


def test_pyramid_groups_shrink():
    pts = datasets.uniform_points(128, seed=1)
    pyr = bulk.build_pyramid(jnp.asarray(pts, jnp.float32), levels=6)
    stats = bulk.pyramid_stats(pyr)
    assert stats[0] == 1
    assert all(b >= a for a, b in zip(stats, stats[1:]))
    assert stats[-1] == 128  # distinct points fully separate
