"""Levelized JAX search == pointer search (results AND disk accesses)."""
import numpy as np
import pytest

from repro.core import bulk, datasets, flat, mqrtree, rtree
from repro.core import mbr as M
import jax.numpy as jnp


@pytest.mark.parametrize("builder", [mqrtree.build, rtree.build])
def test_flat_parity(builder):
    data = datasets.uniform_squares(300, seed=5)
    t = builder(data)
    ft = flat.flatten(t)
    qs = datasets.region_queries(data, 8, seed=6)
    hits, visits = flat.region_search_batch(ft, qs)
    for i, q in enumerate(qs):
        found, v = t.region_search(q)
        assert set(np.nonzero(hits[i])[0]) == set(found)
        assert v == int(visits[i])


def test_pyramid_search_no_false_negatives():
    pts = datasets.uniform_points(256, seed=2)
    pyr = bulk.build_pyramid(jnp.asarray(pts, jnp.float32), levels=6)
    for seed in range(5):
        rng = np.random.default_rng(seed)
        lo = rng.uniform(0, 800, 2)
        region = jnp.asarray([*lo, *(lo + 250)], jnp.float32)
        surv = np.asarray(bulk.pyramid_search(pyr, region))
        brute = M.overlaps(pts, np.asarray(region))
        assert not (brute & ~surv).any(), "pyramid search missed an object"


def test_pyramid_groups_shrink():
    pts = datasets.uniform_points(128, seed=1)
    pyr = bulk.build_pyramid(jnp.asarray(pts, jnp.float32), levels=6)
    stats = bulk.pyramid_stats(pyr)
    assert stats[0] == 1
    assert all(b >= a for a, b in zip(stats, stats[1:]))
    assert stats[-1] == 128  # distinct points fully separate
