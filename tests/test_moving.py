"""Moving-object workload: differential churn soak over the live index.

The scenario of ``repro.launch.moving`` under test: every tick a batch
of objects moves (batch delete + batch insert through the delta buffer)
while a continuous query set — region rectangles plus a spatial join
against a static zone index — keeps answering.  Every answer is checked
against independent host oracles; overflow-triggered merges must not
move any answer; tombstoned ids must never appear in any pair; a
``FaultPlan`` kill mid-tick must recover via ``DurableIndex`` to
exactly the last durable operation.

``REPRO_SOAK=1`` stretches the churn soak to >=1e4 ticks (CI nightly /
manual); the default sizes keep the suite minutes-fast.
"""
import os

import numpy as np
import pytest

from repro.checkpoint import DurableIndex
from repro.ft import FaultPlan, KillPoint
from repro.launch.moving import MovingConfig, MovingWorkload
from repro.update import oracle

SOAK = os.environ.get("REPRO_SOAK", "0") == "1"
TICKS = 10_000 if SOAK else 120
QUERY_EVERY = 50 if SOAK else 6


def _overlap_np(a, b):
    return (
        (a[..., 0] <= b[..., 2])
        & (b[..., 0] <= a[..., 2])
        & (a[..., 1] <= b[..., 3])
        & (b[..., 1] <= a[..., 3])
    )


def join_oracle(left, right) -> np.ndarray:
    """Brute-force pair mask over two indexes' live tables (float32)."""

    def side(idx):
        log = idx._updates
        if log is None:
            t = np.asarray(idx.artifacts.mbrs, np.float32)
            return t, np.ones((t.shape[0],), bool)
        return log.mbr_table.astype(np.float32), log.alive

    ta, aa = side(left)
    tb, ab = side(right)
    ov = _overlap_np(ta[:, None, :], tb[None, :, :])
    return ov & aa[:, None] & ab[None, :]


def check_tick(w: MovingWorkload, res) -> None:
    """One full differential check of a query tick's answers."""
    idx = w.query_index
    # region: independent replay of the update log on the host oracle
    expect_hits = oracle.hits_mask(idx, w.queries, idx.id_space)
    assert np.array_equal(res.region.hits, expect_hits), f"tick {res.tick}"
    # join: brute-force float32 pair mask over the live tables
    expect_pairs = join_oracle(idx, w.zones)
    assert np.array_equal(res.join.pairs, expect_pairs), f"tick {res.tick}"
    # tombstoned objects are in NO pair, ever
    if w.dead_gids:
        assert not res.join.pairs[np.asarray(w.dead_gids)].any(), (
            f"tombstoned id paired at tick {res.tick}"
        )
    # the live slot <-> gid map covers exactly the live rows
    live = np.zeros((idx.id_space,), bool)
    live[w.gid] = True
    assert not res.join.pairs[~live].any()


# ---------------------------------------------------------------------------
# The churn soak
# ---------------------------------------------------------------------------


def test_churn_soak_every_answer_matches_oracle():
    """TICKS of churn on the pallas backend, capacity small enough that
    overflow auto-merges fire repeatedly mid-run; every query tick's
    region AND join answers are bit-identical to the host oracles."""
    cfg = MovingConfig(n_objects=64, moves_per_tick=8, n_zones=10,
                      n_queries=4, query_every=QUERY_EVERY, seed=3)
    w = MovingWorkload(cfg, backend="pallas", capacity=48)
    checked = 0
    for _ in range(TICKS):
        res = w.tick()
        if res.join is not None:
            check_tick(w, res)
            checked += 1
    assert checked == TICKS // QUERY_EVERY
    idx = w.query_index
    # churn actually exercised the merge path, repeatedly
    assert idx.stats.flushes >= 2, "soak never overflowed the buffer"
    assert idx.stats.inserts == idx.stats.deletes == TICKS * 8
    assert idx.stats.joins == checked


def test_overflow_merge_preserves_pair_parity():
    """An explicit compaction between two joins moves no answer: the
    post-flush pair set restricted to the pre-flush id space is
    identical, and the flush leaves zero delta cross-scans."""
    cfg = MovingConfig(n_objects=48, moves_per_tick=6, query_every=1,
                      seed=11)
    w = MovingWorkload(cfg, backend="pallas", capacity=64)
    w.run(5)   # leave real state in the delta buffer
    idx = w.query_index
    before = idx.join(w.zones)
    assert int(before.delta_tests.sum()) > 0   # deltas were live
    na = before.pairs.shape[0]
    assert idx.flush()
    after = idx.join(w.zones)
    assert np.array_equal(after.pairs[:na], before.pairs)
    assert not after.pairs[na:].any()
    assert int(after.delta_tests.sum()) == 0
    assert np.array_equal(after.pairs, join_oracle(idx, w.zones))


def test_cross_backend_agreement_mid_run():
    """Mid-churn (deltas + tombstones live), every backend answers the
    continuous query set identically."""
    cfg = MovingConfig(n_objects=48, moves_per_tick=6, query_every=1,
                      seed=5)
    w = MovingWorkload(cfg, backend="pallas", capacity=96)
    res = w.run(7)
    for backend in ("host", "lax", "serve"):
        other = w.query_index.with_backend(backend)
        assert np.array_equal(other.region(w.queries).hits,
                              res.region.hits), backend
        assert np.array_equal(other.join(w.zones).pairs,
                              res.join.pairs), backend


def test_live_churn_equals_naive_rebuild():
    """The delta-buffer workload and the rebuild-per-tick baseline give
    the same geometry answers tick for tick — only the global-id spaces
    differ, so answers are compared per object SLOT via the gid map."""
    cfg = MovingConfig(n_objects=40, moves_per_tick=5, query_every=4,
                      seed=7)
    live = MovingWorkload(cfg, backend="pallas", capacity=64)
    base = MovingWorkload(cfg, backend="host", rebuild_per_tick=True)
    for _ in range(16):
        rl, rb = live.tick(), base.tick()
        assert np.array_equal(rl.moved, rb.moved)  # same seeded motion
        if rl.join is None:
            continue
        assert np.array_equal(rl.region.hits[:, live.gid],
                              rb.region.hits[:, base.gid])
        assert np.array_equal(rl.join.pairs[live.gid],
                              rb.join.pairs[base.gid])


# ---------------------------------------------------------------------------
# Kill mid-tick, recover to the last durable op
# ---------------------------------------------------------------------------


def test_fault_kill_mid_tick_recovers_to_last_durable_op(tmp_path):
    """Each tick is two durable ops (batch delete, batch insert).  A
    kill landing on tick T+1's delete leaves a half-applied tick; after
    ``DurableIndex.recover`` the index must equal a clean replay of T
    full ticks plus that one delete — checked by region and join."""
    t_full = 5
    kill_op = 2 * t_full              # zero-based: tick t_full+1's delete
    cfg = MovingConfig(n_objects=48, moves_per_tick=6, query_every=1,
                      seed=13)
    probe = MovingWorkload(cfg, backend="host", capacity=64)
    plan = FaultPlan(kill_at_op=kill_op, kill_site="post-apply")
    d = DurableIndex.create(
        probe.boxes(), tmp_path / "d", backend="host", sync=False,
        capacity=64, fault_plan=plan,
    )
    w = MovingWorkload(cfg, index=d)
    killed = False
    try:
        for _ in range(t_full + 1):
            w.tick()
    except KillPoint:
        killed = True
    assert killed and plan.kills == 1
    d.close()

    r = DurableIndex.recover(tmp_path / "d", backend="host", sync=False)
    assert r.ops_total == kill_op + 1   # the delete was durable
    assert r.recovered_ops == kill_op + 1

    # clean replay: T full ticks, then replicate tick T+1's delete only
    ref = MovingWorkload(cfg, backend="host", capacity=64)
    ref.run(t_full)
    moved = np.sort(ref._rng.choice(cfg.n_objects, size=cfg.moves_per_tick,
                                    replace=False))
    ref.index.delete(ref.gid[moved])

    assert r.index.id_space == ref.index.id_space
    assert np.array_equal(r.region(ref.queries).hits,
                          ref.index.region(ref.queries).hits)
    assert np.array_equal(r.join(ref.zones).pairs,
                          join_oracle(ref.index, ref.zones))
    # and the recovered index keeps absorbing churn: finish the torn
    # tick's insert and verify against the oracle again
    boxes = ref.boxes(moved)
    r.insert(boxes)
    ref.index.insert(boxes)
    assert np.array_equal(
        r.region(ref.queries).hits, ref.index.region(ref.queries).hits
    )
    r.close()


def test_moving_rejects_mismatched_soak_knob():
    """`REPRO_SOAK` only stretches sizes — the soak path and the default
    path run the identical code (guard against silent divergence)."""
    assert TICKS // QUERY_EVERY == (200 if SOAK else 20)


@pytest.mark.skipif(SOAK, reason="redundant under the long soak")
def test_workload_is_replayable():
    """Same config, same seed -> bit-identical tick stream (the whole
    differential harness rests on this)."""
    cfg = MovingConfig(n_objects=32, moves_per_tick=4, query_every=3,
                      seed=21)
    a = MovingWorkload(cfg, backend="host", capacity=48)
    b = MovingWorkload(cfg, backend="host", capacity=48)
    for _ in range(9):
        ra, rb = a.tick(), b.tick()
        assert np.array_equal(ra.moved, rb.moved)
        assert np.array_equal(ra.new_gids, rb.new_gids)
        if ra.join is not None:
            assert np.array_equal(ra.join.pairs, rb.join.pairs)
            assert np.array_equal(ra.region.hits, rb.region.hits)
