"""`SpatialIndex.join`: differential harness against the nested-loop oracle.

DESIGN.md §10's acceptance contract: the join pair-set is bit-identical
to the brute-force O(n·m) oracle over the two live object sets on every
structure × backend × precision — pristine AND mid-buffer live state,
tombstones excluded, degradation rungs included — and on point data the
paper's zero-overlap property (§4) makes a self-join exactly the
identity pairs.  The sweep's pair-visit ledger is backend-invariant for
float32 and conservatively larger for compact tiles.

`REPRO_JOIN_SEED` (CI matrix) salts every dataset in this module.
"""
import os

import numpy as np
import pytest

import conftest
from conftest import f32_exact
from repro.core import datasets
from repro.ft import FaultPlan
from repro.index import JoinResult, SpatialIndex
from repro.index.join import JOIN_LADDER, PREDICATES

SEED = int(os.environ.get("REPRO_JOIN_SEED", "0"))
STRUCTURES = ("mqr", "rtree", "pyramid")
BACKENDS = ("host", "lax", "pallas", "serve")


def _overlap_np(a, b):
    return (
        (a[..., 0] <= b[..., 2])
        & (b[..., 0] <= a[..., 2])
        & (a[..., 1] <= b[..., 3])
        & (b[..., 1] <= a[..., 3])
    )


def oracle_pairs(left, right) -> np.ndarray:
    """Brute-force nested-loop join over the two indexes' live object
    sets, in float32 (the device coordinate convention)."""

    def side(idx):
        log = idx._updates
        if log is None:
            t = np.asarray(idx.artifacts.mbrs, np.float32)
            return t, np.ones((t.shape[0],), bool)
        return log.mbr_table.astype(np.float32), log.alive

    ta, aa = side(left)
    tb, ab = side(right)
    ov = _overlap_np(ta[:, None, :], tb[None, :, :])
    return ov & aa[:, None] & ab[None, :]


def _data(tag: str, kind: str, n: int) -> np.ndarray:
    """Per-side dataset: ``tag`` keeps the two join sides on distinct
    deterministic streams, ``SEED`` freshens both per CI matrix leg."""
    return f32_exact(conftest.mbr_dataset(f"test_join/{tag}", kind, n,
                                          salt=SEED))


def _check(left, right):
    res = left.join(right)
    assert isinstance(res, JoinResult)
    expect = oracle_pairs(left, right)
    assert res.pairs.shape == expect.shape
    assert np.array_equal(res.pairs, expect)
    return res


# ---------------------------------------------------------------------------
# The parity matrix: structures × backends × precision
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("structure", STRUCTURES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_join_parity_matrix(structure, backend):
    """Pair sets bit-identical to the oracle on every structure ×
    backend, and the pair-visit ledger identical across float32 engines
    (the sweep recurrence is the same computation everywhere)."""
    da = _data("a", "uniform_squares", 160)
    db = _data("b", "exponential_squares", 130)
    left = SpatialIndex.build(da, structure=structure, backend=backend)
    right = SpatialIndex.build(db, structure="mqr", backend="host")
    res = _check(left, right)
    ref = SpatialIndex.build(
        da, structure=structure, backend="host"
    ).join(right)
    assert np.array_equal(res.pair_visits, ref.pair_visits), (
        f"{structure}×{backend} pair-visit parity"
    )


@pytest.mark.parametrize("structure", STRUCTURES)
def test_join_compact_parity_and_conservative_visits(structure):
    """precision="compact" joins on the joint uint16 grid: identical
    pair sets, per-level visits a conservative superset of float32."""
    da = _data("a", "uniform_squares", 160)
    db = _data("b", "uniform_squares", 130)
    right = SpatialIndex.build(db, structure="rtree", backend="host")
    exact = SpatialIndex.build(
        da, structure=structure, backend="pallas"
    ).join(right)
    left = SpatialIndex.build(
        da, structure=structure, backend="pallas", precision="compact"
    )
    res = _check(left, right)
    assert np.array_equal(res.pairs, exact.pairs)
    assert (res.pair_visits >= exact.pair_visits).all()


def test_join_mixed_structures_and_depths():
    """Left and right may differ in structure and tree height — the
    sweep runs to the shallower depth and stays exact."""
    da = _data("a", "exponential_squares", 300)   # deep mqr
    db = _data("b", "uniform_squares", 40)        # shallow
    left = SpatialIndex.build(da, structure="mqr", backend="pallas")
    right = SpatialIndex.build(db, structure="pyramid", backend="host")
    res = _check(left, right)
    assert res.base_levels == min(
        left.schedule.levels, right.schedule.levels
    )
    _check(right.with_backend("pallas"), left)  # and the transpose


def test_self_join_points_is_identity():
    """The paper's zero-overlap claim (§4) on point data: distinct
    points overlap only themselves, so a self-join is EXACTLY the
    identity pair set — on the exact and compact paths."""
    pts = f32_exact(conftest.mbr_dataset("test_join", "uniform_points",
                                         150, salt=SEED))
    assert np.unique(pts, axis=0).shape[0] == pts.shape[0]
    idx = SpatialIndex.build(pts, structure="mqr", backend="pallas")
    res = idx.join(idx)
    assert np.array_equal(res.pairs, np.eye(150, dtype=bool))
    cmp_ = SpatialIndex.build(
        pts, structure="mqr", backend="pallas", precision="compact"
    )
    assert np.array_equal(cmp_.join(cmp_).pairs, np.eye(150, dtype=bool))


# ---------------------------------------------------------------------------
# Adversarial geometry (explicit) — co-centred, degenerate, grid-aligned
# ---------------------------------------------------------------------------


def _build_all_backends(data, structure="mqr"):
    host = SpatialIndex.build(data, structure=structure, backend="host")
    return [host] + [host.with_backend(b) for b in ("lax", "pallas")]


def test_join_cocentred_stacks():
    """Co-centred boxes (the mqr CENTER-chain worst case): every pair
    overlaps within a stack; deep chains on both sides stay exact."""
    rng = np.random.default_rng(conftest.derived_seed(
        "test_join", "cocentred", SEED))
    centres = rng.uniform(100, 900, size=(6, 2))
    sides = np.arange(1, 9, dtype=np.float64)[:, None]
    da = f32_exact(np.concatenate([
        np.concatenate([c - sides, c + sides], axis=1) for c in centres
    ]))
    db = f32_exact(np.concatenate([
        np.concatenate([c - 2 * sides, c + 2 * sides], axis=1)
        for c in centres + rng.uniform(-30, 30, centres.shape)
    ]))
    right = SpatialIndex.build(db, structure="mqr", backend="host")
    for left in _build_all_backends(da):
        _check(left, right)


def test_join_degenerate_zero_area():
    """Zero-area boxes (points, horizontal/vertical segments) joined
    against squares: closed-boundary touching counts as a pair."""
    pts = np.array([[10.0, 10, 10, 10], [20, 5, 20, 25],   # point, v-seg
                    [5, 20, 25, 20], [30, 30, 30, 30]])    # h-seg, point
    boxes = np.array([[0.0, 0, 10, 10],    # corner-touches the point
                      [15, 0, 20, 30],     # edge-touches the v-segment
                      [26, 26, 29, 29]])   # disjoint from everything
    da, db = f32_exact(pts), f32_exact(boxes)
    right = SpatialIndex.build(db, structure="rtree", backend="host")
    for left in _build_all_backends(da):
        res = _check(left, right)
        assert res.pairs[0, 0] and res.pairs[1, 1] and not res.pairs[:, 2].any()
    cleft = SpatialIndex.build(da, structure="mqr", backend="pallas",
                               precision="compact")
    _check(cleft, right)


def test_join_grid_aligned_boundaries():
    """Integer-lattice boxes that exactly share edges: boundary pairs
    must survive quantization (outward rounding on the joint grid can
    only widen, and the confirming pass is exact)."""
    xs, ys = np.meshgrid(np.arange(4) * 10.0, np.arange(4) * 10.0)
    ll = np.stack([xs.ravel(), ys.ravel()], axis=1)
    da = f32_exact(np.concatenate([ll, ll + 10.0], axis=1))    # tiling
    db = f32_exact(np.concatenate([ll + 10.0, ll + 20.0], axis=1))
    right = SpatialIndex.build(db, structure="mqr", backend="host")
    for left in _build_all_backends(da):
        _check(left, right)
    cleft = SpatialIndex.build(da, structure="mqr", backend="pallas",
                               precision="compact")
    _check(cleft, right)


# ---------------------------------------------------------------------------
# Live state: mid-buffer, tombstones, post-flush
# ---------------------------------------------------------------------------


def test_join_live_midbuffer_tombstones_and_flush():
    da = _data("a", "uniform_squares", 120)
    db = _data("b", "uniform_squares", 100)
    left = SpatialIndex.build(da, structure="pyramid", backend="pallas",
                              capacity=64)
    right = SpatialIndex.build(db, structure="mqr", backend="pallas",
                               capacity=64)
    ga = left.insert(f32_exact(datasets.uniform_squares(
        30, seed=conftest.derived_seed("test_join", "ins-a", SEED))))
    left.delete(np.arange(10))
    left.delete(ga[:5])
    gb = right.insert(f32_exact(datasets.uniform_squares(
        25, seed=conftest.derived_seed("test_join", "ins-b", SEED))))
    right.delete(gb[:3])

    # mid-buffer: every backend, both sides carrying deltas + tombstones
    expect = oracle_pairs(left, right)
    for backend in BACKENDS:
        res = left.with_backend(backend).join(right)
        assert np.array_equal(res.pairs, expect), f"live×{backend}"
        assert int(res.delta_tests.sum()) > 0  # deltas actually cross-scan
    res = left.with_backend("pallas", precision="compact").join(right)
    assert np.array_equal(res.pairs, expect)

    # tombstoned ids appear in no pair, ever
    res = left.join(right)
    assert not res.pairs[np.arange(10), :].any()
    assert not res.pairs[ga[:5], :].any()
    assert not res.pairs[:, gb[:3]].any()

    # post-flush: same global ids, same pair set (padded to new id space)
    left.flush()
    right.flush()
    post = left.join(right)
    assert np.array_equal(post.pairs, oracle_pairs(left, right))
    na, nb = expect.shape
    assert np.array_equal(post.pairs[:na, :nb], expect)
    assert not post.pairs[na:, :].any() and not post.pairs[:, nb:].any()
    assert int(post.delta_tests.sum()) == 0


# ---------------------------------------------------------------------------
# Serve ladder, API contract, stats
# ---------------------------------------------------------------------------


def test_join_serve_degrades_bit_identically():
    da = _data("a", "uniform_squares", 80)
    db = _data("b", "uniform_squares", 80)
    right = SpatialIndex.build(db, structure="mqr", backend="host")
    healthy = SpatialIndex.build(da, structure="mqr", backend="serve")
    expect = healthy.join(right).pairs
    assert healthy.stats.rung_dispatches.get("pallas", 0) == 1

    hurt = SpatialIndex.build(da, structure="mqr", backend="serve")
    hurt.bind_fault_plan(FaultPlan(fail_launches=1, fail_rungs=("pallas",)))
    res = hurt.join(right)
    assert np.array_equal(res.pairs, expect)
    assert hurt.stats.degraded
    assert hurt.stats.launch_failures == 1
    assert hurt.stats.rung_dispatches.get("lax", 0) == 1

    floor = SpatialIndex.build(da, structure="mqr", backend="serve")
    floor.bind_fault_plan(FaultPlan(fail_launches=2,
                                    fail_rungs=("pallas", "lax")))
    res = floor.join(right)
    assert np.array_equal(res.pairs, expect)
    assert floor.stats.rung_dispatches.get("host", 0) == 1
    assert tuple(JOIN_LADDER) == ("pallas", "lax", "host")


def test_join_unknown_predicate_raises():
    da = _data("a", "uniform_squares", 40)
    idx = SpatialIndex.build(da, structure="mqr", backend="host")
    with pytest.raises(ValueError, match="predicate"):
        idx.join(idx, predicate="within")
    assert PREDICATES == ("intersects",)


def test_join_stats_ledger():
    da = _data("a", "uniform_squares", 90)
    db = _data("b", "uniform_squares", 70)
    left = SpatialIndex.build(da, structure="mqr", backend="pallas")
    right = SpatialIndex.build(db, structure="mqr", backend="host")
    res = left.join(right)
    assert left.stats.joins == 1
    assert left.stats.queries == 1
    assert left.stats.node_accesses == int(res.pair_visits.sum())
    assert left.stats.launches == 1
    assert res.n_pairs == len(res.pair_list())
    assert np.array_equal(
        np.argwhere(res.pairs), res.pair_list()
    )


# ---------------------------------------------------------------------------
# Self-join fast path: symmetric upper-triangle sweep (half the work)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_self_join_symmetric_sweep_bit_parity(backend):
    """``idx.join(idx)`` takes the symmetric fast path: pair set
    bit-identical to the full sweep over an equal twin index, with
    strictly fewer sweep pair-tests (only the upper triangle runs)."""
    da = _data("a", "exponential_squares", 150)
    idx = SpatialIndex.build(da, structure="mqr", backend=backend)
    twin = SpatialIndex.build(da, structure="mqr", backend=backend)
    fast = idx.join(idx)        # right IS left -> symmetric sweep
    full = idx.join(twin)       # equal data, different object -> full sweep
    assert np.array_equal(fast.pairs, full.pairs)
    assert np.array_equal(fast.pairs, oracle_pairs(idx, idx))
    assert fast.sweep_visits.sum() < full.sweep_visits.sum()
    # the delta cross-scan columns are untouched by the fast path
    assert np.array_equal(fast.pair_visits[-2:], full.pair_visits[-2:])


def test_self_join_symmetric_visits_block_size_invariant():
    """The kernel's triu mask is SLOT-granular, so the surviving set —
    and therefore the visit ledger — cannot depend on tile block size,
    and matches the lax/host twins bit-for-bit."""
    da = _data("a", "uniform_squares", 150)
    ref = SpatialIndex.build(da, structure="mqr", backend="host")
    want = ref.join(ref)
    for backend, opts in (("lax", {}), ("pallas", {}),
                          ("pallas", {"block_w": 32}),
                          ("pallas", {"block_w": 64})):
        idx = SpatialIndex.build(da, structure="mqr", backend=backend,
                                 **opts)
        res = idx.join(idx)
        assert np.array_equal(res.pairs, want.pairs), (backend, opts)
        assert np.array_equal(res.pair_visits, want.pair_visits), (
            backend, opts
        )


def test_self_join_symmetric_compact_and_live():
    """Fast path holds on the compact uint16 grid and across live state
    (delta buffer + tombstones): pairs equal to a full-sweep twin that
    replayed the identical mutations."""
    da = _data("a", "uniform_squares", 140)
    extra = _data("b", "uniform_squares", 12)

    def build():
        idx = SpatialIndex.build(da, structure="mqr", backend="pallas",
                                 precision="compact", capacity=32)
        idx.insert(extra)
        idx.delete(np.arange(6))
        return idx

    idx, twin = build(), build()
    fast = idx.join(idx)
    full = idx.join(twin)
    assert np.array_equal(fast.pairs, full.pairs)
    assert np.array_equal(fast.pairs, oracle_pairs(idx, idx))
    assert fast.sweep_visits.sum() < full.sweep_visits.sum()


# ---------------------------------------------------------------------------
# Property: arbitrary finite geometry on both sides
# ---------------------------------------------------------------------------
# Unlike the module-level ``importorskip`` idiom elsewhere, the guard is a
# plain try/except: the parity matrix above must still run where the dev
# extras are absent — only the property test downgrades to a skip.

try:
    from hypothesis import given, settings, strategies as st

    _HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dev dependency
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    _coord = st.floats(
        min_value=-1e6, max_value=1e6, allow_nan=False,
        allow_infinity=False, width=32,
    )
    _rect = st.tuples(_coord, _coord, _coord, _coord).map(
        lambda t: (min(t[0], t[2]), min(t[1], t[3]),
                   max(t[0], t[2]), max(t[1], t[3]))
    )

    # Fixed sizes so the jitted pair sweeps compile once across examples.
    _N_A, _N_B = 16, 12

    @settings(max_examples=25, deadline=None)
    @given(
        rects_a=st.lists(_rect, min_size=_N_A, max_size=_N_A),
        rects_b=st.lists(_rect, min_size=_N_B, max_size=_N_B),
        builder=st.sampled_from(["mqr", "rtree"]),
    )
    def test_join_matches_oracle_on_adversarial_geometry(rects_a, rects_b,
                                                         builder):
        """For arbitrary finite geometry (huge magnitudes, degenerate and
        co-located boxes) the join equals brute-force float32 overlap on
        the exact AND compact paths — the sweep may only
        over-approximate, and the confirming pass restores exactness."""
        da = np.asarray(rects_a, np.float64)
        db = np.asarray(rects_b, np.float64)
        left = SpatialIndex.build(da, structure=builder, backend="pallas")
        right = SpatialIndex.build(db, structure=builder, backend="host")
        expect = _overlap_np(
            np.asarray(da, np.float32)[:, None, :],
            np.asarray(db, np.float32)[None, :, :],
        )
        assert np.array_equal(left.join(right).pairs, expect)
        compact = SpatialIndex.build(
            da, structure=builder, backend="pallas", precision="compact"
        )
        assert np.array_equal(compact.join(right).pairs, expect)
else:
    @pytest.mark.skip(reason="pip install -r requirements-dev.txt")
    def test_join_matches_oracle_on_adversarial_geometry():
        pass
