"""MBR algebra unit + property tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import mbr as M

coord = st.floats(-1e6, 1e6, allow_nan=False, width=64)


def rect(lx, ly, hx, hy):
    return M.make_mbr(lx, ly, hx, hy)


@given(coord, coord, coord, coord)
def test_make_mbr_well_formed(a, b, c, d):
    m = rect(a, b, c, d)
    assert m[0] <= m[2] and m[1] <= m[3]


@given(coord, coord, coord, coord, coord, coord, coord, coord)
@settings(max_examples=200)
def test_merge_contains_both(a, b, c, d, e, f, g, h):
    m1, m2 = rect(a, b, c, d), rect(e, f, g, h)
    merged = M.merge(m1, m2)
    assert M.contains(merged, m1) and M.contains(merged, m2)


@given(coord, coord, coord, coord, coord, coord, coord, coord)
@settings(max_examples=200)
def test_intersection_symmetric_and_bounded(a, b, c, d, e, f, g, h):
    m1, m2 = rect(a, b, c, d), rect(e, f, g, h)
    i12 = M.intersection_area(m1, m2)
    assert i12 == M.intersection_area(m2, m1)
    assert i12 <= min(M.area(m1), M.area(m2)) + 1e-6
    assert (i12 > 0) <= bool(M.overlaps(m1, m2))


def test_union_area_exact_cases():
    rects = np.array([[0, 0, 2, 2], [1, 1, 3, 3]], float)
    assert M.union_area(rects) == pytest.approx(7.0)
    rects = np.array([[0, 0, 1, 1], [2, 2, 3, 3]], float)
    assert M.union_area(rects) == pytest.approx(2.0)
    # containment
    rects = np.array([[0, 0, 4, 4], [1, 1, 2, 2]], float)
    assert M.union_area(rects) == pytest.approx(16.0)


@given(st.integers(1, 8), st.integers(0, 10_000))
@settings(max_examples=50)
def test_union_area_vs_monte_carlo(n, seed):
    rng = np.random.default_rng(seed)
    ll = rng.uniform(0, 8, (n, 2))
    wh = rng.uniform(0.1, 4, (n, 2))
    rects = np.concatenate([ll, ll + wh], axis=1)
    exact = M.union_area(rects)
    pts = rng.uniform(0, 12, (4000, 2))
    inside = M.contains_point(rects[:, None, :], pts[None, :, :]).any(axis=0)
    approx = inside.mean() * 144.0
    assert abs(exact - approx) < 0.15 * 144.0


def test_pairwise_overlap_total():
    rects = np.array([[0, 0, 2, 2], [1, 1, 3, 3], [10, 10, 11, 11]], float)
    assert M.pairwise_overlap_total(rects) == pytest.approx(1.0)
