"""Autotuned tiling for the fused sweep (DESIGN.md §12).

The tuner only ever changes WHICH configuration runs — every candidate is
bit-identical on answers — so the tests pin the selection machinery:
candidate grids always contain the fixed default (tuned can't lose to
fixed), shape keys bucket correctly, timing picks the fastest fake
runner and survives raising candidates, explicit overrides pin the fixed
config without spending tuning time, and winners land in
``BuildArtifacts.tuned`` where ``with_backend`` twins reuse them.
"""
import time

import numpy as np
import pytest

import conftest
from repro.index import SpatialIndex
from repro.kernels.autotune import (
    AUTO_MIN_WIDTH,
    TileConfig,
    candidates,
    shape_key,
    tune,
)

_N = 260


def _data(n=_N):
    return conftest.mbr_dataset("test_autotune", "uniform_squares", n)


def _queries(n=_N):
    return conftest.dataset_queries("test_autotune", "uniform_squares", n)


# ---------------------------------------------------------------------------
# candidate grid
# ---------------------------------------------------------------------------


def test_candidates_always_include_fixed_default():
    for kwargs in (
        dict(precision="float32"),
        dict(precision="compact"),
        dict(precision="compact8"),
        dict(precision="float32", stream=True),
        dict(precision="float32", live=True),
    ):
        cands = candidates(2048, 64, **kwargs)
        assert TileConfig() in cands


def test_candidates_per_level_plan_only_for_plain_float32():
    plain = candidates(2048, 64, precision="float32")
    assert any(not c.levels_in_grid for c in plain)
    for kwargs in (
        dict(precision="compact"),
        dict(precision="compact8"),
        dict(precision="float32", stream=True),
        dict(precision="float32", live=True),
    ):
        assert all(
            c.levels_in_grid for c in candidates(2048, 64, **kwargs)
        )


def test_candidates_block_ws_bounded_by_width():
    # a 200-wide grid never proposes 512-wide tiles (pure padding)
    assert {c.block_w for c in candidates(200, 8)} <= {64, 128, 256}
    assert {c.block_w for c in candidates(4096, 8)} >= {64, 128, 256, 512}


def test_candidates_query_block_only_for_large_batches():
    assert all(c.query_block is None for c in candidates(2048, 8))
    assert any(c.query_block == 32 for c in candidates(2048, 100))


# ---------------------------------------------------------------------------
# shape keys
# ---------------------------------------------------------------------------


def test_shape_key_buckets_width_and_queries():
    a = shape_key(1000, 5, 60, "float32", False)
    b = shape_key(1024, 5, 64, "float32", False)
    assert a == b
    assert shape_key(1025, 5, 64, "float32", False) != a


def test_shape_key_exact_on_kernel_identity():
    base = shape_key(1024, 5, 64, "float32", False)
    assert shape_key(1024, 6, 64, "float32", False) != base
    assert shape_key(1024, 5, 64, "compact", False) != base
    assert shape_key(1024, 5, 64, "float32", True) != base


# ---------------------------------------------------------------------------
# the timing loop
# ---------------------------------------------------------------------------


def test_tune_picks_fastest_and_skips_raising():
    slow = TileConfig(64)
    fast = TileConfig(128)
    broken = TileConfig(256)

    def make_run(cfg):
        if cfg is broken:
            raise RuntimeError("unsupported tile")
        delay = 0.02 if cfg is slow else 0.0
        return lambda: time.sleep(delay)

    best, timings = tune(make_run, [slow, broken, fast], iters=2)
    assert best == fast
    assert broken not in timings
    assert timings[slow] > timings[fast]


def test_tune_all_raising_falls_back_to_default():
    def make_run(cfg):
        raise RuntimeError("no runtime")

    best, timings = tune(make_run, [TileConfig(64), TileConfig(256)])
    assert best == TileConfig()
    assert timings == {}


# ---------------------------------------------------------------------------
# backend wiring: pinning, tuning, and the shared winner cache
# ---------------------------------------------------------------------------


def test_explicit_block_w_pins_fixed_config():
    idx = SpatialIndex.build(
        _data(), backend="pallas",
        backend_opts={"block_w": 256, "autotune": "on"},
    )
    host = SpatialIndex.build(_data(), backend="host")
    qs = _queries()
    res = idx.region(qs)
    assert np.array_equal(res.hits, host.region(qs).hits)
    assert idx.artifacts.tuned == {}  # explicit override: no timing spent


def test_autotune_off_pins_fixed_config():
    idx = SpatialIndex.build(
        _data(), backend="pallas", backend_opts={"autotune": "off"}
    )
    idx.region(_queries())
    assert idx.artifacts.tuned == {}


def test_autotune_auto_skips_narrow_grids():
    idx = SpatialIndex.build(_data(), backend="pallas")  # width << 1024
    assert idx.artifacts.schedule.width < AUTO_MIN_WIDTH
    idx.region(_queries())
    assert idx.artifacts.tuned == {}


def test_autotune_on_tunes_and_caches_in_artifacts():
    data, qs = _data(), _queries()
    host = SpatialIndex.build(data, backend="host")
    idx = SpatialIndex.build(
        data, backend="pallas", backend_opts={"autotune": "on"}
    )
    res = idx.region(qs)
    assert np.array_equal(res.hits, host.region(qs).hits)
    assert len(idx.artifacts.tuned) == 1
    (key, cfg), = idx.artifacts.tuned.items()
    assert key == shape_key(
        idx.artifacts.schedule.width, idx.artifacts.schedule.levels,
        qs.shape[0], "float32", False,
    )
    assert isinstance(cfg, TileConfig)
    # same shape again: the cached winner is reused, not re-timed
    idx.region(qs)
    assert len(idx.artifacts.tuned) == 1


def test_with_backend_twin_shares_tuned_cache():
    data, qs = _data(), _queries()
    idx = SpatialIndex.build(
        data, backend="pallas", backend_opts={"autotune": "on"}
    )
    ref = idx.region(qs)
    twin = idx.with_backend("pallas", autotune="on")
    res = twin.region(qs)
    assert np.array_equal(res.hits, ref.hits)
    assert len(idx.artifacts.tuned) == 1  # twin reused the measurement


def test_autotune_validation():
    with pytest.raises(ValueError, match="autotune"):
        SpatialIndex.build(
            _data(), backend="pallas", backend_opts={"autotune": "sometimes"}
        )


# ---------------------------------------------------------------------------
# backend_opts strictness (satellite a)
# ---------------------------------------------------------------------------


def test_backend_opts_unknown_key_is_typeerror():
    with pytest.raises(TypeError):
        SpatialIndex.build(
            _data(), backend="pallas", backend_opts={"block_width": 256}
        )


def test_backend_opts_duplicate_of_direct_opt_is_typeerror():
    with pytest.raises(TypeError, match="duplicates"):
        SpatialIndex.build(
            _data(), backend="pallas", precision="compact",
            backend_opts={"precision": "float32"},
        )


def test_backend_opts_rejects_build_options():
    with pytest.raises(TypeError, match="build option"):
        SpatialIndex.build(
            _data(), backend="pallas", backend_opts={"levels": 3}
        )
    with pytest.raises(TypeError, match="build option"):
        SpatialIndex.build(
            _data(), backend="pallas", backend_opts={"order": "hilbert"}
        )


def test_backend_opts_none_and_empty_are_noops():
    qs = _queries()
    a = SpatialIndex.build(_data(), backend="pallas")
    b = SpatialIndex.build(_data(), backend="pallas", backend_opts={})
    assert np.array_equal(a.region(qs).hits, b.region(qs).hits)
