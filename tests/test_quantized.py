"""Compact uint16 tiles: conservative by construction, exact after confirm.

DESIGN.md §7's contract: the quantized sweep prunes a SUPERSET of the
exact survivors (outward rounding can only widen boxes), and the exact
float32 confirming pass makes the final hit sets bit-identical to the
float32 path — across structures, backends, dataset shapes, and (via
hypothesis) adversarial coordinate distributions.  Visit counts are the
compact sweep's own: always >= the exact path's, never fewer.
"""
import numpy as np
import pytest

import jax.numpy as jnp

import conftest
from repro.core import datasets, flat, mqrtree, rtree
from repro.core.flat import CELLS, Q_NEVER_MBR
from repro.index import SpatialIndex
from repro.kernels import ops
from repro.kernels import quantize as kq

# shared builders live in tests/conftest.py; sizes are this module's own
_SIZES = {
    "uniform_squares": 300,
    "uniform_points": 256,
    "exponential_squares": 250,
}
DATASETS = {
    name: (lambda name=name: conftest.mbr_dataset(
        "test_quantized", name, _SIZES[name]))
    for name in _SIZES
}


def _overlap_np(a, b):
    return (
        (a[..., 0] <= b[..., 2])
        & (b[..., 0] <= a[..., 2])
        & (a[..., 1] <= b[..., 3])
        & (b[..., 1] <= a[..., 3])
    )


@pytest.mark.parametrize("name", sorted(DATASETS))
@pytest.mark.parametrize("structure", ["mqr", "rtree", "pyramid"])
def test_compact_hits_bit_identical(name, structure):
    data = DATASETS[name]()
    qs = datasets.region_queries(data, 6, seed=6)
    idx = SpatialIndex.build(data, structure=structure, backend="pallas")
    ref = idx.region(qs)
    cmp_ = idx.with_backend("pallas", precision="compact").region(qs)
    assert np.array_equal(cmp_.hits, ref.hits), f"{structure} on {name}"
    # conservative sweep: never fewer accesses than the exact sweep
    assert (cmp_.visits_per_level >= ref.visits_per_level).all()


def test_outward_rounding_contains_exact_boxes():
    """Every finite quantized box contains its exact box on the grid:
    lo cells round down, hi cells round up."""
    data = DATASETS["uniform_squares"]()
    sched = flat.level_schedule(flat.flatten(mqrtree.build(data)))
    qsched = ops.quantize_schedule(sched)
    exact = (sched.mbr_cm - qsched.origin[None, :, None]) \
        * qsched.inv_cell[None, :, None]
    q = qsched.mbr_q.astype(np.float64)
    finite = np.isfinite(sched.mbr_cm)
    lo = finite[:, :2]
    assert (q[:, :2][lo] <= exact[:, :2][lo] + 1e-6).all()
    hi = finite[:, 2:]
    assert (q[:, 2:][hi] >= exact[:, 2:][hi] - 1e-6).all()


def test_padded_slots_quantize_to_never_sentinel():
    data = DATASETS["uniform_squares"]()
    sched = flat.level_schedule(flat.flatten(mqrtree.build(data)))
    qsched = ops.quantize_schedule(sched)
    padded = ~np.isfinite(sched.mbr_cm[:, 0, :])  # lo_x == +inf
    assert padded.any()
    for c in range(4):
        assert (qsched.mbr_q[:, c, :][padded] == Q_NEVER_MBR[c]).all()
    assert Q_NEVER_MBR[0] == CELLS + 1  # lo beyond every clipped query hi


def test_quantize_kernel_matches_jnp():
    data = DATASETS["exponential_squares"]()
    sched = ops.device_schedule(data)
    origin, inv_cell = kq.grid_params(sched)
    a = kq.quantize_cm_pallas(
        sched.mbr_cm, jnp.asarray(origin), jnp.asarray(inv_cell),
        interpret=True,
    )
    b = kq.quantize_cm_jnp(
        sched.mbr_cm, jnp.asarray(origin), jnp.asarray(inv_cell)
    )
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_wide_schedule_falls_back_to_int32_parents():
    """precision="compact" must not fail in the large-n regime it exists
    for: pyramid schedules wider than uint16 slots keep int32 parents
    (tiles stay uint16, bytes ratio 0.6 instead of 0.5)."""
    n = (1 << 16) + 8
    data = datasets.uniform_points(n, seed=3)
    sched = ops.device_schedule(data, engine="jnp")
    assert sched.width == n > np.iinfo(np.uint16).max
    qsched = ops.quantize_schedule(sched, engine="jnp")
    assert qsched.parent_q.dtype == np.int32
    assert qsched.mbr_q.dtype == np.uint16
    # and the narrow case still streams uint16 parents
    narrow = ops.quantize_schedule(
        ops.device_schedule(data[:512], engine="jnp"), engine="jnp"
    )
    assert narrow.parent_q.dtype == np.uint16


def test_serve_compact_transparent():
    """The batching server in compact precision returns the same hits as
    the float32 fused scan, through dedupe, padding, and the LRU."""
    data = DATASETS["uniform_squares"]()
    sched = flat.level_schedule(flat.flatten(mqrtree.build(data)))
    from repro.launch.spatial_serve import SpatialServer

    server = SpatialServer(sched, query_block=4, cache_size=64,
                           precision="compact")
    qs = datasets.region_queries(data, 6, seed=14)
    stream = np.concatenate([qs, qs[:3]])
    hits, visits = server.search(stream)
    ref_hits, _ = ops.pyramid_scan(sched, stream)
    assert np.array_equal(hits, np.asarray(ref_hits))
    # second pass served from cache, no extra launches
    launches = server.stats.kernel_launches
    hits2, _ = server.search(qs)
    assert np.array_equal(hits2, hits[:6])
    assert server.stats.kernel_launches == launches


def test_facade_stats_count_compact_accesses():
    data = DATASETS["uniform_squares"]()
    qs = datasets.region_queries(data, 6, seed=6)
    idx = SpatialIndex.build(
        data, structure="pyramid", backend="pallas", build="device",
        precision="compact",
    )
    res = idx.region(qs)
    # the ledger records what the compact sweep actually fetched
    assert idx.stats.node_accesses == int(res.visits_per_level.sum())
    assert idx.stats.launches == 1


# ---------------------------------------------------------------------------
# Hierarchical uint8 upper-level tiles (DESIGN.md §12)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(DATASETS))
@pytest.mark.parametrize("structure", ["mqr", "rtree", "pyramid"])
def test_compact8_hits_bit_identical(name, structure):
    """uint8 coarse tiles above the leaf level + uint16 leaves + exact
    confirm == float32 hit sets, across structures and dataset shapes.
    Visits are compared against the FLOAT32 sweep (the u8 and u16 grids
    are not nested, so c8 vs c visit counts can go either way)."""
    data = DATASETS[name]()
    qs = datasets.region_queries(data, 6, seed=6)
    idx = SpatialIndex.build(data, structure=structure, backend="pallas")
    ref = idx.region(qs)
    c8 = idx.with_backend("pallas", precision="compact8").region(qs)
    assert np.array_equal(c8.hits, ref.hits), f"{structure} on {name}"
    assert (c8.visits_per_level >= ref.visits_per_level).all()


def test_quantized8_schedule_layout():
    data = DATASETS["uniform_squares"]()
    sched = flat.level_schedule(flat.flatten(mqrtree.build(data)))
    qsched = ops.quantize_schedule(sched, upper8=True)
    assert qsched.hierarchical
    assert qsched.split == sched.levels - 1  # leaf level stays uint16
    assert qsched.mbr_q8.dtype == np.uint8
    assert qsched.mbr_q8.shape == (qsched.split, 4, qsched.width)
    # coarse boxes contain exact boxes on the uint8 grid (outward
    # rounding); both sides clip to [0, cells8] — queries clip to the
    # same range, which is what keeps boundary cells conservative
    exact8 = np.clip(
        (sched.mbr_cm[:qsched.split] - qsched.origin[None, :, None])
        * qsched.inv_cell8[None, :, None],
        0.0, float(qsched.cells8),
    )
    q8 = qsched.mbr_q8.astype(np.float64)
    finite = np.isfinite(sched.mbr_cm[:qsched.split])
    assert (q8[:, :2][finite[:, :2]] <= exact8[:, :2][finite[:, :2]] + 1e-6).all()
    assert (q8[:, 2:][finite[:, 2:]] >= exact8[:, 2:][finite[:, 2:]] - 1e-6).all()


def test_compact8_single_level_degenerates_to_uint16():
    """A one-level schedule has no upper levels to coarsen: split == 0 and
    the sweep is the plain uint16 path."""
    data = DATASETS["uniform_squares"]()[:40]
    sched = ops.device_schedule(data, levels=1, engine="jnp")
    qsched = ops.quantize_schedule(sched, upper8=True, engine="jnp")
    assert qsched.split == 0 and not qsched.hierarchical
    qs = datasets.region_queries(data, 4, seed=9)
    h8, _ = ops.pyramid_scan_compact8(qsched, qs, interpret=True)
    hf, _ = ops.pyramid_scan(sched, qs, interpret=True)
    assert np.array_equal(np.asarray(h8), np.asarray(hf))


def test_compact8_adversarial_boundary_geometry():
    """Deterministic mirror of the hypothesis property below (which the
    image skips: hypothesis is a dev-only dependency): geometry engineered
    to sit ON uint8 cell boundaries — boxes a hair inside/outside coarse
    cell edges, degenerate points co-located at a cell corner, and a huge
    outlier that stretches the grid so every other box collapses into few
    coarse cells.  Coarse rounding must never drop a true hit."""
    eps = 1e-3
    rects = [
        (0.0, 0.0, 1.0, 1.0),
        (1.0 + eps, 1.0 + eps, 2.0, 2.0),     # just past a shared corner
        (1.0 - eps, 1.0 - eps, 1.0, 1.0),     # just inside it
        (1.0, 1.0, 1.0, 1.0),                 # a point ON the corner
        (1.0, 1.0, 1.0, 1.0),                 # co-located twin
        (-1e6, -1e6, -1e6 + eps, -1e6 + eps),  # grid-stretching outlier
        (257.0, 257.0, 258.0, 258.0),         # >> 254 coarse cells away
        (0.5, 0.5, 0.5 + eps, 0.5 + eps),
    ]
    data = np.asarray(rects, np.float64)
    qs = np.asarray(
        [
            (1.0, 1.0, 1.0, 1.0),             # point query on the corner
            (0.0, 0.0, 2.0, 2.0),
            (1.0 + eps / 2, 1.0 + eps / 2, 1.5, 1.5),  # between the eps pair
            (-1e6, -1e6, -1e6, -1e6),
            (300.0, 300.0, 301.0, 301.0),     # empty region
        ],
        np.float32,
    )
    for build in (mqrtree.build, rtree.build):
        sched = flat.level_schedule(flat.flatten(build(data)))
        qsched = ops.quantize_schedule(sched, upper8=True)
        hits_f, visits_f = ops.pyramid_scan(sched, qs)
        hits_8, visits_8 = ops.pyramid_scan_compact8(qsched, qs)
        hits_f, hits_8 = np.asarray(hits_f), np.asarray(hits_8)
        assert np.array_equal(hits_8, hits_f)
        brute = _overlap_np(
            np.asarray(sched.obj_mbr, np.float32)[None, :, :], qs[:, None, :]
        )
        expect = np.zeros_like(hits_f)
        np.maximum.at(expect, (slice(None), sched.obj_id), brute)
        assert np.array_equal(hits_f, expect)
        assert (np.asarray(visits_8) >= np.asarray(visits_f)).all()


def test_compact8_matches_fallback_twins():
    from repro.kernels import fallback

    data = DATASETS["exponential_squares"]()
    qs = datasets.region_queries(data, 6, seed=11)
    sched = flat.level_schedule(flat.flatten(mqrtree.build(data)))
    qsched = ops.quantize_schedule(sched, upper8=True)
    ref_h, ref_v = ops.pyramid_scan_compact8(qsched, qs)
    args = (
        qs, qsched.mbr_q8, qsched.mbr_q[qsched.split:], qsched.parent_q,
        qsched.confirm_mbr, sched.obj_level, sched.obj_slot, sched.obj_id,
        qsched.origin, qsched.inv_cell, qsched.inv_cell8,
    )
    kwargs = dict(
        n_objects=sched.n_objects, cells=qsched.cells, cells8=qsched.cells8,
        split=qsched.split, root_unconditional=sched.root_unconditional,
    )
    for fn in (fallback.fused_search_compact8_lax,
               fallback.fused_search_compact8_np):
        h, v = fn(*args, **kwargs)
        assert np.array_equal(np.asarray(h), np.asarray(ref_h))
        assert np.array_equal(np.asarray(v), np.asarray(ref_v))


def test_serve_compact8_transparent():
    data = DATASETS["uniform_squares"]()
    sched = flat.level_schedule(flat.flatten(mqrtree.build(data)))
    from repro.launch.spatial_serve import SpatialServer

    server = SpatialServer(sched, query_block=4, cache_size=64,
                           precision="compact8")
    qs = datasets.region_queries(data, 6, seed=15)
    hits, _ = server.search(qs)
    ref_hits, _ = ops.pyramid_scan(sched, qs)
    assert np.array_equal(hits, np.asarray(ref_hits))


# ---------------------------------------------------------------------------
# Property: conservative rounding never drops a true hit
# ---------------------------------------------------------------------------
# The guard is a try/except (test_join.py idiom), NOT a module-level
# ``importorskip``: the deterministic parity tests above must still run
# where the dev extras are absent — only the property tests skip.

try:
    from hypothesis import given, settings, strategies as st

    _HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dev dependency
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    _coord = st.floats(
        min_value=-1e6, max_value=1e6, allow_nan=False, allow_infinity=False,
        width=32,
    )
    _rect = st.tuples(_coord, _coord, _coord, _coord).map(
        lambda t: (min(t[0], t[2]), min(t[1], t[3]),
                   max(t[0], t[2]), max(t[1], t[3]))
    )

    # Fixed sizes so the jitted scans compile once across examples.
    _N_OBJ, _N_Q = 16, 4

    @settings(max_examples=25, deadline=None)
    @given(
        rects=st.lists(_rect, min_size=_N_OBJ, max_size=_N_OBJ),
        queries=st.lists(_rect, min_size=_N_Q, max_size=_N_Q),
        builder=st.sampled_from(["mqr", "rtree"]),
    )
    def test_conservative_rounding_never_drops_a_hit(rects, queries, builder):
        """For arbitrary finite geometry (huge magnitudes, degenerate/point
        boxes, co-located objects), the compact pipeline's hit sets equal
        brute-force float32 overlap — the quantized sweep may widen boxes
        by a grid cell but the confirming pass restores exactness, and no
        true hit is ever dropped."""
        data = np.asarray(rects, np.float64)
        qs = np.asarray(queries, np.float32)
        build = mqrtree.build if builder == "mqr" else rtree.build
        sched = flat.level_schedule(flat.flatten(build(data)))
        qsched = ops.quantize_schedule(sched)
        hits_f, visits_f = ops.pyramid_scan(sched, qs)
        hits_c, visits_c = ops.pyramid_scan_compact(qsched, qs)
        hits_f, hits_c = np.asarray(hits_f), np.asarray(hits_c)
        # never a dropped hit, and (after confirm) never a spurious one
        assert np.array_equal(hits_c, hits_f)
        # the exact semantics: brute-force float32 rectangle overlap
        brute = _overlap_np(
            np.asarray(sched.obj_mbr, np.float32)[None, :, :], qs[:, None, :]
        )
        expect = np.zeros_like(hits_f)
        np.maximum.at(expect, (slice(None), sched.obj_id), brute)
        assert np.array_equal(hits_f, expect)
        assert (np.asarray(visits_c) >= np.asarray(visits_f)).all()

    @settings(max_examples=25, deadline=None)
    @given(
        rects=st.lists(_rect, min_size=_N_OBJ, max_size=_N_OBJ),
        queries=st.lists(_rect, min_size=_N_Q, max_size=_N_Q),
        builder=st.sampled_from(["mqr", "rtree"]),
    )
    def test_uint8_coarse_rounding_never_drops_a_hit(rects, queries, builder):
        """The hierarchical compact8 pipeline under the same adversarial
        geometry: 254-cell uint8 upper tiles are far coarser than the
        uint16 grid, but outward rounding + the exact confirming pass keep
        hit sets equal to brute-force float32 overlap.  Visits are bounded
        below by the FLOAT32 sweep only — the u8 and u16 grids are not
        nested."""
        data = np.asarray(rects, np.float64)
        qs = np.asarray(queries, np.float32)
        build = mqrtree.build if builder == "mqr" else rtree.build
        sched = flat.level_schedule(flat.flatten(build(data)))
        qsched = ops.quantize_schedule(sched, upper8=True)
        hits_f, visits_f = ops.pyramid_scan(sched, qs)
        hits_8, visits_8 = ops.pyramid_scan_compact8(qsched, qs)
        hits_f, hits_8 = np.asarray(hits_f), np.asarray(hits_8)
        assert np.array_equal(hits_8, hits_f)
        brute = _overlap_np(
            np.asarray(sched.obj_mbr, np.float32)[None, :, :], qs[:, None, :]
        )
        expect = np.zeros_like(hits_f)
        np.maximum.at(expect, (slice(None), sched.obj_id), brute)
        assert np.array_equal(hits_f, expect)
        assert (np.asarray(visits_8) >= np.asarray(visits_f)).all()

else:
    @pytest.mark.skip(reason="pip install -r requirements-dev.txt")
    def test_conservative_rounding_never_drops_a_hit():
        pass

    @pytest.mark.skip(reason="pip install -r requirements-dev.txt")
    def test_uint8_coarse_rounding_never_drops_a_hit():
        pass
