"""Data pipeline determinism + spatial shard router."""
import numpy as np

from repro.core import datasets
from repro.data import DataConfig, SyntheticLM, route_shards


def test_batch_determinism_and_shapes():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=8, n_shards=2, shard_id=1)
    ds = SyntheticLM(cfg)
    b1, b2 = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


def test_shards_differ():
    mk = lambda sid: SyntheticLM(
        DataConfig(vocab_size=100, seq_len=32, global_batch=8, n_shards=2, shard_id=sid)
    ).batch(0)["tokens"]
    assert not (mk(0) == mk(1)).all()


def test_spatial_router_assigns_all_disjoint():
    shard_mbrs = datasets.uniform_squares(64, seed=7, side=30.0)
    assign = route_shards(shard_mbrs, n_hosts=8)
    got = sorted(i for ids in assign.values() for i in ids)
    assert got == list(range(64))
    # spatial coherence: avg within-host bbox area << global area
    from repro.core import mbr as M

    areas = []
    for ids in assign.values():
        if ids:
            areas.append(M.area(M.merge_many(shard_mbrs[ids])))
    assert np.mean(areas) < 0.5 * M.area(M.merge_many(shard_mbrs))
