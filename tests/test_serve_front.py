"""Serving front end: batching, admission, tenants, parity (DESIGN.md §11).

The contract under test, in order of importance:

1. every queue-served answer is BIT-IDENTICAL to calling the tenant's
   SpatialIndex directly — including while a FaultPlan forces the pallas
   rung to fail mid-run (degradation shows as slower batches, never as
   wrong or failed answers);
2. continuous batching launches on EITHER bound — a full query_block, or
   the oldest request's deadline slack running out (driven by a fake
   clock, so the tests are deterministic);
3. admission control sheds or parks per SLO class, visibly in the
   per-tenant AccessStats ledger;
4. the boundary rejects degenerate geometry with the typed
   InvalidQueryError before it can poison a batch;
5. tenants are isolated: one tenant's mutations bump only its own epoch,
   the other's cached answers stay valid and bit-identical.
"""

from __future__ import annotations

import pathlib
import re

import numpy as np
import pytest

from conftest import f32_exact, mbr_dataset

from repro.ft import FaultPlan, InjectedFailure
from repro.index import InvalidQueryError, SpatialIndex
from repro.serve import (
    OverloadShed,
    ServerConfig,
    ServingFrontEnd,
    SLOClass,
    TenantConfig,
)
from repro.serve.loadgen import data_extent, poisson_arrivals, rect_workload
from repro.serve.telemetry import LatencyHistogram

MOD = "serve_front"
N = 220


class FakeClock:
    """Deterministic front-end clock: time moves only when told to."""

    def __init__(self, t: float = 100.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _data(kind: str = "exponential_squares") -> np.ndarray:
    return f32_exact(mbr_dataset(MOD, kind, N))


def _front(*, query_block=4, clock=None, classes=None, tenants=None,
           data=None, **cfg_extra) -> ServingFrontEnd:
    mbrs = _data() if data is None else data
    cfg = ServerConfig.from_dict({
        "tenants": tenants or [
            {"name": "a", "backend": "host"},
        ],
        "classes": classes or [
            {"name": "interactive", "deadline_ms": 50.0,
             "overload": "shed", "max_queue": 8},
            {"name": "batch", "deadline_ms": 2000.0, "overload": "queue",
             "max_queue": 4},
        ],
        "query_block": query_block,
        **cfg_extra,
    })
    names = [t.name for t in cfg.tenants]
    return ServingFrontEnd.build(
        cfg, {n: mbrs for n in names},
        clock=clock or FakeClock(),
    )


# ---------------------------------------------------------------------------
# declarative config boundary (the factory-config contract)
# ---------------------------------------------------------------------------


def test_config_typo_raises_with_accepted_keys():
    with pytest.raises(TypeError, match="bakend.*accepted"):
        TenantConfig.from_dict({"name": "a", "bakend": "serve"})
    with pytest.raises(TypeError, match="deadlines_ms"):
        ServerConfig.from_dict({
            "tenants": [{"name": "a"}],
            "classes": [{"name": "x", "deadlines_ms": 5}],
        })


def test_config_bad_values_fail_at_the_boundary():
    with pytest.raises(ValueError, match="structure"):
        TenantConfig(name="a", structure="kdtree")
    with pytest.raises(ValueError, match="backend"):
        TenantConfig(name="a", backend="gpu")
    with pytest.raises(ValueError, match="overload"):
        SLOClass("x", deadline_ms=10, overload="drop")
    with pytest.raises(ValueError, match="duplicate"):
        ServerConfig.from_dict(
            {"tenants": [{"name": "a"}, {"name": "a"}]}
        )
    with pytest.raises(ValueError, match="at least one tenant"):
        ServerConfig.from_dict({"tenants": []})


def test_unknown_tenant_kind_and_slo_rejected():
    front = _front()
    with pytest.raises(ValueError, match="unknown tenant"):
        front.submit("nope", "region", [0, 0, 1, 1])
    with pytest.raises(ValueError, match="unknown kind"):
        front.submit("a", "nearest", [0, 0])
    with pytest.raises(ValueError, match="unknown SLO class"):
        front.submit("a", "region", [0, 0, 1, 1], slo="platinum")


# ---------------------------------------------------------------------------
# continuous batching: size bound and deadline bound
# ---------------------------------------------------------------------------


def test_full_block_launches_without_waiting():
    clock = FakeClock()
    front = _front(query_block=4, clock=clock)
    reqs = [front.submit("a", "region", [0, 0, 9, 9]) for _ in range(4)]
    assert front.pump() == 1          # size bound tripped, clock never moved
    assert all(r.done for r in reqs)
    assert front.telemetry.deadline_launches == 0
    assert front.telemetry.avg_batch == 4.0


def test_partial_batch_waits_then_launches_on_deadline_slack():
    clock = FakeClock()
    front = _front(query_block=4, clock=clock)
    req = front.submit("a", "region", [0, 0, 9, 9])   # 50 ms deadline
    assert front.pump() == 0          # fresh: plenty of slack
    clock.advance(0.010)
    assert front.pump() == 0          # 10 ms in: still slack
    clock.advance(0.038)              # 48 ms in: inside slack margin
    assert front.pump() == 1
    assert req.done
    assert front.telemetry.deadline_launches == 1
    # the ticket records the full enqueue -> launch -> complete timeline
    tl = req.timeline()
    assert tl.queue_wait == pytest.approx(0.048)
    assert tl.latency >= tl.queue_wait


def test_coalescing_groups_by_tenant_and_k():
    front = _front(
        query_block=8,
        tenants=[{"name": "a", "backend": "host"},
                 {"name": "b", "backend": "host"}],
    )
    front.submit("a", "region", [0, 0, 1, 1])
    front.submit("a", "point", [0.5, 0.5])
    front.submit("a", "count", [0, 0, 2, 2])
    front.submit("b", "region", [0, 0, 1, 1])
    front.submit("a", "knn", [0.5, 0.5], k=3)
    front.submit("a", "knn", [0.1, 0.1], k=5)
    # rect kinds coalesce per tenant; knn splits further per k
    assert front.queue.pending() == 6
    assert len(front.queue.drain_keys()) == 4
    assert front.drain() == 4
    assert front.telemetry.completed == 6


# ---------------------------------------------------------------------------
# admission control: shed and queue per SLO class
# ---------------------------------------------------------------------------


def test_overload_shed_returns_typed_ticket_and_counts():
    front = _front(classes=[
        {"name": "interactive", "deadline_ms": 50.0, "overload": "shed",
         "max_queue": 2},
    ])
    r1 = front.submit("a", "region", [0, 0, 1, 1])
    r2 = front.submit("a", "region", [0, 0, 2, 2])
    r3 = front.submit("a", "region", [0, 0, 3, 3])   # over max_queue=2
    assert r3.status == "shed"
    with pytest.raises(OverloadShed, match="shed by admission control"):
        front.result(r3)
    assert front.telemetry.shed == 1
    assert front.stats("a").shed_queries == 1
    # the admitted requests still complete normally
    front.drain()
    assert r1.done and r2.done
    assert front.telemetry.completed == 2


def test_overload_queue_parks_but_still_serves():
    clock = FakeClock()
    front = _front(clock=clock, classes=[
        {"name": "batch", "deadline_ms": 100.0, "overload": "queue",
         "max_queue": 1},
    ])
    r1 = front.submit("a", "region", [0, 0, 1, 1])
    r2 = front.submit("a", "region", [0, 0, 2, 2])   # parked past max_queue
    assert r2.parked and r2.status == "pending"
    assert front.stats("a").queued_queries == 1
    # parked requests never drive the deadline bound...
    clock.advance(10.0)
    front.pump()
    assert r1.done          # r1's deadline launched the group
    assert r2.done          # ...but parked riders launch with it, FIFO
    assert front.telemetry.queued_overload == 1


# ---------------------------------------------------------------------------
# the hardened boundary
# ---------------------------------------------------------------------------


def test_degenerate_geometry_rejected_typed_and_batch_unpoisoned():
    front = _front()
    good = front.submit("a", "region", [0, 0, 5, 5])
    for bad in ([np.nan, 0, 1, 1], [0, 0, np.inf, 1], [3, 0, 1, 1]):
        with pytest.raises(InvalidQueryError):
            front.submit("a", "region", bad)
    with pytest.raises(InvalidQueryError, match="finite"):
        front.submit("a", "point", [np.nan, 0.5])
    with pytest.raises(InvalidQueryError, match="k"):
        front.submit("a", "knn", [0.5, 0.5], k=0)
    with pytest.raises(InvalidQueryError, match="exceeds"):
        front.submit("a", "knn", [0.5, 0.5], k=N + 1)
    # InvalidQueryError is a ValueError: one except clause serves both
    assert issubclass(InvalidQueryError, ValueError)
    # the rejected requests never entered the queue
    assert front.queue.pending() == 1
    ref = SpatialIndex.build(_data(), backend="host")
    hits = front.result(good).hits
    assert (hits == ref.region(np.array([[0, 0, 5, 5]], np.float32))
            .hits[0]).all()
    assert front.telemetry.rejected == 4


def test_served_engine_boundary_is_hardened_too():
    # satellite: the low-level SpatialServer validates as well, so even
    # callers that bypass the front end can't poison a padded batch
    idx = SpatialIndex.build(_data(), backend="serve", query_block=4)
    with pytest.raises(InvalidQueryError):
        idx.region(np.array([[0, 0, np.nan, 1]], np.float32))
    with pytest.raises(InvalidQueryError):
        idx.region(np.array([[5, 0, 1, 1]], np.float32))


# ---------------------------------------------------------------------------
# bit-parity: served == direct, always — the acceptance criterion
# ---------------------------------------------------------------------------


def _drive_mixed(front, tenant, rects, *, knn_every=4, k=3):
    """Submit a mixed open-loop trace; return [(req, kind, payload)]."""
    out = []
    for i, rect in enumerate(rects):
        if knn_every and i % knn_every == knn_every - 1:
            req = front.submit(tenant, "knn", rect[:2], k=k)
            out.append((req, "knn", rect[:2]))
        else:
            kind = ("region", "count", "point")[i % 3]
            payload = rect[:2] if kind == "point" else rect
            req = front.submit(tenant, kind, payload)
            out.append((req, kind, payload))
        front.pump()
    front.drain()
    return out


def _assert_parity(front, tenant, served, ref=None):
    """Every served answer == calling the index directly, bit for bit.

    ``ref`` defaults to the tenant's OWN index (the acceptance
    criterion); pass an independent host-backend index to additionally
    assert the repo-wide cross-backend parity on region hits.
    """
    if ref is None:
        ref = front.tenants[tenant].index
    for req, kind, payload in served:
        got = front.result(req)
        if kind == "knn":
            r = ref.knn(np.asarray(payload, np.float32)[None], k=req.k)
            assert (got[0] == r.ids[0]).all()
            assert (got[1] == r.dists[0]).all()
            continue
        rect = (
            np.concatenate([payload, payload])
            if kind == "point" else payload
        )
        r = ref.region(np.asarray(rect, np.float32)[None])
        if kind == "count":
            assert got == int(r.hits[0].sum())
        else:
            assert (got.hits == r.hits[0]).all()
            assert (got.visits == r.visits_per_level[0]).all()


@pytest.mark.parametrize("backend", ["host", "serve"])
def test_every_served_answer_bit_identical_to_direct(backend):
    data = _data()
    opts = {"backoff": 0.0} if backend == "serve" else {}
    front = _front(
        query_block=4,
        tenants=[{"name": "t", "backend": backend, "backend_opts": opts}],
        data=data,
    )
    rects = rect_workload(data_extent(data), 24, seed=11, sel=0.2)
    served = _drive_mixed(front, "t", rects)
    assert all(r.done for r, _, _ in served)
    # the acceptance criterion: served == the tenant's own index, direct
    _assert_parity(front, "t", served)
    # and region hits also match an INDEPENDENT host-backend reference
    # (cross-backend hit parity is the repo-wide invariant)
    ref = SpatialIndex.build(data, backend="host")
    for req, kind, payload in served:
        if kind == "region":
            r = ref.region(np.asarray(payload, np.float32)[None])
            assert (front.result(req).hits == r.hits[0]).all()


def test_parity_survives_mid_run_forced_degradation():
    """FaultPlan starts killing the pallas rung partway through the run:
    answers stay bit-identical, the ladder records the degradation."""
    data = _data()
    front = _front(
        query_block=4,
        tenants=[{"name": "t", "backend": "serve",
                  "backend_opts": {"backoff": 0.0, "max_retries": 0}}],
        data=data,
    )
    front.warmup()
    plan = FaultPlan(fail_launches=10 ** 9, fail_from_launch=3,
                     fail_rungs=("pallas",))
    front.bind_fault_plan(plan)

    rects = rect_workload(data_extent(data), 20, seed=13, sel=0.2)
    served = _drive_mixed(front, "t", rects, knn_every=0)
    assert all(r.done for r, _, _ in served)          # zero user-visible errors
    _assert_parity(front, "t", served)
    # the fault landed: healthy pallas batches first, lax degradation after
    assert plan.launch_failures > 0
    stats = front.stats("t")
    assert stats.degraded_batches > 0
    assert stats.rung_dispatches.get("pallas", 0) > 0
    assert stats.rung_dispatches.get("lax", 0) > 0


def test_fail_from_launch_arms_after_n_attempts():
    plan = FaultPlan(fail_launches=2, fail_from_launch=2)
    plan.launch("lax")        # not a failing rung: not even counted
    plan.launch("pallas")     # 1st pallas attempt: healthy
    plan.launch("pallas")     # 2nd: healthy
    with pytest.raises(InjectedFailure):
        plan.launch("pallas")  # 3rd: countdown armed
    with pytest.raises(InjectedFailure):
        plan.launch("pallas")
    plan.launch("pallas")     # countdown exhausted
    assert plan.launches_seen == 5
    assert plan.launch_failures == 2


# ---------------------------------------------------------------------------
# multi-tenant isolation: epochs and caches
# ---------------------------------------------------------------------------


def test_tenant_mutation_bumps_only_its_own_epoch_and_cache():
    data = _data()
    front = _front(
        query_block=2,
        tenants=[
            {"name": "a", "backend": "serve", "capacity": 32,
             "backend_opts": {"backoff": 0.0}},
            {"name": "b", "backend": "serve",
             "backend_opts": {"backoff": 0.0}},
        ],
        data=data,
    )
    rect = [0.0, 0.0, 0.6, 0.6]

    def ask(tenant):
        r = front.submit(tenant, "region", rect)
        front.drain()
        return front.result(r)

    first_a, first_b = ask("a"), ask("b")
    b_server = front.tenants["b"].spatial._backend.server
    hits_before = b_server.stats.cache_hits

    # tenant A mutates: insert inside the query rect, then merge
    gid = front.insert("a", np.array([[0.1, 0.1, 0.2, 0.2]], np.float32))
    front.flush("a")
    assert front.tenants["a"].epoch > 0
    assert front.tenants["b"].epoch == 0    # B untouched

    second_a, second_b = ask("a"), ask("b")
    # A sees its new object; B's answer is bit-identical to before...
    assert second_a.hits[int(gid[0])]
    assert (second_b.hits == first_b.hits).all()
    assert (second_b.visits == first_b.visits).all()
    # ...and was served from B's still-valid epoch-tagged cache
    assert b_server.stats.cache_hits == hits_before + 1
    # fresh reference agrees with the cached answer
    ref = SpatialIndex.build(data, backend="host")
    assert (second_b.hits == ref.region(
        np.asarray(rect, np.float32)[None]).hits[0]).all()


def test_durable_tenant_recovers_across_front_end_restart(tmp_path):
    data = _data()
    root = str(tmp_path / "tenant_a")
    tenants = [{"name": "a", "backend": "host", "durable_root": root,
                "capacity": 32}]
    front = _front(tenants=tenants, data=data)
    res = front.insert("a", np.array([[0.3, 0.3, 0.4, 0.4]], np.float32))
    assert res.applied
    gid = res.ids
    req = front.submit("a", "region", [0.25, 0.25, 0.45, 0.45])
    front.drain()
    want = front.result(req)

    # restart: same config, NO dataset needed — recovery, not rebuild
    front2 = _front(tenants=tenants, data=data)
    assert front2.tenants["a"].index.recovered_ops == 1
    req2 = front2.submit("a", "region", [0.25, 0.25, 0.45, 0.45])
    front2.drain()
    got = front2.result(req2)
    assert got.hits[int(gid[0])]
    assert (got.hits == want.hits).all()


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------


def test_latency_histogram_quantiles():
    h = LatencyHistogram()
    assert h.quantile(0.5) == 0.0
    rng = np.random.default_rng(5)
    samples = rng.lognormal(-5.0, 1.0, size=4000)
    for s in samples:
        h.record(s)
    for q in (0.5, 0.9, 0.99, 0.999):
        exact = float(np.quantile(samples, q))
        # log-bucketed: within one 7% growth factor of the exact quantile
        assert exact / 1.07 <= h.quantile(q) <= exact * 1.07
    assert h.quantile(0.5) <= h.quantile(0.99) <= h.quantile(0.999)
    assert h.mean == pytest.approx(samples.mean(), rel=1e-6)
    ms = h.quantiles_ms()
    assert set(ms) == {"p50_ms", "p99_ms", "p999_ms"}


def test_poisson_arrivals_and_snapshot_shape():
    arr = poisson_arrivals(200.0, 1.0, seed=3)
    assert (np.diff(arr) > 0).all() and arr[-1] < 1.0
    assert 120 < len(arr) < 300      # ~200 ± slack
    front = _front()
    front.submit("a", "region", [0, 0, 1, 1])
    front.drain()
    snap = front.telemetry.snapshot()
    for key in ("submitted", "completed", "shed", "p50_ms", "p99_ms",
                "p999_ms", "avg_batch", "slo_violations"):
        assert key in snap
    assert snap["completed"] == 1


# ---------------------------------------------------------------------------
# layering: one documented entry point, no private cross-imports
# ---------------------------------------------------------------------------


def test_no_private_cross_imports_between_serving_layers():
    """The front end uses only PUBLIC surface of the serving engine, and
    nothing outside repro/serve imports its `_`-private symbols — the
    same grep contract the kernel package enforces."""
    root = pathlib.Path(__file__).resolve().parents[1]
    pats = [
        # _-private imports from either launch serving module
        re.compile(
            r"from\s+repro\.launch\.(?:spatial_serve|serve)\s+import"
            r"\s+[^\n]*\b_\w+"
        ),
        re.compile(r"\bspatial_serve\._\w+"),
        # _-private imports from the front-end package, outside it
        re.compile(r"from\s+repro\.serve(?:\.\w+)?\s+import\s+[^\n]*\b_\w+"),
    ]
    offenders = []
    for sub in ("src", "tests", "benchmarks", "examples"):
        for f in sorted((root / sub).rglob("*.py")):
            inside_serve = "serve" in f.parts  # src/repro/serve/*
            text = f.read_text()
            for i, pat in enumerate(pats):
                if i == 2 and inside_serve:
                    continue  # the package may use its own privates
                for m in pat.finditer(text):
                    offenders.append(f"{f.relative_to(root)}: {m.group(0)}")
    assert not offenders, "\n".join(offenders)


def test_serving_layers_document_each_other():
    import repro.serve as front
    from repro.launch import serve as lm_serve
    from repro.launch import spatial_serve as engine

    assert "repro.serve" in (engine.__doc__ or "")
    assert "front end" in (engine.__doc__ or "").lower()
    assert "repro.serve" in (lm_serve.__doc__ or "")
    assert "front end" in (front.__doc__ or "").lower()
