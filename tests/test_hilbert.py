"""Build-time Hilbert leaf ordering is a pure renumbering (DESIGN.md §12).

``hilbert_permute`` renumbers each level's real slots along the Hilbert
curve of their MBR centers.  The contract: it is a within-level bijection
(padded slots untouched) and the sweep is invariant under it — hit sets,
``AccessStats`` ids, and per-level visit counts bit-identical on every
structure × backend pair.  Only tile locality changes, which is what the
bytes/query metric measures.
"""
import numpy as np
import pytest

import conftest
from repro.index import SpatialIndex
from repro.kernels import ops

_N = 300
STRUCTURES = ("mqr", "rtree", "pyramid")


def _data(kind="uniform_squares", n=_N):
    return conftest.mbr_dataset("test_hilbert", kind, n)


def _queries(kind="uniform_squares", n=_N):
    return conftest.dataset_queries("test_hilbert", kind, n)


# ---------------------------------------------------------------------------
# hilbert_keys: a bijection on the discrete grid
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("order", [1, 2, 4, 6])
def test_hilbert_keys_bijection_on_full_grid(order):
    n = 1 << order
    gx, gy = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    # cell centers in [0, 1) so the internal floor lands on the lattice
    keys = ops.hilbert_keys(
        (gx.ravel() + 0.5) / n, (gy.ravel() + 0.5) / n, order=order
    )
    assert np.array_equal(np.sort(keys), np.arange(n * n))


@pytest.mark.parametrize("order", [2, 4])
def test_hilbert_keys_adjacent_cells(order):
    """Consecutive curve positions are 4-adjacent grid cells — the
    locality property the tiling win rests on."""
    n = 1 << order
    gx, gy = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    xs, ys = gx.ravel(), gy.ravel()
    keys = ops.hilbert_keys((xs + 0.5) / n, (ys + 0.5) / n, order=order)
    by_key = np.argsort(keys)
    dx = np.abs(np.diff(xs[by_key]))
    dy = np.abs(np.diff(ys[by_key]))
    assert (dx + dy == 1).all()


def test_hilbert_keys_clip_out_of_range():
    keys = ops.hilbert_keys(
        np.array([-0.5, 1.5]), np.array([2.0, -1.0]), order=4
    )
    lo = ops.hilbert_keys(np.array([0.0]), np.array([0.999]), order=4)
    hi = ops.hilbert_keys(np.array([0.999]), np.array([0.0]), order=4)
    assert keys[0] == lo[0] and keys[1] == hi[0]


# ---------------------------------------------------------------------------
# hilbert_permute: within-level bijection, sweep-invariant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("structure", STRUCTURES)
def test_hilbert_permute_is_within_level_bijection(structure):
    idx = SpatialIndex.build(_data(), structure=structure, backend="pallas")
    sched = idx.artifacts.schedule
    perm = ops.hilbert_permute(sched)
    assert perm.mbr_cm.shape == sched.mbr_cm.shape
    for l in range(sched.levels):
        nr = int(sched.n_real[l])
        # real slots: same multiset of MBR columns, just renumbered
        old = np.sort(sched.mbr_cm[l, :, :nr], axis=1)
        new = np.sort(perm.mbr_cm[l, :, :nr], axis=1)
        assert np.array_equal(new, old)
        # padded slots untouched (sentinels stay where they were)
        assert np.array_equal(perm.mbr_cm[l, :, nr:], sched.mbr_cm[l, :, nr:])
        assert np.array_equal(perm.parent[l, nr:], sched.parent[l, nr:])
        if l > 0:
            # every remapped parent is a real slot of the level above
            assert (np.asarray(perm.parent[l, :nr]) <
                    int(sched.n_real[l - 1])).all()
    # child→parent containment survives the renumbering
    for l in range(1, sched.levels):
        nr = int(sched.n_real[l])
        p = np.asarray(perm.parent[l, :nr], np.int64)
        child = perm.mbr_cm[l, :, :nr]
        par = perm.mbr_cm[l - 1][:, p]
        assert (par[0] <= child[0] + 1e-6).all()
        assert (par[1] <= child[1] + 1e-6).all()
        assert (par[2] >= child[2] - 1e-6).all()
        assert (par[3] >= child[3] - 1e-6).all()


def test_hilbert_permute_unpermuted_fields_shared():
    idx = SpatialIndex.build(_data(), structure="mqr", backend="pallas")
    sched = idx.artifacts.schedule
    perm = ops.hilbert_permute(sched)
    assert perm.obj_mbr is sched.obj_mbr
    assert perm.obj_id is sched.obj_id
    assert perm.n_objects == sched.n_objects


@pytest.mark.parametrize("structure", STRUCTURES)
@pytest.mark.parametrize("backend", ["lax", "pallas"])
def test_hilbert_invariance_matrix(structure, backend):
    """order="hilbert" changes nothing observable: hits, per-query ids,
    and per-level visit counts all bit-identical across backends."""
    data, qs = _data(), _queries()
    plain = SpatialIndex.build(data, structure=structure, backend=backend)
    hil = SpatialIndex.build(
        data, structure=structure, backend=backend, order="hilbert"
    )
    ref = plain.region(qs)
    res = hil.region(qs)
    assert np.array_equal(res.hits, ref.hits)
    assert np.array_equal(res.visits_per_level, ref.visits_per_level)
    for i in range(qs.shape[0]):
        assert np.array_equal(res.ids(i), ref.ids(i))


def test_hilbert_invariance_compact_and_compact8():
    data, qs = _data(), _queries()
    plain = SpatialIndex.build(data, structure="mqr", backend="pallas")
    hil = SpatialIndex.build(
        data, structure="mqr", backend="pallas", order="hilbert"
    )
    ref = plain.region(qs)
    for precision in ("compact", "compact8"):
        res = hil.with_backend("pallas", precision=precision).region(qs)
        assert np.array_equal(res.hits, ref.hits)


def test_hilbert_access_stats_match():
    data, qs = _data(), _queries()
    plain = SpatialIndex.build(data, structure="mqr", backend="pallas")
    hil = SpatialIndex.build(
        data, structure="mqr", backend="pallas", order="hilbert"
    )
    plain.region(qs)
    hil.region(qs)
    assert hil.stats.node_accesses == plain.stats.node_accesses
    assert hil.stats.queries == plain.stats.queries


def test_hilbert_order_recorded_in_build_opts():
    idx = SpatialIndex.build(_data(), order="hilbert")
    assert idx.artifacts.build_opts.get("order") == "hilbert"


def test_hilbert_save_load_no_double_permutation(tmp_path):
    """The checkpoint stores the already-permuted schedule; restore must
    NOT apply the permutation again."""
    from repro.checkpoint.spatial import load_index, save_index

    data, qs = _data(), _queries()
    idx = SpatialIndex.build(
        data, structure="mqr", backend="pallas", order="hilbert"
    )
    ref = idx.region(qs)
    path = tmp_path / "hilbert.idx"
    save_index(idx, path)
    back = load_index(path, backend="pallas")
    assert back.artifacts.build_opts.get("order") == "hilbert"
    assert np.array_equal(
        back.artifacts.schedule.parent, idx.artifacts.schedule.parent
    )
    res = back.region(qs)
    assert np.array_equal(res.hits, ref.hits)
    assert np.array_equal(res.visits_per_level, ref.visits_per_level)


def test_order_validation():
    with pytest.raises(ValueError, match="order"):
        SpatialIndex.build(_data(), order="zorder")
    with pytest.raises(ValueError):
        ops.device_schedule(_data(), order="morton")
