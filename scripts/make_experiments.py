"""Regenerate the data-driven sections of EXPERIMENTS.md from
experiments/dryrun/*.json (run after sweeps / perf iterations)."""

import json
import pathlib
import sys

sys.path.insert(0, "src")
from repro.launch.roofline import analyze, load_cells  # noqa: E402

DRY = "experiments/dryrun"


def dryrun_section():
    cells = load_cells(DRY)
    ok_multi = sum(1 for c in cells if c["mesh"] == "multi")
    ok_single = sum(1 for c in cells if c["mesh"] == "single")
    lines = [
        "## §Dry-run",
        "",
        f"All **{ok_single}/40 single-pod (16x16 = 256 chips)** and "
        f"**{ok_multi}/40 multi-pod (2x16x16 = 512 chips)** cells lower + "
        "compile (`experiments/dryrun/*.json`; `memory_analysis()` and "
        "`cost_analysis()` recorded per cell, collective schedule parsed from "
        "the post-SPMD HLO with loop-trip-count correction — see "
        "`launch/hlo_cost.py`).",
        "",
        "| arch | shape | mesh | peak GiB/dev | HLO GFLOP/dev | coll wire GiB/dev | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        a = analyze(c)
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {a['peak_gib']:.2f} "
            f"| {c['cost']['flops_per_device'] / 1e9:.1f} "
            f"| {c['collectives']['total_wire_bytes'] / 2**30:.3f} "
            f"| {c['compile_s']:.1f} |"
        )
    return "\n".join(lines)


def roofline_section():
    cells = load_cells(DRY)
    lines = [
        "## §Roofline",
        "",
        "Terms per cell (v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link):",
        "`compute = HLO_FLOPs/(chips*peak)`, `memory = HLO_bytes/(chips*HBM)`,",
        "`collective = ring-model wire bytes per device / link_bw`.",
        "`useful` = MODEL_FLOPS / HLO_FLOPs (6*N_act*D train, 2*N_act*D",
        "prefill, 2*N_act*B decode); `r-MFU` = useful model FLOPs per",
        "chip-second at the bounding term.",
        "",
        "NOTE on the memory term: HLO bytes come from the CPU-backend",
        "compile, which fuses far less than the TPU backend — the memory",
        "term is an upper bound and the true bound for the starred cells is",
        "likely the next-largest term (see §Perf napkin math per cell).",
        "",
        "| arch | shape | mesh | compute s | memory s | collective s | bound | useful | r-MFU | peak GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        a = analyze(c)
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {a['t_compute_s']:.2e} | {a['t_memory_s']:.2e} "
            f"| {a['t_collective_s']:.2e} | {a['bound']} "
            f"| {a['useful_flops_ratio']:.3f} | {a['roofline_mfu']:.4f} "
            f"| {a['peak_gib']:.2f} |"
        )
    return "\n".join(lines)


def perf_cells_table(names):
    rows = [
        "| cell | variant | compute s | memory s | collective s | bound | useful | peak GiB |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for fname, label in names:
        p = pathlib.Path(DRY) / fname
        if not p.exists():
            rows.append(f"| {label} | MISSING | | | | | | |")
            continue
        a = analyze(json.loads(p.read_text()))
        rows.append(
            f"| {a['arch']} x {a['shape']} ({a['mesh']}) | {label} "
            f"| {a['t_compute_s']:.3e} | {a['t_memory_s']:.3e} "
            f"| {a['t_collective_s']:.3e} | {a['bound']} "
            f"| {a['useful_flops_ratio']:.3f} | {a['peak_gib']:.2f} |"
        )
    return "\n".join(rows)


if __name__ == "__main__":
    out = sys.argv[1] if len(sys.argv) > 1 else "/dev/stdout"
    section = sys.argv[2] if len(sys.argv) > 2 else "all"
    parts = []
    if section in ("all", "dryrun"):
        parts.append(dryrun_section())
    if section in ("all", "roofline"):
        parts.append(roofline_section())
    text = "\n\n".join(parts)
    if out == "/dev/stdout":
        print(text)
    else:
        pathlib.Path(out).write_text(text)
