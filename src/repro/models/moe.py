"""Mixture-of-Experts FFN: token-choice top-k routing with capacity-based
dispatch (GShard-style einsum dispatch — the GSPMD/TPU-idiomatic form; the
expert dimension shards over the ``model`` mesh axis, so dispatch/combine
lower to all-to-all collectives).

Supports DeepSeek-V3 flavour: sigmoid router scores with aux-free bias,
shared experts alongside routed ones, and granite-moe flavour (softmax
top-k).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .modules import act_fn, dense_init, shard


def init_moe(key, cfg, d_model: int) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    e, f = cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    params = {
        "router": dense_init(ks[0], d_model, (e,), jnp.float32),
        "router_bias": jnp.zeros((e,), jnp.float32),  # aux-loss-free bias
        "w_in": dense_init(ks[1], d_model, (e, f), dt).transpose(1, 0, 2),
        "w_gate": dense_init(ks[2], d_model, (e, f), dt).transpose(1, 0, 2),
        "w_out": dense_init(ks[3], f, (e, d_model), dt).transpose(1, 0, 2),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        params["shared"] = {
            "w_in": dense_init(ks[4], d_model, (fs,), dt),
            "w_gate": dense_init(jax.random.fold_in(ks[4], 1), d_model, (fs,), dt),
            "w_out": dense_init(ks[5], fs, (d_model,), dt),
        }
    return params


def moe_ffn(params, cfg, x, capacity_factor: float = None):
    """x: (B, S, D) -> (B, S, D).

    Dispatch: (tokens, experts*capacity) one-hot einsum.  Capacity is
    static: C = ceil(S*topk/E * factor) per batch row.
    """
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_tok
    act = act_fn(cfg.act)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    if cfg.router_kind == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel_scores = scores + params["router_bias"][None, None, :]
    else:
        scores = jax.nn.softmax(logits, axis=-1)
        sel_scores = scores

    top_vals, top_idx = jax.lax.top_k(sel_scores, k)  # (B, S, k)
    # Combine weights use the *unbiased* scores of the selected experts.
    gate = jnp.take_along_axis(scores, top_idx, axis=-1)
    if cfg.router_kind == "sigmoid":
        gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(s * k / e * capacity_factor))

    # Position of each (token, choice) within its expert queue.
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.int32)  # (B,S,k,E)
    pos_in_expert = (
        jnp.cumsum(onehot.reshape(b, s * k, e), axis=1).reshape(b, s, k, e) * onehot
        - onehot
    )
    keep = (pos_in_expert < capacity) & (onehot > 0)
    slot = jnp.clip(pos_in_expert, 0, capacity - 1)

    if cfg.moe_dispatch == "scatter":
        # Optimized path (EXPERIMENTS.md §Perf): route tokens with
        # scatter-add / gather instead of the (tokens x E*C) dispatch
        # matmuls — removes the GShard dispatch FLOPs entirely.
        slot_tc = jnp.take_along_axis(slot, top_idx[..., None], axis=-1)[..., 0]
        keep_tc = jnp.take_along_axis(keep, top_idx[..., None], axis=-1)[..., 0]
        dest = jnp.where(keep_tc, top_idx * capacity + slot_tc, e * capacity)
        dest = dest.reshape(b, s * k)  # (B, S*k)
        x_rep = jnp.repeat(x, k, axis=1)  # (B, S*k, D)

        def scatter_b(dest_b, xr_b):
            buf = jnp.zeros((e * capacity + 1, d), x.dtype)
            return buf.at[dest_b].add(xr_b)[: e * capacity]

        xe = jax.vmap(scatter_b)(dest, x_rep).reshape(b, e, capacity, d)
        xe = shard(xe, ("pod", "data"), "model", None, None)
        hidden = act(jnp.einsum("becd,edf->becf", xe, params["w_gate"])) * jnp.einsum(
            "becd,edf->becf", xe, params["w_in"]
        )
        ye = jnp.einsum("becf,efd->becd", hidden, params["w_out"])
        ye = shard(ye, ("pod", "data"), "model", None, None)
        ye_flat = jnp.concatenate(
            [ye.reshape(b, e * capacity, d), jnp.zeros((b, 1, d), ye.dtype)], axis=1
        )

        def gather_b(dest_b, ye_b):
            return ye_b[dest_b]  # (S*k, D)

        y_tc = jax.vmap(gather_b)(dest, ye_flat).reshape(b, s, k, d)
        y = jnp.einsum(
            "bsk,bskd->bsd",
            (gate * keep_tc).astype(y_tc.dtype),
            y_tc,
        )
    else:
        # GShard einsum dispatch (paper-era baseline): one-hot matmuls, bf16
        # so they hit the MXU; lowers to all-to-all under EP sharding.
        disp = (
            (keep[..., None] & (slot[..., None] == jnp.arange(capacity)))
            .any(axis=2)
            .astype(x.dtype)
        )  # (B, S, E, C)
        comb = jnp.einsum(
            "bsk,bske,bsec->bsec",
            gate.astype(jnp.float32),
            keep.astype(jnp.float32),
            disp.astype(jnp.float32),
        ).astype(x.dtype)

        xe = jnp.einsum("bsd,bsec->becd", x, disp)  # all-to-all under EP
        xe = shard(xe, ("pod", "data"), "model", None, None)
        hidden = act(
            jnp.einsum("becd,edf->becf", xe, params["w_gate"])
        ) * jnp.einsum("becd,edf->becf", xe, params["w_in"])
        ye = jnp.einsum("becf,efd->becd", hidden, params["w_out"])
        ye = shard(ye, ("pod", "data"), "model", None, None)
        y = jnp.einsum("becd,bsec->bsd", ye, comb)

    if cfg.n_shared_experts:
        sp = params["shared"]
        y = y + jnp.einsum(
            "bsf,fd->bsd",
            act(jnp.einsum("bsd,df->bsf", x, sp["w_gate"]))
            * jnp.einsum("bsd,df->bsf", x, sp["w_in"]),
            sp["w_out"],
        )

    # Router statistics for the aux-free bias update (returned via aux).
    load = keep.any(2).astype(jnp.float32).mean(axis=(0, 1))  # (E,) fraction routed
    return y, {"expert_load": load}
