from .transformer import ModelConfig, init_params, loss_and_aux, prefill, decode_step, init_caches  # noqa: F401
