"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Real-gated linear recurrent unit:
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses ``lax.associative_scan`` (log-depth parallel scan — the
TPU-friendly schedule); decode is an O(1) state update.  The full
RecurrentGemma recurrent block wraps the RG-LRU with a linear in-proj,
short causal conv, GeLU gate branch, and out-proj.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .modules import dense_init

_C = 8.0


def init_rglru(key, cfg, d_model: int) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    w = cfg.lru_width
    ks = jax.random.split(key, 6)
    return {
        "in_x": dense_init(ks[0], d_model, (w,), dt),
        "in_gate": dense_init(ks[1], d_model, (w,), dt),
        "conv_w": dense_init(ks[2], cfg.conv_kernel, (w,), dt) * 0.1,
        "conv_b": jnp.zeros((w,), dt),
        "w_a": dense_init(ks[3], w, (w,), dt),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": dense_init(ks[4], w, (w,), dt),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": jnp.log(jnp.expm1(jnp.linspace(0.9, 0.999, w))) * 0 + 0.5,
        "out": dense_init(ks[5], w, (d_model,), dt),
    }


def _causal_conv(x, w, b):
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def _gates(params, u):
    r = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", u, params["w_a"]).astype(jnp.float32)
        + params["b_a"]
    )
    i = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", u, params["w_i"]).astype(jnp.float32)
        + params["b_i"]
    )
    log_a = -_C * jax.nn.softplus(params["lam"]) * r  # (B,S,W), <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12))
    return a, beta * i * u.astype(jnp.float32)


def rglru_train(params, cfg, x, positions=None):
    """x: (B, S, D) -> (B, S, D)."""
    u = jnp.einsum("bsd,dw->bsw", x, params["in_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["in_gate"]))
    u = _causal_conv(u, params["conv_w"], params["conv_b"])
    a, b = _gates(params, u)

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    y = (h.astype(x.dtype)) * gate
    return jnp.einsum("bsw,wd->bsd", y, params["out"])


def init_rglru_cache(cfg, batch: int, dtype) -> Dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.lru_width), dtype),
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }


def rglru_decode(params, cfg, x, cache, pos=None):
    u = jnp.einsum("bsd,dw->bsw", x, params["in_x"])  # (B,1,W)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["in_gate"]))
    window = jnp.concatenate([cache["conv"], u], axis=1)
    u = (jnp.einsum("bkw,kw->bw", window, params["conv_w"]) + params["conv_b"])[
        :, None, :
    ]
    a, b = _gates(params, u)
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = h[:, None, :].astype(x.dtype) * gate
    out = jnp.einsum("bsw,wd->bsd", y, params["out"])
    return out, {"conv": window[:, 1:, :], "h": h}
