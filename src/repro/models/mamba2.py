"""Mamba-2 (SSD, state-space duality — arXiv:2405.21060) mixer.

Chunked SSD algorithm: quadratic attention-like compute within chunks,
linear state recurrence across chunks (lax.scan).  Decode is an O(1)
recurrent state update.  All einsum-based so the MXU sees matmuls.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .modules import dense_init, rmsnorm, rmsnorm_init, shard


def init_mamba2(key, cfg, d_model: int) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    d_inner = cfg.ssm_expand * d_model
    nheads = d_inner // cfg.ssm_headdim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    conv_dim = d_inner + 2 * g * n
    ks = jax.random.split(key, 4)
    return {
        # fused input projection: [z, x, B, C, dt]
        "in_proj": dense_init(
            ks[0], d_model, (2 * d_inner + 2 * g * n + nheads,), dt
        ),
        "conv_w": dense_init(ks[1], cfg.conv_kernel, (conv_dim,), dt) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads, dtype=jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm": rmsnorm_init(d_inner),
        "out_proj": dense_init(ks[2], d_inner, (d_model,), dt),
    }


def _split_proj(cfg, d_model, zxbcdt):
    d_inner = cfg.ssm_expand * d_model
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    nheads = d_inner // cfg.ssm_headdim
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * g * n], axis=-1)
    return z, xbc, dt, d_inner, g, n, nheads


def _causal_conv(x, w, b):
    """Depthwise causal conv1d.  x: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def mamba2_train(params, cfg, x, positions=None, chunk: int = 256):
    """x: (B, S, D) -> (B, S, D) via chunked SSD."""
    b, s, d_model = x.shape
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt, d_inner, g, n, nheads = _split_proj(cfg, d_model, zxbcdt)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"]))
    xs, bs_, cs = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    p = cfg.ssm_headdim
    h = nheads
    xs = xs.reshape(b, s, h, p)
    xs = shard(xs, ("pod", "data"), None, "model", None)
    bs_ = bs_.reshape(b, s, g, n)
    cs = cs.reshape(b, s, g, n)
    hg = h // g  # heads per group

    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["A_log"])  # (H,) negative
    da = dt_f * a  # (B,S,H) log-decay per step

    chunk = min(chunk, s)
    nc = s // chunk
    assert s % chunk == 0
    # Scan over chunks: only ONE chunk's quadratic term is ever live
    # (memory ~ B*Q^2*H/tp instead of nc*that) — the SSD schedule.
    xs_c = jnp.moveaxis(xs.reshape(b, nc, chunk, h, p), 1, 0)
    b_c = jnp.moveaxis(bs_.reshape(b, nc, chunk, g, n), 1, 0)
    c_c = jnp.moveaxis(cs.reshape(b, nc, chunk, g, n), 1, 0)
    da_c = jnp.moveaxis(da.reshape(b, nc, chunk, h), 1, 0)
    dt_c = jnp.moveaxis(dt_f.reshape(b, nc, chunk, h), 1, 0)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(hstate, inp):
        xc, bc, cc, dac, dtc = inp  # (B,Q,H,P), (B,Q,G,N)x2, (B,Q,H)x2
        xc = shard(xc, ("pod", "data"), None, "model", None)
        cum = jnp.cumsum(dac, axis=1)  # (B,Q,H)
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Qi,Qj,H)
        # Mask in log space BEFORE exp: exp of +ve garbage above the
        # diagonal would propagate NaN through the backward where.
        seg = jnp.where(causal[None, :, :, None], seg, -jnp.inf)
        decay = jnp.exp(seg)
        b_h = bc[:, :, :, None, :].repeat(hg, axis=3).reshape(b, chunk, h, n)
        c_h = cc[:, :, :, None, :].repeat(hg, axis=3).reshape(b, chunk, h, n)
        b_h = shard(b_h, ("pod", "data"), None, "model", None)
        c_h = shard(c_h, ("pod", "data"), None, "model", None)
        # Intra-chunk (quadratic) term.
        cb = jnp.einsum("bihn,bjhn->bhij", c_h, b_h)  # (B,H,Qi,Qj)
        scores = cb * jnp.moveaxis(decay, -1, 1)
        y_intra = jnp.einsum(
            "bhij,bjh,bjhp->bihp",
            scores.astype(jnp.float32),
            dtc,
            xc.astype(jnp.float32),
        )
        # Inter-chunk term from the entering state.
        dfs = jnp.exp(cum)  # (B,Q,H)
        y_inter = jnp.einsum(
            "bihn,bhnp->bihp",
            c_h.astype(jnp.float32) * dfs[..., None],
            hstate,
        )
        # State update to the chunk end.
        dte = jnp.exp(cum[:, -1:, :] - cum)  # (B,Q,H)
        bx = jnp.einsum(
            "bjhn,bjh,bjhp->bhnp",
            b_h.astype(jnp.float32),
            dte * dtc,
            xc.astype(jnp.float32),
        )
        h_new = hstate * jnp.exp(cum[:, -1, :])[:, :, None, None] + bx
        return h_new, (y_intra + y_inter).astype(x.dtype)

    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    _, y_c = jax.lax.scan(body, h0, (xs_c, b_c, c_c, da_c, dt_c))
    y = jnp.moveaxis(y_c, 0, 1).reshape(b, s, h, p)
    y = y + xs * params["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"])


def init_mamba2_cache(cfg, batch: int, d_model: int, dtype) -> Dict:
    d_inner = cfg.ssm_expand * d_model
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    nheads = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * g * n
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nheads, n, cfg.ssm_headdim), jnp.float32),
    }


def mamba2_decode(params, cfg, x, cache, pos=None):
    """Single-token recurrent update. x: (B, 1, D)."""
    b, _, d_model = x.shape
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt, d_inner, g, n, nheads = _split_proj(cfg, d_model, zxbcdt)
    # conv over cached window
    window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B, K, conv_dim)
    conv_out = (
        jnp.einsum("bkc,kc->bc", window, params["conv_w"]) + params["conv_b"]
    )[:, None, :]
    xbc = jax.nn.silu(conv_out)
    xs, bs_, cs = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    p = cfg.ssm_headdim
    h = nheads
    hg = h // g
    xs = xs.reshape(b, h, p)
    b_h = bs_.reshape(b, g, n)[:, :, None, :].repeat(hg, axis=2).reshape(b, h, n)
    c_h = cs.reshape(b, g, n)[:, :, None, :].repeat(hg, axis=2).reshape(b, h, n)

    dt_f = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["A_log"])
    da = jnp.exp(dt_f * a)  # (B,H)

    ssm = cache["ssm"] * da[:, :, None, None] + jnp.einsum(
        "bhn,bh,bhp->bhnp", b_h.astype(jnp.float32), dt_f, xs.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhnp->bhp", c_h.astype(jnp.float32), ssm).astype(x.dtype)
    y = y + xs * params["D"][None, :, None].astype(x.dtype)
    y = y.reshape(b, 1, d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    new_cache = {"conv": window[:, 1:, :], "ssm": ssm}
    return out, new_cache
