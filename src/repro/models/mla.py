"""Multi-head Latent Attention (DeepSeek-V2/V3).

Train/prefill: project to a compressed KV latent ``c_kv`` (kv_lora_rank) plus
a decoupled RoPE key ``k_rope`` shared across heads; expand per-head
``k_nope, v`` from the latent.  Decode: *absorbed* form — queries are folded
through the up-projections so attention runs directly against the cached
latent, never materializing per-head K/V for the full context
(DESIGN.md §3.2: the mqr-KV 2-D score axis lives on the latent).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import kvindex
from .modules import apply_rope, dense_init, rmsnorm, rmsnorm_init, shard

NEG_INF = -1e30


def init_mla(key, cfg, d_model: int) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    qk_nope, qk_rope, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": dense_init(ks[0], d_model, (cfg.q_lora_rank,), dt),
        "q_norm": rmsnorm_init(cfg.q_lora_rank),
        "wq_b": dense_init(ks[1], cfg.q_lora_rank, (h, qk_nope + qk_rope), dt),
        "wkv_a": dense_init(ks[2], d_model, (cfg.kv_lora_rank + qk_rope,), dt),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank),
        "wk_b": dense_init(ks[3], cfg.kv_lora_rank, (h, qk_nope), dt),
        "wv_b": dense_init(ks[4], cfg.kv_lora_rank, (h, dv), dt),
        "wo": dense_init(ks[5], h * dv, (d_model,), dt),
        "probe": dense_init(ks[6], cfg.kv_lora_rank, (1,), jnp.float32)[:, 0],
    }


def _latent(params, cfg, x, positions):
    """Compressed path shared by train/prefill/decode-append."""
    kv_a = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv, k_rope = jnp.split(kv_a, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)  # (B,S,rope_dim)
    return c_kv, k_rope


def _queries(params, cfg, x, positions):
    q_a = rmsnorm(
        params["q_norm"], jnp.einsum("bsd,dr->bsr", x, params["wq_a"]), cfg.norm_eps
    )
    q = jnp.einsum("bsr,rhk->bshk", q_a, params["wq_b"])
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_train(params, cfg, x, positions, chunk: int = 1024):
    """Training/prefill forward: expands K/V per head, flash-style scan."""
    b, s, _ = x.shape
    h = cfg.n_heads
    c_kv, k_rope = _latent(params, cfg, x, positions)
    q_nope, q_rope = _queries(params, cfg, x, positions)
    q_nope = shard(q_nope, ("pod", "data"), "model", None, None)
    q_rope = shard(q_rope, ("pod", "data"), "model", None, None)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"])

    scale = 1.0 / jnp.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    chunk = min(chunk, s)
    n_chunks = s // chunk

    kn_c = jnp.moveaxis(k_nope.reshape(b, n_chunks, chunk, h, -1), 1, 0)
    kr_c = jnp.moveaxis(k_rope.reshape(b, n_chunks, chunk, -1), 1, 0)
    v_c = jnp.moveaxis(v.reshape(b, n_chunks, chunk, h, -1), 1, 0)
    kp_c = positions.reshape(b, n_chunks, chunk)[0]

    def body(carry, inputs):
        m, l, acc = carry
        kn, kr, vc, kp = inputs
        logits = (
            jnp.einsum("bshk,bchk->bshc", q_nope, kn)
            + jnp.einsum("bshk,bck->bshc", q_rope, kr)
        ).astype(jnp.float32) * scale
        mask = positions[:, :, None, None] >= kp[None, None, None, :]
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bshc,bchk->bshk", p.astype(vc.dtype), vc
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, h), jnp.float32)
    acc0 = jnp.zeros((b, s, h, cfg.v_head_dim), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kn_c, kr_c, v_c, kp_c))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].reshape(h, cfg.v_head_dim, -1))


def init_mla_cache(cfg, batch: int, max_len: int, dtype) -> Dict:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_decode(params, cfg, x, cache, pos, mqr_sparse: bool = False):
    """Absorbed-latent single-token decode. x: (B, 1, D)."""
    b = x.shape[0]
    h = cfg.n_heads
    positions = jnp.full((b, 1), pos, jnp.int32)
    c_new, kr_new = _latent(params, cfg, x, positions)
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1
    )
    kr_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos, axis=1
    )
    new_cache = {"c_kv": c_cache, "k_rope": kr_cache}

    q_nope, q_rope = _queries(params, cfg, x, positions)
    # Absorb the key up-projection into the query: (B,1,H,rank)
    q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, params["wk_b"])

    scale = 1.0 / jnp.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    skv = c_cache.shape[1]
    kv_pos = jnp.arange(skv)

    if mqr_sparse:
        bs = cfg.mqr_block
        nb = skv // bs
        topk = min(cfg.mqr_topk, nb)
        probe = params["probe"]

        def per_b(c_b, qe_b):
            idx = kvindex.build_kv_index(c_b.astype(jnp.float32), probe, bs, cfg.mqr_levels)
            regions = jax.vmap(
                lambda qq: kvindex.query_region(qq.astype(jnp.float32), probe, pos + 1)
            )(qe_b)  # (H, 4)
            return jax.vmap(lambda r: kvindex.select_blocks(idx, r, topk))(regions)

        ids = jax.vmap(per_b)(c_cache, q_eff[:, 0])  # (B, H, topk)
        cb = c_cache.reshape(b, nb, bs, -1)
        krb = kr_cache.reshape(b, nb, bs, -1)
        cg = jax.vmap(lambda cb_b, ids_b: cb_b[ids_b])(cb, ids)   # (B,H,topk,bs,rank)
        krg = jax.vmap(lambda kb_b, ids_b: kb_b[ids_b])(krb, ids)
        logits = (
            jnp.einsum("bshr,bhksr->bhks", q_eff, cg)
            + jnp.einsum("bshk,bhcsk->bhcs", q_rope, krg)
        ).astype(jnp.float32) * scale
        sel_pos = ids[..., None] * bs + jnp.arange(bs)[None, None, None, :]
        logits = jnp.where(sel_pos <= pos, logits, NEG_INF)
        p = jax.nn.softmax(logits.reshape(b, h, -1), axis=-1).reshape(logits.shape)
        attn_c = jnp.einsum("bhks,bhksr->bhr", p.astype(cg.dtype), cg)
    else:
        logits = (
            jnp.einsum("bshr,btr->bsht", q_eff, c_cache)[:, 0]
            + jnp.einsum("bshk,btk->bsht", q_rope, kr_cache)[:, 0]
        ).astype(jnp.float32) * scale  # (B, H, skv)
        logits = jnp.where(kv_pos[None, None, :] <= pos, logits, NEG_INF)
        p = jax.nn.softmax(logits, axis=-1)
        attn_c = jnp.einsum("bht,btr->bhr", p.astype(c_cache.dtype), c_cache)

    # Expand through the value up-projection, then output proj.
    out = jnp.einsum("bhr,rhk->bhk", attn_c, params["wv_b"])
    out = out.reshape(b, 1, h, cfg.v_head_dim)
    return (
        jnp.einsum("bshk,hkd->bsd", out, params["wo"].reshape(h, cfg.v_head_dim, -1)),
        new_cache,
    )
