"""Composable decoder-only LM covering all ten assigned architectures.

A model is a stack of *superblocks*; each superblock applies the layer
pattern ``cfg.block_pattern`` (e.g. ``("attn",)`` for llama,
``("rglru", "rglru", "local")`` for RecurrentGemma, ``("mamba2",)`` for
Mamba-2).  Each pattern entry is mixer + FFN with pre-RMSNorm residuals.
Superblocks are parameter-stacked and executed with ``jax.lax.scan``
(+ optional remat), so the HLO is O(1) in depth.

Three execution paths: ``loss_and_aux`` (training), ``prefill``
(inference-prefill, returns caches), ``decode_step`` (single token with
caches; optionally the mqr-KV sparse path — the paper's technique).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba2 as m2
from . import mla as mla_mod
from . import moe as moe_mod
from . import rglru as rg
from .modules import (
    Params,
    act_fn,
    dense_init,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    shard,
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    block_pattern: Tuple[str, ...] = ("attn",)
    tail_pattern: Tuple[str, ...] = ()  # trailing layers when n_layers % pattern != 0
    ffn_kind: str = "swiglu"  # swiglu | geglu | mlp_gelu | moe | none
    act: str = "silu"
    # MoE
    n_experts: int = 0
    experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0  # leading layers with dense FFN (DeepSeek)
    router_kind: str = "softmax"  # softmax | sigmoid
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "einsum"  # einsum (GShard baseline) | scatter (optimized)
    # MLA
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0  # multi-token-prediction heads (DeepSeek-V3)
    # Mamba-2
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    conv_kernel: int = 4
    ssd_chunk: int = 256
    # RG-LRU
    lru_width: int = 0
    local_window: int = 0
    local_attn_impl: str = "banded"  # banded | masked (perf baseline)
    # misc
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"  # full (nothing saveable) | dots (save matmuls)
    attn_chunk: int = 1024
    # frontends (stubs per assignment: precomputed embeddings/codebooks)
    frontend: str = "none"  # none | audio_codebooks | vision_patches
    n_codebooks: int = 0
    n_patches: int = 0
    # mqr-KV sparse attention (the paper's technique)
    mqr_block: int = 128
    mqr_topk: int = 64
    mqr_levels: int = 6
    mqr_incremental: bool = False  # index lives in the cache (see §Perf)

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """LM-head vocab padded to 256 so it shards over the model axis
        (standard practice; pad ids are masked at serve time)."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def n_superblocks(self) -> int:
        body = self.n_layers - len(self.tail_pattern)
        assert body % len(self.block_pattern) == 0, (self.n_layers, self.block_pattern)
        return body // len(self.block_pattern)

    def param_count(self) -> int:
        """Analytic total parameter count N (for 6·N·D roofline)."""
        d = self.d_model
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.frontend == "audio_codebooks":
            total += self.n_codebooks * self.vocab_size * d  # heads
        per_pattern = 0
        for kind in self.block_pattern:
            per_pattern += self._mixer_params(kind)
        n_super = self.n_superblocks
        total += n_super * per_pattern
        for kind in self.tail_pattern:
            total += self._mixer_params(kind)
        # ffn per layer
        for li in range(self.n_layers):
            total += self._ffn_params(li)
        total += self.n_layers * 2 * d  # norms
        return total

    def _mixer_params(self, kind: str) -> int:
        d, dh = self.d_model, self.head_dim_
        if kind in ("attn", "local"):
            return d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d
        if kind == "mla":
            r, rk = self.q_lora_rank, self.kv_lora_rank
            qk = self.qk_nope_head_dim + self.qk_rope_head_dim
            return (
                d * r
                + r * self.n_heads * qk
                + d * (rk + self.qk_rope_head_dim)
                + rk * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
        if kind == "mamba2":
            d_inner = self.ssm_expand * d
            gn = self.ssm_ngroups * self.ssm_state
            nheads = d_inner // self.ssm_headdim
            return d * (2 * d_inner + 2 * gn + nheads) + d_inner * d
        if kind == "rglru":
            w = self.lru_width
            return 2 * d * w + 2 * w * w + w * d
        raise ValueError(kind)

    def _ffn_params(self, layer_idx: int) -> int:
        d = self.d_model
        if self.ffn_kind == "none":
            return 0
        if self.ffn_kind == "moe" and layer_idx >= self.n_dense_layers:
            e, f = self.n_experts, self.moe_d_ff
            shared = 3 * d * self.moe_d_ff * self.n_shared_experts
            return e * 3 * d * f + d * e + shared
        f = self.d_ff
        if self.ffn_kind == "mlp_gelu":
            return 2 * d * f
        return 3 * d * f

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if self.ffn_kind != "moe":
            return self.param_count()
        total = self.param_count()
        e, k = self.n_experts, self.experts_per_tok
        inactive_layers = self.n_layers - self.n_dense_layers
        inactive = inactive_layers * (e - k) * 3 * self.d_model * self.moe_d_ff
        return total - inactive


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_mixer(key, cfg, kind: str) -> Params:
    if kind in ("attn", "local"):
        return attn.init_attention(key, cfg, cfg.d_model)
    if kind == "mla":
        return mla_mod.init_mla(key, cfg, cfg.d_model)
    if kind == "mamba2":
        return m2.init_mamba2(key, cfg, cfg.d_model)
    if kind == "rglru":
        return rg.init_rglru(key, cfg, cfg.d_model)
    raise ValueError(kind)


def _init_ffn(key, cfg, moe_layer: bool) -> Params:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    if cfg.ffn_kind == "none":
        return {}
    if moe_layer:
        return moe_mod.init_moe(key, cfg, d)
    f = cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.ffn_kind == "mlp_gelu":
        return {
            "w_in": dense_init(ks[0], d, (f,), dt),
            "w_out": dense_init(ks[1], f, (d,), dt),
        }
    return {
        "w_gate": dense_init(ks[0], d, (f,), dt),
        "w_in": dense_init(ks[1], d, (f,), dt),
        "w_out": dense_init(ks[2], f, (d,), dt),
    }


def _init_superblock(key, cfg, moe_flags, pattern=None) -> Params:
    """One superblock: pattern of (mixer + ffn) layers.

    moe_flags: tuple of bool per pattern entry — whether the FFN is MoE.
    """
    out = {}
    pattern = pattern or cfg.block_pattern
    for i, kind in enumerate(pattern):
        k1, k2, key = jax.random.split(key, 3)
        out[f"l{i}"] = {
            "mixer_norm": rmsnorm_init(cfg.d_model),
            "mixer": _init_mixer(k1, cfg, kind),
            "ffn_norm": rmsnorm_init(cfg.d_model),
            "ffn": _init_ffn(k2, cfg, moe_flags[i]),
        }
    return out


def init_params(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Params = {}
    vpad = cfg.padded_vocab
    if cfg.frontend == "audio_codebooks":
        params["embed"] = jax.vmap(
            lambda k: embed_init(k, vpad, cfg.d_model, dt)
        )(jax.random.split(keys[0], cfg.n_codebooks))
        params["lm_head"] = jax.vmap(
            lambda k: dense_init(k, cfg.d_model, (vpad,), dt)
        )(jax.random.split(keys[1], cfg.n_codebooks))
    else:
        params["embed"] = embed_init(keys[0], vpad, cfg.d_model, dt)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(keys[1], cfg.d_model, (vpad,), dt)
    params["final_norm"] = rmsnorm_init(cfg.d_model)

    p_len = len(cfg.block_pattern)
    n_super = cfg.n_superblocks
    moe = cfg.ffn_kind == "moe"

    if moe and cfg.n_dense_layers:
        # Two homogeneous stacks (e.g. DeepSeek: first k layers dense FFN).
        assert p_len == 1, "n_dense_layers requires a single-entry pattern"
        nd = cfg.n_dense_layers
        dense_keys = jax.random.split(keys[2], nd)
        moe_keys = jax.random.split(keys[3], cfg.n_layers - nd)
        params["blocks_dense"] = jax.vmap(
            lambda k: _init_superblock(k, cfg, (False,))
        )(dense_keys)
        params["blocks"] = jax.vmap(lambda k: _init_superblock(k, cfg, (True,)))(
            moe_keys
        )
    else:
        flags = tuple(moe for _ in range(p_len))
        params["blocks"] = jax.vmap(lambda k: _init_superblock(k, cfg, flags))(
            jax.random.split(keys[2], n_super)
        )
    if cfg.tail_pattern:
        tflags = tuple(moe for _ in cfg.tail_pattern)
        params["tail"] = _init_superblock(keys[5], cfg, tflags, cfg.tail_pattern)
    if cfg.mtp_depth:
        # DeepSeek-V3 MTP: one extra transformer block + projection per depth.
        mk = jax.random.split(keys[4], cfg.mtp_depth)
        params["mtp"] = jax.vmap(
            lambda k: {
                "proj": dense_init(k, 2 * cfg.d_model, (cfg.d_model,), dt),
                "block": _init_superblock(
                    jax.random.fold_in(k, 1), cfg, (cfg.ffn_kind == "moe",)
                ),
            }
        )(mk)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _ffn_apply(p, cfg, x, moe_layer: bool):
    if cfg.ffn_kind == "none":
        return x * 0.0, None
    if moe_layer:
        return moe_mod.moe_ffn(p, cfg, x)
    act = act_fn(cfg.act)
    if cfg.ffn_kind == "mlp_gelu":
        h = act(jnp.einsum("bsd,df->bsf", x, p["w_in"]))
        return jnp.einsum("bsf,fd->bsd", h, p["w_out"]), None
    h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * jnp.einsum(
        "bsd,df->bsf", x, p["w_in"]
    )
    h = shard(h, ("pod", "data"), None, "model")
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"]), None


def _mixer_apply_train(p, cfg, kind, x, positions):
    if kind == "attn":
        return attn.attention_train(p, cfg, x, positions)
    if kind == "local":
        return attn.attention_train(p, cfg, x, positions, window=cfg.local_window)
    if kind == "mla":
        return mla_mod.mla_train(p, cfg, x, positions, chunk=cfg.attn_chunk)
    if kind == "mamba2":
        return m2.mamba2_train(p, cfg, x, positions, chunk=cfg.ssd_chunk)
    if kind == "rglru":
        return rg.rglru_train(p, cfg, x, positions)
    raise ValueError(kind)


def _superblock_train(block_params, cfg, x, positions, moe_flags, pattern=None):
    aux_load = None
    for i, kind in enumerate(pattern or cfg.block_pattern):
        lp = block_params[f"l{i}"]
        h = rmsnorm(lp["mixer_norm"], x, cfg.norm_eps)
        x = x + _mixer_apply_train(lp["mixer"], cfg, kind, h, positions)
        h = rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
        y, aux = _ffn_apply(lp["ffn"], cfg, h, moe_flags[i])
        x = x + y
        if aux is not None:
            aux_load = aux["expert_load"] if aux_load is None else aux_load + aux["expert_load"]
    return x, aux_load


def _stack_scan(params_stack, cfg, x, positions, moe_flags):
    """Scan superblocks with optional remat."""

    def body(carry, block_params):
        h, load = carry
        # Sequence parallelism: the residual carry (the only activation saved
        # by remat per layer) shards its sequence dim over the model axis;
        # attention/FFN internals gather/scatter as needed (Megatron-SP).
        if h.shape[1] % 2048 == 0:
            h = shard(h, ("pod", "data"), "model", None)
        h2, aux_load = _superblock_train(block_params, cfg, h, positions, moe_flags)
        if aux_load is not None:
            load = load + aux_load
        return (h2, load), None

    if cfg.remat:
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat_policy == "dots"
            else jax.checkpoint_policies.nothing_saveable
        )
        body = jax.checkpoint(body, policy=policy)
    e = cfg.n_experts if cfg.ffn_kind == "moe" else 1
    (x, load), _ = jax.lax.scan(body, (x, jnp.zeros((e,), jnp.float32)), params_stack)
    return x, load


def embed_inputs(params, cfg, batch: Dict[str, jnp.ndarray]):
    """Returns (hidden (B,S,D), positions (B,S), loss_mask (B,S))."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio_codebooks":
        tokens = batch["tokens"]  # (B, S, K)
        emb = params["embed"]  # (K, V, D)
        x = jnp.sum(
            jnp.take_along_axis(
                emb[None], tokens.transpose(0, 2, 1)[..., None], axis=2
            ),
            axis=1,
        )
        b, s = tokens.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        return x.astype(dt), positions, jnp.ones((b, s), bool)
    if cfg.frontend == "vision_patches":
        tokens = batch["tokens"]  # (B, S_txt)
        vis = batch["vision_embeds"].astype(dt)  # (B, P, D)
        tx = params["embed"][tokens].astype(dt)
        x = jnp.concatenate([vis, tx], axis=1)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        mask = jnp.concatenate(
            [jnp.zeros((b, vis.shape[1]), bool), jnp.ones(tokens.shape, bool)], axis=1
        )
        return x, positions, mask
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens].astype(dt)
    if cfg.name.startswith("gemma") or cfg.name.startswith("recurrentgemma"):
        x = x * jnp.sqrt(cfg.d_model).astype(dt)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    return x, positions, jnp.ones((b, s), bool)


def forward_hidden(params, cfg, x, positions):
    """Hidden trunk shared by train/prefill."""
    x = shard(x, ("pod", "data"), None, None)
    moe = cfg.ffn_kind == "moe"
    if moe and cfg.n_dense_layers:
        x, _ = _stack_scan(params["blocks_dense"], cfg, x, positions, (False,))
        x, load = _stack_scan(params["blocks"], cfg, x, positions, (True,))
    else:
        flags = tuple(moe for _ in cfg.block_pattern)
        x, load = _stack_scan(params["blocks"], cfg, x, positions, flags)
    if cfg.tail_pattern:
        tflags = tuple(moe for _ in cfg.tail_pattern)
        tail_fn = lambda p, h: _superblock_train(
            p, cfg, h, positions, tflags, cfg.tail_pattern
        )
        if cfg.remat:
            policy = (
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                if cfg.remat_policy == "dots"
                else jax.checkpoint_policies.nothing_saveable
            )
            tail_fn = jax.checkpoint(tail_fn, policy=policy)
        x, _ = tail_fn(params["tail"], x)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, load


def logits_fn(params, cfg, hidden):
    if cfg.frontend == "audio_codebooks":
        out = jnp.einsum("bsd,kdv->bskv", hidden, params["lm_head"])
        return shard(out, ("pod", "data"), None, None, "model")
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if cfg.tie_embeddings:
        out = jnp.einsum("bsd,vd->bsv", hidden, w)
    else:
        out = jnp.einsum("bsd,dv->bsv", hidden, w)
    # vocab over the model axis, batch over data axes: the CE block
    # (one-hot, logsumexp, dlogits) stays fully sharded.
    return shard(out, ("pod", "data"), None, "model")


def loss_and_aux(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    """Next-token cross-entropy (+ MoE load stats).  batch['labels'] aligns
    with batch['tokens'] shifted by the caller (data pipeline)."""
    x, positions, mask = embed_inputs(params, cfg, batch)
    hidden, load = forward_hidden(params, cfg, x, positions)
    logits = logits_fn(params, cfg, hidden).astype(jnp.float32)
    labels = batch["labels"]
    if cfg.frontend == "vision_patches":
        # prepend ignore labels for patch positions
        b, p = labels.shape[0], cfg.n_patches
        labels = jnp.concatenate(
            [jnp.full((b, p), -1, labels.dtype), labels], axis=1
        )
    labels_c = jnp.clip(labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # one-hot reduction instead of take_along_axis: reduces over the
    # (model-axis sharded) vocab dim without gathering the logits.
    v = logits.shape[-1]
    onehot = jax.nn.one_hot(labels_c, v, dtype=logits.dtype)
    ll = jnp.sum(logits * onehot, axis=-1)
    if cfg.frontend == "audio_codebooks":
        # labels: (B, S, K); logits: (B, S, K, V)
        nll = (logz - ll).mean(axis=-1)  # mean over codebooks
        valid = mask & (labels >= 0).all(axis=-1)
    else:
        nll = logz - ll
        valid = mask & (labels >= 0)
    loss = jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)
    return loss, {"expert_load": load, "n_tokens": jnp.sum(valid)}


# ---------------------------------------------------------------------------
# Inference: prefill + decode
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    """Stacked (per-superblock) cache pytree."""
    dt = jnp.dtype(cfg.dtype)

    def one(kind):
        if kind in ("attn",):
            return attn.init_kv_cache(cfg, batch, max_len, dt)
        if kind == "local":
            return attn.init_local_cache(cfg, batch, dt)
        if kind == "mla":
            return mla_mod.init_mla_cache(cfg, batch, max_len, dt)
        if kind == "mamba2":
            return m2.init_mamba2_cache(cfg, batch, cfg.d_model, dt)
        if kind == "rglru":
            return rg.init_rglru_cache(cfg, batch, dt)
        raise ValueError(kind)

    per_super = {f"l{i}": one(kind) for i, kind in enumerate(cfg.block_pattern)}

    def stack(n):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape), per_super
        )

    if cfg.ffn_kind == "moe" and cfg.n_dense_layers:
        nd = cfg.n_dense_layers
        nm = cfg.n_layers - len(cfg.tail_pattern) - nd
        out = {"dense": stack(nd), "moe": stack(nm)}
    else:
        out = {"all": stack(cfg.n_superblocks)}
    if cfg.tail_pattern:
        out["tail"] = {
            f"l{i}": one(kind) for i, kind in enumerate(cfg.tail_pattern)
        }
    return out


def _mixer_decode(p, cfg, kind, x, cache, pos, mqr_sparse):
    if kind == "attn":
        return attn.attention_decode(p, cfg, x, cache, pos, mqr_sparse=mqr_sparse)
    if kind == "local":
        return attn.local_attention_decode(p, cfg, x, cache, pos)
    if kind == "mla":
        return mla_mod.mla_decode(p, cfg, x, cache, pos, mqr_sparse=mqr_sparse)
    if kind == "mamba2":
        return m2.mamba2_decode(p, cfg, x, cache, pos)
    if kind == "rglru":
        return rg.rglru_decode(p, cfg, x, cache, pos)
    raise ValueError(kind)


def _superblock_decode(
    block_params, cfg, x, caches, pos, moe_flags, mqr_sparse, pattern=None
):
    new_caches = {}
    for i, kind in enumerate(pattern or cfg.block_pattern):
        lp = block_params[f"l{i}"]
        h = rmsnorm(lp["mixer_norm"], x, cfg.norm_eps)
        y, new_caches[f"l{i}"] = _mixer_decode(
            lp["mixer"], cfg, kind, h, caches[f"l{i}"], pos, mqr_sparse
        )
        x = x + y
        h = rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
        y, _ = _ffn_apply(lp["ffn"], cfg, h, moe_flags[i])
        x = x + y
    return x, new_caches


def _decode_stack(params_stack, cache_stack, cfg, x, pos, moe_flags, mqr_sparse):
    def body(h, inp):
        block_params, cache = inp
        h2, new_cache = _superblock_decode(
            block_params, cfg, h, cache, pos, moe_flags, mqr_sparse
        )
        return h2, new_cache

    return jax.lax.scan(body, x, (params_stack, cache_stack))


def decode_step(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, 1) int32 (or (B,1,K) for audio)
    caches,
    pos,  # scalar int32
    mqr_sparse: bool = False,
):
    """One decode step. Returns (logits (B,1,V...), new_caches)."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio_codebooks":
        emb = params["embed"]
        x = jnp.sum(
            jnp.take_along_axis(
                emb[None], tokens.transpose(0, 2, 1)[..., None], axis=2
            ),
            axis=1,
        ).astype(dt)
    else:
        x = params["embed"][tokens].astype(dt)
        if cfg.name.startswith("gemma") or cfg.name.startswith("recurrentgemma"):
            x = x * jnp.sqrt(cfg.d_model).astype(dt)
    moe = cfg.ffn_kind == "moe"
    if moe and cfg.n_dense_layers:
        x, cd = _decode_stack(
            params["blocks_dense"], caches["dense"], cfg, x, pos, (False,), mqr_sparse
        )
        x, cm = _decode_stack(
            params["blocks"], caches["moe"], cfg, x, pos, (True,), mqr_sparse
        )
        new_caches = {"dense": cd, "moe": cm}
    else:
        flags = tuple(moe for _ in cfg.block_pattern)
        x, ca = _decode_stack(
            params["blocks"], caches["all"], cfg, x, pos, flags, mqr_sparse
        )
        new_caches = {"all": ca}
    if cfg.tail_pattern:
        tflags = tuple(moe for _ in cfg.tail_pattern)
        x, ct = _superblock_decode(
            params["tail"], cfg, x, caches["tail"], pos, tflags, mqr_sparse,
            cfg.tail_pattern,
        )
        new_caches["tail"] = ct
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, x), new_caches


def prefill(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    """Inference prefill: full forward, returns (last-token logits, hidden).

    Cache extraction for every layer is available via decode-oriented
    serving (launch/serve.py streams prefill chunks through decode_step);
    the prefill benchmark path measures the forward trunk itself.
    """
    x, positions, _ = embed_inputs(params, cfg, batch)
    hidden, _ = forward_hidden(params, cfg, x, positions)
    return logits_fn(params, cfg, hidden[:, -1:, :])
