"""Attention mixers: GQA/MQA full + sliding-window, flash-style chunked
training path, decode with KV cache, and the mqr-KV sparse decode path
(the paper's technique; DESIGN.md §3).

Shapes: hidden (B, S, D); q (B, S, H, Dh); kv (B, S, Hkv, Dh).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import kvindex
from .modules import apply_rope, dense_init, shard

NEG_INF = -1e30


def init_attention(key, cfg, d_model: int) -> Dict:
    dh = cfg.head_dim_
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    params = {
        "wq": dense_init(ks[0], d_model, (cfg.n_heads, dh), dt),
        "wk": dense_init(ks[1], d_model, (cfg.n_kv_heads, dh), dt),
        "wv": dense_init(ks[2], d_model, (cfg.n_kv_heads, dh), dt),
        "wo": dense_init(ks[3], cfg.n_heads * dh, (d_model,), dt),
        # mqr-KV probe direction per kv head (the 2-D score axis).
        "probe": dense_init(jax.random.fold_in(key, 9), dh, (cfg.n_kv_heads,), jnp.float32).T,
    }
    return params


def _project_qkv(params, cfg, x, positions):
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def flash_attention_jnp(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_positions: jnp.ndarray,
    kv_positions: jnp.ndarray,
    window: Optional[int] = None,
    chunk: int = 1024,
) -> jnp.ndarray:
    """Causal (optionally windowed) attention, never materializing (S, S).

    q: (B, S, H, Dh); k/v: (B, Skv, Hkv, Dh).  Scan over KV chunks with a
    running-softmax accumulator (portable equivalent of the Pallas flash
    kernel in repro.kernels.flash_attention).
    """
    b, s, h, dh = q.shape
    skv = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qs = q.reshape(b, s, hkv, g, dh)

    chunk = min(chunk, skv)
    n_chunks = skv // chunk
    assert skv % chunk == 0, (skv, chunk)

    k_c = k.reshape(b, n_chunks, chunk, hkv, dh)
    v_c = v.reshape(b, n_chunks, chunk, hkv, dh)
    kp_c = kv_positions.reshape(b, n_chunks, chunk)[0]  # positions are shared

    def body(carry, inputs):
        m, l, acc = carry
        kc, vc, kpc = inputs  # (B, chunk, Hkv, Dh), (chunk,)
        logits = (
            jnp.einsum("bshgd,bchd->bshgc", qs, kc).astype(jnp.float32) * scale
        )
        mask = q_positions[:, :, None, None, None] >= kpc[None, None, None, None, :]
        if window is not None:
            mask &= (
                q_positions[:, :, None, None, None]
                - kpc[None, None, None, None, :]
            ) < window
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bshgc,bchd->bshgd", p.astype(vc.dtype), vc
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, hkv, g), jnp.float32)
    acc0 = jnp.zeros((b, s, hkv, g, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, acc0),
        (
            jnp.moveaxis(k_c, 1, 0),
            jnp.moveaxis(v_c, 1, 0),
            kp_c,
        ),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, s, h, dh).astype(q.dtype)


def attention_train(params, cfg, x, positions, window=None):
    """Full training/prefill path. x: (B, S, D) -> (B, S, D)."""
    q, k, v = _project_qkv(params, cfg, x, positions)
    # SP attention: shard q's sequence over the model axis (k/v stay full);
    # the flash logits (B, S/tp, H, chunk) then shard with it.
    q = shard(q, ("pod", "data"), "model", None, None)
    if window is not None and cfg.local_attn_impl == "banded" and x.shape[1] % window == 0:
        out = local_attention_banded(q, k, v, positions, window)
    else:
        out = flash_attention_jnp(
            q, k, v, positions, positions, window=window, chunk=cfg.attn_chunk
        )
    return jnp.einsum(
        "bshk,hkd->bsd", out, params["wo"].reshape(cfg.n_heads, cfg.head_dim_, -1)
    )


def local_attention_banded(q, k, v, positions, window: int):
    """Exact sliding-window attention in O(S*2W): chunk the sequence at the
    window size; each chunk attends to itself + the previous chunk.

    This is the optimized path for local-attention layers (vs. the 'masked'
    baseline that computes the full S^2 score matrix and masks it) — see
    EXPERIMENTS.md §Perf.
    """
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    w = window
    assert s % w == 0, (s, w)
    nc = s // w
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    qc = q.reshape(b, nc, w, hkv, g, dh)
    kc = k.reshape(b, nc, w, hkv, dh)
    vc = v.reshape(b, nc, w, hkv, dh)
    pc = positions.reshape(b, nc, w)
    # previous chunk (zeros before the first)
    prev = lambda a: jnp.concatenate([jnp.zeros_like(a[:, :1]), a[:, :-1]], axis=1)
    k2 = jnp.concatenate([prev(kc), kc], axis=2)  # (B,nc,2w,hkv,dh)
    v2 = jnp.concatenate([prev(vc), vc], axis=2)
    # positions of k2 entries; the phantom chunk before c=0 is masked via -1
    p2 = jnp.concatenate(
        [jnp.where(jnp.arange(nc)[None, :, None] == 0, -1, pc - w), pc], axis=2
    )

    logits = (
        jnp.einsum("bcqhgd,bckhd->bcqhgk", qc, k2).astype(jnp.float32) * scale
    )
    mask = (pc[:, :, :, None, None, None] >= p2[:, :, None, None, None, :]) & (
        pc[:, :, :, None, None, None] - p2[:, :, None, None, None, :] < w
    ) & (p2[:, :, None, None, None, :] >= 0)
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bcqhgk,bckhd->bcqhgd", p.astype(v2.dtype), v2)
    return out.reshape(b, s, h, dh)


def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> Dict:
    dh = cfg.head_dim_
    cache = {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), dtype),
    }
    if cfg.mqr_incremental and max_len % cfg.mqr_block == 0:
        nb = max_len // cfg.mqr_block
        idx0 = kvindex.init_incremental(nb, cfg.mqr_block, cfg.mqr_levels)
        bc = lambda a: jnp.broadcast_to(
            a, (batch, cfg.n_kv_heads) + a.shape
        )
        cache["idx_block"] = bc(idx0.block_mbr)
        cache["idx_group"] = bc(idx0.group_mbr)
        cache["idx_gof"] = bc(idx0.group_of)
    return cache


def init_local_cache(cfg, batch: int, dtype) -> Dict:
    """Ring buffer of window size for sliding-window layers."""
    dh = cfg.head_dim_
    w = cfg.local_window
    return {
        "k": jnp.zeros((batch, w, cfg.n_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, w, cfg.n_kv_heads, dh), dtype),
        "pos": jnp.full((w,), -1, jnp.int32),
    }


def local_attention_decode(params, cfg, x, cache, pos):
    """Single-token decode against the ring buffer. x: (B, 1, D)."""
    b = x.shape[0]
    dh = cfg.head_dim_
    w = cache["k"].shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)

    slot = jnp.mod(pos, w)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1
    )
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1
    )
    kv_pos = cache["pos"].at[slot].set(pos)
    new_cache = {"k": k_cache, "v": v_cache, "pos": kv_pos}

    h = cfg.n_heads
    hkv = cfg.n_kv_heads
    g = h // hkv
    qs = q.reshape(b, hkv, g, dh)
    logits = jnp.einsum("bhgd,bshd->bhgs", qs, k_cache).astype(jnp.float32)
    logits = logits / jnp.sqrt(dh)
    valid = (kv_pos >= 0) & (kv_pos <= pos) & (pos - kv_pos < w)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache)
    out = out.reshape(b, 1, h, dh)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].reshape(h, dh, -1))
    return out, new_cache


def attention_prefill(params, cfg, x, positions, window=None):
    """Returns (out, cache-contents k/v) for subsequent decode."""
    q, k, v = _project_qkv(params, cfg, x, positions)
    if window is not None and cfg.local_attn_impl == "banded" and x.shape[1] % window == 0:
        out = local_attention_banded(q, k, v, positions, window)
    else:
        out = flash_attention_jnp(
            q, k, v, positions, positions, window=window, chunk=cfg.attn_chunk
        )
    out = jnp.einsum(
        "bshk,hkd->bsd",
        out,
        params["wo"].reshape(cfg.n_heads, cfg.head_dim_, -1),
    )
    return out, {"k": k, "v": v}


def attention_decode(
    params,
    cfg,
    x,
    cache: Dict,
    pos,  # scalar int32: current length (position of the new token)
    window=None,
    mqr_sparse: bool = False,
):
    """Single-token decode. x: (B, 1, D). Returns (out, new_cache)."""
    b = x.shape[0]
    dh = cfg.head_dim_
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)

    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    new_cache = dict(cache, k=k_cache, v=v_cache)

    if mqr_sparse and "idx_block" in cache:
        out, new_cache = _mqr_incremental_decode(
            params, cfg, q, k_new, new_cache, pos
        )
    elif mqr_sparse:
        out = _mqr_sparse_decode(params, cfg, q, k_cache, v_cache, pos)
    else:
        out = _dense_decode(cfg, q, k_cache, v_cache, pos, window)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].reshape(cfg.n_heads, dh, -1))
    return out, new_cache


def _dense_decode(cfg, q, k_cache, v_cache, pos, window):
    b, _, h, dh = q.shape
    skv = k_cache.shape[1]
    hkv = cfg.n_kv_heads
    g = h // hkv
    qs = q.reshape(b, hkv, g, dh)
    logits = jnp.einsum("bhgd,bshd->bhgs", qs, k_cache).astype(jnp.float32)
    logits = logits / jnp.sqrt(dh)
    kv_pos = jnp.arange(skv)
    mask = kv_pos[None, None, None, :] <= pos
    if window is not None:
        mask &= kv_pos[None, None, None, :] > pos - window
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, dh)


def _mqr_sparse_decode(params, cfg, q, k_cache, v_cache, pos):
    """The paper's technique on the KV cache: region-search the mqr-KV index
    and attend only over the selected blocks (DESIGN.md §3)."""
    b, _, h, dh = q.shape
    skv = k_cache.shape[1]
    hkv = cfg.n_kv_heads
    g = h // hkv
    bs = cfg.mqr_block
    nb = skv // bs
    topk = min(cfg.mqr_topk, nb)
    probe = params["probe"]  # (Hkv, Dh) fp32

    kb = k_cache.reshape(b, nb, bs, hkv, dh)
    vb = v_cache.reshape(b, nb, bs, hkv, dh)

    def per_bh(k_bh, q_bh, probe_h):
        # k_bh: (S, Dh) for one (batch, kv head); q_bh: (G, Dh)
        idx = kvindex.build_kv_index(
            k_bh.astype(jnp.float32), probe_h, bs, cfg.mqr_levels
        )
        regions = jax.vmap(
            lambda qq: kvindex.query_region(qq.astype(jnp.float32), probe_h, pos + 1)
        )(q_bh)
        ids = jax.vmap(lambda r: kvindex.select_blocks(idx, r, topk))(regions)
        return ids  # (G, topk)

    k_flat = k_cache.reshape(b, skv, hkv, dh)
    ids = jax.vmap(  # over batch
        lambda kf, qf: jax.vmap(per_bh, in_axes=(1, 0, 0))(
            kf, qf.reshape(hkv, g, dh), probe
        )
    )(k_flat, q[:, 0])
    # ids: (B, Hkv, G, topk)

    kg = _gather(kb, ids)  # (B, Hkv, G, topk, bs, Dh)
    vg = _gather(vb, ids)

    qs = q.reshape(b, hkv, g, dh)
    logits = jnp.einsum("bhgd,bhgksd->bhgks", qs, kg).astype(jnp.float32)
    logits = logits / jnp.sqrt(dh)
    kv_pos = ids[..., None] * bs + jnp.arange(bs)[None, None, None, None, :]
    mask = kv_pos <= pos
    logits = jnp.where(mask, logits, NEG_INF)
    shape = logits.shape
    p = jax.nn.softmax(logits.reshape(*shape[:3], -1), axis=-1).reshape(shape)
    out = jnp.einsum("bhgks,bhgksd->bhgd", p.astype(vg.dtype), vg)
    return out.reshape(b, 1, h, dh)


def _mqr_incremental_decode(params, cfg, q, k_new, cache, pos):
    """Sparse decode against the cache-resident incremental index: the key
    cache is only read for the K selected blocks (EXPERIMENTS.md §Perf)."""
    b, _, h, dh = q.shape
    k_cache, v_cache = cache["k"], cache["v"]
    skv = k_cache.shape[1]
    hkv = cfg.n_kv_heads
    g = h // hkv
    bs = cfg.mqr_block
    nb = skv // bs
    topk = min(cfg.mqr_topk, nb)
    probe = params["probe"]  # (Hkv, Dh)

    # 1. update the index with the new key's (pos, score) point
    s_new = jnp.einsum("bhd,hd->bh", k_new[:, 0].astype(jnp.float32), probe)

    def upd(idx_b, idx_g, idx_o, s_bh):
        idx = kvindex.IncKVIndex(idx_b, idx_g, idx_o)
        idx = kvindex.incremental_update(idx, pos, s_bh, bs)
        return idx.block_mbr, idx.group_mbr

    nb_, ng_ = jax.vmap(jax.vmap(upd))(
        cache["idx_block"], cache["idx_group"], cache["idx_gof"], s_new
    )
    cache = dict(cache, idx_block=nb_, idx_group=ng_)

    # 2. region search per query head (reads only the index arrays)
    def per_bh(idx_b, idx_g, idx_o, q_bh, probe_h):
        idx = kvindex.IncKVIndex(idx_b, idx_g, idx_o)
        regions = jax.vmap(
            lambda qq: kvindex.query_region(qq.astype(jnp.float32), probe_h, pos + 1)
        )(q_bh)  # (G, 4)
        return jax.vmap(
            lambda r: kvindex.incremental_select(idx, r, topk)
        )(regions)  # (G, topk)

    ids = jax.vmap(  # batch
        lambda ib, ig, io, qb: jax.vmap(per_bh, in_axes=(0, 0, 0, 0, 0))(
            ib, ig, io, qb.reshape(hkv, g, dh), probe
        )
    )(cache["idx_block"], cache["idx_group"], cache["idx_gof"], q[:, 0])
    # ids: (B, Hkv, G, topk)

    # 3. gather only the selected blocks and attend
    kb = k_cache.reshape(b, nb, bs, hkv, dh)
    vb = v_cache.reshape(b, nb, bs, hkv, dh)
    kg = _gather(kb, ids)
    vg = _gather(vb, ids)
    qs = q.reshape(b, hkv, g, dh)
    logits = jnp.einsum("bhgd,bhgksd->bhgks", qs, kg).astype(jnp.float32)
    logits = logits / jnp.sqrt(dh)
    kv_pos = ids[..., None] * bs + jnp.arange(bs)[None, None, None, None, :]
    logits = jnp.where(kv_pos <= pos, logits, NEG_INF)
    shape = logits.shape
    p = jax.nn.softmax(logits.reshape(*shape[:3], -1), axis=-1).reshape(shape)
    out = jnp.einsum("bhgks,bhgksd->bhgd", p.astype(vg.dtype), vg)
    return out.reshape(b, 1, h, dh), cache


def _gather(blocks, ids):
    """blocks: (B, nb, bs, Hkv, Dh); ids: (B, Hkv, G, topk)
    -> (B, Hkv, G, topk, bs, Dh)"""
    bt = blocks.transpose(0, 3, 1, 2, 4)  # (B, Hkv, nb, bs, Dh)

    def per_b(bt_b, ids_b):  # (Hkv, nb, bs, Dh), (Hkv, G, topk)
        def per_h(bt_h, ids_h):  # (nb, bs, Dh), (G, topk)
            return bt_h[ids_h]  # (G, topk, bs, Dh)

        return jax.vmap(per_h)(bt_b, ids_b)

    return jax.vmap(per_b)(bt, ids)
