"""Minimal functional module system (no flax in this environment).

Parameters are nested dicts of jnp arrays.  Each "module" is a pair of
functions: ``init_*(key, ...) -> params`` and an apply function taking
``(params, x, ...)``.  Initializers follow standard LM practice
(truncated-normal fan-in scaling).
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def param_dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, in_dim: int, out_shape, dtype=jnp.bfloat16, scale: float = 1.0):
    """Weight of shape (in_dim, *out_shape), fan-in scaled normal."""
    shape = (in_dim,) + tuple(out_shape)
    std = scale / math.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.bfloat16):
    # 1/sqrt(dim) scale keeps tied-head logits O(1); input-side models that
    # expect unit-scale embeddings (gemma family) multiply by sqrt(dim).
    std = dim ** -0.5
    return (
        jax.random.truncated_normal(key, -2.0, 2.0, (vocab, dim), jnp.float32) * std
    ).astype(dtype)


def rmsnorm_init(dim: int, dtype=jnp.float32):
    # Norm scales stay fp32: they are tiny and precision-critical.
    return jnp.ones((dim,), dtype)


def rmsnorm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dt)


def act_fn(kind: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[kind]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, Dh) or (..., S, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == ang.ndim + 1:  # (..., S, H, Dh): broadcast over heads
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Sharding helper: constraint that degrades to a no-op without a mesh.
# ---------------------------------------------------------------------------


def _active_mesh_axes():
    """Axis names of the mesh in scope (with mesh: ...), or empty set."""
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and m.axis_names:
            return set(m.axis_names), dict(zip(m.axis_names, m.devices.shape))
    except Exception:
        pass
    return set(), {}


def shard(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """Sharding constraint that adapts to the active mesh: axis names not in
    the mesh are dropped (single-pod has no "pod" axis), non-divisible dims
    fall back to replication, and without a mesh this is a no-op."""
    from jax.sharding import PartitionSpec as P

    axes, sizes = _active_mesh_axes()
    if not axes:
        return x
    clean = []
    for i, s in enumerate(spec):
        names = s if isinstance(s, (tuple, list)) else (s,)
        kept = tuple(a for a in names if a is not None and a in axes)
        total = 1
        for a in kept:
            total *= sizes[a]
        if not kept or x.shape[i] % total != 0:
            clean.append(None)
        elif len(kept) == 1:
            clean.append(kept[0])
        else:
            clean.append(kept)
    try:
        return jax.lax.with_sharding_constraint(x, P(*clean))
    except Exception:
        return x


def tree_cast(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        tree,
    )


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))
