"""Straggler detection: rolling z-score over per-step wall times.

On a real pod the step time of every host is gathered through the
coordination service each heartbeat; here the monitor consumes whatever
times the loop reports (tests feed synthetic distributions).  Policy
actions are pluggable — log, drop the offending host from the next elastic
re-mesh, or trigger a checkpoint-now so a restart loses no work.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Deque, List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    host: int
    step_time: float
    median: float
    ratio: float


class StragglerMonitor:
    def __init__(
        self,
        window: int = 50,
        ratio_threshold: float = 2.0,
        min_samples: int = 10,
        on_straggler: Optional[Callable[[StragglerEvent], None]] = None,
    ):
        self.window = window
        self.ratio_threshold = ratio_threshold
        self.min_samples = min_samples
        self.on_straggler = on_straggler
        self._times: Deque[float] = collections.deque(maxlen=window)
        self.events: List[StragglerEvent] = []

    def observe(self, step: int, step_time: float, host: int = 0) -> bool:
        """Feed one (host, step_time). Returns True if flagged straggler."""
        flagged = False
        if len(self._times) >= self.min_samples:
            ts = sorted(self._times)
            median = ts[len(ts) // 2]
            ratio = step_time / max(median, 1e-9)
            if ratio > self.ratio_threshold:
                ev = StragglerEvent(step, host, step_time, median, ratio)
                self.events.append(ev)
                if self.on_straggler:
                    self.on_straggler(ev)
                flagged = True
        # stragglers do not poison the window
        if not flagged:
            self._times.append(step_time)
        return flagged
