"""Elastic re-meshing: rebuild the device mesh after node loss and compute
the resharding plan for a checkpointed state.

The contract at 1000+ nodes: when hosts drop, the job restarts from the
latest checkpoint on the surviving device set.  Parameters were saved with
*logical* axes (the PartitionSpec tree is a pure function of the param tree
via repro.sharding.rules), so resharding = re-deriving specs on the new
mesh; nothing about the checkpoint format depends on the old topology.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax

from repro.sharding import rules


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    n_devices: int
    dropped: int


def plan_mesh(n_available: int, *, model_parallel: int = 16,
              multi_pod_threshold: int = 512) -> MeshPlan:
    """Largest well-formed mesh on the surviving devices.

    Keeps the model axis fixed (TP degree is a property of the model fit),
    shrinks the data axis, and drops remainder devices (they rejoin at the
    next re-mesh — the standard elastic-DP contract).
    """
    mp = model_parallel
    usable = (n_available // mp) * mp
    if usable == 0:
        raise ValueError(f"cannot keep model_parallel={mp} with {n_available} devices")
    data = usable // mp
    if usable >= multi_pod_threshold and data % 2 == 0:
        return MeshPlan((2, data // 2, mp), ("pod", "data", "model"),
                        usable, n_available - usable)
    return MeshPlan((data, mp), ("data", "model"), usable, n_available - usable)


def build_mesh(plan: MeshPlan, devices: Optional[List] = None):
    devices = devices if devices is not None else jax.devices()
    import numpy as np

    grid = np.array(devices[: plan.n_devices]).reshape(plan.shape)
    return jax.sharding.Mesh(grid, plan.axis_names)


def reshard_plan(params_abs, old_mesh, new_mesh):
    """(old_spec, new_spec) pairs per leaf — the logical axes are identical,
    only the mesh changed, so this is exactly the device_put plan."""
    old = rules.param_specs(params_abs, old_mesh)
    new = rules.param_specs(params_abs, new_mesh)
    return jax.tree.map(lambda o, n: (o, n), old, new)
