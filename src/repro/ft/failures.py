"""Failure injection for fault-tolerance tests.

Two generations of harness live here:

* :class:`FailureInjector` — the original train-loop hook: deterministic
  or random crashes at step boundaries (``maybe_fail(step)``).
* :class:`FaultPlan` — the reusable spatial-serving harness (DESIGN.md
  §9).  One plan threads through the durable index, the write-ahead log,
  the update engine's merge, and the spatial server's dispatch loop, so a
  single object scripts *where* in the op/launch timeline a fault lands:

    - ``kill_at_op`` / ``kill_site``: simulate a process kill at op ``k``,
      at the ``pre-append`` / ``post-append`` / ``post-apply`` WAL
      boundary or ``mid-merge`` (inside the compaction the op triggered);
    - ``torn_write``: the kill lands mid-append, leaving a torn
      (checksum-failing) record at the WAL tail;
    - ``fail_launches`` / ``fail_rungs``: the next N device dispatches on
      the named backend rungs raise, exercising the degradation ladder;
    - ``slow_merge``: stretch every merge by a sleep, widening the
      mid-merge kill window for racier schedules.

Kills raise :class:`KillPoint`, which deliberately subclasses
``BaseException`` so production ``except Exception`` recovery paths can
never swallow a simulated SIGKILL — only the test harness catches it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import numpy as np


class InjectedFailure(RuntimeError):
    """A scripted component failure (device launch, node, ...)."""


class KillPoint(BaseException):
    """Simulated process kill: NOT an Exception, so no recovery/retry
    path can accidentally absorb it — the process is 'dead'."""


KILL_SITES = ("pre-append", "post-append", "post-apply", "mid-merge")


@dataclasses.dataclass
class FaultPlan:
    """Scripted faults threaded through the durability + serving stack.

    The op counter is owned by the caller (the durable index passes the
    op index into :meth:`op_event` / sets :attr:`current_op` before the
    apply phase); launch failures keep their own countdown.
    """

    kill_at_op: Optional[int] = None
    kill_site: str = "post-append"
    torn_write: bool = False
    fail_launches: int = 0
    fail_rungs: Tuple[str, ...] = ("pallas",)
    fail_from_launch: Optional[int] = None
    slow_merge: float = 0.0
    current_op: int = dataclasses.field(default=-1, init=False)
    kills: int = dataclasses.field(default=0, init=False)
    launch_failures: int = dataclasses.field(default=0, init=False)
    launches_seen: int = dataclasses.field(default=0, init=False)

    def __post_init__(self):
        if self.kill_site not in KILL_SITES:
            raise ValueError(
                f"kill_site {self.kill_site!r} not in {KILL_SITES}"
            )

    # -- op timeline ----------------------------------------------------
    def op_event(self, site: str, op_index: int) -> None:
        """Called by the durable index at each WAL boundary of op
        ``op_index``; raises :class:`KillPoint` when the plan says the
        process dies here.  A ``torn_write`` kill is raised by the WAL
        itself (mid-append), never at a clean boundary."""
        self.current_op = op_index
        if self.torn_write:
            return
        if self.kill_at_op == op_index and self.kill_site == site:
            self.kills += 1
            raise KillPoint(f"injected kill at op {op_index} ({site})")

    def tear_now(self) -> bool:
        """Should the WAL tear the record of the current op?  (The WAL
        writes a partial record, then raises the kill itself.)"""
        return self.torn_write and self.kill_at_op == self.current_op

    def killed_mid_append(self) -> KillPoint:
        self.kills += 1
        return KillPoint(
            f"injected kill mid-append at op {self.current_op} (torn write)"
        )

    def merge_event(self) -> None:
        """Called from inside the update log's merge (compaction)."""
        if self.slow_merge > 0:
            time.sleep(self.slow_merge)
        if (
            self.kill_site == "mid-merge"
            and self.kill_at_op is not None
            and self.kill_at_op == self.current_op
        ):
            self.kills += 1
            raise KillPoint(
                f"injected kill mid-merge at op {self.current_op}"
            )

    # -- launch timeline ------------------------------------------------
    def launch(self, rung: str) -> None:
        """Called by the server before dispatching on ``rung``; raises
        :class:`InjectedFailure` while the countdown lasts.

        With ``fail_from_launch=N`` the countdown is armed only once the
        plan has witnessed N launch attempts on the named rungs — a
        mid-run degradation: the server runs healthy, then its device
        rung starts failing partway through a workload.
        """
        if rung not in self.fail_rungs:
            return
        self.launches_seen += 1
        if (
            self.fail_from_launch is not None
            and self.launches_seen <= self.fail_from_launch
        ):
            return
        if self.fail_launches > 0:
            self.fail_launches -= 1
            self.launch_failures += 1
            raise InjectedFailure(f"injected launch failure on rung {rung!r}")


class FailureInjector:
    def __init__(self, fail_at_step: Optional[int] = None,
                 fail_prob: float = 0.0, seed: int = 0, max_failures: int = 1):
        self.fail_at_step = fail_at_step
        self.fail_prob = fail_prob
        self.rng = np.random.default_rng(seed)
        self.remaining = max_failures

    def maybe_fail(self, step: int) -> None:
        if self.remaining <= 0:
            return
        hit = (self.fail_at_step is not None and step == self.fail_at_step) or (
            self.fail_prob > 0 and self.rng.random() < self.fail_prob
        )
        if hit:
            self.remaining -= 1
            raise InjectedFailure(f"injected node failure at step {step}")
