"""Failure injection for fault-tolerance tests: deterministic or random
crashes at step boundaries (the train loop calls ``maybe_fail(step)``)."""

from __future__ import annotations

from typing import Optional

import numpy as np


class InjectedFailure(RuntimeError):
    pass


class FailureInjector:
    def __init__(self, fail_at_step: Optional[int] = None,
                 fail_prob: float = 0.0, seed: int = 0, max_failures: int = 1):
        self.fail_at_step = fail_at_step
        self.fail_prob = fail_prob
        self.rng = np.random.default_rng(seed)
        self.remaining = max_failures

    def maybe_fail(self, step: int) -> None:
        if self.remaining <= 0:
            return
        hit = (self.fail_at_step is not None and step == self.fail_at_step) or (
            self.fail_prob > 0 and self.rng.random() < self.fail_prob
        )
        if hit:
            self.remaining -= 1
            raise InjectedFailure(f"injected node failure at step {step}")
