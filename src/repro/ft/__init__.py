from .straggler import StragglerMonitor, StragglerEvent  # noqa: F401
from .elastic import plan_mesh, build_mesh, reshard_plan, MeshPlan  # noqa: F401
from .failures import (  # noqa: F401
    FailureInjector,
    FaultPlan,
    InjectedFailure,
    KillPoint,
)
