"""repro.obs — zero-dependency observability layer (DESIGN.md §13).

Three pieces, one discipline: the numbers production discloses are the
numbers the benches disclose.

* :mod:`repro.obs.trace` — flight-recorder spans with Chrome/Perfetto
  ``trace.json`` export, threaded through façade → backend → kernel and
  the serving/durability paths.
* :mod:`repro.obs.counters` — the per-launch kernel byte/tile ledger
  (:class:`~repro.obs.counters.LaunchReport`) and the §12 bench's
  accounting functions, now shared by bench and production.
* :mod:`repro.obs.metrics` — a Prometheus-text / JSON metrics registry
  snapshotting ``AccessStats`` + serve telemetry with per-tenant labels.

This package imports nothing from the rest of ``repro`` (only numpy and
the stdlib), so every layer may depend on it without cycles.
"""

from repro.obs import counters, metrics, trace
from repro.obs.counters import (
    LaunchReport,
    collect_launch_reports,
    merge_reports,
)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import (
    Tracer,
    counter,
    disable,
    enable,
    get_tracer,
    instant,
    set_tracer,
    span,
)

__all__ = [
    "LaunchReport",
    "MetricsRegistry",
    "Tracer",
    "collect_launch_reports",
    "counter",
    "counters",
    "disable",
    "enable",
    "get_tracer",
    "instant",
    "merge_reports",
    "metrics",
    "set_tracer",
    "span",
    "trace",
]
