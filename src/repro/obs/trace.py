"""Flight-recorder tracing with Chrome/Perfetto export (DESIGN.md §13).

A single process-wide :class:`Tracer` records three Chrome-trace event
kinds into a bounded ring buffer:

* ``span(name, **args)`` — a nestable context manager emitting one
  complete ("ph": "X") event on exit, covering the region's wall time.
  Nesting is implicit: Perfetto reconstructs the stack from ts/dur
  containment per thread, so spans survive exceptions — ``__exit__``
  always runs and stamps the error type into ``args``.
* ``instant(name, **args)`` — a point event ("ph": "i"), used for
  degradation-rung transitions, deadline trips, enqueue marks.
* ``counter(name, **values)`` — a counter track ("ph": "C"), used for
  span-less overload accounting (shed/queued requests).

The disabled fast path is a single attribute check returning a shared
no-op span object — no allocation, no clock read — so production code
can leave instrumentation inline (the <2% overhead budget is enforced
by ``bench_obs`` + the CI perf guard).  The ring buffer (default 64k
events) makes the tracer a flight recorder: always safe to leave on,
oldest events are dropped and counted in :attr:`Tracer.dropped`.

Timestamps are microseconds on ``time.monotonic`` relative to tracer
creation, which is exactly what the Chrome trace-event format expects.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional


class _NullSpan:
    """Shared do-nothing span returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, et, ev, tb) -> bool:
        return False

    def annotate(self, **args: Any) -> None:
        pass

    def event(self, name: str, **args: Any) -> None:
        pass


NULL_SPAN = _NullSpan()


class Span:
    """A live span; created only when the tracer is enabled."""

    __slots__ = ("_tracer", "name", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._t0 = 0.0

    def __enter__(self) -> "Span":
        self._t0 = self._tracer._now_us()
        return self

    def annotate(self, **args: Any) -> None:
        """Attach extra args discovered mid-span (e.g. result sizes)."""
        self.args.update(args)

    def event(self, name: str, **args: Any) -> None:
        """An instant event stamped inside this span's thread track."""
        self._tracer.instant(name, **args)

    def __exit__(self, et, ev, tb) -> bool:
        t1 = self._tracer._now_us()
        args = self.args
        if et is not None:
            # spans close under exceptions (incl. BaseException kills);
            # record what tore through so the trace shows the failure.
            args = dict(args)
            args["error"] = et.__name__
        self._tracer._append(
            {
                "name": self.name,
                "ph": "X",
                "ts": self._t0,
                "dur": max(t1 - self._t0, 0.0),
                "pid": os.getpid(),
                "tid": threading.get_ident(),
                "args": args,
            }
        )
        return False


class Tracer:
    """Bounded in-memory trace recorder with Chrome-trace export."""

    def __init__(self, capacity: int = 65536, clock=time.monotonic):
        self.enabled = False
        self.clock = clock
        self.dropped = 0
        self._t0 = clock()
        self._events: deque = deque(maxlen=int(capacity))
        self._lock = threading.Lock()

    # -- recording ------------------------------------------------------
    def _now_us(self) -> float:
        return (self.clock() - self._t0) * 1e6

    def _append(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)

    def span(self, name: str, **args: Any):
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, args)

    def instant(self, name: str, **args: Any) -> None:
        if not self.enabled:
            return
        self._append(
            {
                "name": name,
                "ph": "i",
                "s": "t",
                "ts": self._now_us(),
                "pid": os.getpid(),
                "tid": threading.get_ident(),
                "args": args,
            }
        )

    def counter(self, name: str, **values: Any) -> None:
        if not self.enabled:
            return
        self._append(
            {
                "name": name,
                "ph": "C",
                "ts": self._now_us(),
                "pid": os.getpid(),
                "args": {k: float(v) for k, v in values.items()},
            }
        )

    # -- inspection / export --------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    def export_chrome_trace(self, path) -> str:
        """Write the ring buffer as a Perfetto-loadable ``trace.json``."""
        doc = {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "metadata": {
                "recorder": "repro.obs.trace",
                "dropped_events": self.dropped,
            },
        }
        path = os.fspath(path)
        with open(path, "w") as fh:
            json.dump(doc, fh)
        return path


# -- process-wide tracer ------------------------------------------------
_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process tracer (tests install a fresh one); returns it."""
    global _TRACER
    _TRACER = tracer
    return tracer


def enable(capacity: Optional[int] = None) -> Tracer:
    if capacity is not None and capacity != _TRACER._events.maxlen:
        set_tracer(Tracer(capacity=capacity))
    _TRACER.enabled = True
    return _TRACER


def disable() -> None:
    _TRACER.enabled = False


def span(name: str, **args: Any):
    """Module-level span helper; the disabled path is one attr check."""
    t = _TRACER
    if not t.enabled:
        return NULL_SPAN
    return Span(t, name, args)


def instant(name: str, **args: Any) -> None:
    _TRACER.instant(name, **args)


def counter(name: str, **values: Any) -> None:
    _TRACER.counter(name, **values)


if os.environ.get("REPRO_TRACE") == "1":  # opt-in via env for CLIs
    enable()
