"""Per-launch kernel counter ledger (DESIGN.md §13).

The paper states its headline results in *accesses*; PR 9's streaming
bench (`benchmarks/jax_bench.py::bench_stream_scan`) turned those into a
byte-exact HBM-traffic ledger — but only the bench could see it.  This
module is the single home of that accounting so bench and production
disclose **identical** numbers:

* :func:`survivor_recurrence`, :func:`tile_bytes_per_query`,
  :func:`stream_fetch_bytes`, :func:`quantize_queries_grid` — the ledger
  math, moved here verbatim from the bench (which now imports them).
* :class:`LaunchReport` — the structured per-launch record (bytes
  streamed, tiles fetched/skipped, mask traffic, survivors per level,
  tiling used), built by the eager ``pyramid_scan*`` wrappers and the
  host fallback twins through a side channel, drained by the façade into
  ``RegionResult.launch_report`` and folded into ``AccessStats``.

The side channel is opt-in (:func:`collect_launch_reports`): the ledger
replays the survivor recurrence on the host (O(L·Q·W) numpy), which is
fine for forensics and tests but not for the hot path, so the default is
a single module-flag check costing nothing.  Only eagerly-executed
launch paths can emit — the lax twins and the serve backend's vmapped
inner functions run traced, where a host side channel cannot exist.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# the ledger math (single source of truth; benchmarks import these)
# ---------------------------------------------------------------------------

def survivor_recurrence(mbr_grid, parent, qq_per_level, *,
                        root_unconditional=True):
    """Yield ``(l, tested, act)`` of the quantized sweep's own recurrence.

    ``mbr_grid`` is the integer (L, 4, W) grid the sweep actually tests,
    ``qq_per_level(l)`` the matching outward-quantized queries for level
    ``l`` — so survivors here are the kernel's own, conservative widening
    included.
    """
    levels, _, w = mbr_grid.shape
    prev = None
    for l in range(levels):
        qq = qq_per_level(l)
        rm = mbr_grid[l].T[None, :, :]  # (1, W, 4)
        ov = (
            (rm[..., 0] <= qq[:, None, 2]) & (qq[:, None, 0] <= rm[..., 2])
            & (rm[..., 1] <= qq[:, None, 3]) & (qq[:, None, 1] <= rm[..., 3])
        )
        if l == 0:
            tested = np.ones((qq.shape[0], w), bool)
            if root_unconditional:
                # the kernel's root mask is slot 0 only (_act_formula)
                act = np.zeros_like(ov)
                act[:, 0] = True
            else:
                act = ov
        else:
            tested = prev[:, parent[l]]
            act = tested & ov
        yield l, tested, act
        prev = act


def tile_bytes_per_query(mbr_grid, parent, n_real, qq, *, split,
                         levels8_bytes=384, levels16_bytes=640, tile=64,
                         root_unconditional=True, qq8=None):
    """Visited-tile HBM traffic of one quantized sweep, per query.

    The fetch model is the paper's disk-access ledger at tile grain: a
    64-slot tile is fetched at level ``l`` when any of its *real* slots
    (``n_real[l]`` — padding slots alias parent 0 and must not count)
    must be tested, i.e. its parent survived level ``l-1``; every tile at
    the root.  A uint16 tile costs 64·4·2 B of MBR lanes + 64·2 B of
    parent row = 640 B; a uint8 upper tile (levels < split) 64·4·1 +
    64·2 = 384 B, tested against the coarse-grid queries ``qq8``.
    """
    n_q = qq.shape[0]
    total = 0.0
    sweep = survivor_recurrence(
        mbr_grid, parent, lambda l: qq8 if l < split else qq,
        root_unconditional=root_unconditional,
    )
    for l, tested, _ in sweep:
        nr = int(n_real[l])
        tr = tested[:, :nr]
        pad = (-nr) % tile
        fetched = np.pad(tr, ((0, 0), (0, pad))).reshape(
            n_q, -1, tile).any(axis=2).sum()
        total += float(fetched) * (levels8_bytes if l < split
                                   else levels16_bytes)
    return total / n_q


def stream_fetch_bytes(mbr_grid, parent, qq, win_off, win_w, *,
                       block_w=128, slot_bytes=10,
                       root_unconditional=True):
    """Per-launch HBM tile traffic of the dead-window-skip streamed sweep.

    Mirrors ``_stream_sweep_kernel``'s fetch rule exactly: the
    (block_w)-slot tile at (l, t) is DMA'd iff it is not statically
    empty (``win_off[l, t] == -1`` marks tiles wholly past ``n_real``)
    AND (``l == 0``, or ``t == 0`` — a level boundary's window cannot be
    read a step early — or the parent window ``[win_off[l, t], +win_w)``
    holds a survivor for ANY query in the batch).  Returns
    ``(tile_bytes, mask_bytes, fetched, total_tiles, survivors)`` where
    ``mask_bytes`` is the survivor-window traffic (window reads for
    non-empty gated tiles + write-back of every tile) that the streaming
    design pays for unbounded capacity, and ``survivors`` the per-level
    active-slot totals of the recurrence (summed over the query batch).
    """
    levels, _, w = mbr_grid.shape
    n_q = qq.shape[0]
    wp = ((w + block_w - 1) // block_w) * block_w
    n_tiles = wp // block_w
    fetched, windows, prev = 0, 0, None
    survivors: List[int] = []
    for l, _, act in survivor_recurrence(
            mbr_grid, parent, lambda l: qq,
            root_unconditional=root_unconditional):
        survivors.append(int(act.sum()))
        for t in range(n_tiles):
            off = int(win_off[l, t])
            if off < 0:
                continue  # statically empty: never DMA'd
            if l > 0:
                windows += 1
            if l == 0 or t == 0:
                fetched += 1
                continue
            pv = np.pad(prev, ((0, 0), (0, wp - w)))
            alive = pv.any(axis=0)  # batch union: one DMA serves all q
            if alive[off:off + win_w].any():
                fetched += 1
        prev = act
    total_tiles = levels * n_tiles
    mask_bytes = (windows * n_q * win_w * 4          # window reads
                  + total_tiles * n_q * block_w * 4)  # mask write-back
    return (float(fetched * block_w * slot_bytes), float(mask_bytes),
            fetched, total_tiles, tuple(survivors))


def quantize_queries_grid(queries, origin, inv_cell, cells):
    """Outward-quantize float queries onto an integer grid — exactly the
    transform the compact kernels apply (floor lo, ceil hi, clip)."""
    queries = np.asarray(queries)
    origin = np.asarray(origin)
    inv_cell = np.asarray(inv_cell)
    t = (queries - origin[None, :]) * inv_cell[None, :]
    qq = np.concatenate([np.floor(t[:, :2]), np.ceil(t[:, 2:])], axis=1)
    return np.clip(qq, 0.0, float(cells)).astype(np.int64)


# ---------------------------------------------------------------------------
# LaunchReport + side channel
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LaunchReport:
    """One fused-sweep launch, in the same units the §12 bench discloses.

    ``bytes_streamed`` is mbr+parent tile traffic for the whole query
    batch (divide by ``queries`` for the bench's bytes/query rows);
    ``mask_bytes`` the survivor-window side traffic of the streamed
    kernel; ``survivors_per_level`` the kernel's own per-level active
    counts summed over the batch (== column sums of ``visits``).
    """

    kind: str                      # "float32" | "compact" | "compact8"
    stream: bool
    queries: int
    block_w: int
    bytes_streamed: float
    mask_bytes: float = 0.0
    tiles_fetched: int = 0
    tiles_total: int = 0
    survivors_per_level: Optional[Tuple[int, ...]] = None
    query_block: Optional[int] = None
    backend: Optional[str] = None
    launches: int = 1

    @property
    def tiles_skipped(self) -> int:
        return max(self.tiles_total - self.tiles_fetched, 0)

    @property
    def bytes_per_query(self) -> float:
        return self.bytes_streamed / self.queries if self.queries else 0.0

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["tiles_skipped"] = self.tiles_skipped
        if self.survivors_per_level is not None:
            d["survivors_per_level"] = list(self.survivors_per_level)
        return d


def merge_reports(reports) -> Optional[LaunchReport]:
    """Fold the reports of one logical query batch (the pallas backend
    chunks by ``query_block``, emitting one report per chunk)."""
    reports = [r for r in reports if r is not None]
    if not reports:
        return None
    out = dataclasses.replace(reports[0])
    for r in reports[1:]:
        out.queries += r.queries
        out.launches += r.launches
        out.bytes_streamed += r.bytes_streamed
        out.mask_bytes += r.mask_bytes
        out.tiles_fetched += r.tiles_fetched
        out.tiles_total += r.tiles_total
        if out.survivors_per_level is not None and \
                r.survivors_per_level is not None:
            out.survivors_per_level = tuple(
                a + b for a, b in
                zip(out.survivors_per_level, r.survivors_per_level))
        elif r.survivors_per_level is not None:
            out.survivors_per_level = r.survivors_per_level
    return out


_collecting = False
_pending: List[LaunchReport] = []


def collect_launch_reports(on: bool = True) -> None:
    """Arm (or disarm) the side channel; drains any stale reports."""
    global _collecting, _pending
    _collecting = bool(on)
    _pending = []


def collecting() -> bool:
    return _collecting


def emit(report: LaunchReport) -> None:
    _pending.append(report)


def drain() -> List[LaunchReport]:
    global _pending
    out, _pending = _pending, []
    return out


# ---------------------------------------------------------------------------
# report builders (called by the eager kernel wrappers when collecting)
# ---------------------------------------------------------------------------

def _grid_tiles(w: int, levels: int, block_w: int) -> Tuple[int, int]:
    n_tiles = (int(w) + block_w - 1) // block_w
    return levels * n_tiles, levels * n_tiles


def scan_report_float32(schedule, queries, *, block_w, stream,
                        win_off=None, win_w=None) -> LaunchReport:
    mbr = np.asarray(schedule.mbr_cm)
    parent = np.asarray(schedule.parent)
    n_q = int(np.asarray(queries).shape[0])
    slot_bytes = 4 * mbr.dtype.itemsize + parent.dtype.itemsize
    if stream:
        tile_b, mask_b, fetched, total, surv = stream_fetch_bytes(
            mbr, parent, np.asarray(queries),
            np.asarray(win_off), int(win_w), block_w=block_w,
            slot_bytes=slot_bytes,
            root_unconditional=schedule.root_unconditional,
        )
        return LaunchReport("float32", True, n_q, block_w, tile_b,
                            mask_bytes=mask_b, tiles_fetched=fetched,
                            tiles_total=total, survivors_per_level=surv)
    # resident: pallas_call DMAs the full grid every launch
    fetched, total = _grid_tiles(mbr.shape[2], mbr.shape[0], block_w)
    return LaunchReport("float32", False, n_q, block_w,
                        float(mbr.nbytes + parent.nbytes),
                        tiles_fetched=fetched, tiles_total=total)


def scan_report_compact(qsched, queries, *, block_w, stream,
                        win_off=None, win_w=None) -> LaunchReport:
    """uint16 compact sweep — the bench_stream_scan headline rows.

    The streamed branch calls :func:`stream_fetch_bytes` on exactly the
    inputs ``bench_stream_scan`` uses (int64 views of the same quantized
    grid, the same outward query quantization, the same parent windows),
    so ``bytes_streamed`` matches the "bytes-streamed-skip-uint16"
    disclosure bit for bit; the resident branch reports the schedule's
    own ``streamed_bytes`` (the "bytes-compact-uint16-resident" row).
    """
    n_q = int(np.asarray(queries).shape[0])
    g = np.asarray(qsched.mbr_q, np.int64)
    p = np.asarray(qsched.parent_q, np.int64)
    if stream:
        qq = quantize_queries_grid(queries, qsched.origin, qsched.inv_cell,
                                   qsched.cells)
        tile_b, mask_b, fetched, total, surv = stream_fetch_bytes(
            g, p, qq, np.asarray(win_off), int(win_w), block_w=block_w,
            root_unconditional=qsched.base.root_unconditional,
        )
        return LaunchReport("compact", True, n_q, block_w, tile_b,
                            mask_bytes=mask_b, tiles_fetched=fetched,
                            tiles_total=total, survivors_per_level=surv)
    fetched, total = _grid_tiles(g.shape[2], g.shape[0], block_w)
    return LaunchReport("compact", False, n_q, block_w,
                        float(qsched.streamed_bytes),
                        tiles_fetched=fetched, tiles_total=total)


def scan_report_compact8(qsched, queries, *, block_w) -> LaunchReport:
    """uint8-upper mixed-grid sweep: the paper-style visited-tile ledger
    (the resident kernel has no dead-window skip, so the visited model is
    the number this path discloses in bench_stream_scan)."""
    n_q = int(np.asarray(queries).shape[0])
    mixed = np.asarray(qsched.mbr_q, np.int64).copy()
    if qsched.split:
        mixed[:qsched.split] = np.asarray(qsched.mbr_q8, np.int64)
    bpq = tile_bytes_per_query(
        mixed, np.asarray(qsched.parent_q, np.int64),
        np.asarray(qsched.base.n_real, np.int64),
        quantize_queries_grid(queries, qsched.origin, qsched.inv_cell,
                              qsched.cells),
        split=qsched.split,
        root_unconditional=qsched.base.root_unconditional,
        qq8=quantize_queries_grid(queries, qsched.origin, qsched.inv_cell8,
                                  qsched.cells8),
    )
    g = np.asarray(qsched.mbr_q)
    fetched, total = _grid_tiles(g.shape[2], g.shape[0], block_w)
    return LaunchReport("compact8", False, n_q, block_w, bpq * n_q,
                        tiles_fetched=fetched, tiles_total=total)


def host_twin_report(queries, mbr_cm, parent, *, stream) -> LaunchReport:
    """The numpy degradation twins touch the full grid per sweep; the
    streamed twin additionally walks it level-by-level but fetches the
    same bytes — the ledger records grid traffic, not cache behaviour."""
    mbr = np.asarray(mbr_cm)
    par = np.asarray(parent)
    n_q = int(np.asarray(queries).shape[0])
    return LaunchReport("host-twin", bool(stream), n_q, mbr.shape[2],
                        float(mbr.nbytes + par.nbytes),
                        tiles_fetched=mbr.shape[0], tiles_total=mbr.shape[0],
                        backend="host")
