"""Metrics registry: one snapshot, two exports (DESIGN.md §13).

:class:`MetricsRegistry` is a point-in-time snapshot builder — callers
(``SpatialIndex.metrics()`` / ``ServingFrontEnd.metrics()``) pour
`AccessStats` counters and `serve/telemetry.py` histograms into it, then
render either Prometheus text exposition or JSON.  Zero dependencies;
the registry holds plain samples, not live instruments, so snapshotting
never perturbs the serving path.

Families follow Prometheus conventions: ``{namespace}_{name}`` with
sanitised metric names, ``# HELP`` / ``# TYPE`` headers, label sets per
sample, and latency histograms exported as summaries (``quantile``
labels + ``_sum`` / ``_count``).
"""

from __future__ import annotations

import json
import re
from typing import Any, Dict, List, Optional, Tuple

_BAD = re.compile(r"[^a-zA-Z0-9_]")
_LABEL_ESC = {"\\": "\\\\", '"': '\\"', "\n": "\\n"}

DEFAULT_QUANTILES = (0.5, 0.99, 0.999)


def _san(name: str) -> str:
    s = _BAD.sub("_", str(name))
    return ("_" + s) if s[:1].isdigit() else s


def _esc(v: Any) -> str:
    return "".join(_LABEL_ESC.get(c, c) for c in str(v))


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


class MetricsRegistry:
    """Snapshot of metric samples, renderable as Prometheus text or JSON."""

    def __init__(self, namespace: str = "repro"):
        self.namespace = _san(namespace)
        # family -> (type, help); insertion order is render order
        self._families: Dict[str, Tuple[str, str]] = {}
        # (family, suffix, labels, value)
        self._samples: List[Tuple[str, str, Dict[str, str], float]] = []

    def _family(self, name: str, mtype: str, help_: str) -> str:
        fam = f"{self.namespace}_{_san(name)}"
        prev = self._families.get(fam)
        if prev is not None and prev[0] != mtype:
            raise ValueError(
                f"metric family {fam!r} registered as {prev[0]}, not {mtype}")
        self._families.setdefault(fam, (mtype, help_ or fam))
        return fam

    def _add(self, fam: str, suffix: str,
             labels: Optional[Dict[str, Any]], value: float) -> None:
        lbl = {_san(k): str(v) for k, v in (labels or {}).items()}
        self._samples.append((fam, suffix, lbl, float(value)))

    # -- public instruments --------------------------------------------
    def counter(self, name: str, value: float, *,
                labels: Optional[Dict[str, Any]] = None,
                help: str = "") -> None:
        self._add(self._family(name, "counter", help), "", labels, value)

    def gauge(self, name: str, value: float, *,
              labels: Optional[Dict[str, Any]] = None,
              help: str = "") -> None:
        self._add(self._family(name, "gauge", help), "", labels, value)

    def summary(self, name: str, hist, *,
                labels: Optional[Dict[str, Any]] = None, help: str = "",
                quantiles: Tuple[float, ...] = DEFAULT_QUANTILES) -> None:
        """Export a LatencyHistogram as a Prometheus summary."""
        fam = self._family(name, "summary", help)
        base = dict(labels or {})
        for q in quantiles:
            self._add(fam, "", {**base, "quantile": str(q)},
                      hist.quantile(q))
        self._add(fam, "_sum", base, hist.total)
        self._add(fam, "_count", base, hist.n)

    # -- renderers ------------------------------------------------------
    def to_prometheus(self) -> str:
        lines: List[str] = []
        for fam, (mtype, help_) in self._families.items():
            lines.append(f"# HELP {fam} {help_}")
            lines.append(f"# TYPE {fam} {mtype}")
            for f, suffix, labels, value in self._samples:
                if f != fam:
                    continue
                if labels:
                    lbl = ",".join(f'{k}="{_esc(v)}"'
                                   for k, v in sorted(labels.items()))
                    lines.append(f"{fam}{suffix}{{{lbl}}} {_fmt(value)}")
                else:
                    lines.append(f"{fam}{suffix} {_fmt(value)}")
        return "\n".join(lines) + "\n"

    def to_json(self) -> Dict[str, Any]:
        return {
            "namespace": self.namespace,
            "metrics": [
                {
                    "name": fam + suffix,
                    "type": self._families[fam][0],
                    "labels": labels,
                    "value": value,
                }
                for fam, suffix, labels, value in self._samples
            ],
        }

    def write_json(self, path) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=1)


# -- snapshot builders --------------------------------------------------

def stats_into(reg: MetricsRegistry, stats, *,
               prefix: str = "index",
               labels: Optional[Dict[str, Any]] = None) -> MetricsRegistry:
    """Pour an ``AccessStats`` snapshot (via ``to_dict``) into ``reg``."""
    d = stats.to_dict()
    rungs = d.pop("rung_dispatches", {}) or {}
    for k, v in d.items():
        reg.counter(f"{prefix}_{k}", v, labels=labels,
                    help=f"AccessStats.{k}")
    for rung, n in rungs.items():
        reg.counter(f"{prefix}_rung_dispatches", n,
                    labels={**(labels or {}), "rung": rung},
                    help="AccessStats.rung_dispatches")
    return reg


def telemetry_into(reg: MetricsRegistry, tel, *,
                   labels: Optional[Dict[str, Any]] = None) -> MetricsRegistry:
    """Pour a ``ServeTelemetry`` snapshot into ``reg``: scalar counters,
    overall latency/queue-wait summaries, and per-class / per-tenant
    latency summaries (p50/p99/p99.9)."""
    for k, v in tel.snapshot().items():
        if isinstance(v, (int, float)):
            reg.counter(f"serve_{k}", v, labels=labels,
                        help=f"ServeTelemetry.{k}")
    reg.summary("serve_latency_seconds", tel.latency, labels=labels,
                help="request latency (submit to complete)")
    reg.summary("serve_queue_wait_seconds", tel.queue_wait, labels=labels,
                help="queue wait (submit to launch)")
    for cls, h in sorted(tel.by_class.items()):
        reg.summary("serve_class_latency_seconds", h,
                    labels={**(labels or {}), "slo_class": cls},
                    help="request latency per SLO class")
    for tenant, h in sorted(getattr(tel, "by_tenant", {}).items()):
        reg.summary("serve_tenant_latency_seconds", h,
                    labels={**(labels or {}), "tenant": tenant},
                    help="request latency per tenant")
    return reg
