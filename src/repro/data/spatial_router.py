"""Spatial shard router: the faithful mqr-tree applied to the data plane.

Multi-host pipelines with spatial payloads (geo tiles, molecular frames,
image patches) want co-located data on the same host.  The router builds an
mqr-tree over shard MBRs and assigns hosts by subtree — spatially coherent
shards land together, and the paper's zero-overlap property means no shard
is fetched by two hosts.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import mqrtree


def route_shards(shard_mbrs: np.ndarray, n_hosts: int) -> Dict[int, List[int]]:
    """Assign shards (by MBR) to hosts via mqr-tree subtree decomposition.

    Returns {host_id: [shard ids]} with contiguous spatial groups.
    """
    tree = mqrtree.build(shard_mbrs)
    order: List[int] = []

    def walk(node):
        for _, e in sorted(node.entries(), key=lambda t: t[0]):
            if e.is_node:
                walk(e.node)
            else:
                order.append(e.obj)

    walk(tree.root)
    assert len(order) == shard_mbrs.shape[0]
    per = int(np.ceil(len(order) / n_hosts))
    return {h: order[h * per : (h + 1) * per] for h in range(n_hosts)}
