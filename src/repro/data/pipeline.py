"""Synthetic sharded LM data pipeline.

Deterministic per-(shard, step) token generation — every host materializes
only its shard of the global batch, which is how a 1000-node input pipeline
must behave (no host ever holds the global batch).  A mixture of Zipfian
unigram sampling and repeated-ngram structure gives the loss a learnable
signal (used by examples/train_lm.py and the convergence test).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    n_shards: int = 1
    shard_id: int = 0
    seed: int = 0
    zipf_a: float = 1.3
    motif_len: int = 16
    n_motifs: int = 64


class SyntheticLM:
    """Iterator of {'tokens', 'labels'} numpy batches for one host shard."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_shards == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_shards
        rng = np.random.default_rng(cfg.seed)
        # shared motif table (identical across shards: same seed)
        self.motifs = rng.integers(
            0, cfg.vocab_size, size=(cfg.n_motifs, cfg.motif_len), dtype=np.int32
        )
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** -cfg.zipf_a
        self.unigram = p / p.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.shard_id
        )
        toks = rng.choice(
            cfg.vocab_size, size=(self.local_batch, cfg.seq_len + 1),
            p=self.unigram,
        ).astype(np.int32)
        # plant motifs: structure the model can learn
        for row in range(self.local_batch):
            n_plant = rng.integers(2, 6)
            for _ in range(n_plant):
                m = self.motifs[rng.integers(0, cfg.n_motifs)]
                start = rng.integers(0, cfg.seq_len + 1 - cfg.motif_len)
                toks[row, start : start + cfg.motif_len] = m
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch_fn(cfg: DataConfig):
    ds = SyntheticLM(cfg)
    return ds.batch
