from .pipeline import DataConfig, SyntheticLM, make_batch_fn  # noqa: F401
from .spatial_router import route_shards  # noqa: F401
