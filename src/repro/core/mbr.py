"""Minimum-bounding-rectangle algebra shared by every index in repro.core.

An MBR is ``(lx, ly, hx, hy)`` with ``lx <= hx`` and ``ly <= hy``.  The
numpy representation used throughout is a float64 array of shape ``(4,)``
(single MBR) or ``(n, 4)`` (a batch).  All functions accept either.

Definitions used by the paper's evaluation (Section 5.2):
  coverage      Sum of node-MBR areas over every node of the tree.
  overcoverage  Whitespace: for each node, area(node MBR) minus the area of
                the union of its entries' MBRs, summed over nodes.
  overlap       For each node, the total pairwise intersection area between
                the MBRs of its entries, summed over nodes.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "make_mbr",
    "merge",
    "merge_many",
    "area",
    "centroid",
    "intersection_area",
    "overlaps",
    "contains",
    "contains_point",
    "union_area",
    "pairwise_overlap_total",
]

LX, LY, HX, HY = 0, 1, 2, 3


def make_mbr(lx: float, ly: float, hx: float, hy: float) -> np.ndarray:
    """Construct a well-formed MBR, swapping coordinates if necessary."""
    return np.array(
        [min(lx, hx), min(ly, hy), max(lx, hx), max(ly, hy)], dtype=np.float64
    )


def merge(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Smallest MBR enclosing both ``a`` and ``b`` (paper: merge_mbrs)."""
    return np.array(
        [
            min(a[LX], b[LX]),
            min(a[LY], b[LY]),
            max(a[HX], b[HX]),
            max(a[HY], b[HY]),
        ],
        dtype=np.float64,
    )


def merge_many(mbrs: np.ndarray) -> np.ndarray:
    """Enclosing MBR of a non-empty ``(n, 4)`` batch."""
    mbrs = np.asarray(mbrs, dtype=np.float64).reshape(-1, 4)
    return np.array(
        [
            mbrs[:, LX].min(),
            mbrs[:, LY].min(),
            mbrs[:, HX].max(),
            mbrs[:, HY].max(),
        ],
        dtype=np.float64,
    )


def area(m: np.ndarray) -> np.ndarray:
    """Area; zero-extent (point / degenerate line) MBRs have area 0."""
    m = np.asarray(m, dtype=np.float64)
    return (m[..., HX] - m[..., LX]) * (m[..., HY] - m[..., LY])


def centroid(m: np.ndarray) -> np.ndarray:
    m = np.asarray(m, dtype=np.float64)
    return np.stack(
        [(m[..., LX] + m[..., HX]) * 0.5, (m[..., LY] + m[..., HY]) * 0.5],
        axis=-1,
    )


def intersection_area(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Intersection area between (broadcastable batches of) MBRs."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    w = np.minimum(a[..., HX], b[..., HX]) - np.maximum(a[..., LX], b[..., LX])
    h = np.minimum(a[..., HY], b[..., HY]) - np.maximum(a[..., LY], b[..., LY])
    return np.clip(w, 0.0, None) * np.clip(h, 0.0, None)


def overlaps(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Closed-boundary intersection test (touching rectangles DO overlap).

    The paper's region search descends every entry whose MBR intersects the
    query region, including boundary contact — required for point data whose
    MBRs are degenerate (zero area).
    """
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    return (
        (a[..., LX] <= b[..., HX])
        & (b[..., LX] <= a[..., HX])
        & (a[..., LY] <= b[..., HY])
        & (b[..., LY] <= a[..., HY])
    )


def contains(outer: np.ndarray, inner: np.ndarray) -> np.ndarray:
    outer = np.asarray(outer, dtype=np.float64)
    inner = np.asarray(inner, dtype=np.float64)
    return (
        (outer[..., LX] <= inner[..., LX])
        & (outer[..., LY] <= inner[..., LY])
        & (outer[..., HX] >= inner[..., HX])
        & (outer[..., HY] >= inner[..., HY])
    )


def contains_point(m: np.ndarray, p: np.ndarray) -> np.ndarray:
    m = np.asarray(m, dtype=np.float64)
    p = np.asarray(p, dtype=np.float64)
    return (
        (m[..., LX] <= p[..., 0])
        & (p[..., 0] <= m[..., HX])
        & (m[..., LY] <= p[..., 1])
        & (p[..., 1] <= m[..., HY])
    )


def union_area(mbrs: np.ndarray) -> float:
    """Exact area of the union of a set of MBRs (sweep over x slabs).

    Used for overcoverage; n is at most a node's fan-out in the metrics path
    so the O(n^2) slab sweep is fine.
    """
    mbrs = np.asarray(mbrs, dtype=np.float64).reshape(-1, 4)
    if mbrs.shape[0] == 0:
        return 0.0
    xs = np.unique(np.concatenate([mbrs[:, LX], mbrs[:, HX]]))
    total = 0.0
    for x0, x1 in zip(xs[:-1], xs[1:]):
        w = x1 - x0
        if w <= 0:
            continue
        # rectangles spanning this slab
        live = mbrs[(mbrs[:, LX] <= x0) & (mbrs[:, HX] >= x1)]
        if live.shape[0] == 0:
            continue
        # union of y-intervals
        order = np.argsort(live[:, LY])
        y_lo = live[order, LY]
        y_hi = live[order, HY]
        cov = 0.0
        cur_lo, cur_hi = y_lo[0], y_hi[0]
        for lo, hi in zip(y_lo[1:], y_hi[1:]):
            if lo > cur_hi:
                cov += cur_hi - cur_lo
                cur_lo, cur_hi = lo, hi
            else:
                cur_hi = max(cur_hi, hi)
        cov += cur_hi - cur_lo
        total += w * cov
    return float(total)


def pairwise_overlap_total(mbrs: np.ndarray) -> float:
    """Sum of pairwise intersection areas among sibling MBRs."""
    mbrs = np.asarray(mbrs, dtype=np.float64).reshape(-1, 4)
    n = mbrs.shape[0]
    if n < 2:
        return 0.0
    inter = intersection_area(mbrs[:, None, :], mbrs[None, :, :])
    iu = np.triu_indices(n, k=1)
    return float(inter[iu].sum())
