"""Faithful mqr-tree (Moreau & Osborn 2012) — pointer-level reproduction.

Implements Section 3 of the paper:

* 5-location two-dimensional nodes (``NE, NW, SW, SE, EQ``) — Fig. 1.
* The Fig. 2 orientation table.  With ``A`` the centroid being placed and
  ``B`` the node-MBR centroid:

      A == B                -> EQ
      Ax > Bx, Ay >= By     -> NE   (due E folds into NE)
      Ax > Bx, Ay <  By     -> SE
      Ax < Bx, Ay >  By     -> NW
      Ax < Bx, Ay <= By     -> SW   (due W folds into SW)
      Ax == Bx, Ay > By     -> NW   (due N folds into NW)
      Ax == Bx, Ay < By     -> SE   (due S folds into SE)

* NORMAL / CENTER node types (Section 3.2).  A CENTER node stores only
  objects whose centroid equals the node-MBR centroid, linearly; chains of
  CENTER nodes extend capacity (Section 3.4, Fig. 9).
* The insertion strategy of Figs. 5-9: merge the node MBR, queue the new
  object, find all objects (recursively, at any depth) whose location became
  invalid because the node centroid moved, remove them, and re-insert
  everything starting at the current node.

Deviation log (documented; see DESIGN.md §3.1 and tests):

1. The paper's Figs. 6-7 enumerate the shifted *regions* for each of the
   expansion/contraction cases on an integer grid (with ±1 boundary
   offsets).  Those regions are exactly the set
   ``{p : quad(p, old_centroid) != quad(p, new_centroid)}``.  We detect
   shifted objects with that predicate directly (branch-free, float-exact)
   instead of enumerating regions — identical result without the
   integer-grid assumption.
2. ``insert_queue``'s CENTER branch in Fig. 9 would file an object whose
   centroid differs from the node centroid into a CENTER node (possible
   when the merged MBR's centroid does not move).  We restore the CENTER
   invariant by demoting the node to NORMAL and re-queueing its objects,
   mirroring the Fig. 6 CENTER case.
3. After objects are pulled out of a subtree (``remove_and_q_objects``), the
   subtree MBRs contract; we additionally re-validate affected descendants
   so the node-validity invariant of Section 3.2 holds at *every* node —
   the paper's Section 4 properties implicitly require this.
"""

from __future__ import annotations

from collections import deque
from typing import Iterator, List, Optional, Tuple

import numpy as np

from . import mbr as M

# Location indices (Fig. 1).
NE, NW, SW, SE, EQ = 0, 1, 2, 3, 4
N_LOCS = 5
LOC_NAMES = ("NE", "NW", "SW", "SE", "EQ")

NORMAL = 0
CENTER = 1

_MAX_REINSERT_OPS = 1_000_000  # safety valve against pathological cycles


def find_insert_quad(a_mbr: np.ndarray, b_mbr: np.ndarray) -> int:
    """Fig. 2: orientation of centroid(a) with respect to centroid(b)."""
    ax, ay = M.centroid(a_mbr)
    bx, by = M.centroid(b_mbr)
    return quad_of_point(ax, ay, bx, by)


def quad_of_point(ax: float, ay: float, bx: float, by: float) -> int:
    if ax == bx and ay == by:
        return EQ
    if ax > bx:
        return NE if ay >= by else SE
    if ax < bx:
        return NW if ay > by else SW
    # ax == bx
    return NW if ay > by else SE


class Entry:
    """Content of one node location: an object or a subtree."""

    __slots__ = ("mbr", "node", "obj")

    def __init__(self, mbr: np.ndarray, node: "Node" = None, obj: int = None):
        self.mbr = np.asarray(mbr, dtype=np.float64)
        self.node = node
        self.obj = obj

    @property
    def is_node(self) -> bool:
        return self.node is not None


class Node:
    __slots__ = ("mbr", "locs", "ntype", "parent")

    def __init__(self, parent: "Node" = None):
        self.mbr: Optional[np.ndarray] = None
        self.locs: List[Optional[Entry]] = [None] * N_LOCS
        self.ntype = NORMAL
        self.parent = parent

    # -- small helpers -------------------------------------------------
    def entries(self) -> Iterator[Tuple[int, Entry]]:
        for i, e in enumerate(self.locs):
            if e is not None:
                yield i, e

    def num_children(self) -> int:
        return sum(1 for e in self.locs if e is not None)

    def is_empty(self) -> bool:
        return all(e is None for e in self.locs)

    def recompute_mbr(self) -> None:
        ms = [e.mbr for e in self.locs if e is not None]
        self.mbr = M.merge_many(np.stack(ms)) if ms else None


class MQRTree:
    """The mqr-tree.  Objects are referenced by integer ids."""

    def __init__(self):
        self.root = Node()
        self._ops = 0

    # ------------------------------------------------------------------
    # Insertion (Figs. 5-9)
    # ------------------------------------------------------------------
    def insert(self, obj_id: int, obj_mbr: np.ndarray) -> None:
        self._ops = 0
        self._insert(self.root, Entry(np.asarray(obj_mbr, np.float64), obj=obj_id))
        # Hoist: a root with a single subtree entry is a pure husk.
        while True:
            entries = list(self.root.entries())
            if len(entries) == 1 and entries[0][1].is_node:
                self.root = entries[0][1].node
                self.root.parent = None
            else:
                break

    @staticmethod
    def _normalize(e: Optional[Entry]) -> Optional[Entry]:
        """Collapse chains of single-entry interior nodes (``adjust_node``:
        the paper deletes nodes emptied by removal; a one-entry husk carries
        no information and breaks insertion-order independence)."""
        while e is not None and e.is_node and e.node.num_children() == 1:
            (_, inner), = list(e.node.entries())
            e = inner
        return e

    def _insert(self, n: Node, entry: Entry) -> None:
        """Fig. 5 ``insert``: entry is an object entry (never a subtree)."""
        self._ops += 1
        if self._ops > _MAX_REINSERT_OPS:
            raise RuntimeError("mqr-tree insertion did not converge")

        if n.num_children() == 0:
            n.mbr = entry.mbr.copy()
            n.locs[EQ] = entry
            n.ntype = NORMAL
            return

        orig_mbr = n.mbr.copy()
        n.mbr = M.merge(n.mbr, entry.mbr)

        queue: deque = deque()
        quad = find_insert_quad(entry.mbr, n.mbr)
        queue.append((quad, entry))

        self._find_shifted_objs(queue, n, orig_mbr)
        self._insert_queue(n, queue)

    # ------------------------------------------------------------------
    def _find_shifted_objs(self, queue: deque, n: Node, orig_mbr: np.ndarray) -> None:
        """Figs. 6-7: queue every object whose location became invalid.

        The paper enumerates the affected sub-regions per expansion /
        contraction case (Fig. 4).  All of those regions are contained in the
        union of the vertical band ``x in [old_cx, new_cx]`` and the
        horizontal band ``y in [old_cy, new_cy]``: a centroid's quadrant can
        only change if its x-relation or its y-relation to the node centroid
        changes.  We prune subtree descent with that band (equivalent to the
        paper's region list, robust for float coordinates).
        """
        quad_move = find_insert_quad(n.mbr, orig_mbr)
        if quad_move == EQ:
            # Centroid did not move; all existing placements remain valid.
            return

        ncx, ncy = M.centroid(n.mbr)
        ocx, ocy = M.centroid(orig_mbr)
        band = (
            min(ocx, ncx), max(ocx, ncx),  # x band
            min(ocy, ncy), max(ocy, ncy),  # y band
        )

        if n.ntype == CENTER:
            # Fig. 6 CENTER case: every stored object shares the *old*
            # centroid; they all move to the quadrant of old-centroid
            # relative to the new centroid.
            for obj_entry in self._drain_center_chain(n):
                q = quad_of_point(*M.centroid(obj_entry.mbr), ncx, ncy)
                queue.append((q, obj_entry))
            n.ntype = NORMAL
            return

        # NORMAL node: for each location, pull out (recursively) every object
        # whose quadrant w.r.t. the *new* centroid differs from its location.
        self._queue_invalid_members(queue, n, ncx, ncy, band)

    def _queue_invalid_members(
        self, queue: deque, n: Node, ncx: float, ncy: float, band
    ) -> None:
        """Enforce the object-level validity invariant at node ``n``: every
        object reachable from location ``li`` must have its centroid in
        quadrant ``li`` of ``n``'s centroid (paper Section 4, property 2 —
        what ``remove_and_q_objects`` maintains).  Violators are removed and
        queued.  ``band`` prunes subtree descent."""
        for li in range(N_LOCS):
            e = n.locs[li]
            if e is None:
                continue
            if not e.is_node:
                q = quad_of_point(*M.centroid(e.mbr), ncx, ncy)
                if q != li:
                    n.locs[li] = None
                    queue.append((q, e))
            else:
                self._collect_shifted_from_subtree(
                    queue, e.node, li, ncx, ncy, band
                )
                if e.node.is_empty():
                    n.locs[li] = None
                else:
                    e.node.recompute_mbr()
                    e.mbr = e.node.mbr
                    e = self._normalize(e)
                    n.locs[li] = e
                    # Entry-level rule (Section 3.2): the entry's own MBR
                    # centroid must also sit in the location's quadrant.
                    q = quad_of_point(*M.centroid(e.mbr), ncx, ncy)
                    if q != li:
                        n.locs[li] = None
                        if e.is_node:
                            for obj_entry in self._drain_subtree(e.node):
                                qq = quad_of_point(
                                    *M.centroid(obj_entry.mbr), ncx, ncy
                                )
                                queue.append((qq, obj_entry))
                        else:
                            queue.append((q, e))

    @staticmethod
    def _hits_band(mbr: np.ndarray, band) -> bool:
        x_lo, x_hi, y_lo, y_hi = band
        return (mbr[0] <= x_hi and mbr[2] >= x_lo) or (
            mbr[1] <= y_hi and mbr[3] >= y_lo
        )

    def _collect_shifted_from_subtree(
        self, queue: deque, sub: Node, li: int, ncx: float, ncy: float, band
    ) -> bool:
        """Fig. 8 ``remove_and_q_objects`` over a subtree: remove the objects
        whose centroid is no longer in quadrant ``li`` of the new parent
        centroid and queue them for re-insertion.  Returns True if anything
        was removed from within ``sub``."""
        if sub.mbr is not None and not self._hits_band(sub.mbr, band):
            return False
        removed = False
        for si in range(N_LOCS):
            e = sub.locs[si]
            if e is None:
                continue
            if e.is_node:
                if self._collect_shifted_from_subtree(
                    queue, e.node, li, ncx, ncy, band
                ):
                    removed = True
                    if e.node.is_empty():
                        sub.locs[si] = None
                    else:
                        e.node.recompute_mbr()
                        e.mbr = e.node.mbr
                        sub.locs[si] = self._normalize(e)
            else:
                q = quad_of_point(*M.centroid(e.mbr), ncx, ncy)
                if q != li:
                    sub.locs[si] = None
                    queue.append((q, e))
                    removed = True
        # ``adjust_node``: contraction moved this subtree node's centroid —
        # restore validity of its own members (deviation 3).
        if removed and not sub.is_empty():
            old_c = M.centroid(sub.mbr)
            sub.recompute_mbr()
            self._local_revalidate(sub, old_c)
        return removed

    def _local_revalidate(self, node: Node, old_centroid) -> None:
        """Restore the full (object-level) validity invariant of ``node``
        after its MBR moved from ``old_centroid``.  Same machinery as
        ``_find_shifted_objs`` but rooted at an interior node."""
        if node.ntype == CENTER or node.is_empty() or node.mbr is None:
            return
        ncx, ncy = M.centroid(node.mbr)
        ocx, ocy = old_centroid
        if ncx == ocx and ncy == ocy:
            return
        band = (min(ocx, ncx), max(ocx, ncx), min(ocy, ncy), max(ocy, ncy))
        local_q: deque = deque()
        self._queue_invalid_members(local_q, node, ncx, ncy, band)
        if local_q:
            self._insert_queue(node, local_q)

    def _drain_center_chain(self, n: Node) -> List[Entry]:
        """Remove and return all object entries of a CENTER node chain."""
        out: List[Entry] = []
        for i in range(N_LOCS):
            e = n.locs[i]
            n.locs[i] = None
            if e is None:
                continue
            if e.is_node:
                out.extend(self._drain_center_chain(e.node))
            else:
                out.append(e)
        return out

    def _drain_subtree(self, n: Node) -> List[Entry]:
        out: List[Entry] = []
        for i in range(N_LOCS):
            e = n.locs[i]
            n.locs[i] = None
            if e is None:
                continue
            if e.is_node:
                out.extend(self._drain_subtree(e.node))
            else:
                out.append(e)
        return out

    # ------------------------------------------------------------------
    def _insert_queue(self, n: Node, queue: deque) -> None:
        """Fig. 9: (re)insert queued entries into node ``n``."""
        while queue:
            self._ops += 1
            if self._ops > _MAX_REINSERT_OPS:
                raise RuntimeError("mqr-tree insertion did not converge")
            quad, entry = queue.popleft()

            if n.is_empty():
                n.ntype = NORMAL
                n.mbr = entry.mbr.copy()
                n.locs[EQ] = entry
                continue

            # Keep the node MBR consistent with everything being placed.
            orig = n.mbr.copy()
            n.mbr = M.merge(n.mbr, entry.mbr)
            if not np.array_equal(orig, n.mbr):
                # The centroid may have moved again: re-check validity of the
                # current occupants.
                self._find_shifted_objs(queue, n, orig)
            # The quad stored at enqueue time can be stale (later merges move
            # the centroid); always recompute against the current node MBR.
            quad = find_insert_quad(entry.mbr, n.mbr)

            if n.ntype == CENTER:
                if np.allclose(M.centroid(entry.mbr), M.centroid(n.mbr)):
                    self._center_insert(n, entry)
                else:
                    # Deviation 2: restore the CENTER invariant.
                    ncx, ncy = M.centroid(n.mbr)
                    for obj_entry in self._drain_center_chain(n):
                        q = quad_of_point(*M.centroid(obj_entry.mbr), ncx, ncy)
                        queue.append((q, obj_entry))
                    n.ntype = NORMAL
                    queue.append((find_insert_quad(entry.mbr, n.mbr), entry))
                continue

            occupant = n.locs[quad]
            if occupant is None:
                n.locs[quad] = entry
                continue

            if occupant.is_node:
                # Descend: Fig. 9 calls insert() on the subtree root.
                occupant.node.parent = n
                self._insert(occupant.node, entry)
                occupant.mbr = occupant.node.mbr
                # The subtree MBR grew; its centroid can drift out of the
                # quadrant (wide objects).  Restore node validity at object
                # granularity (as the paper's remove_and_q_objects does).
                ncx, ncy = M.centroid(n.mbr)
                q_now = quad_of_point(*M.centroid(occupant.mbr), ncx, ncy)
                if q_now != quad:
                    n.locs[quad] = None
                    for obj_entry in self._drain_subtree(occupant.node):
                        qq = quad_of_point(*M.centroid(obj_entry.mbr), ncx, ncy)
                        queue.append((qq, obj_entry))
                continue

            # Occupied by an object.
            if quad == EQ and n.num_children() == 1:
                # Convert this node into a CENTER node (same centroids).
                n.ntype = CENTER
                existing = n.locs[EQ]
                n.locs = [None] * N_LOCS
                n.locs[0] = existing
                queue.append((quad, entry))
                continue

            # Create a new child holding both objects (Fig. 9 tail).
            child = Node(parent=n)
            self._insert(child, occupant)
            self._insert(child, entry)
            n.locs[quad] = Entry(child.mbr.copy(), node=child)

    def _center_insert(self, n: Node, entry: Entry) -> None:
        """Place an object into a CENTER node chain (linear organization)."""
        node = n
        while True:
            node.mbr = M.merge(node.mbr, entry.mbr)
            for i in range(N_LOCS - 1):
                if node.locs[i] is None:
                    node.locs[i] = entry
                    return
            # All 4 object slots used: follow/create the chain link in the
            # last slot.
            link = node.locs[N_LOCS - 1]
            if link is None:
                nxt = Node(parent=node)
                nxt.ntype = CENTER
                nxt.mbr = entry.mbr.copy()
                nxt.locs[0] = entry
                node.locs[N_LOCS - 1] = Entry(nxt.mbr.copy(), node=nxt)
                return
            if not link.is_node:
                # Slot 4 holds an object (legacy layout): push it down.
                carried = link
                nxt = Node(parent=node)
                nxt.ntype = CENTER
                nxt.mbr = carried.mbr.copy()
                nxt.locs[0] = carried
                node.locs[N_LOCS - 1] = Entry(nxt.mbr.copy(), node=nxt)
                link = node.locs[N_LOCS - 1]
            node = link.node
            # keep the chain entry MBR fresh
            link.mbr = M.merge(link.mbr, entry.mbr)

    # ------------------------------------------------------------------
    # Region search (Section 3.6): overlap test against every location.
    # ------------------------------------------------------------------
    def region_search(self, query: np.ndarray) -> Tuple[List[int], int]:
        """Return (object ids overlapping query, node visits aka disk accesses)."""
        query = np.asarray(query, dtype=np.float64)
        found: List[int] = []
        visits = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            if node.mbr is None:
                continue
            visits += 1
            for _, e in node.entries():
                if not M.overlaps(e.mbr, query):
                    continue
                if e.is_node:
                    stack.append(e.node)
                else:
                    found.append(e.obj)
        return found, visits

    # ------------------------------------------------------------------
    # Introspection used by tests / metrics
    # ------------------------------------------------------------------
    def iter_nodes(self) -> Iterator[Tuple[Node, int]]:
        stack = [(self.root, 1)]
        while stack:
            node, depth = stack.pop()
            yield node, depth
            for _, e in node.entries():
                if e.is_node:
                    stack.append((e.node, depth + 1))

    def all_objects(self) -> List[Tuple[int, np.ndarray]]:
        out = []
        for node, _ in self.iter_nodes():
            for _, e in node.entries():
                if not e.is_node:
                    out.append((e.obj, e.mbr))
        return out

    def validate(self) -> None:
        """Assert the Section 3.2 validity rules at every node."""
        for node, _ in self.iter_nodes():
            if node.is_empty():
                assert node is self.root, "empty non-root node"
                continue
            ms = np.stack([e.mbr for _, e in node.entries()])
            enclosing = M.merge_many(ms)
            assert np.allclose(node.mbr, enclosing), (
                f"node MBR {node.mbr} != enclosing {enclosing}"
            )
            if node.ntype == CENTER:
                c = M.centroid(node.mbr)
                for _, e in node.entries():
                    if not e.is_node:
                        assert np.allclose(M.centroid(e.mbr), c), "CENTER invariant"
                continue
            ncx, ncy = M.centroid(node.mbr)
            for li, e in node.entries():
                q = quad_of_point(*M.centroid(e.mbr), ncx, ncy)
                assert q == li, (
                    f"entry at {LOC_NAMES[li]} belongs in {LOC_NAMES[q]} "
                    f"(centroid {M.centroid(e.mbr)}, node centroid {(ncx, ncy)})"
                )


def build(mbrs: np.ndarray) -> MQRTree:
    """Build an mqr-tree by inserting ``mbrs`` (shape (n, 4)) in order."""
    t = MQRTree()
    for i, m in enumerate(np.asarray(mbrs, dtype=np.float64)):
        t.insert(i, m)
    return t


# ---------------------------------------------------------------------------
# Additional queries (paper §5.5 / §6 directions)
# ---------------------------------------------------------------------------


def point_search(tree: MQRTree, point) -> Tuple[List[int], int]:
    """Exact point query.  For point data the paper's zero-overlap property
    (§4) implies at most ONE path is followed — §5.5: "it is possible that
    the mqr-tree can perform a one-path search at most".  Returns
    (object ids whose MBR contains the point, nodes visited)."""
    import numpy as _np

    p = _np.asarray(point, dtype=_np.float64)
    found: List[int] = []
    visits = 0
    stack = [tree.root]
    while stack:
        node = stack.pop()
        if node.mbr is None:
            continue
        visits += 1
        for _, e in node.entries():
            if not M.contains_point(e.mbr, p):
                continue
            if e.is_node:
                stack.append(e.node)
            else:
                found.append(e.obj)
    return found, visits


def knn_search(tree: MQRTree, point, k: int) -> Tuple[List[int], int]:
    """Best-first k-nearest-neighbour over MBR min-distance (the paper's
    §6 future direction, as realized by the DR-tree line of work).
    Returns (k object ids nearest to point, nodes visited)."""
    import heapq
    import numpy as _np

    p = _np.asarray(point, dtype=_np.float64)

    def mindist(mbr) -> float:
        dx = max(mbr[0] - p[0], 0.0, p[0] - mbr[2])
        dy = max(mbr[1] - p[1], 0.0, p[1] - mbr[3])
        return float(dx * dx + dy * dy)

    visits = 0
    heap = [(0.0, 0, True, tree.root)]
    tie = 1
    out: List[Tuple[float, int]] = []
    while heap and len(out) < k:
        d, _, is_node, item = heapq.heappop(heap)
        if is_node:
            node = item
            if node.mbr is None:
                continue
            visits += 1
            for _, e in node.entries():
                tie += 1
                if e.is_node:
                    heapq.heappush(heap, (mindist(e.mbr), tie, True, e.node))
                else:
                    heapq.heappush(heap, (mindist(e.mbr), tie, False, e.obj))
        else:
            out.append((d, item))
    return [o for _, o in out], visits
