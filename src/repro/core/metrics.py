"""Tree-quality metrics exactly as evaluated in the paper (Section 5.2).

Works for both MQRTree and RTree through a small adapter layer: a *node view*
is ``(child_mbrs, child_is_node, depth)`` per node.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from . import mbr as M
from .mqrtree import MQRTree
from .rtree import RTree


@dataclasses.dataclass
class TreeMetrics:
    n_nodes: int
    height: int                 # worst-case root->node depth
    avg_path: float             # average depth over object references
    coverage: float             # sum of node-MBR areas
    overcoverage: float         # sum of per-node whitespace
    overlap: float              # sum of per-node pairwise entry intersection
    space_utilization: float    # mean fraction of locations/entries used

    def row(self) -> dict:
        return dataclasses.asdict(self)


def _node_views(tree) -> List[Tuple[np.ndarray, np.ndarray, int, int]]:
    """Return per-node (entry_mbrs, is_node_flags, depth, capacity)."""
    views = []
    if isinstance(tree, MQRTree):
        for node, depth in tree.iter_nodes():
            ms, flags = [], []
            for _, e in node.entries():
                ms.append(e.mbr)
                flags.append(e.is_node)
            if ms:
                views.append((np.stack(ms), np.array(flags), depth, 5))
    elif isinstance(tree, RTree):
        for node, depth in tree.iter_nodes():
            ms = [e.mbr for e in node.entries]
            flags = [not node.leaf] * len(ms)
            if ms:
                views.append((np.stack(ms), np.array(flags), depth, tree.M))
    else:  # pragma: no cover - defensive
        raise TypeError(type(tree))
    return views


def compute_metrics(tree) -> TreeMetrics:
    views = _node_views(tree)
    n_nodes = len(views)
    height = 0
    coverage = 0.0
    overcoverage = 0.0
    overlap = 0.0
    util = 0.0
    obj_depth_sum = 0.0
    obj_count = 0
    for ms, is_node, depth, cap in views:
        node_mbr = M.merge_many(ms)
        coverage += float(M.area(node_mbr))
        overcoverage += float(M.area(node_mbr)) - M.union_area(ms)
        overlap += M.pairwise_overlap_total(ms)
        util += ms.shape[0] / cap
        height = max(height, depth)
        n_objs_here = int((~is_node).sum())
        obj_depth_sum += depth * n_objs_here
        obj_count += n_objs_here
    return TreeMetrics(
        n_nodes=n_nodes,
        height=height,
        avg_path=obj_depth_sum / max(obj_count, 1),
        coverage=coverage,
        overcoverage=overcoverage,
        overlap=overlap,
        space_utilization=util / max(n_nodes, 1),
    )
