"""mqr-KV: the paper's spatial index over a transformer KV cache.

DESIGN.md §3: KV positions are grouped into fixed-size blocks; each block
gets a 2-D MBR over ``(token position, k·u)`` where ``u`` is a per-head probe
direction.  Blocks are organized by the mqr quadrant-centroid rule (bulk
pyramid, :mod:`repro.core.bulk`), and a decode query performs a *region
search* — position window × query-dependent score range — to select the
K most relevant blocks (static K for XLA).  Sparse attention then reads only
those blocks.

The paper's zero-overlap property for point data means sibling group MBRs of
the (position, score) centroids partition cleanly: each HBM block fetch is
unique useful bytes — the 2012 "fewer disk accesses" result becomes a
smaller roofline memory term (EXPERIMENTS.md §Perf).

All functions are single-(head,batch); callers vmap.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bulk import GroupPyramid, build_pyramid, pyramid_search

DEFAULT_BLOCK = 128
DEFAULT_LEVELS = 6


class KVIndex(NamedTuple):
    block_mbr: jnp.ndarray   # (nb, 4) f32: [lo_pos, lo_score, hi_pos, hi_score]
    pyramid: GroupPyramid    # mqr group pyramid over the block MBR centroids


def block_mbrs(keys: jnp.ndarray, probe: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Per-block MBRs in (position, score) space.

    keys: (S, d); probe: (d,).  S must be a multiple of block_size.
    """
    s, _ = keys.shape
    nb = s // block_size
    scores = (keys @ probe).reshape(nb, block_size)
    pos = jnp.arange(s, dtype=jnp.float32).reshape(nb, block_size)
    return jnp.stack(
        [pos.min(1), scores.min(1), pos.max(1), scores.max(1)], axis=-1
    )


def build_kv_index(
    keys: jnp.ndarray,
    probe: jnp.ndarray,
    block_size: int = DEFAULT_BLOCK,
    levels: int = DEFAULT_LEVELS,
) -> KVIndex:
    bm = block_mbrs(keys, probe, block_size)
    return KVIndex(block_mbr=bm, pyramid=build_pyramid(bm, levels))


def query_region(
    q: jnp.ndarray,
    probe: jnp.ndarray,
    kv_len,
    score_halfwidth: float = 2.0,
    pos_lo: float = 0.0,
) -> jnp.ndarray:
    """Decode-query region: full causal position window x score band around
    the query's own probe projection.  ``score_halfwidth`` is in units of the
    query-score scale (beyond-paper knob; the paper's region is an input)."""
    sq = q @ probe
    width = score_halfwidth * (jnp.abs(sq) + 1.0)
    return jnp.stack(
        [
            jnp.asarray(pos_lo, jnp.float32),
            sq - width,
            jnp.asarray(kv_len, jnp.float32),
            sq + width,
        ]
    )


def select_blocks(index: KVIndex, region: jnp.ndarray, k: int) -> jnp.ndarray:
    """mqr region search + static top-K.

    Returns (k,) int32 block ids; ids may repeat only when fewer than k
    blocks survive the region search (callers mask via the returned order:
    survivors first, then the highest-overlap non-survivors as padding —
    attention over padding is still *correct*, just not pruned).
    """
    survive = pyramid_search(index.pyramid, region)  # (nb,) bool
    # Overlap area between block MBR and the region = relevance score.
    bm = index.block_mbr
    w = jnp.minimum(bm[:, 2], region[2]) - jnp.maximum(bm[:, 0], region[0])
    h = jnp.minimum(bm[:, 3], region[3]) - jnp.maximum(bm[:, 1], region[1])
    area = jnp.clip(w, 0.0, None) * jnp.clip(h, 0.0, None)
    # survivors strictly dominate; among them larger overlap first.
    score = jnp.where(survive, 1e6 + area, area)
    _, ids = jax.lax.top_k(score, k)
    return ids.astype(jnp.int32)


def select_blocks_batched(index_mbr, pyramid, regions, k):
    """vmapped helper used by models: regions (H, 4) -> (H, k)."""
    idx = KVIndex(index_mbr, pyramid)
    return jax.vmap(lambda r: select_blocks(idx, r, k))(regions)


# ---------------------------------------------------------------------------
# Incremental index maintenance (beyond-paper optimization, EXPERIMENTS §Perf)
#
# Rebuilding the index each decode step re-reads the whole key cache — the
# memory-roofline term then equals dense attention's.  Instead the index
# lives in the KV cache and is updated per token with MONOTONE MBR growth:
# the new key's (position, score) point is merged into its block MBR and
# into every ancestor group MBR.  Group membership is frozen (from the
# initial position-only pyramid); growth keeps every group MBR a superset of
# its true bounds, so region search stays conservative (no false negatives)
# — the cost is overcoverage, exactly the quantity the paper trades against
# access count.
# ---------------------------------------------------------------------------


class IncKVIndex(NamedTuple):
    block_mbr: jnp.ndarray   # (nb, 4)
    group_mbr: jnp.ndarray   # (L, nb, 4) — padded by dense group id
    group_of: jnp.ndarray    # (L, nb) int32 — frozen membership


def init_incremental(nb: int, block_size: int, levels: int) -> IncKVIndex:
    """Position-only initial pyramid; score extents start EMPTY (+inf/-inf)
    so unwritten blocks never overlap a query region."""
    pos_lo = jnp.arange(nb, dtype=jnp.float32) * block_size
    pos_hi = pos_lo + (block_size - 1)
    inf = jnp.float32(3.4e38)
    block_mbr = jnp.stack(
        [pos_lo, jnp.full((nb,), inf), pos_hi, jnp.full((nb,), -inf)], axis=-1
    )
    # membership from the position-centroid pyramid (scores all equal 0 at
    # freeze time -> splits happen on the position axis)
    seed = jnp.stack([pos_lo, jnp.zeros((nb,)), pos_hi, jnp.zeros((nb,))], -1)
    pyr = build_pyramid(seed, levels)
    group_mbr = jnp.broadcast_to(block_mbr[None], (levels, nb, 4)).copy()
    # scatter block mbrs into dense-group slots (min/max per group)
    def level_bounds(gof):
        lo_x = jax.ops.segment_min(block_mbr[:, 0], gof, num_segments=nb)
        lo_s = jax.ops.segment_min(block_mbr[:, 1], gof, num_segments=nb)
        hi_x = jax.ops.segment_max(block_mbr[:, 2], gof, num_segments=nb)
        hi_s = jax.ops.segment_max(block_mbr[:, 3], gof, num_segments=nb)
        return jnp.stack([lo_x, lo_s, hi_x, hi_s], axis=-1)

    group_mbr = jax.vmap(level_bounds)(pyr.group_of)
    return IncKVIndex(block_mbr, group_mbr, pyr.group_of)


def incremental_update(
    idx: IncKVIndex, pos, score, block_size: int
) -> IncKVIndex:
    """Merge the new key's (pos, score) point into its block + ancestors."""
    pos = jnp.asarray(pos)
    b = (pos // block_size).astype(jnp.int32)
    pf = pos.astype(jnp.float32)
    sf = jnp.asarray(score).astype(jnp.float32)

    def merge_point(m):
        return jnp.stack(
            [
                jnp.minimum(m[0], pf),
                jnp.minimum(m[1], sf),
                jnp.maximum(m[2], pf),
                jnp.maximum(m[3], sf),
            ]
        )

    block_mbr = idx.block_mbr.at[b].set(merge_point(idx.block_mbr[b]))

    def level_update(gm, gof):
        g = gof[b]
        return gm.at[g].set(merge_point(gm[g]))

    group_mbr = jax.vmap(level_update)(idx.group_mbr, idx.group_of)
    return IncKVIndex(block_mbr, group_mbr, idx.group_of)


def incremental_select(idx: IncKVIndex, region: jnp.ndarray, k: int) -> jnp.ndarray:
    """Region search against the incrementally-maintained pyramid: reads
    O((L+1)*nb) floats — never the key cache."""
    # per-level survival via frozen membership
    anc = jnp.take_along_axis(
        idx.group_mbr, idx.group_of[:, :, None].repeat(4, axis=2), axis=1
    )  # (L, nb, 4)
    ov = (
        (anc[..., 0] <= region[2])
        & (region[0] <= anc[..., 2])
        & (anc[..., 1] <= region[3])
        & (region[1] <= anc[..., 3])
    )
    survive = ov.all(axis=0)
    bm = idx.block_mbr
    w = jnp.minimum(bm[:, 2], region[2]) - jnp.maximum(bm[:, 0], region[0])
    h = jnp.minimum(bm[:, 3], region[3]) - jnp.maximum(bm[:, 1], region[1])
    area = jnp.clip(w, 0.0, None) * jnp.clip(h, 0.0, None)
    score = jnp.where(survive, 1e6 + area, area)
    _, ids = jax.lax.top_k(score, k)
    return ids.astype(jnp.int32)
