"""Dataset generators matching the paper's Section 5.1.

All synthetic sets live in a ``[0, EXTENT]^2`` world (EXTENT=1000), which
reproduces the coverage magnitudes of the paper's tables to within a small
constant factor.  The DCW road/rail files are not available offline; the
``roadlike`` generator synthesizes sequential, connected, short line segments
with matching statistics (documented deviation, DESIGN.md §5).
"""

from __future__ import annotations

import numpy as np

EXTENT = 1000.0


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def uniform_squares(n: int, seed: int = 0, side: float = 10.0) -> np.ndarray:
    """n squares of ``side x side`` units, uniformly distributed."""
    r = _rng(seed)
    ll = r.uniform(0.0, EXTENT - side, size=(n, 2))
    return np.concatenate([ll, ll + side], axis=1)


def uniform_points(n: int, seed: int = 0) -> np.ndarray:
    r = _rng(seed)
    p = r.uniform(0.0, EXTENT, size=(n, 2))
    return np.concatenate([p, p], axis=1)


def exponential_squares(
    n: int, seed: int = 0, side: float = 10.0, scale: float = 200.0
) -> np.ndarray:
    r = _rng(seed)
    ll = np.minimum(r.exponential(scale, size=(n, 2)), EXTENT - side)
    return np.concatenate([ll, ll + side], axis=1)


def exponential_points(n: int, seed: int = 0, scale: float = 200.0) -> np.ndarray:
    r = _rng(seed)
    p = np.minimum(r.exponential(scale, size=(n, 2)), EXTENT)
    return np.concatenate([p, p], axis=1)


def _lines_to_mbrs(p0: np.ndarray, p1: np.ndarray) -> np.ndarray:
    lo = np.minimum(p0, p1)
    hi = np.maximum(p0, p1)
    return np.concatenate([lo, hi], axis=1)


def hv_lines(n: int, seed: int = 0, length: float = 10.0) -> np.ndarray:
    """50% horizontal / 50% vertical 10-unit lines."""
    r = _rng(seed)
    start = r.uniform(0.0, EXTENT - length, size=(n, 2))
    horiz = r.random(n) < 0.5
    delta = np.where(horiz[:, None], np.array([[length, 0.0]]), np.array([[0.0, length]]))
    return _lines_to_mbrs(start, start + delta)


def sloped_lines(n: int, seed: int = 0, length: float = 10.0) -> np.ndarray:
    """Equal mix of slopes 1/2, 1, 2, -1/2, -1, -2 (length-10 lines)."""
    r = _rng(seed)
    slopes = np.array([0.5, 1.0, 2.0, -0.5, -1.0, -2.0])
    s = slopes[r.integers(0, len(slopes), size=n)]
    dx = length / np.sqrt(1.0 + s**2)
    dy = s * dx
    start = r.uniform(np.abs(np.stack([dx, dy], 1)), EXTENT - np.abs(np.stack([dx, dy], 1)))
    return _lines_to_mbrs(start, start + np.stack([dx, dy], axis=1))


def mixed_lines(n: int, seed: int = 0, length: float = 10.0) -> np.ndarray:
    """Slopes 1/2, 1, 2, -1/2, -1, -2 plus horizontal and vertical."""
    r = _rng(seed)
    kinds = r.integers(0, 8, size=n)
    slopes = np.array([0.5, 1.0, 2.0, -0.5, -1.0, -2.0])
    dx = np.empty(n)
    dy = np.empty(n)
    sloped = kinds < 6
    s = slopes[np.minimum(kinds, 5)]
    dx[sloped] = (length / np.sqrt(1.0 + s**2))[sloped]
    dy[sloped] = (s * length / np.sqrt(1.0 + s**2))[sloped]
    dx[kinds == 6] = length
    dy[kinds == 6] = 0.0
    dx[kinds == 7] = 0.0
    dy[kinds == 7] = length
    d = np.stack([dx, dy], axis=1)
    start = r.uniform(np.abs(d), EXTENT - np.abs(d))
    return _lines_to_mbrs(start, start + d)


def roadlike_lines(n: int, seed: int = 0, step: float = 1.5) -> np.ndarray:
    """Sequential connected short segments (road/rail surrogate).

    Random walks of ~200-segment "roads": heading evolves smoothly, segment
    length ~ U(0.5, 1.5)*step, reflected at the world boundary.  Produces the
    paper's observed regime: tiny, chained MBRs with near-zero overlap.
    """
    r = _rng(seed)
    segs = np.empty((n, 4))
    i = 0
    while i < n:
        road_len = min(int(r.integers(100, 300)), n - i)
        pos = r.uniform(0.1 * EXTENT, 0.9 * EXTENT, size=2)
        heading = r.uniform(0, 2 * np.pi)
        for _ in range(road_len):
            heading += r.normal(0.0, 0.15)
            L = step * r.uniform(0.5, 1.5)
            nxt = pos + L * np.array([np.cos(heading), np.sin(heading)])
            for d in range(2):
                if nxt[d] < 0 or nxt[d] > EXTENT:
                    heading += np.pi / 2
                    nxt = pos
                    break
            segs[i] = [
                min(pos[0], nxt[0]),
                min(pos[1], nxt[1]),
                max(pos[0], nxt[0]),
                max(pos[1], nxt[1]),
            ]
            pos = nxt
            i += 1
            if i >= n:
                break
    return segs


def region_queries(
    data: np.ndarray, n_queries: int, seed: int = 0, target_found: float = 4.0
) -> np.ndarray:
    """Query rectangles sized so a uniform dataset returns ~target_found
    objects, centred at random data centroids (paper runs 20 per tree)."""
    r = _rng(seed + 7)
    n = data.shape[0]
    side = EXTENT * np.sqrt(target_found / max(n, 1))
    centers = data[r.integers(0, n, size=n_queries)]
    cx = (centers[:, 0] + centers[:, 2]) * 0.5
    cy = (centers[:, 1] + centers[:, 3]) * 0.5
    q = np.stack([cx - side / 2, cy - side / 2, cx + side / 2, cy + side / 2], axis=1)
    return q


def dense_region_queries(n_queries: int, seed: int = 0, side: float = 450.0) -> np.ndarray:
    """Fixed large queries anchored near the origin-dense corner, matching the
    paper's exponential-data search workloads (large #found)."""
    r = _rng(seed + 13)
    off = r.uniform(0.0, 80.0, size=(n_queries, 2))
    return np.concatenate([off, off + side], axis=1)


REGISTRY = {
    "uniform_squares": uniform_squares,
    "uniform_points": uniform_points,
    "exponential_squares": exponential_squares,
    "exponential_points": exponential_points,
    "hv_lines": hv_lines,
    "sloped_lines": sloped_lines,
    "mixed_lines": mixed_lines,
    "roadlike_lines": roadlike_lines,
}
