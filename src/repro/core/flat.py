"""Levelized struct-of-arrays view of a spatial tree + batched JAX search.

TPU adaptation layer (DESIGN.md §3.1): pointer-chasing trees do not
vectorize, so a built tree (mqr or R) is flattened into dense arrays and
region search becomes a masked breadth-first frontier sweep expressed with
``jax.lax`` control flow.  One "disk access" of the paper = one live row of
the frontier (a node whose entries are examined), so the JAX search reports
the *same* disk-access count as the host pointer implementation — this
equivalence is tested in tests/test_flat_search.py.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .mqrtree import MQRTree
from .rtree import RTree

EMPTY = -1  # children_idx sentinel: no entry
# children_idx >= 0   -> index of a child node
# children_idx <= -2  -> object id encoded as -(obj + 2)


@dataclasses.dataclass(frozen=True)
class FlatTree:
    """Dense array form of a spatial tree.

    node_mbr:      (N, 4)   float32
    children_mbr:  (N, F, 4) float32 (F = max fan-out)
    children_idx:  (N, F)   int32 (see sentinels above)
    n_objects:     int
    root:          int (node index of the root, always 0)
    """

    node_mbr: np.ndarray
    children_mbr: np.ndarray
    children_idx: np.ndarray
    n_objects: int
    root: int = 0

    @property
    def n_nodes(self) -> int:
        return self.node_mbr.shape[0]


def flatten(tree) -> FlatTree:
    """Flatten an ``MQRTree`` or ``RTree`` into a :class:`FlatTree`."""
    if isinstance(tree, MQRTree):
        fan = 5

        def node_entries(node):
            for _, e in node.entries():
                yield e.mbr, (e.node if e.is_node else None), e.obj

        root = tree.root
    elif isinstance(tree, RTree):
        fan = tree.M

        def node_entries(node):
            for e in node.entries:
                yield e.mbr, e.child, e.obj

        root = tree.root
    else:  # pragma: no cover
        raise TypeError(type(tree))

    nodes = []
    index = {}

    def assign(node):
        index[id(node)] = len(nodes)
        nodes.append(node)
        for _, child, _ in node_entries(node):
            if child is not None:
                assign(child)

    assign(root)

    n = len(nodes)
    node_mbr = np.zeros((n, 4), np.float32)
    children_mbr = np.zeros((n, fan, 4), np.float32)
    children_idx = np.full((n, fan), EMPTY, np.int32)
    n_objects = 0
    for ni, node in enumerate(nodes):
        mbr = node.mbr if isinstance(tree, MQRTree) else node.mbr()
        node_mbr[ni] = np.asarray(mbr, np.float32)
        for fi, (embr, child, obj) in enumerate(node_entries(node)):
            children_mbr[ni, fi] = np.asarray(embr, np.float32)
            if child is not None:
                children_idx[ni, fi] = index[id(child)]
            else:
                children_idx[ni, fi] = -(obj + 2)
                n_objects = max(n_objects, obj + 1)
    return FlatTree(node_mbr, children_mbr, children_idx, n_objects)


def _overlaps(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Closed-boundary rectangle intersection, broadcasting."""
    return (
        (a[..., 0] <= b[..., 2])
        & (b[..., 0] <= a[..., 2])
        & (a[..., 1] <= b[..., 3])
        & (b[..., 1] <= a[..., 3])
    )


def region_search_batch(
    flat: FlatTree, queries: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Batched region search.

    Args:
      flat: flattened tree.
      queries: (Q, 4) query rectangles.

    Returns:
      hits:   (Q, n_objects) bool — object overlap mask.
      visits: (Q,) int32 — node visits (disk accesses), identical to the
              pointer implementation's count.
    """
    children_mbr = jnp.asarray(flat.children_mbr)
    children_idx = jnp.asarray(flat.children_idx)
    queries = jnp.asarray(queries, jnp.float32)
    n, fan = children_idx.shape
    q = queries.shape[0]
    n_obj = flat.n_objects

    is_node = children_idx >= 0
    is_obj = children_idx <= -2
    obj_ids = jnp.where(is_obj, -(children_idx + 2), 0)
    child_node = jnp.where(is_node, children_idx, 0)

    def step(state):
        frontier, visits, hits, _ = state
        visits = visits + frontier.sum(axis=1, dtype=jnp.int32)
        # (Q, N, F): does entry f of node n overlap query q?
        ov = _overlaps(children_mbr[None, :, :, :], queries[:, None, None, :])
        act = frontier[:, :, None] & ov
        # record object hits
        def per_query(hits_q, act_q):
            vals = (act_q & is_obj).reshape(-1)
            ids = obj_ids.reshape(-1)
            return hits_q.at[ids].max(vals)

        hits = jax.vmap(per_query)(hits, act)
        # propagate frontier to child nodes
        def frontier_query(act_q):
            vals = (act_q & is_node).reshape(-1)
            ids = child_node.reshape(-1)
            return jnp.zeros((n,), bool).at[ids].max(vals)

        nxt = jax.vmap(frontier_query)(act)
        return nxt, visits, hits, nxt.any()

    def cond(state):
        return state[3]

    frontier0 = jnp.zeros((q, n), bool).at[:, flat.root].set(True)
    visits0 = jnp.zeros((q,), jnp.int32)
    hits0 = jnp.zeros((q, max(n_obj, 1)), bool)
    frontier, visits, hits, _ = jax.lax.while_loop(
        cond, step, (frontier0, visits0, hits0, jnp.array(True))
    )
    return np.asarray(hits), np.asarray(visits)
