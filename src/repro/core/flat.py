"""Levelized struct-of-arrays view of a spatial tree + batched JAX search.

TPU adaptation layer (DESIGN.md §3.1): pointer-chasing trees do not
vectorize, so a built tree (mqr or R) is flattened into dense arrays and
region search becomes a masked breadth-first frontier sweep expressed with
``jax.lax`` control flow.  One "disk access" of the paper = one live row of
the frontier (a node whose entries are examined), so the JAX search reports
the *same* disk-access count as the host pointer implementation — this
equivalence is tested in tests/test_flat_search.py.

This module also exports the :class:`LevelSchedule` — the dense per-level
form of a tree that the fused region-search kernel
(:mod:`repro.kernels.pyramid_scan`, DESIGN.md §3.3) consumes in a single
launch.  Both pointer trees (via :func:`level_schedule`) and the bulk group
pyramid (via :func:`pyramid_schedule`) lower to the same schedule, so the
kernel serves either build path.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .mqrtree import MQRTree
from .rtree import RTree

EMPTY = -1  # children_idx sentinel: no entry
# children_idx >= 0   -> index of a child node
# children_idx <= -2  -> object id encoded as -(obj + 2)


@dataclasses.dataclass(frozen=True)
class FlatTree:
    """Dense array form of a spatial tree.

    node_mbr:      (N, 4)   float32
    children_mbr:  (N, F, 4) float32 (F = max fan-out)
    children_idx:  (N, F)   int32 (see sentinels above)
    n_objects:     int
    root:          int (node index of the root, always 0)
    """

    node_mbr: np.ndarray
    children_mbr: np.ndarray
    children_idx: np.ndarray
    n_objects: int
    root: int = 0

    @property
    def n_nodes(self) -> int:
        return self.node_mbr.shape[0]


def flatten(tree) -> FlatTree:
    """Flatten an ``MQRTree`` or ``RTree`` into a :class:`FlatTree`."""
    if isinstance(tree, MQRTree):
        fan = 5

        def node_entries(node):
            for _, e in node.entries():
                yield e.mbr, (e.node if e.is_node else None), e.obj

        root = tree.root
    elif isinstance(tree, RTree):
        fan = tree.M

        def node_entries(node):
            for e in node.entries:
                yield e.mbr, e.child, e.obj

        root = tree.root
    else:  # pragma: no cover
        raise TypeError(type(tree))

    nodes = []
    index = {}

    # Explicit-stack preorder walk: tree depth is unbounded (CENTER chains
    # grow one node per ~4 co-centred objects, Section 3.4), so recursion
    # would trip Python's recursion limit on degenerate datasets.
    stack = [root]
    while stack:
        node = stack.pop()
        index[id(node)] = len(nodes)
        nodes.append(node)
        children = [c for _, c, _ in node_entries(node) if c is not None]
        stack.extend(reversed(children))

    n = len(nodes)
    node_mbr = np.zeros((n, 4), np.float32)
    children_mbr = np.zeros((n, fan, 4), np.float32)
    children_idx = np.full((n, fan), EMPTY, np.int32)
    n_objects = 0
    for ni, node in enumerate(nodes):
        mbr = node.mbr if isinstance(tree, MQRTree) else node.mbr()
        node_mbr[ni] = np.asarray(mbr, np.float32)
        for fi, (embr, child, obj) in enumerate(node_entries(node)):
            children_mbr[ni, fi] = np.asarray(embr, np.float32)
            if child is not None:
                children_idx[ni, fi] = index[id(child)]
            else:
                children_idx[ni, fi] = -(obj + 2)
                n_objects = max(n_objects, obj + 1)
    return FlatTree(node_mbr, children_mbr, children_idx, n_objects)


def _overlaps(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Closed-boundary rectangle intersection, broadcasting."""
    return (
        (a[..., 0] <= b[..., 2])
        & (b[..., 0] <= a[..., 2])
        & (a[..., 1] <= b[..., 3])
        & (b[..., 1] <= a[..., 3])
    )


def region_search_batch(
    flat: FlatTree, queries: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Batched region search.

    Args:
      flat: flattened tree.
      queries: (Q, 4) query rectangles.

    Returns:
      hits:   (Q, n_objects) bool — object overlap mask.
      visits: (Q,) int32 — node visits (disk accesses), identical to the
              pointer implementation's count.
    """
    children_mbr = jnp.asarray(flat.children_mbr)
    children_idx = jnp.asarray(flat.children_idx)
    queries = jnp.asarray(queries, jnp.float32)
    n, fan = children_idx.shape
    q = queries.shape[0]
    n_obj = flat.n_objects

    is_node = children_idx >= 0
    is_obj = children_idx <= -2
    obj_ids = jnp.where(is_obj, -(children_idx + 2), 0)
    child_node = jnp.where(is_node, children_idx, 0)

    def step(state):
        frontier, visits, hits, _ = state
        visits = visits + frontier.sum(axis=1, dtype=jnp.int32)
        # (Q, N, F): does entry f of node n overlap query q?
        ov = _overlaps(children_mbr[None, :, :, :], queries[:, None, None, :])
        act = frontier[:, :, None] & ov
        # record object hits
        def per_query(hits_q, act_q):
            vals = (act_q & is_obj).reshape(-1)
            ids = obj_ids.reshape(-1)
            return hits_q.at[ids].max(vals)

        hits = jax.vmap(per_query)(hits, act)
        # propagate frontier to child nodes
        def frontier_query(act_q):
            vals = (act_q & is_node).reshape(-1)
            ids = child_node.reshape(-1)
            return jnp.zeros((n,), bool).at[ids].max(vals)

        nxt = jax.vmap(frontier_query)(act)
        return nxt, visits, hits, nxt.any()

    def cond(state):
        return state[3]

    frontier0 = jnp.zeros((q, n), bool).at[:, flat.root].set(True)
    visits0 = jnp.zeros((q,), jnp.int32)
    hits0 = jnp.zeros((q, max(n_obj, 1)), bool)
    frontier, visits, hits, _ = jax.lax.while_loop(
        cond, step, (frontier0, visits0, hits0, jnp.array(True))
    )
    return np.asarray(hits), np.asarray(visits)


# ---------------------------------------------------------------------------
# Level schedule: the input of the fused pyramid_scan kernel (DESIGN.md §3.3)
# ---------------------------------------------------------------------------

# MBR sentinel for padded slots: lo=+inf, hi=-inf never overlaps anything.
# Shared by the kernel (tile padding) and the server (null query padding).
NEVER_MBR = np.array([np.inf, np.inf, -np.inf, -np.inf], np.float32)

# Quantized-tile grid: real coordinates land in cells [0, CELLS]; lo=CELLS+1
# is the integer never-overlap sentinel (queries are clipped to <= CELLS, so
# a padded slot's lo exceeds every query hi).  DESIGN.md §7.
CELLS = 65534
Q_NEVER_MBR = np.array([CELLS + 1, CELLS + 1, 0, 0], np.uint16)

# Coarse uint8 grid for the UPPER levels of a hierarchical quantization
# (DESIGN.md §12): same outward rounding on a 255-cell grid, same sentinel
# scheme (lo=CELLS8+1=255 never overlaps a clipped query).  Conservativity
# holds at any resolution, so upper levels can afford 1-byte coordinates —
# the exact confirming pass still makes hit sets bit-identical.
CELLS8 = 254
Q8_NEVER_MBR = np.array([CELLS8 + 1, CELLS8 + 1, 0, 0], np.uint8)


@dataclasses.dataclass(frozen=True)
class LevelSchedule:
    """Dense per-level form of a spatial tree for the fused level sweep.

    A node at level ``l`` (depth ``l`` from the root) occupies a *slot*
    ``j`` in that level's row; padded slots carry never-overlapping
    sentinel MBRs.  The fused kernel computes, level by level,

        active[l, q, j] = active[l-1, q, parent[l, j]] & overlaps(mbr[l, j], q)

    which is exactly the breadth-first frontier of the pointer search, so
    ``active[l].sum()`` reproduces the paper's per-level disk-access counts
    (DESIGN.md §3: one MBR tile fetch = one disk access).

    mbr_cm:   (L, 4, W) float32 — node MBRs coordinate-major (lx, ly, hx, hy
              as contiguous lane vectors; W = padded max level width).
    parent:   (L, W) int32 — slot of the parent in level l-1 (0 at level 0
              and for padding; harmless, padding never overlaps).
    n_real:   (L,) int32 — real (non-padding) slots per level.
    obj_mbr:  (E, 4) float32 — MBR of each object entry.
    obj_level/obj_slot: (E,) int32 — the node holding the entry.
    obj_id:   (E,) int32 — object id the entry resolves to.
    n_objects: dense object-id space size.
    root_unconditional: the pointer search visits the root without testing
              its MBR — True for tree schedules; the group pyramid instead
              requires overlap at every level (False).
    test_object_mbr: whether an object hit additionally requires the entry
              MBR to overlap the query (True for trees; the pyramid's
              deepest group *is* the membership test, False).
    """

    mbr_cm: np.ndarray
    parent: np.ndarray
    n_real: np.ndarray
    obj_mbr: np.ndarray
    obj_level: np.ndarray
    obj_slot: np.ndarray
    obj_id: np.ndarray
    n_objects: int
    root_unconditional: bool = True
    test_object_mbr: bool = True

    @property
    def levels(self) -> int:
        return self.mbr_cm.shape[0]

    @property
    def width(self) -> int:
        return self.mbr_cm.shape[2]


@dataclasses.dataclass(frozen=True)
class QuantizedSchedule:
    """Conservatively quantized tile form of a :class:`LevelSchedule`.

    Node MBRs are snapped to a uint16 grid with OUTWARD rounding (lo
    coordinates floor, hi coordinates ceil), so a quantized box always
    contains its exact box and the quantized level sweep prunes a
    *superset* of the exact survivors — it can never drop a true hit.
    Survivors get one exact float32 confirming pass against
    ``confirm_mbr`` (the entry's own MBR for tree schedules; the entry's
    deepest group MBR for pyramid schedules — in both cases an exact
    overlap there implies every enclosing ancestor overlaps, so confirmed
    hit sets are bit-identical to the float32 path).  Streaming uint16
    node tiles + uint16 parent slots moves half the bytes per query of
    the float32 schedule (DESIGN.md §7).

    base:        the exact schedule (float32 oracle; also carries the
                 object table the confirming pass scatters through).
    mbr_q:       (L, 4, W) uint16 outward-rounded node MBR grid cells.
    parent_q:    (L, W) uint16 parent slots while the level width fits
                 (W <= 65535); wider schedules (pyramid width == n) keep
                 int32 parents and uint16 tiles — bytes ratio 0.6.
    origin:      (4,) float32 grid origin, coordinate-major (ox, oy, ox, oy).
    inv_cell:    (4,) float32 cells-per-unit, coordinate-major.
    confirm_mbr: (E, 4) float32 exact MBR the confirming pass tests.
    cells:       highest real grid cell index (sentinel is cells+1).

    Hierarchical (uint8 upper-level) extension — DESIGN.md §12.  When
    ``mbr_q8`` is present, levels ``[0, split)`` additionally carry a
    coarse uint8 form on a 254-cell grid sharing ``origin``; the hier
    sweep tests those levels on the coarse grid (1 byte/coordinate) and
    the remaining ``[split, L)`` levels on the fine uint16 grid.  Both
    grids round outward, so every level's candidate mask stays a superset
    of the exact sweep's and the confirming pass keeps hit sets
    bit-identical; only the access counts (``visits``) may inflate.

    mbr_q8:    (split, 4, W) uint8 coarse tiles of the upper levels, or
               ``None`` for a flat (uint16-only) quantization.
    split:     first level swept on the fine grid (0 = no coarse levels).
    cells8:    highest real coarse cell index (sentinel is cells8+1).
    inv_cell8: (4,) float32 coarse cells-per-unit (shares ``origin``).
    """

    base: LevelSchedule
    mbr_q: np.ndarray
    parent_q: np.ndarray
    origin: np.ndarray
    inv_cell: np.ndarray
    confirm_mbr: np.ndarray
    cells: int = CELLS
    mbr_q8: np.ndarray | None = None
    split: int = 0
    cells8: int = CELLS8
    inv_cell8: np.ndarray | None = None

    @property
    def levels(self) -> int:
        return self.base.levels

    @property
    def width(self) -> int:
        return self.base.width

    @property
    def n_objects(self) -> int:
        return self.base.n_objects

    @property
    def hierarchical(self) -> bool:
        """Whether the uint8 upper-level tiles are materialized."""
        return self.mbr_q8 is not None and self.split > 0

    @property
    def streamed_bytes(self) -> int:
        """HBM bytes the fused sweep streams per launch (node tiles +
        parent rows); the float32 path streams ``base`` at 2x.  The
        hierarchical form streams uint8 tiles for the upper levels."""
        if self.hierarchical:
            return (
                self.mbr_q8.nbytes
                + self.mbr_q[self.split:].nbytes
                + self.parent_q.nbytes
            )
        return self.mbr_q.nbytes + self.parent_q.nbytes


def level_schedule(flat: FlatTree) -> LevelSchedule:
    """Lower a :class:`FlatTree` (mqr or R) to the kernel's level schedule."""
    n, fan = flat.children_idx.shape
    depth = np.full((n,), -1, np.int64)
    depth[flat.root] = 0
    order = [flat.root]
    head = 0
    parent_of = np.full((n,), -1, np.int64)
    while head < len(order):
        ni = order[head]
        head += 1
        for ci in flat.children_idx[ni]:
            if ci >= 0:
                depth[int(ci)] = depth[ni] + 1
                parent_of[int(ci)] = ni
                order.append(int(ci))
    levels = int(depth.max()) + 1
    width = int(np.bincount(depth, minlength=levels).max())

    slot_of = np.zeros((n,), np.int64)
    fill = np.zeros((levels,), np.int64)
    mbr = np.broadcast_to(NEVER_MBR, (levels, width, 4)).copy()
    parent = np.zeros((levels, width), np.int32)
    for ni in order:  # BFS order => parents are slotted before children
        l = int(depth[ni])
        j = int(fill[l])
        fill[l] += 1
        slot_of[ni] = j
        mbr[l, j] = flat.node_mbr[ni]
        if l > 0:
            parent[l, j] = slot_of[parent_of[ni]]

    is_obj = flat.children_idx <= -2
    node_ids, _ = np.nonzero(is_obj)
    obj_mbr = flat.children_mbr[is_obj].astype(np.float32)
    obj_level = depth[node_ids].astype(np.int32)
    obj_slot = slot_of[node_ids].astype(np.int32)
    obj_id = (-(flat.children_idx[is_obj] + 2)).astype(np.int32)

    return LevelSchedule(
        mbr_cm=np.ascontiguousarray(mbr.transpose(0, 2, 1)),
        parent=parent,
        n_real=fill.astype(np.int32),
        obj_mbr=obj_mbr,
        obj_level=obj_level,
        obj_slot=obj_slot,
        obj_id=obj_id,
        n_objects=flat.n_objects,
        root_unconditional=True,
        test_object_mbr=True,
    )


def ancestor_chains(schedule: LevelSchedule, k_levels: int) -> np.ndarray:
    """Per-entry ancestor slots: ``(E, k_levels)`` int32, column ``k`` =
    the slot of entry ``e``'s ancestor node at level ``k``.

    The tree-vs-tree join epilogue (DESIGN.md §10) looks each entry pair
    up in the synchronized pair mask at ``k = min(level_a, level_b)``;
    these chains are the row/column coordinates of that lookup.  Columns
    past an entry's own level are left 0 — the join never reads them
    (``min`` clamps to the shallower entry).  Vectorized bottom-up walk:
    O(E · max_level) numpy, no per-entry Python loop.
    """
    levels = np.asarray(schedule.obj_level, np.int64)
    e = levels.shape[0]
    max_l = int(levels.max(initial=0))
    chains = np.zeros((e, max(k_levels, max_l + 1)), np.int64)
    cur = np.asarray(schedule.obj_slot, np.int64).copy()
    chains[np.arange(e), levels] = cur
    for t in range(max_l, 0, -1):
        step = levels >= t  # entries whose chain passes through level t
        cur = np.where(step, schedule.parent[t][cur], cur)
        chains[:, t - 1] = np.where(levels >= t - 1, cur, 0)
    return chains[:, :k_levels].astype(np.int32)


def pyramid_schedule(pyr, obj_mbrs: np.ndarray) -> LevelSchedule:
    """Lower a :class:`repro.core.bulk.GroupPyramid` to the level schedule.

    Dense group ids are the slots; ``bulk._group_bounds`` already pads
    unused ids with +inf/-inf sentinels.  Group nesting (a level-``l``
    group's members share one level-``l-1`` group) makes the parent map
    well defined.  Search semantics match :func:`repro.core.bulk.
    pyramid_search`: an object survives iff every ancestor group overlaps.
    """
    group_of = np.asarray(pyr.group_of)       # (L, n)
    group_mbr = np.asarray(pyr.group_mbr, np.float32)  # (L, n, 4)
    levels, n = group_of.shape
    parent = np.zeros((levels, n), np.int32)
    for l in range(1, levels):
        parent[l, group_of[l]] = group_of[l - 1]
    n_real = (group_of.max(axis=1) + 1).astype(np.int32)
    return LevelSchedule(
        mbr_cm=np.ascontiguousarray(group_mbr.transpose(0, 2, 1)),
        parent=parent,
        n_real=n_real,
        obj_mbr=np.asarray(obj_mbrs, np.float32),
        obj_level=np.full((n,), levels - 1, np.int32),
        obj_slot=group_of[levels - 1].astype(np.int32),
        obj_id=np.arange(n, dtype=np.int32),
        n_objects=n,
        root_unconditional=False,
        test_object_mbr=False,
    )
