"""Bulk (bottom-up batched) mqr construction in pure JAX.

The paper's insertion places an entry by the orientation of its MBR centroid
relative to the node-MBR centroid, and Section 4 property 1 proves the result
is *insertion-order independent* for distinct points: every centroid has
exactly one possible location.  The canonical tree is therefore a recursive
fixed point — each node's MBR is the bounding box of its member centroids'
objects, and members are partitioned by the Fig. 2 quadrant rule about that
box's centroid.  We compute that fixed point level-by-level as dense array
ops (segment min/max + branch-free quadrant select), which is the
TPU-idiomatic equivalent of incremental insertion (DESIGN.md §3.1).

Output is a "group pyramid": for each level l, ``group_of[l, i]`` gives the
dense group id of object i, and ``group_mbr[l, g]`` the group's MBR.  Group
0 at level 0 is the root.  An object stops splitting once alone in its group
(its group id simply stays fixed at deeper levels — harmless for search).
The pyramid supports pointer-free region search: an object survives a query
region iff every ancestor group MBR overlaps the region.  For the fused
single-launch TPU sweep, lower the pyramid to a level schedule with
``repro.core.flat.pyramid_schedule`` and run
``repro.kernels.ops.pyramid_scan`` (DESIGN.md §3.3).

Everything is static-shape and jit/vmap-compatible.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Quadrant codes (order irrelevant to correctness; matches mqrtree).
_NE, _NW, _SW, _SE, _EQ = 0, 1, 2, 3, 4


class GroupPyramid(NamedTuple):
    group_of: jnp.ndarray   # (L, n) int32 — dense group id per object per level
    group_mbr: jnp.ndarray  # (L, n, 4) float32 — MBR per dense group id
    # (padded groups carry +inf/-inf sentinels that never overlap anything)
    levels: int


def quad_code(acx, acy, bcx, bcy):
    """Branch-free Fig. 2 orientation table (vectorized).

    a = entry centroid, b = node centroid.
    """
    gx = acx > bcx
    lx = acx < bcx
    gy = acy > bcy
    ly = acy < bcy
    ex = ~gx & ~lx
    ey = ~gy & ~ly
    ne = (gx & ~ly)             # Ax>Bx, Ay>=By
    # SE ((Ax>Bx,Ay<By) or (Ax==Bx,Ay<By)) is the final else branch below
    nw = (lx & gy) | (ex & gy)  # Ax<Bx,Ay>By  or  Ax==Bx,Ay>By
    sw = lx & ~gy               # Ax<Bx, Ay<=By
    eq = ex & ey
    return jnp.where(
        eq,
        _EQ,
        jnp.where(ne, _NE, jnp.where(nw, _NW, jnp.where(sw, _SW, _SE))),
    ).astype(jnp.int32)


def _densify(keys: jnp.ndarray) -> jnp.ndarray:
    """Map arbitrary int keys to dense ids in [0, n), order-preserving on
    first occurrence after sort.  Static shapes throughout."""
    n = keys.shape[0]
    order = jnp.argsort(keys)
    sk = keys[order]
    new = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), (sk[1:] != sk[:-1]).astype(jnp.int32)]
    )
    dense_sorted = jnp.cumsum(new)
    return jnp.zeros((n,), jnp.int32).at[order].set(dense_sorted)


def _group_bounds(gid: jnp.ndarray, mbrs: jnp.ndarray, n: int):
    """Per-group enclosing MBR via segment min/max. Returns (n, 4) table."""
    lo_x = jax.ops.segment_min(mbrs[:, 0], gid, num_segments=n)
    lo_y = jax.ops.segment_min(mbrs[:, 1], gid, num_segments=n)
    hi_x = jax.ops.segment_max(mbrs[:, 2], gid, num_segments=n)
    hi_y = jax.ops.segment_max(mbrs[:, 3], gid, num_segments=n)
    return jnp.stack([lo_x, lo_y, hi_x, hi_y], axis=-1)


def default_levels(n: int) -> int:
    """Pyramid depth heuristic shared by every bulk build path: enough
    5-way splits to separate ``n`` distinct centroids, plus slack for the
    root and one uneven split."""
    import math

    return int(math.ceil(math.log(max(n, 2)) / math.log(5))) + 2


def build_pyramid(mbrs: jnp.ndarray, levels: int) -> GroupPyramid:
    """Build the mqr group pyramid for ``mbrs`` (n, 4) with ``levels`` levels.

    Level 0 is the root (all objects in group 0).  Each deeper level applies
    the Fig. 2 quadrant rule about the group-MBR centroid.  Groups that have
    a single member stop subdividing (their id is frozen).
    """
    mbrs = jnp.asarray(mbrs, jnp.float32)
    n = mbrs.shape[0]
    cx = (mbrs[:, 0] + mbrs[:, 2]) * 0.5
    cy = (mbrs[:, 1] + mbrs[:, 3]) * 0.5

    gid = jnp.zeros((n,), jnp.int32)
    group_of = [gid]
    bounds = _group_bounds(gid, mbrs, n)
    group_mbr = [bounds]

    for _ in range(levels - 1):
        counts = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), gid, num_segments=n)
        multi = counts[gid] > 1
        gb = bounds[gid]
        gcx = (gb[:, 0] + gb[:, 2]) * 0.5
        gcy = (gb[:, 1] + gb[:, 3]) * 0.5
        quad = quad_code(cx, cy, gcx, gcy)
        # singles keep subdividing trivially (they stay alone); key stays
        # unique per object either way.
        key = jnp.where(multi, gid * 5 + quad, gid * 5)
        gid = _densify(key)
        bounds = _group_bounds(gid, mbrs, n)
        group_of.append(gid)
        group_mbr.append(bounds)

    return GroupPyramid(
        group_of=jnp.stack(group_of),
        group_mbr=jnp.stack(group_mbr),
        levels=levels,
    )


def _overlaps(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return (
        (a[..., 0] <= b[..., 2])
        & (b[..., 0] <= a[..., 2])
        & (a[..., 1] <= b[..., 3])
        & (b[..., 1] <= a[..., 3])
    )


def pyramid_search(pyr: GroupPyramid, region: jnp.ndarray) -> jnp.ndarray:
    """Pointer-free region search: object i survives iff the group MBR of
    every ancestor level overlaps ``region`` (4,).  Returns (n,) bool."""
    # (L, n): does object's level-l group overlap the region?
    per_level = _overlaps(
        jnp.take_along_axis(
            pyr.group_mbr, pyr.group_of[:, :, None].repeat(4, axis=2), axis=1
        ),
        region[None, None, :],
    )
    return per_level.all(axis=0)


def pyramid_stats(pyr: GroupPyramid):
    """Diagnostics: number of distinct groups per level (host-side)."""
    import numpy as np

    return [int(np.unique(np.asarray(g)).size) for g in pyr.group_of]
