"""Guttman R-tree (1984) — the paper's benchmark baseline.

Quadratic split, ``M = 5`` entries per node (matching the mqr-tree's five
locations, and consistent with the node counts reported in the paper's
tables: ~196 nodes for 500 objects), ``m = 2``.
"""

from __future__ import annotations

from typing import Iterator, List, Optional, Tuple

import numpy as np

from . import mbr as M

DEFAULT_M = 5
DEFAULT_m = 2


class REntry:
    __slots__ = ("mbr", "child", "obj")

    def __init__(self, mbr: np.ndarray, child: "RNode" = None, obj: int = None):
        self.mbr = np.asarray(mbr, dtype=np.float64)
        self.child = child
        self.obj = obj


class RNode:
    __slots__ = ("entries", "leaf", "parent")

    def __init__(self, leaf: bool = True, parent: "RNode" = None):
        self.entries: List[REntry] = []
        self.leaf = leaf
        self.parent = parent

    def mbr(self) -> np.ndarray:
        return M.merge_many(np.stack([e.mbr for e in self.entries]))


class RTree:
    def __init__(self, max_entries: int = DEFAULT_M, min_entries: int = DEFAULT_m):
        self.M = max_entries
        self.m = min_entries
        self.root = RNode(leaf=True)

    # ------------------------------------------------------------------
    def insert(self, obj_id: int, obj_mbr: np.ndarray) -> None:
        entry = REntry(np.asarray(obj_mbr, np.float64), obj=obj_id)
        leaf = self._choose_leaf(self.root, entry)
        leaf.entries.append(entry)
        if len(leaf.entries) > self.M:
            self._split_and_adjust(leaf)
        else:
            self._adjust_upward(leaf)

    def _choose_leaf(self, node: RNode, entry: REntry) -> RNode:
        while not node.leaf:
            best: Optional[REntry] = None
            best_enl = np.inf
            best_area = np.inf
            for e in node.entries:
                a = M.area(e.mbr)
                enl = M.area(M.merge(e.mbr, entry.mbr)) - a
                if enl < best_enl or (enl == best_enl and a < best_area):
                    best, best_enl, best_area = e, enl, a
            node = best.child
        return node

    def _adjust_upward(self, node: RNode) -> None:
        while node.parent is not None:
            parent = node.parent
            for e in parent.entries:
                if e.child is node:
                    e.mbr = node.mbr()
                    break
            node = parent

    def _split_and_adjust(self, node: RNode) -> None:
        while True:
            a_entries, b_entries = self._quadratic_split(node.entries)
            node.entries = a_entries
            sibling = RNode(leaf=node.leaf, parent=node.parent)
            sibling.entries = b_entries
            for e in sibling.entries:
                if e.child is not None:
                    e.child.parent = sibling
            if node.parent is None:
                new_root = RNode(leaf=False)
                new_root.entries = [
                    REntry(node.mbr(), child=node),
                    REntry(sibling.mbr(), child=sibling),
                ]
                node.parent = new_root
                sibling.parent = new_root
                self.root = new_root
                return
            parent = node.parent
            for e in parent.entries:
                if e.child is node:
                    e.mbr = node.mbr()
                    break
            parent.entries.append(REntry(sibling.mbr(), child=sibling))
            if len(parent.entries) > self.M:
                node = parent
                continue
            self._adjust_upward(parent)
            return

    def _quadratic_split(
        self, entries: List[REntry]
    ) -> Tuple[List[REntry], List[REntry]]:
        # PickSeeds: the pair wasting the most area.
        n = len(entries)
        worst = -np.inf
        s1 = s2 = 0
        for i in range(n):
            for j in range(i + 1, n):
                waste = (
                    M.area(M.merge(entries[i].mbr, entries[j].mbr))
                    - M.area(entries[i].mbr)
                    - M.area(entries[j].mbr)
                )
                if waste > worst:
                    worst, s1, s2 = waste, i, j
        group_a = [entries[s1]]
        group_b = [entries[s2]]
        mbr_a = entries[s1].mbr.copy()
        mbr_b = entries[s2].mbr.copy()
        rest = [e for k, e in enumerate(entries) if k not in (s1, s2)]
        while rest:
            need_a = self.m - len(group_a)
            need_b = self.m - len(group_b)
            if need_a >= len(rest):
                group_a.extend(rest)
                for e in rest:
                    mbr_a = M.merge(mbr_a, e.mbr)
                break
            if need_b >= len(rest):
                group_b.extend(rest)
                for e in rest:
                    mbr_b = M.merge(mbr_b, e.mbr)
                break
            # PickNext: entry with max preference difference.
            best_k = 0
            best_diff = -np.inf
            for k, e in enumerate(rest):
                d1 = M.area(M.merge(mbr_a, e.mbr)) - M.area(mbr_a)
                d2 = M.area(M.merge(mbr_b, e.mbr)) - M.area(mbr_b)
                if abs(d1 - d2) > best_diff:
                    best_diff = abs(d1 - d2)
                    best_k = k
            e = rest.pop(best_k)
            d1 = M.area(M.merge(mbr_a, e.mbr)) - M.area(mbr_a)
            d2 = M.area(M.merge(mbr_b, e.mbr)) - M.area(mbr_b)
            if d1 < d2 or (
                d1 == d2
                and (
                    M.area(mbr_a) < M.area(mbr_b)
                    or (M.area(mbr_a) == M.area(mbr_b) and len(group_a) <= len(group_b))
                )
            ):
                group_a.append(e)
                mbr_a = M.merge(mbr_a, e.mbr)
            else:
                group_b.append(e)
                mbr_b = M.merge(mbr_b, e.mbr)
        return group_a, group_b

    # ------------------------------------------------------------------
    def region_search(self, query: np.ndarray) -> Tuple[List[int], int]:
        query = np.asarray(query, dtype=np.float64)
        found: List[int] = []
        visits = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            visits += 1
            for e in node.entries:
                if not M.overlaps(e.mbr, query):
                    continue
                if node.leaf:
                    found.append(e.obj)
                else:
                    stack.append(e.child)
        return found, visits

    # ------------------------------------------------------------------
    def iter_nodes(self) -> Iterator[Tuple[RNode, int]]:
        stack = [(self.root, 1)]
        while stack:
            node, depth = stack.pop()
            yield node, depth
            if not node.leaf:
                for e in node.entries:
                    stack.append((e.child, depth + 1))

    def validate(self) -> None:
        for node, _ in self.iter_nodes():
            if node is not self.root:
                assert self.m <= len(node.entries) <= self.M
            else:
                assert len(node.entries) <= self.M
            if not node.leaf:
                for e in node.entries:
                    assert np.allclose(e.mbr, e.child.mbr()), "stale parent MBR"


def build(mbrs: np.ndarray, max_entries: int = DEFAULT_M) -> RTree:
    t = RTree(max_entries=max_entries)
    for i, m in enumerate(np.asarray(mbrs, dtype=np.float64)):
        t.insert(i, m)
    return t
