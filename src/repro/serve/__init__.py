"""repro.serve — the spatial serving front end (DESIGN.md §11).

THE documented serving entry point for spatial queries.  The layering is:

* :mod:`repro.launch.spatial_serve` — the low-level batch ENGINE
  (:class:`SpatialServer`: LRU + dedupe + vmap/pmap fan-out + the
  degradation ladder).  It only accepts pre-formed batches; the façade's
  ``backend="serve"`` wraps it per index.
* :mod:`repro.serve` (this package) — the FRONT END over any number of
  tenant indexes: an async request queue that coalesces single
  region/point/knn/count arrivals into size- and deadline-bounded
  batches (continuous batching), admission control with per-class SLO
  deadlines that sheds or queues under overload, a declarative
  multi-tenant registry (config → built stack), and streaming latency
  telemetry (p50/p99/p99.9 histograms).
* :mod:`repro.launch.serve` — unrelated: the LM token-decoding driver.

Every answer served through the queue is bit-identical to calling the
tenant's :class:`repro.index.SpatialIndex` directly
(tests/test_serve_front.py), including while a bound
:class:`repro.ft.FaultPlan` forces the degradation ladder mid-run —
degradation shows up in tail latency, never in answers or errors.
"""

from .config import (  # noqa: F401
    DEFAULT_SLO_CLASSES,
    SLOClass,
    ServerConfig,
    TenantConfig,
)
from .frontend import (  # noqa: F401
    Answer,
    OverloadShed,
    ServingFrontEnd,
    TenantRuntime,
)
from .queue import Request  # noqa: F401
from .telemetry import LatencyHistogram, ServeTelemetry  # noqa: F401

__all__ = [
    "Answer",
    "DEFAULT_SLO_CLASSES",
    "LatencyHistogram",
    "OverloadShed",
    "Request",
    "SLOClass",
    "ServeTelemetry",
    "ServerConfig",
    "ServingFrontEnd",
    "TenantConfig",
    "TenantRuntime",
]
