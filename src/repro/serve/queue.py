"""Continuous batching: coalesce single arrivals into bounded batches.

Single region/point/knn/count requests arrive one at a time; kernels
want dense ``(query_block, 4)`` batches.  A :class:`BatchQueue` holds
one FIFO per coalescing group — ``(tenant, "region")`` for the three
rectangle-shaped kinds (a point is a degenerate rectangle, a count is a
region reduced at completion) and ``(tenant, "knn", k)`` per distinct
``k`` — and launches a group's head batch when EITHER bound trips
(DESIGN.md §11):

* **size**: the group holds a full ``query_block`` of requests;
* **deadline**: the oldest non-parked request's slack runs out —
  ``now >= deadline - est_service - margin`` where ``est_service`` is an
  EWMA of the group's recent launch-to-complete times.  Waiting any
  longer would spend the request's remaining SLO budget on queueing.

Requests parked by ``overload="queue"`` admission never drive the
deadline bound (their SLO is already forfeit); they ride along in FIFO
order whenever the size bound or another request's deadline launches
their group, or when the front end drains.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import trace as _obs_trace

#: request kinds the front end coalesces
KINDS = ("region", "point", "count", "knn")

#: kinds that share the rectangle-batch coalescing group
RECT_KINDS = ("region", "point", "count")

_SEQ = itertools.count()


@dataclasses.dataclass
class Request:
    """One in-flight request: payload + SLO + the latency timeline.

    The object doubles as the caller's ticket — :attr:`status` moves
    ``pending -> done`` (or is born ``rejected``/``shed``) and
    :attr:`result` holds the per-kind answer once completed.
    """

    tenant: str
    kind: str
    payload: np.ndarray          # (4,) rect for rect kinds; (2,) point for knn
    slo_class: str
    deadline: float              # absolute, on the front end's clock
    t_arrival: float
    k: Optional[int] = None      # knn only
    parked: bool = False         # admitted past max_queue (overload="queue")
    seq: int = dataclasses.field(default_factory=lambda: next(_SEQ))
    status: str = "pending"      # pending | done | shed | rejected
    result: Any = None
    t_launch: Optional[float] = None
    t_complete: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.status == "done"

    def timeline(self):
        from .telemetry import RequestTimeline

        return RequestTimeline(self.t_arrival, self.t_launch, self.t_complete)


GroupKey = Tuple  # ("rect", tenant) | ("knn", tenant, k)


def group_key(req: Request) -> GroupKey:
    if req.kind in RECT_KINDS:
        return ("rect", req.tenant)
    return ("knn", req.tenant, req.k)


class BatchQueue:
    """FIFO coalescing queues, one per group, with EWMA service estimates."""

    def __init__(self, query_block: int, *, slack_margin: float = 1e-3,
                 est_alpha: float = 0.25, est_init: float = 2e-3):
        self.query_block = int(query_block)
        self.slack_margin = float(slack_margin)
        self.est_alpha = float(est_alpha)
        self.est_init = float(est_init)
        self._queues: Dict[GroupKey, Deque[Request]] = {}
        self._est: Dict[GroupKey, float] = {}
        self.pending_by_class: Dict[str, int] = {}

    # -- admission-side bookkeeping ------------------------------------
    def pending(self, slo_class: Optional[str] = None) -> int:
        if slo_class is None:
            return sum(len(q) for q in self._queues.values())
        return self.pending_by_class.get(slo_class, 0)

    def add(self, req: Request) -> None:
        self._queues.setdefault(group_key(req), deque()).append(req)
        self.pending_by_class[req.slo_class] = (
            self.pending_by_class.get(req.slo_class, 0) + 1
        )

    # -- launch decisions ----------------------------------------------
    def est_service(self, key: GroupKey) -> float:
        return self._est.get(key, self.est_init)

    def observe_service(self, key: GroupKey, seconds: float) -> None:
        prev = self._est.get(key)
        a = self.est_alpha
        self._est[key] = (
            seconds if prev is None else (1 - a) * prev + a * seconds
        )

    def due_groups(self, now: float) -> List[Tuple[GroupKey, bool]]:
        """Groups that must launch at ``now``: ``(key, by_deadline)`` —
        full groups first (size bound), then any group whose oldest
        non-parked request has run out of deadline slack."""
        out = []
        for key, q in self._queues.items():
            if not q:
                continue
            if len(q) >= self.query_block:
                out.append((key, False))
                continue
            oldest = next((r for r in q if not r.parked), None)
            if oldest is None:
                continue
            slack = oldest.deadline - now - self.est_service(key)
            if slack <= self.slack_margin:
                _obs_trace.instant("queue.deadline_due",
                                   group=str(key), seq=oldest.seq,
                                   slack_ms=slack * 1e3)
                out.append((key, True))
        return out

    def pop_batch(self, key: GroupKey) -> List[Request]:
        """Dequeue up to ``query_block`` requests of one group, FIFO."""
        q = self._queues.get(key)
        batch: List[Request] = []
        while q and len(batch) < self.query_block:
            req = q.popleft()
            self.pending_by_class[req.slo_class] -= 1
            batch.append(req)
        return batch

    def drain_keys(self) -> List[GroupKey]:
        return [k for k, q in self._queues.items() if q]
