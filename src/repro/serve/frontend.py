"""The multi-tenant serving front end (DESIGN.md §11).

One :class:`ServingFrontEnd` owns any number of tenant index stacks and
turns single-query arrivals into kernel-shaped launches:

* :meth:`submit` — the hardened boundary: geometry is validated per
  request (NaN/±inf/inverted rects raise the typed
  :class:`repro.index.InvalidQueryError` BEFORE touching a batch), then
  admission control compares the request's SLO class queue depth against
  the class limit — over it, ``overload="shed"`` returns a ``shed``
  ticket (the request never queues) and ``overload="queue"`` parks the
  request best-effort;
* :meth:`pump` — continuous batching: launches every group whose size or
  deadline bound has tripped (:mod:`repro.serve.queue`), one
  ``SpatialIndex`` call per coalesced batch;
* answers are BIT-IDENTICAL to calling the tenant's index directly: the
  front end only stacks, dispatches, and unstacks — caching, dedupe,
  padding, and the pallas→lax→host degradation ladder all live in the
  per-tenant serving stack underneath, which is also why a bound
  :class:`repro.ft.FaultPlan` shows up as tail latency, never as errors;
* every tenant has its own index, its own epoch-tagged result cache, and
  its own :class:`repro.index.AccessStats` ledger — tenant A's mutations
  bump only A's epoch, so B's cached answers stay valid (isolation is
  structural, verified in tests/test_serve_front.py).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Union

import dataclasses

import numpy as np

from repro.index.api import InvalidQueryError, SpatialIndex, validate_queries
from repro.obs import trace as _obs_trace

from .config import ServerConfig, TenantConfig
from .queue import KINDS, BatchQueue, GroupKey, Request, group_key
from .telemetry import ServeTelemetry


class OverloadShed(Exception):
    """Raised by :meth:`Request-awaiting helpers <ServingFrontEnd.result>`
    when asked for the answer of a request that admission control shed."""


@dataclasses.dataclass(frozen=True)
class Answer:
    """Per-request region/point answer: one row of the batched result."""

    hits: np.ndarray              # (id_space,) bool global-id overlap mask
    visits: np.ndarray            # (L,) int32 per-level accesses

    @property
    def ids(self) -> np.ndarray:
        return np.nonzero(self.hits)[0]


class TenantRuntime:
    """One tenant's built stack: the config plus its live index.

    ``index`` is the queryable object — a :class:`SpatialIndex`, or a
    :class:`repro.checkpoint.DurableIndex` when the tenant declared
    ``durable_root`` (mutations then go WAL-first and a front-end
    restart recovers the tenant's last durable state).
    """

    def __init__(self, config: TenantConfig, index):
        self.config = config
        self.index = index

    @property
    def spatial(self) -> SpatialIndex:
        """The underlying SpatialIndex (unwraps DurableIndex)."""
        return getattr(self.index, "index", self.index)

    @property
    def stats(self):
        return self.index.stats

    @property
    def epoch(self) -> int:
        """The tenant's mutation epoch (0 until the first mutation)."""
        log = self.spatial._updates
        return 0 if log is None else int(log.epoch)


class ServingFrontEnd:
    """Continuous batching + admission control over a tenant registry."""

    def __init__(self, config: ServerConfig,
                 runtimes: Dict[str, TenantRuntime], *,
                 clock: Optional[Callable[[], float]] = None):
        self.config = config
        self.tenants = dict(runtimes)
        self.clock = clock if clock is not None else time.monotonic
        self.queue = BatchQueue(
            config.query_block, slack_margin=config.slack_margin_ms / 1e3
        )
        self.telemetry = ServeTelemetry()

    # -- construction ---------------------------------------------------
    @classmethod
    def build(cls, config: Union[ServerConfig, dict], data: Dict[str, np.ndarray],
              *, clock=None, fault_plan=None) -> "ServingFrontEnd":
        """Declarative config → built front end (the factory idiom).

        ``data`` maps tenant name → (n, 4) MBRs; every declared tenant
        must be covered (durable tenants with an existing generation
        recover from disk instead and may omit their entry).
        """
        if not isinstance(config, ServerConfig):
            config = ServerConfig.from_dict(config)
        runtimes: Dict[str, TenantRuntime] = {}
        for tc in config.tenants:
            runtimes[tc.name] = TenantRuntime(
                tc, cls._build_tenant_index(tc, config, data)
            )
        front = cls(config, runtimes, clock=clock)
        if fault_plan is not None:
            front.bind_fault_plan(fault_plan)
        return front

    @staticmethod
    def _build_tenant_index(tc: TenantConfig, config: ServerConfig,
                            data: Dict[str, np.ndarray]):
        opts = tc.index_opts(config.query_block)
        if tc.durable_root is not None:
            from repro.checkpoint import DurableIndex

            structure = opts.pop("structure")
            backend = opts.pop("backend")
            opts.pop("admission", None)
            return DurableIndex.open(
                tc.durable_root, data.get(tc.name),
                structure=structure, backend=backend,
                admission=tc.admission, **opts,
            )
        if tc.name not in data:
            raise ValueError(
                f"tenant {tc.name!r} declared but no dataset provided "
                f"(have: {sorted(data)})"
            )
        return SpatialIndex.build(data[tc.name], **opts)

    # -- the hardened boundary -----------------------------------------
    def submit(self, tenant: str, kind: str, payload, *,
               k: Optional[int] = None, slo: Optional[str] = None,
               t_arrival: Optional[float] = None) -> Request:
        """Enqueue ONE query; returns its ticket (the mutable Request).

        ``t_arrival`` overrides the arrival timestamp — the open-loop
        load generator passes the SCHEDULED arrival so latency includes
        any submit-side lag (no coordinated omission).
        """
        if tenant not in self.tenants:
            raise ValueError(
                f"unknown tenant {tenant!r} (have: {sorted(self.tenants)})"
            )
        if kind not in KINDS:
            raise ValueError(f"unknown kind {kind!r}; expected one of {KINDS}")
        cls = self.config.slo_class(slo)
        now = self.clock()
        arrival = now if t_arrival is None else float(t_arrival)

        # geometry is validated BEFORE the request can touch a batch —
        # one poisoned rect must never invalidate its neighbours' answers
        if kind == "knn":
            payload = self._validate_point(payload, tenant)
            if k is None or k < 1:
                raise InvalidQueryError(f"knn needs k >= 1, got {k!r}")
            rt = self.tenants[tenant]
            if k > rt.index.n_objects:
                raise InvalidQueryError(
                    f"k={k} exceeds tenant {tenant!r} live objects "
                    f"({rt.index.n_objects})"
                )
        else:
            if kind == "point":
                payload = self._validate_point(payload, tenant)
                payload = np.concatenate([payload, payload])
            else:
                try:
                    payload = validate_queries(
                        payload, what=f"{tenant}/{kind} query"
                    ).reshape(4)
                except InvalidQueryError:
                    self.telemetry.rejected += 1
                    raise
        self.telemetry.submitted += 1

        req = Request(
            tenant=tenant, kind=kind, payload=payload, k=k,
            slo_class=cls.name, deadline=arrival + cls.deadline_s,
            t_arrival=arrival,
        )
        # admission control: per-class queue-depth limit (DESIGN.md §11)
        if self.queue.pending(cls.name) >= cls.max_queue:
            if cls.overload == "shed":
                req.status = "shed"
                self.telemetry.shed += 1
                self.tenants[tenant].stats.shed_queries += 1
                # span-less counter event: overload is visible in the
                # trace export, not just in AccessStats (DESIGN.md §13)
                _obs_trace.counter("serve.shed", shed=self.telemetry.shed)
                return req
            req.parked = True    # overload="queue": best-effort, no SLO
            self.telemetry.queued_overload += 1
            self.tenants[tenant].stats.queued_queries += 1
            _obs_trace.counter("serve.queued_overload",
                               queued=self.telemetry.queued_overload)
        _obs_trace.instant("serve.enqueue", tenant=tenant, kind=kind,
                           slo=cls.name, seq=req.seq)
        self.queue.add(req)
        return req

    def _validate_point(self, payload, tenant: str) -> np.ndarray:
        p = np.asarray(payload, np.float32).reshape(-1)
        if p.shape[0] != 2 or not np.isfinite(p).all():
            self.telemetry.rejected += 1
            raise InvalidQueryError(
                f"{tenant!r}: point must be 2 finite coordinates, got "
                f"{np.asarray(payload).tolist()!r}"
            )
        return p

    # -- continuous batching -------------------------------------------
    def pump(self, now: Optional[float] = None) -> int:
        """Launch every batch whose size or deadline bound has tripped;
        returns the number of batches launched."""
        launched = 0
        while True:
            t = self.clock() if now is None else now
            due = self.queue.due_groups(t)
            if not due:
                return launched
            for key, by_deadline in due:
                batch = self.queue.pop_batch(key)
                if batch:
                    self._launch(key, batch, by_deadline=by_deadline)
                    launched += 1

    def drain(self) -> int:
        """Flush everything still queued, bounds or not (shutdown /
        end-of-run path); returns the number of batches launched."""
        launched = self.pump()
        for key in self.queue.drain_keys():
            while True:
                batch = self.queue.pop_batch(key)
                if not batch:
                    break
                self._launch(key, batch, by_deadline=False)
                launched += 1
        return launched

    def _launch(self, key: GroupKey, batch, *, by_deadline: bool) -> None:
        t_launch = self.clock()
        for req in batch:
            req.t_launch = t_launch
        rt = self.tenants[batch[0].tenant]
        with _obs_trace.span("serve.launch", tenant=batch[0].tenant,
                             kind=key[0], batch=len(batch),
                             by_deadline=by_deadline):
            if key[0] == "rect":
                rects = np.stack([r.payload for r in batch])
                res = rt.index.region(rects)
                for i, req in enumerate(batch):
                    if req.kind == "count":
                        req.result = int(res.hits[i].sum())
                    else:
                        req.result = Answer(
                            hits=res.hits[i], visits=res.visits_per_level[i]
                        )
                    self._complete(req)
            else:
                pts = np.stack([r.payload for r in batch])
                res = rt.index.knn(pts, k=key[2])
                for i, req in enumerate(batch):
                    req.result = (res.ids[i], res.dists[i])
                    self._complete(req)
        done = self.clock()
        self.queue.observe_service(key, done - t_launch)
        self.telemetry.batches += 1
        self.telemetry.batched_requests += len(batch)
        if by_deadline:
            self.telemetry.deadline_launches += 1

    def _complete(self, req: Request) -> None:
        req.t_complete = self.clock()
        req.status = "done"
        self.telemetry.observe(
            req, self.config.slo_class(req.slo_class).deadline_s
        )

    def result(self, req: Request):
        """The answer for a ticket, pumping the queue until it lands.
        Raises :class:`OverloadShed` for shed requests — the typed
        signal that admission control, not an error, dropped the work."""
        if req.status == "shed":
            raise OverloadShed(
                f"request {req.seq} ({req.tenant}/{req.kind}, class "
                f"{req.slo_class!r}) was shed by admission control"
            )
        while req.status == "pending":
            if not self.pump():
                # nothing due yet: force the straggler's group out
                batch = self.queue.pop_batch(group_key(req))
                if batch:
                    self._launch(group_key(req), batch, by_deadline=True)
        return req.result

    # -- mutations (per-tenant epochs) ---------------------------------
    def insert(self, tenant: str, mbrs):
        """Insert into ONE tenant's live set; only that tenant's epoch
        (and therefore only its cached answers) is touched."""
        return self._tenant(tenant).index.insert(mbrs)

    def delete(self, tenant: str, ids):
        return self._tenant(tenant).index.delete(ids)

    def flush(self, tenant: str):
        return self._tenant(tenant).index.flush()

    def _tenant(self, tenant: str) -> TenantRuntime:
        try:
            return self.tenants[tenant]
        except KeyError:
            raise ValueError(
                f"unknown tenant {tenant!r} (have: {sorted(self.tenants)})"
            ) from None

    # -- health / introspection ----------------------------------------
    def bind_fault_plan(self, plan) -> None:
        """Thread one :class:`repro.ft.FaultPlan` through every tenant's
        serving ladder — injected launch failures then surface as
        degraded (slower) batches, never as failed requests."""
        for rt in self.tenants.values():
            rt.index.bind_fault_plan(plan)

    def stats(self, tenant: str):
        """The tenant's :class:`repro.index.AccessStats` ledger."""
        return self._tenant(tenant).stats

    def metrics(self):
        """One :class:`repro.obs.MetricsRegistry` snapshot of the whole
        front end: serve telemetry (latency/queue-wait summaries, per
        SLO class and per tenant) plus every tenant's ``AccessStats``
        under a ``tenant`` label (DESIGN.md §13).  Render with
        ``.to_prometheus()`` or ``.to_json()``."""
        from repro.obs import metrics as _obs_metrics

        reg = _obs_metrics.MetricsRegistry()
        _obs_metrics.telemetry_into(reg, self.telemetry)
        for name, rt in sorted(self.tenants.items()):
            _obs_metrics.stats_into(reg, rt.stats,
                                    labels={"tenant": name})
        return reg

    def warmup(self, *, knn_k: Optional[int] = None) -> None:
        """Compile every tenant's batched query path at the serving
        shape (one full-block region batch, plus one knn batch when
        ``knn_k`` is given) so the first timed request doesn't pay jit
        lowering.  Touches caches and stats like any query."""
        qb = self.config.query_block
        rect = np.array([0.0, 0.0, 1.0, 1.0], np.float32)
        for rt in self.tenants.values():
            rt.index.region(np.tile(rect, (qb, 1)))
            if knn_k is not None and knn_k <= rt.index.n_objects:
                rt.index.knn(np.zeros((qb, 2), np.float32), k=knn_k)

    def pending(self) -> int:
        return self.queue.pending()
