"""Declarative serving configuration: config dicts → a built tenant stack.

The idiom is the xformers model factory (SNIPPETS.md): plain dicts are
typed into frozen dataclass configs at the boundary — typos and illegal
values fail THERE, with the offending key named, never as a shape error
three layers down — and one ``build`` call assembles the runtime stack.

A :class:`ServerConfig` declares the whole front end::

    cfg = ServerConfig.from_dict({
        "query_block": 8,
        "classes": [
            {"name": "interactive", "deadline_ms": 50, "overload": "shed",
             "max_queue": 64},
            {"name": "batch", "deadline_ms": 2000, "overload": "queue"},
        ],
        "tenants": [
            {"name": "maps", "structure": "pyramid", "backend": "serve",
             "build": "device", "precision": "compact"},
            {"name": "fleet", "structure": "mqr", "backend": "serve",
             "capacity": 256, "durable_root": "/data/fleet"},
        ],
    })
    front = ServingFrontEnd.build(cfg, data={"maps": ..., "fleet": ...})

Each tenant maps to its own (structure, backend, precision, merge
policy) stack — its own :class:`repro.index.SpatialIndex` (or, with
``durable_root``, a WAL-backed :class:`repro.checkpoint.DurableIndex`
that recovers on restart), and therefore its own epoch-tagged result
cache: one tenant's mutations can never invalidate or leak into
another's answers (DESIGN.md §11).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

#: admission vocabulary shared with DurableIndex (repro.checkpoint.durable):
#: ``shed`` drops over-limit work, ``queue`` parks it best-effort.
OVERLOAD_MODES = ("shed", "queue")


def _typed(cls, d: dict):
    """Dict → dataclass with typo catching: unknown keys raise with the
    accepted field names listed (the factory-config contract)."""
    fields = {f.name for f in dataclasses.fields(cls)}
    bad = sorted(set(d) - fields)
    if bad:
        raise TypeError(
            f"{cls.__name__}: unknown key(s) {bad}; accepted: {sorted(fields)}"
        )
    return cls(**d)


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One admission class: a completion deadline plus the overload verb.

    deadline_ms: per-request SLO — also the continuous-batching bound (a
        pending batch launches when its oldest request's deadline slack
        runs out, see :mod:`repro.serve.queue`).
    overload:    what happens to arrivals beyond ``max_queue`` pending in
        this class — ``"shed"`` rejects them (a typed
        :class:`~repro.serve.frontend.OverloadShed` answer, counted in
        ``AccessStats.shed_queries``), ``"queue"`` parks them best-effort
        (deadline no longer drives their launch; counted in
        ``AccessStats.queued_queries``).
    max_queue:   pending-request admission limit for the class.
    """

    name: str
    deadline_ms: float
    overload: str = "shed"
    max_queue: int = 1024

    def __post_init__(self):
        if self.deadline_ms <= 0:
            raise ValueError(f"SLO class {self.name!r}: deadline_ms must be > 0")
        if self.overload not in OVERLOAD_MODES:
            raise ValueError(
                f"SLO class {self.name!r}: overload {self.overload!r} not in "
                f"{OVERLOAD_MODES}"
            )
        if self.max_queue < 1:
            raise ValueError(f"SLO class {self.name!r}: max_queue must be >= 1")

    @property
    def deadline_s(self) -> float:
        return self.deadline_ms / 1e3


DEFAULT_SLO_CLASSES: Tuple[SLOClass, ...] = (
    SLOClass("interactive", deadline_ms=50.0, overload="shed", max_queue=256),
    SLOClass("batch", deadline_ms=2000.0, overload="queue", max_queue=65536),
)


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """One tenant's declarative index stack.

    The fields mirror ``SpatialIndex.build`` keyword-for-keyword —
    structure, backend, precision, device build, delta-buffer capacity,
    merge-policy kwargs, mutation admission — plus ``durable_root``:
    when set, the tenant is backed by a :class:`repro.checkpoint.
    DurableIndex` at that path (WAL-first mutations; an existing
    generation is recovered instead of rebuilt, so a front-end restart
    resumes every durable tenant where it crashed).
    """

    name: str
    structure: str = "mqr"
    backend: str = "serve"
    precision: str = "float32"
    build: Optional[str] = None        # pyramid-only: "host" | "device"
    levels: Optional[int] = None       # pyramid-only
    max_entries: Optional[int] = None  # rtree-only
    capacity: Optional[int] = None     # delta-buffer slots (DESIGN.md §8)
    merge: Optional[dict] = None       # MergePolicy kwargs
    admission: str = "merge"           # mutation admission (DESIGN.md §9)
    durable_root: Optional[str] = None
    query_block: Optional[int] = None  # override the server-wide block
    backend_opts: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        from repro.index.api import ADMISSION_MODES, STRUCTURES
        from repro.index.registry import backend_names

        if not self.name:
            raise ValueError("tenant name must be non-empty")
        if self.structure not in STRUCTURES:
            raise ValueError(
                f"tenant {self.name!r}: unknown structure {self.structure!r}; "
                f"expected one of {STRUCTURES}"
            )
        if self.backend not in backend_names():
            raise ValueError(
                f"tenant {self.name!r}: unknown backend {self.backend!r}; "
                f"registered: {backend_names()}"
            )
        if self.precision not in ("float32", "compact"):
            raise ValueError(
                f"tenant {self.name!r}: unknown precision {self.precision!r}"
            )
        if self.admission not in ADMISSION_MODES:
            raise ValueError(
                f"tenant {self.name!r}: unknown admission {self.admission!r}; "
                f"expected one of {ADMISSION_MODES}"
            )

    @classmethod
    def from_dict(cls, d: dict) -> "TenantConfig":
        return _typed(cls, d)

    def index_opts(self, server_query_block: int) -> dict:
        """The ``SpatialIndex.build`` keyword set this config declares."""
        opts = dict(self.backend_opts)
        opts["structure"] = self.structure
        opts["backend"] = self.backend
        if self.backend in ("pallas", "serve"):
            opts.setdefault("precision", self.precision)
        if self.backend == "serve":
            opts.setdefault(
                "query_block",
                self.query_block if self.query_block is not None
                else server_query_block,
            )
        for k in ("build", "levels", "max_entries", "capacity", "merge"):
            v = getattr(self, k)
            if v is not None:
                opts[k] = v
        if self.capacity is not None or self.merge is not None:
            opts.setdefault("admission", self.admission)
        return opts


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """The whole front end, declaratively.

    tenants:     the tenant stacks (at least one; names unique).
    classes:     SLO admission classes (names unique; the first is the
                 default class for requests that don't name one).
    query_block: coalesced-batch size — matched to the serving kernel's
                 query block so padded launches stay shape-stable.
    slack_margin_ms: safety margin subtracted from deadline slack when
                 deciding that a partial batch must launch NOW.
    """

    tenants: Tuple[TenantConfig, ...]
    classes: Tuple[SLOClass, ...] = DEFAULT_SLO_CLASSES
    query_block: int = 16
    slack_margin_ms: float = 1.0

    def __post_init__(self):
        if not self.tenants:
            raise ValueError("ServerConfig needs at least one tenant")
        for field, items in (("tenant", self.tenants), ("class", self.classes)):
            names = [x.name for x in items]
            if len(set(names)) != len(names):
                raise ValueError(f"duplicate {field} names: {names}")
        if self.query_block < 1:
            raise ValueError("query_block must be >= 1")

    @classmethod
    def from_dict(cls, d: dict) -> "ServerConfig":
        d = dict(d)
        tenants = tuple(
            t if isinstance(t, TenantConfig) else TenantConfig.from_dict(t)
            for t in d.pop("tenants", ())
        )
        classes = d.pop("classes", None)
        if classes is None:
            classes = DEFAULT_SLO_CLASSES
        else:
            classes = tuple(
                c if isinstance(c, SLOClass) else _typed(SLOClass, c)
                for c in classes
            )
        return _typed(
            cls, dict(d, tenants=tenants, classes=classes)
        )

    def slo_class(self, name: Optional[str]) -> SLOClass:
        if name is None:
            return self.classes[0]
        for c in self.classes:
            if c.name == name:
                return c
        raise ValueError(
            f"unknown SLO class {name!r}; declared: "
            f"{[c.name for c in self.classes]}"
        )
