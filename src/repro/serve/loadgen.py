"""Open-loop load generation: latency-under-load for the front end.

Arrivals follow a Poisson process at the OFFERED rate — the generator
never waits for an answer before sending the next request, and each
request's latency is measured from its *scheduled* arrival time, not
from when the driver got around to submitting it.  A closed-loop driver
(send, wait, send) silently stops offering load exactly when the server
slows down, hiding the tail; the open-loop clock keeps the pressure
honest (the classic coordinated-omission trap).

:func:`run_sweep` drives one fresh front end per offered-QPS level and
returns one row per level — p50/p99/p99.9 completion latency, shed and
queued counts, achieved throughput — which :func:`write_bench_rows`
merges into the repo's ``BENCH_<date>.json`` trajectory file.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from .frontend import ServingFrontEnd


def poisson_arrivals(qps: float, duration: float, *, seed: int = 0
                     ) -> np.ndarray:
    """Arrival offsets (seconds from start) of a Poisson process at
    ``qps`` over ``duration`` — exponential inter-arrival gaps."""
    if qps <= 0:
        return np.zeros((0,), np.float64)
    rng = np.random.default_rng(seed)
    # mean count + 4 sigma, then clip to the window
    n = int(qps * duration + 4 * np.sqrt(qps * duration)) + 8
    gaps = rng.exponential(1.0 / qps, size=n)
    t = np.cumsum(gaps)
    return t[t < duration]


def rect_workload(extent, n: int, *, seed: int = 0,
                  sel: float = 0.05) -> np.ndarray:
    """(n, 4) valid query rects covering ≈``sel`` of ``extent`` each."""
    lo = np.asarray(extent[:2], np.float64)
    hi = np.asarray(extent[2:], np.float64)
    span = np.maximum(hi - lo, 1e-6)
    rng = np.random.default_rng(seed)
    side = span * np.sqrt(sel)
    c = lo + rng.random((n, 2)) * (span - side)
    return np.concatenate([c, c + side], axis=1).astype(np.float32)


def data_extent(mbrs) -> np.ndarray:
    m = np.asarray(mbrs, np.float64)
    return np.concatenate([m[:, :2].min(axis=0), m[:, 2:].max(axis=0)])


def run_load(front: ServingFrontEnd, tenant: str, queries: np.ndarray,
             arrivals: np.ndarray, *, kind: str = "region",
             knn_k: int = 8, knn_every: int = 0,
             slo: Optional[str] = None) -> Dict[str, float]:
    """Drive one open-loop run; returns the telemetry snapshot plus
    offered/achieved QPS.

    ``knn_every=n`` turns every n-th request into a knn at the query
    rect's lower corner, exercising the second coalescing group under
    the same arrival process.
    """
    clock = front.clock
    start = clock()
    n = len(arrivals)
    for i in range(n):
        target = start + float(arrivals[i])
        # pump while waiting out the gap — this IS the serving loop
        while True:
            now = clock()
            if now >= target:
                break
            if not front.pump():
                time.sleep(min(target - now, 1e-4))
        q = queries[i % len(queries)]
        if knn_every and (i % knn_every) == knn_every - 1:
            front.submit(tenant, "knn", q[:2], k=knn_k, slo=slo,
                         t_arrival=target)
        else:
            front.submit(tenant, kind, q, slo=slo, t_arrival=target)
        front.pump()
    front.drain()
    elapsed = clock() - start
    row = front.telemetry.snapshot()
    row["qps_offered"] = n / max(arrivals[-1], 1e-9) if n else 0.0
    row["qps_achieved"] = row["completed"] / max(elapsed, 1e-9)
    row["duration_s"] = elapsed
    return row


def run_sweep(make_front: Callable[[], "tuple[ServingFrontEnd, str]"],
              qps_levels: Sequence[float], *, duration: float = 2.0,
              seed: int = 0, sel: float = 0.05, knn_every: int = 0,
              knn_k: int = 8) -> List[Dict[str, float]]:
    """One row per offered-QPS level, each on a FRESH front end (fresh
    telemetry, fresh queues) so levels can't contaminate each other.
    ``make_front`` returns ``(front, tenant_name)``; the front is warmed
    up before timing so jit lowering never lands in the latency curve."""
    rows = []
    for li, qps in enumerate(qps_levels):
        front, tenant = make_front()
        front.warmup(knn_k=knn_k if knn_every else None)
        extent = data_extent(front.tenants[tenant].spatial.artifacts.mbrs)
        arrivals = poisson_arrivals(qps, duration, seed=seed + li)
        queries = rect_workload(
            extent, max(len(arrivals), 1), seed=seed + 1000 + li, sel=sel
        )
        row = run_load(front, tenant, queries, arrivals,
                       knn_every=knn_every, knn_k=knn_k)
        row["qps_level"] = float(qps)
        rows.append(row)
    return rows


def write_bench_rows(rows: Sequence[Dict[str, float]], root: str,
                     *, name: str = "serving") -> str:
    """Merge sweep rows into ``BENCH_<UTC-date>.json`` at ``root``,
    preserving rows other benches already wrote today (the harness in
    benchmarks/run.py owns the file format: name / us_per_call /
    derived).  Each level gets its own row, ``<name>_qps<level>``, so
    the latency-vs-load curve stays legible in the trajectory file."""
    date = time.strftime("%Y-%m-%d", time.gmtime())
    path = os.path.join(root, f"BENCH_{date}.json")
    doc = {"date": date, "rows": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    doc["rows"] = [r for r in doc["rows"]
                   if not str(r.get("name", "")).startswith(f"{name}_qps")]
    for row in rows:
        level = int(round(row.get("qps_level", row.get("qps_offered", 0))))
        doc["rows"].append({
            "name": f"{name}_qps{level}",
            "us_per_call": row.get("mean_ms", 0.0) * 1e3,
            "derived": dict(row),
        })
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=float)
        f.write("\n")
    return path
