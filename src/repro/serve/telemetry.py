"""Streaming latency telemetry for the serving front end (DESIGN.md §11).

Latency is tracked in log-spaced histogram buckets (constant relative
error, O(1) memory, O(buckets) quantile reads), NOT by storing samples —
the front end is sized for open-loop load sweeps where millions of
requests would otherwise accumulate.  Each request carries three
timestamps (enqueue → launch → complete); the queue-wait and service
split is derivable, and the headline numbers are the tail quantiles the
"millions of users" claim needs: p50 / p99 / p99.9 completion latency
versus offered load.

Shed / queued / degradation counters fold into the same per-tenant
:class:`repro.index.AccessStats` ledger every other layer reports
through (``shed_queries`` / ``queued_queries`` / ``degraded_batches``),
so one stats object describes a tenant end to end.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional


class LatencyHistogram:
    """Log-bucketed streaming latency histogram (seconds in, quantiles out).

    Buckets grow geometrically from ``lo`` to ``hi`` by ``growth`` (≈7%
    relative resolution by default); samples clamp into the edge buckets.
    Quantiles report the geometric midpoint of the covering bucket, so a
    quantile is never off by more than one growth factor.
    """

    def __init__(self, lo: float = 1e-5, hi: float = 120.0,
                 growth: float = 1.07):
        self.lo = float(lo)
        self.growth = float(growth)
        self._log_g = math.log(growth)
        self.n_buckets = int(math.ceil(math.log(hi / lo) / self._log_g)) + 1
        self.counts = [0] * self.n_buckets
        self.n = 0
        self.total = 0.0
        self.max = 0.0

    def record(self, seconds: float) -> None:
        v = max(float(seconds), 0.0)
        self.n += 1
        self.total += v
        self.max = max(self.max, v)
        if v <= self.lo:
            idx = 0
        else:
            idx = min(
                int(math.log(v / self.lo) / self._log_g) + 1,
                self.n_buckets - 1,
            )
        self.counts[idx] += 1

    def quantile(self, q: float) -> float:
        """The ``q``-quantile in seconds (0 when empty).  ``q`` is
        clamped: ``q <= 0`` reads the lowest occupied bucket, ``q >= 1``
        returns the exact observed maximum (the midpoint estimate of the
        top bucket could otherwise exceed every recorded sample)."""
        if self.n == 0:
            return 0.0
        if q >= 1.0:
            return self.max
        rank = max(q, 0.0) * (self.n - 1)
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen > rank:
                if i == 0:
                    return self.lo
                # geometric midpoint of bucket i: [lo*g^(i-1), lo*g^i)
                return self.lo * self.growth ** (i - 0.5)
        return self.max  # pragma: no cover — rank always covered above

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Fold ``other``'s samples into this histogram (in place; used
        by the metrics registry to aggregate across tenants/classes).
        Bucket layouts must match — these are log-bucket counts, not
        samples, so incompatible layouts cannot be re-binned."""
        if (self.lo, self.growth, self.n_buckets) != (
                other.lo, other.growth, other.n_buckets):
            raise ValueError(
                "cannot merge histograms with different bucket layouts: "
                f"(lo={self.lo}, growth={self.growth}, "
                f"n_buckets={self.n_buckets}) vs (lo={other.lo}, "
                f"growth={other.growth}, n_buckets={other.n_buckets})"
            )
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.n += other.n
        self.total += other.total
        self.max = max(self.max, other.max)
        return self

    def to_dict(self) -> Dict[str, object]:
        """JSON-friendly snapshot: layout + sparse non-zero buckets."""
        return {
            "lo": self.lo,
            "growth": self.growth,
            "n_buckets": self.n_buckets,
            "n": self.n,
            "total": self.total,
            "max": self.max,
            "counts": {i: c for i, c in enumerate(self.counts) if c},
        }

    @property
    def mean(self) -> float:
        return self.total / max(self.n, 1)

    def quantiles_ms(self) -> Dict[str, float]:
        return {
            "p50_ms": self.quantile(0.50) * 1e3,
            "p99_ms": self.quantile(0.99) * 1e3,
            "p999_ms": self.quantile(0.999) * 1e3,
        }


@dataclasses.dataclass
class ServeTelemetry:
    """Front-end counters + per-class latency histograms.

    One instance per :class:`~repro.serve.frontend.ServingFrontEnd`;
    ``snapshot()`` is the flat dict the load generator turns into
    ``BENCH_<date>.json`` rows.
    """

    submitted: int = 0
    completed: int = 0
    rejected: int = 0          # invalid geometry, refused at the boundary
    shed: int = 0              # admission control dropped (overload)
    queued_overload: int = 0   # admitted past max_queue, parked best-effort
    slo_violations: int = 0    # completed after the class deadline
    batches: int = 0           # coalesced batches launched
    batched_requests: int = 0  # requests inside those batches
    deadline_launches: int = 0 # batches launched by deadline slack, not size
    latency: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram
    )
    queue_wait: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram
    )
    by_class: Dict[str, LatencyHistogram] = dataclasses.field(
        default_factory=dict
    )
    by_tenant: Dict[str, LatencyHistogram] = dataclasses.field(
        default_factory=dict
    )

    def observe(self, req, cls_deadline_s: float) -> None:
        """Fold one completed request's timeline into the histograms."""
        self.completed += 1
        lat = req.t_complete - req.t_arrival
        self.latency.record(lat)
        self.queue_wait.record(req.t_launch - req.t_arrival)
        self.by_class.setdefault(req.slo_class, LatencyHistogram()).record(lat)
        self.by_tenant.setdefault(req.tenant, LatencyHistogram()).record(lat)
        if lat > cls_deadline_s:
            self.slo_violations += 1

    @property
    def avg_batch(self) -> float:
        return self.batched_requests / max(self.batches, 1)

    def snapshot(self) -> Dict[str, float]:
        out = {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "shed": self.shed,
            "queued_overload": self.queued_overload,
            "slo_violations": self.slo_violations,
            "batches": self.batches,
            "deadline_launches": self.deadline_launches,
            "avg_batch": round(self.avg_batch, 2),
            "mean_ms": self.latency.mean * 1e3,
            "queue_wait_p99_ms": self.queue_wait.quantile(0.99) * 1e3,
        }
        out.update(self.latency.quantiles_ms())
        return out


@dataclasses.dataclass(frozen=True)
class RequestTimeline:
    """The three timestamps every served request carries (seconds on the
    front end's clock): scheduled arrival/enqueue, batch launch, and
    completion.  Exposed for tests and offline analysis."""

    t_arrival: float
    t_launch: Optional[float]
    t_complete: Optional[float]

    @property
    def queue_wait(self) -> Optional[float]:
        if self.t_launch is None:
            return None
        return self.t_launch - self.t_arrival

    @property
    def latency(self) -> Optional[float]:
        if self.t_complete is None:
            return None
        return self.t_complete - self.t_arrival
