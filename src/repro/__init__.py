"""repro — mqr-tree (Moreau & Osborn 2012) on TPU.

The top-level package lazily re-exports the unified index façade so
``from repro import SpatialIndex`` works without importing JAX at package
import time (subpackages remain importable directly as before).
"""

_INDEX_EXPORTS = (
    "SpatialIndex",
    "RegionResult",
    "KNNResult",
    "AccessStats",
    "MergePolicy",
    "advertised_pairs",
)

_SERVE_EXPORTS = (
    "ServingFrontEnd",
    "ServerConfig",
)


def __getattr__(name):
    if name in _INDEX_EXPORTS:
        from repro import index as _index

        return getattr(_index, name)
    if name in _SERVE_EXPORTS:
        from repro import serve as _serve

        return getattr(_serve, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(
        list(globals()) + list(_INDEX_EXPORTS) + list(_SERVE_EXPORTS)
    )
