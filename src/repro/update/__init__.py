"""Live-update subsystem: delta buffer, tombstone deletes, merge policy.

Layered over the frozen build artifacts so ``SpatialIndex.insert`` /
``.delete`` / ``.flush`` absorb online mutations without a rebuild per
operation, while every backend's query results stay bit-identical to the
host mqr insertion-rule oracle (DESIGN.md §8).
"""

from .buffer import AugmentedArrays, BufferFullError, UpdateLog
from .policy import DEFAULT_CAPACITY, MergePolicy, as_policy
from .wal import WriteAheadLog, read_wal, recover_wal, repair_wal

__all__ = [
    "AugmentedArrays",
    "BufferFullError",
    "UpdateLog",
    "MergePolicy",
    "as_policy",
    "DEFAULT_CAPACITY",
    "WriteAheadLog",
    "read_wal",
    "recover_wal",
    "repair_wal",
]
