"""Live-update state over a frozen base build: delta buffer + tombstones.

The paper's insertion strategy is per-object and pointer-chasing; the
device pipeline's unit of work is a whole build.  :class:`UpdateLog`
bridges the two the way LSM-ish spatial systems do (DESIGN.md §8):

* **delta buffer** — a fixed-capacity block of MBR rows + validity mask.
  Inserts land in free slots at O(1); the fused sweep scans the buffer as
  appended FLAT levels of the same ``pallas_call`` that walks the base
  ``LevelSchedule`` (``uncond_from`` in :mod:`repro.kernels.pyramid_scan`).
* **tombstones** — deletes mark an id dead in the ``alive`` bitmap; base
  slots keep streaming through the sweep and are masked in the epilogue,
  delta slots are freed in place.
* **merge** — :meth:`flush` compacts the live set (base survivors + valid
  delta rows, ascending global id = insertion order) into a fresh base
  build via the same build path the index was created with, resetting the
  buffer and tombstones.  :class:`repro.update.policy.MergePolicy` decides
  when this happens automatically.

Object ids are GLOBAL and append-only: the base build's objects keep ids
``0..n-1``, every insert gets the next id, deletes never recycle ids, and
a flush preserves them — so hit masks are comparable across mutations and
bit-identical pre/post merge.  The id space is padded to ``id_capacity``
(grown only at flush) so jit shapes stay fixed between merges.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.flat import NEVER_MBR, _overlaps
from repro.obs import trace as _obs_trace

from .policy import MergePolicy


class BufferFullError(RuntimeError):
    """The delta buffer (or its id headroom) cannot absorb a batch and
    the merge policy forbids compacting implicitly (``auto=False``).
    Typed so admission control can shed/queue instead of failing the
    request opaquely (DESIGN.md §9)."""


@dataclasses.dataclass(frozen=True)
class AugmentedArrays:
    """Array bundle for the live fused sweep: base levels + delta levels.

    ``arrays`` are the positional arguments of
    :func:`repro.kernels.ops.fused_search_live` (``precision="float32"``)
    or :func:`repro.kernels.ops.fused_search_compact_live` (``"compact"``)
    after ``queries``; ``statics`` are their static keyword arguments.
    One bundle is built per (mutation epoch × precision) and shared by
    every engine over the same log — the pallas path and the serve path
    stream identical bytes.
    """

    precision: str
    arrays: Tuple
    statics: dict
    levels: int        # total grid levels, base + delta
    base_levels: int
    n_objects: int     # id-space width of the hit mask


class UpdateLog:
    """Shared mutable live-update state (one per logical index).

    ``rebuild`` is the frozen-base build recipe — called with the live
    (n, 4) float64 MBRs at every merge, it must return a fresh
    ``BuildArtifacts``-shaped object (``.schedule`` / ``.quantized`` /
    ``.mbrs`` / ``.n_objects``).  Keeping it a callable keeps this module
    free of façade imports.
    """

    def __init__(self, artifacts, policy: MergePolicy,
                 rebuild: Callable[[np.ndarray], object]):
        self.policy = policy
        self.capacity = int(policy.capacity)
        self._rebuild = rebuild
        self.base = artifacts
        n = int(artifacts.n_objects)
        self.base_gids = np.arange(n, dtype=np.int64)
        self.next_gid = n
        self.id_capacity = n + self.capacity
        self.alive = np.zeros((self.id_capacity,), bool)
        self.alive[:n] = True
        self.mbr_table = np.zeros((self.id_capacity, 4), np.float64)
        self.mbr_table[:n] = np.asarray(artifacts.mbrs, np.float64)
        self.delta_mbrs = np.zeros((self.capacity, 4), np.float64)
        self.delta_gids = np.zeros((self.capacity,), np.int64)
        self.delta_valid = np.zeros((self.capacity,), bool)
        self._slot_of: Dict[int, int] = {}
        self._free = list(range(self.capacity - 1, -1, -1))
        self.dead_base = 0
        self.epoch = 0        # bumps on every mutation
        self.base_epoch = 0   # bumps on every merge (base arrays replaced)
        self.flushes = 0
        # fault-injection hook (repro.ft.FaultPlan): lets the harness
        # stretch merges / kill mid-merge (DESIGN.md §9); None in prod.
        self.fault_plan = None
        self._aug: Dict[str, Tuple[int, AugmentedArrays]] = {}
        self._oracle: Optional[Tuple[int, object]] = None

    # -- introspection --------------------------------------------------
    @property
    def n_base(self) -> int:
        return int(self.base_gids.shape[0])

    @property
    def n_delta(self) -> int:
        return self.capacity - len(self._free)

    @property
    def n_live(self) -> int:
        return int(self.alive.sum())

    @property
    def fill(self) -> float:
        return self.n_delta / self.capacity

    @property
    def tombstone_ratio(self) -> float:
        return self.dead_base / max(self.n_base, 1)

    @property
    def pending(self) -> bool:
        """Anything buffered that a merge would fold in?"""
        return self.n_delta > 0 or self.dead_base > 0

    # -- mutation -------------------------------------------------------
    def can_buffer(self, n: int) -> bool:
        """Room for ``n`` more inserts without merging?  Checks both free
        slots and id-space headroom (freed slots can be reused faster
        than ids, which never recycle)."""
        return len(self._free) >= n and self.next_gid + n <= self.id_capacity

    def buffer_insert(self, mbrs: np.ndarray) -> np.ndarray:
        """Place ``mbrs`` (n, 4) into free delta slots; returns their new
        global ids.  Caller must have checked :meth:`can_buffer`."""
        mbrs = np.asarray(mbrs, np.float64).reshape(-1, 4)
        n = mbrs.shape[0]
        if not self.can_buffer(n):
            raise BufferFullError(
                f"delta buffer cannot absorb {n} inserts "
                f"({len(self._free)} free slots, "
                f"{self.id_capacity - self.next_gid} ids) — flush first"
            )
        gids = np.arange(self.next_gid, self.next_gid + n, dtype=np.int64)
        self.next_gid += n
        for g, m in zip(gids, mbrs):
            s = self._free.pop()
            self.delta_mbrs[s] = m
            self.delta_gids[s] = g
            self.delta_valid[s] = True
            self._slot_of[int(g)] = s
        self.alive[gids] = True
        self.mbr_table[gids] = mbrs
        self.epoch += 1
        return gids

    def delete(self, gids) -> np.ndarray:
        """Tombstone the given live object ids.

        Base ids stay physically in the frozen build (masked in the scan
        epilogue until the next merge); delta ids free their slot in
        place.  A dead, unknown, or duplicated id raises ``KeyError``
        before anything is mutated.
        """
        gids = np.asarray(gids, np.int64).reshape(-1)
        if gids.size == 0:  # no mutation, no epoch bump
            return gids
        uniq, counts = np.unique(gids, return_counts=True)
        if (counts > 1).any():
            raise KeyError(
                f"duplicate id(s) in delete batch: {uniq[counts > 1].tolist()}"
            )
        bad = uniq[(uniq < 0) | (uniq >= self.next_gid)]
        if bad.size == 0:
            bad = uniq[~self.alive[uniq]]
        if bad.size:
            raise KeyError(f"object id(s) not live: {bad.tolist()}")
        for g in gids:
            g = int(g)
            self.alive[g] = False
            s = self._slot_of.pop(g, None)
            if s is None:
                self.dead_base += 1
            else:
                self.delta_valid[s] = False
                self.delta_mbrs[s] = 0.0
                self.delta_gids[s] = 0
                self._free.append(s)
        self.epoch += 1
        return gids

    def flush(self, force: bool = False) -> bool:
        """Compact buffer + tombstones into a fresh base build.

        No-op (returns False) when nothing is pending unless ``force``.
        """
        if not self.pending and not force:
            return False
        self._merge(extra_mbrs=None)
        return True

    def merge_insert(self, mbrs: np.ndarray) -> np.ndarray:
        """Oversized-batch path: fold ``mbrs`` straight into the merge,
        bypassing the buffer entirely; returns their new global ids."""
        mbrs = np.asarray(mbrs, np.float64).reshape(-1, 4)
        return self._merge(extra_mbrs=mbrs)

    def _merge(self, extra_mbrs: Optional[np.ndarray]) -> np.ndarray:
        extra = 0 if extra_mbrs is None else int(extra_mbrs.shape[0])
        with _obs_trace.span("update.merge", extra=extra,
                             epoch=self.base_epoch):
            return self._merge_impl(extra_mbrs)

    def _merge_impl(self, extra_mbrs: Optional[np.ndarray]) -> np.ndarray:
        if extra_mbrs is not None and extra_mbrs.shape[0]:
            b = extra_mbrs.shape[0]
            extra_gids = np.arange(self.next_gid, self.next_gid + b,
                                   dtype=np.int64)
            self.next_gid += b
        else:
            extra_gids = np.zeros((0,), np.int64)
        new_id_capacity = max(self.id_capacity, self.next_gid + self.capacity)
        if new_id_capacity > self.id_capacity:
            alive = np.zeros((new_id_capacity,), bool)
            alive[: self.id_capacity] = self.alive
            table = np.zeros((new_id_capacity, 4), np.float64)
            table[: self.id_capacity] = self.mbr_table
            self.alive, self.mbr_table = alive, table
            self.id_capacity = new_id_capacity
        if extra_gids.size:
            self.alive[extra_gids] = True
            self.mbr_table[extra_gids] = extra_mbrs
        live = np.nonzero(self.alive)[0]
        if live.size == 0:
            raise ValueError(
                "cannot merge an index with no live objects; re-insert "
                "before flushing or keep the deletes buffered"
            )
        # Ascending global id == original insertion order: the canonical
        # order the host mqr-insertion oracle also uses.
        if self.fault_plan is not None:
            # Mid-merge fault window: the WAL record for the triggering
            # op is durable but the compaction has not replaced the base
            # yet — a kill here must recover by re-running the merge.
            self.fault_plan.merge_event()
        self.base = self._rebuild(self.mbr_table[live])
        self.base_gids = live.astype(np.int64)
        self.delta_mbrs[:] = 0.0
        self.delta_gids[:] = 0
        self.delta_valid[:] = False
        self._slot_of.clear()
        self._free = list(range(self.capacity - 1, -1, -1))
        self.dead_base = 0
        self.base_epoch += 1
        self.epoch += 1
        self.flushes += 1
        self._aug.clear()
        self._oracle = None
        return extra_gids

    def snapshot(self) -> "UpdateLog":
        """Independent copy sharing only the frozen base artifacts —
        what ``SpatialIndex.extend`` mutates so the source index stays
        untouched."""
        new = UpdateLog.__new__(UpdateLog)
        new.policy = self.policy
        new.capacity = self.capacity
        new._rebuild = self._rebuild
        new.base = self.base
        new.base_gids = self.base_gids.copy()
        new.next_gid = self.next_gid
        new.id_capacity = self.id_capacity
        new.alive = self.alive.copy()
        new.mbr_table = self.mbr_table.copy()
        new.delta_mbrs = self.delta_mbrs.copy()
        new.delta_gids = self.delta_gids.copy()
        new.delta_valid = self.delta_valid.copy()
        new._slot_of = dict(self._slot_of)
        new._free = list(self._free)
        new.dead_base = self.dead_base
        new.epoch = self.epoch
        new.base_epoch = self.base_epoch
        new.flushes = self.flushes
        new.fault_plan = self.fault_plan
        new._aug = {}
        new._oracle = None
        return new

    # -- durability (DESIGN.md §9) --------------------------------------
    def state_arrays(self) -> Dict[str, np.ndarray]:
        """The complete mutable state as named arrays, for the index
        snapshot (:mod:`repro.checkpoint.spatial`).  ``base`` itself is
        snapshotted by the caller (it owns the schedule arrays)."""
        return {
            "base_gids": self.base_gids,
            "alive": self.alive,
            "mbr_table": self.mbr_table,
            "delta_mbrs": self.delta_mbrs,
            "delta_gids": self.delta_gids,
            "delta_valid": self.delta_valid,
            "free": np.asarray(self._free, np.int64),
        }

    def state_scalars(self) -> Dict[str, int]:
        return {
            "capacity": self.capacity,
            "next_gid": int(self.next_gid),
            "id_capacity": int(self.id_capacity),
            "dead_base": int(self.dead_base),
            "epoch": int(self.epoch),
            "base_epoch": int(self.base_epoch),
            "flushes": int(self.flushes),
        }

    @classmethod
    def restore(cls, artifacts, policy: MergePolicy, rebuild,
                arrays: Dict[str, np.ndarray],
                scalars: Dict[str, int]) -> "UpdateLog":
        """Rebuild an :class:`UpdateLog` from snapshot state — the exact
        inverse of :meth:`state_arrays`/:meth:`state_scalars`, restoring
        slot layout (including free-slot order) bit-for-bit so replayed
        mutations land exactly where they would have pre-crash."""
        new = cls.__new__(cls)
        new.policy = policy
        new.capacity = int(scalars["capacity"])
        new._rebuild = rebuild
        new.base = artifacts
        new.base_gids = np.asarray(arrays["base_gids"], np.int64).copy()
        new.next_gid = int(scalars["next_gid"])
        new.id_capacity = int(scalars["id_capacity"])
        new.alive = np.asarray(arrays["alive"], bool).copy()
        new.mbr_table = np.asarray(arrays["mbr_table"], np.float64).copy()
        new.delta_mbrs = np.asarray(arrays["delta_mbrs"], np.float64).copy()
        new.delta_gids = np.asarray(arrays["delta_gids"], np.int64).copy()
        new.delta_valid = np.asarray(arrays["delta_valid"], bool).copy()
        new._slot_of = {
            int(g): int(s)
            for s, g in enumerate(new.delta_gids)
            if new.delta_valid[s]
        }
        new._free = [int(s) for s in np.asarray(arrays["free"], np.int64)]
        new.dead_base = int(scalars["dead_base"])
        new.epoch = int(scalars["epoch"])
        new.base_epoch = int(scalars["base_epoch"])
        new.flushes = int(scalars["flushes"])
        new.fault_plan = None
        new._aug = {}
        new._oracle = None
        return new

    # -- query-side lowerings ------------------------------------------
    def delta_dense_f32(self) -> np.ndarray:
        """(capacity, 4) float32 delta rows; empty slots carry the
        never-overlap sentinel, so they vanish from sweeps and counts."""
        return np.where(
            self.delta_valid[:, None], self.delta_mbrs, NEVER_MBR[None, :]
        ).astype(np.float32)

    def delta_id_mask(self) -> np.ndarray:
        """(id_capacity,) bool — global ids currently living in the delta
        buffer.  The join path (DESIGN.md §10) treats every pair touching
        one of these rows as a structure-sweep candidate (a flat cross-
        scan: the buffer is O(capacity) rows, so the exact confirming
        pass is the whole cost anyway)."""
        mask = np.zeros((self.id_capacity,), bool)
        if self.delta_valid.any():
            mask[self.delta_gids[self.delta_valid]] = True
        return mask

    def _delta_geometry(self):
        """Tile the capacity across flat levels of the base width."""
        w = self.base.schedule.width
        d = max(1, math.ceil(self.capacity / w))
        return w, d, d * w

    def augmented(self, precision: str = "float32") -> AugmentedArrays:
        """The live sweep's arrays for this epoch (cached per precision):
        base schedule levels + the delta buffer as flat levels, object
        table remapped to global ids, ``alive`` tombstone mask."""
        cached = self._aug.get(precision)
        if cached is not None and cached[0] == self.epoch:
            return cached[1]
        sched = self.base.schedule
        levels, width = sched.levels, sched.width
        w, d, s = self._delta_geometry()
        assert w == width
        dm = self.delta_dense_f32()                                # (C, 4)
        dall = np.concatenate(
            [dm, np.broadcast_to(NEVER_MBR, (s - self.capacity, 4))], axis=0
        )                                                          # (S, 4)
        delta_cm = np.ascontiguousarray(
            dall.reshape(d, w, 4).transpose(0, 2, 1)
        )                                                          # (D, 4, W)
        slot = np.arange(self.capacity, dtype=np.int32)
        obj_level = np.concatenate(
            [sched.obj_level, levels + slot // w]
        ).astype(np.int32)
        obj_slot = np.concatenate([sched.obj_slot, slot % w]).astype(np.int32)
        # Empty slots point at id 0 but their sentinel MBR never activates.
        obj_id = np.concatenate(
            [
                self.base_gids[sched.obj_id],
                np.where(self.delta_valid, self.delta_gids, 0),
            ]
        ).astype(np.int32)
        alive = self.alive.copy()
        statics = dict(
            n_objects=self.id_capacity,
            base_levels=levels,
            root_unconditional=sched.root_unconditional,
        )
        # The live contract is PER-OBJECT exactness (bit-parity with the
        # mqr insertion oracle), so every hit is confirmed against the
        # entry's own MBR.  For tree schedules that is the existing rule;
        # for pyramid schedules it tightens the group semantics — when
        # the bulk fixed point leaves several objects sharing their
        # deepest group, the group's union MBR would otherwise leak
        # false-positive hits into the live id space.  By MBR nesting the
        # object test subsumes the exact ancestor chain, so no true hit
        # is ever dropped.
        if precision == "float32":
            mbr_cm = np.concatenate([sched.mbr_cm, delta_cm], axis=0)
            parent = np.concatenate(
                [sched.parent, np.zeros((d, w), sched.parent.dtype)], axis=0
            )
            obj_mbr = np.concatenate([sched.obj_mbr, dm], axis=0)
            arrays = (mbr_cm, parent, obj_mbr, obj_level, obj_slot, obj_id,
                      alive)
            statics["test_object_mbr"] = True
        elif precision == "compact":
            from repro.kernels import ops

            qs = self.base.quantized
            dq = ops.quantize_rows(dall, qs.origin, qs.inv_cell)   # (S, 4)
            delta_q = np.ascontiguousarray(
                dq.reshape(d, w, 4).transpose(0, 2, 1)
            )
            mbr_q = np.concatenate([np.asarray(qs.mbr_q), delta_q], axis=0)
            parent_q = np.concatenate(
                [qs.parent_q, np.zeros((d, w), qs.parent_q.dtype)], axis=0
            )
            # confirm against the object MBR itself (not the deepest
            # group) — per-object exactness, see above
            confirm = np.concatenate(
                [np.asarray(sched.obj_mbr, np.float32), dm], axis=0
            )
            arrays = (mbr_q, parent_q, confirm, obj_level, obj_slot, obj_id,
                      qs.origin, qs.inv_cell, alive)
            statics["cells"] = qs.cells
        else:
            raise ValueError(f"unknown precision {precision!r}")
        aug = AugmentedArrays(
            precision=precision,
            arrays=arrays,
            statics=statics,
            levels=levels + d,
            base_levels=levels,
            n_objects=self.id_capacity,
        )
        self._aug[precision] = (self.epoch, aug)
        return aug

    def compose(self, hits_pos: np.ndarray, visits: np.ndarray,
                queries: np.ndarray):
        """Lift a POSITIONAL base result into the live global-id space —
        the host/lax composition path: scatter base hits to global ids,
        overlay the delta-buffer scan, mask tombstones, and append the
        delta visit columns (same counts as the fused delta levels)."""
        queries = np.asarray(queries, np.float32)
        nq = queries.shape[0]
        hits = np.zeros((nq, max(self.id_capacity, 1)), bool)
        hits[:, self.base_gids] = hits_pos[:, : self.n_base]
        dm = self.delta_dense_f32()
        ov = _overlaps(dm[None, :, :], queries[:, None, :])        # (Q, C)
        if self.delta_valid.any():
            valid = self.delta_valid
            hits[:, self.delta_gids[valid]] = ov[:, valid]
        hits &= self.alive[None, :]
        # Per-object confirming pass, mirroring the fused live epilogue:
        # structure candidates ∧ exact object-MBR overlap (f32, the
        # device convention) — pyramid group-union semantics never leak.
        table = self.mbr_table.astype(np.float32)
        hits &= _overlaps(table[None, :, :], queries[:, None, :])
        w, d, s = self._delta_geometry()
        ovp = np.concatenate(
            [ov, np.zeros((nq, s - self.capacity), bool)], axis=1
        )
        delta_visits = ovp.reshape(nq, d, w).sum(axis=2).astype(visits.dtype)
        return hits, np.concatenate([visits, delta_visits], axis=1)
