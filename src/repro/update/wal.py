"""Mutation write-ahead log for the durable spatial index (DESIGN.md §9).

Append-only binary file of mutation records.  Every ``insert`` /
``delete`` / ``flush`` is appended — and fsync'd — *before* the in-memory
/ on-device index state is touched, so a crash at any point loses at most
the op whose record never became durable.  Recovery replays the log over
the last snapshot; because the update subsystem is deterministic (global
ids, merge triggers, and rebuilds are pure functions of the op sequence),
replay reconstructs exactly the pre-crash live set.

On-disk layout::

    file   := MAGIC (8 bytes, b"MQRWAL01") record*
    record := u32 payload_len | u32 crc32(payload) | payload
    payload:= json-header \\x00 raw-array-bytes

The JSON header carries ``{op, seq, dtype, shape}``; the array bytes are
the op's operand (``(n, 4)`` float64 MBRs for insert, ``(n,)`` int64 ids
for delete, empty for flush).  All integers are little-endian.

A *torn tail* — a record whose bytes or checksum are incomplete because
the process died mid-append — is detected on replay and truncated away:
everything before it is trusted (each record's crc32 passed), everything
from it on is not.  A checksum failure anywhere therefore ends replay at
the last durable op, never yields garbage mutations.
"""

from __future__ import annotations

import io
import json
import os
import pathlib
import struct
import zlib
from typing import List, Tuple

import numpy as np

from repro.obs import trace as _obs_trace

MAGIC = b"MQRWAL01"
_HEAD = struct.Struct("<II")  # payload_len, crc32

OPS = ("insert", "delete", "flush")

_OP_DTYPE = {"insert": np.float64, "delete": np.int64, "flush": np.float64}
_OP_COLS = {"insert": 4, "delete": None, "flush": None}


class WalCorruption(RuntimeError):
    """The WAL prefix itself is unreadable (bad magic) — distinct from a
    torn tail, which is expected after a crash and repaired silently."""


def _encode(op: str, seq: int, arr: np.ndarray) -> bytes:
    header = json.dumps(
        {"op": op, "seq": seq, "dtype": str(arr.dtype), "shape": list(arr.shape)}
    ).encode()
    payload = header + b"\x00" + arr.tobytes()
    return _HEAD.pack(len(payload), zlib.crc32(payload)) + payload


def _decode(payload: bytes) -> Tuple[str, int, np.ndarray]:
    head, _, raw = payload.partition(b"\x00")
    meta = json.loads(head.decode())
    arr = np.frombuffer(raw, dtype=np.dtype(meta["dtype"])).reshape(
        meta["shape"]
    ).copy()
    return meta["op"], int(meta["seq"]), arr


def _coerce(op: str, arr) -> np.ndarray:
    if op not in OPS:
        raise ValueError(f"unknown WAL op {op!r}; expected one of {OPS}")
    dtype = _OP_DTYPE[op]
    if arr is None:
        arr = np.zeros((0, 4) if op == "insert" else (0,), dtype)
    arr = np.asarray(arr, dtype)
    return arr.reshape(-1, 4) if op == "insert" else arr.reshape(-1)


class WriteAheadLog:
    """One append-only mutation log (one per snapshot generation).

    sync=True fsyncs every append — the durability contract; tests and
    benchmarks may turn it off to measure the fsync tax.  ``fault_plan``
    (a :class:`repro.ft.FaultPlan`) lets the harness tear the in-flight
    record to simulate a kill mid-write.
    """

    def __init__(self, path, *, sync: bool = True, fault_plan=None):
        self.path = pathlib.Path(path)
        self.sync = sync
        self.fault_plan = fault_plan
        fresh = not self.path.exists() or self.path.stat().st_size == 0
        self._f = open(self.path, "ab")
        if fresh:
            self._f.write(MAGIC)
            self._flush()
        self.seq = 0 if fresh else len(read_wal(self.path)[0])

    # ------------------------------------------------------------------
    def append(self, op: str, arr=None) -> int:
        """Durably append one mutation record; returns its sequence
        number.  The record is on disk (fsync'd when ``sync``) before
        this returns — the caller then applies the op to live state."""
        with _obs_trace.span("wal.append", op=op, seq=self.seq,
                             sync=self.sync):
            arr = _coerce(op, arr)
            record = _encode(op, self.seq, arr)
            if self.fault_plan is not None and self.fault_plan.tear_now():
                # Simulated kill mid-write: half the record reaches the
                # disk, the process dies.  Replay must detect and drop
                # this tail.
                self._f.write(record[: max(len(record) // 2, 1)])
                self._flush()
                raise self.fault_plan.killed_mid_append()
            self._f.write(record)
            self._flush()
            self.seq += 1
            return self.seq - 1

    def _flush(self) -> None:
        self._f.flush()
        if self.sync:
            os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_wal(path) -> Tuple[List[Tuple[str, np.ndarray]], bool, int]:
    """Replay a WAL file.

    Returns ``(records, torn, n_valid)``: the decoded ``(op, operand)``
    list, whether a torn/corrupt tail was found after the valid prefix,
    and the byte offset of the end of the valid prefix (pass to
    :func:`repair_wal` to truncate the tail away).  A missing file reads
    as an empty log (the crash window between snapshot publish and WAL
    creation).
    """
    path = pathlib.Path(path)
    if not path.exists():
        return [], False, len(MAGIC)
    data = path.read_bytes()
    if len(data) < len(MAGIC):
        # the header itself was torn: an empty, repairable log
        return [], True, len(MAGIC)
    if data[: len(MAGIC)] != MAGIC:
        raise WalCorruption(f"{path}: bad WAL magic {data[:8]!r}")
    records: List[Tuple[str, np.ndarray]] = []
    off = len(MAGIC)
    expected_seq = 0
    buf = io.BytesIO(data)
    buf.seek(off)
    while True:
        head = buf.read(_HEAD.size)
        if len(head) == 0:
            return records, False, off  # clean EOF
        if len(head) < _HEAD.size:
            return records, True, off  # torn length/crc header
        length, crc = _HEAD.unpack(head)
        payload = buf.read(length)
        if len(payload) < length or zlib.crc32(payload) != crc:
            return records, True, off  # torn or corrupt payload
        try:
            op, seq, arr = _decode(payload)
        except Exception:
            return records, True, off  # checksum passed but undecodable
        if op not in OPS or seq != expected_seq:
            return records, True, off  # out-of-sequence tail: untrusted
        records.append((op, arr))
        expected_seq += 1
        off += _HEAD.size + length


def repair_wal(path, valid_end: int) -> None:
    """Truncate a torn tail off a WAL so future appends extend the valid
    prefix (idempotent; fsyncs the truncation)."""
    path = pathlib.Path(path)
    if not path.exists():
        path.write_bytes(MAGIC)
    with open(path, "r+b") as f:
        f.truncate(max(valid_end, len(MAGIC)))
        size = f.seek(0, os.SEEK_END)
        if size < len(MAGIC):
            f.seek(0)
            f.write(MAGIC)
        f.flush()
        os.fsync(f.fileno())


def recover_wal(path, *, sync: bool = True, fault_plan=None):
    """Read + repair a WAL, then reopen it for appending.

    Returns ``(wal, records, torn)`` — the repaired, append-ready log,
    the surviving op prefix, and whether a torn tail was dropped.
    """
    records, torn, valid_end = read_wal(path)
    if torn:
        repair_wal(path, valid_end)
    wal = WriteAheadLog(path, sync=sync, fault_plan=fault_plan)
    wal.seq = len(records)
    return wal, records, torn
