"""Host oracle for the live-update subsystem: the paper's insertion rules.

The acceptance contract (DESIGN.md §8) is that every backend's hit sets
over base ∪ delta − tombstones stay bit-identical to a pointer mqr-tree
maintained with the paper's own insertion strategy (Figs. 5–9) over the
live object set.  :func:`live_tree` builds that tree — objects inserted
in ascending global id, i.e. original insertion order, which Section 4's
order-independence property makes canonical for point data — and is also
what ``SpatialIndex.live_metrics`` evaluates the Section 5.2 structure
metrics (overlap, overcoverage) on, so the zero-overlap claim can be
asserted after any mutation workload.
"""

from __future__ import annotations

from typing import List, Set

import numpy as np

from repro.core import mqrtree


def live_tree(index_or_log) -> mqrtree.MQRTree:
    """The mqr insertion-rule tree over the CURRENT live object set.

    Accepts a ``SpatialIndex`` (pristine or live) or an ``UpdateLog``.
    Live logs cache the tree per mutation epoch — rebuilding only when
    the live set actually changed.
    """
    log = getattr(index_or_log, "_updates", index_or_log)
    if log is None:  # pristine index: the build inputs ARE the live set
        mbrs = np.asarray(index_or_log.artifacts.mbrs, np.float64)
        return mqrtree.build(mbrs)
    if log._oracle is not None and log._oracle[0] == log.epoch:
        return log._oracle[1]
    tree = mqrtree.MQRTree()
    for g in np.nonzero(log.alive)[0]:
        tree.insert(int(g), log.mbr_table[g])
    log._oracle = (log.epoch, tree)
    return tree


def region_sets(index_or_log, queries) -> List[Set[int]]:
    """Per-query sets of live global ids the oracle tree finds —
    the ground truth the device hit masks are compared against.

    Queries go through the same float32 cast the façade applies, then
    the tree searches in float64 — the exact convention of the ``host``
    backend, so agreement here is agreement everywhere.
    """
    tree = live_tree(index_or_log)
    queries = np.asarray(queries, np.float32).reshape(-1, 4)
    return [
        set(tree.region_search(np.asarray(q, np.float64))[0]) for q in queries
    ]


def hits_mask(index_or_log, queries, width: int) -> np.ndarray:
    """Oracle hit sets as a (Q, width) bool mask in global-id space,
    directly comparable to ``RegionResult.hits``."""
    sets = region_sets(index_or_log, queries)
    out = np.zeros((len(sets), width), bool)
    for i, ids in enumerate(sets):
        out[i, sorted(ids)] = True
    return out
