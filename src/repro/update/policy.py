"""Merge policy of the live-update subsystem (DESIGN.md §8).

The delta buffer absorbs inserts at O(1) and tombstones absorb deletes at
O(1), but both degrade queries: every query pays one flat sweep over the
buffer levels, and tombstoned base slots still stream through the kernel
only to be masked in the epilogue.  The :class:`MergePolicy` decides when
that rent exceeds the cost of compacting everything into a fresh base
build — a size trigger on the buffer fill and a ratio trigger on dead
base objects, with ``auto=False`` leaving compaction entirely to explicit
``SpatialIndex.flush()`` calls (buffer overflow still merges: a full
buffer physically cannot accept the next insert).
"""

from __future__ import annotations

import dataclasses

DEFAULT_CAPACITY = 256


@dataclasses.dataclass(frozen=True)
class MergePolicy:
    """When the delta buffer + tombstones fold into a fresh base build.

    capacity:            delta-buffer slots (device-resident rows swept by
                         every query, so also the flat-scan rent ceiling).
    max_fill:            merge once valid slots / capacity reaches this
                         (1.0 = only when the buffer is full).
    max_tombstone_ratio: merge once dead base objects / base size reaches
                         this (dead slots still stream through the sweep).
    auto:                False = triggers off; merge only on explicit
                         ``flush()`` or physical buffer overflow.
    """

    capacity: int = DEFAULT_CAPACITY
    max_fill: float = 1.0
    max_tombstone_ratio: float = 0.5
    auto: bool = True

    def __post_init__(self):
        if int(self.capacity) < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        if not 0.0 < self.max_fill <= 1.0:
            raise ValueError(f"max_fill must be in (0, 1], got {self.max_fill}")
        if not 0.0 < self.max_tombstone_ratio <= 1.0:
            raise ValueError(
                "max_tombstone_ratio must be in (0, 1], got "
                f"{self.max_tombstone_ratio}"
            )

    def should_flush(self, *, fill: float, tombstone_ratio: float) -> bool:
        """Post-mutation check: is it time to compact?"""
        if not self.auto:
            return False
        return fill >= self.max_fill or tombstone_ratio >= self.max_tombstone_ratio


def as_policy(merge=None, capacity=None) -> MergePolicy:
    """Coerce the façade's ``merge=`` / ``capacity=`` build options.

    ``merge`` may be a :class:`MergePolicy`, a kwargs dict for one, or
    None; ``capacity`` (when given) overrides the policy's capacity —
    the common one-knob case ``SpatialIndex.build(..., capacity=512)``.
    """
    if merge is None:
        policy = MergePolicy()
    elif isinstance(merge, MergePolicy):
        policy = merge
    elif isinstance(merge, dict):
        policy = MergePolicy(**merge)
    else:
        raise TypeError(
            f"merge must be a MergePolicy or dict, got {type(merge).__name__}"
        )
    if capacity is not None:
        policy = dataclasses.replace(policy, capacity=int(capacity))
    return policy
