"""Update-aware query engines: how each façade backend sweeps base ∪ delta.

One :class:`LiveEngine` per ``SpatialIndex``; all engines over the same
:class:`repro.update.buffer.UpdateLog` answer from the same augmented
arrays (DESIGN.md §8), so hit sets and per-level visit counts agree
bit-for-bit across backends:

* ``host`` / ``lax`` — the pristine backend sweeps the frozen base
  (positional ids), then :meth:`UpdateLog.compose` lifts the result into
  global-id space: delta overlap scan + tombstone mask + appended delta
  visit columns, in numpy.
* ``pallas`` — the whole thing is ONE launch:
  :func:`repro.kernels.ops.fused_search_live` sweeps base levels and the
  delta buffer's flat levels in the same ``pallas_call`` and masks
  tombstones in the jit epilogue (compact precision uses the quantized
  twin with its exact confirming pass).
* ``serve`` — a :class:`repro.launch.spatial_serve.SpatialServer` bound
  to the augmented arrays; every mutation epoch rebinds the device
  arrays and advances the server's epoch tag so LRU entries cached under
  older epochs are invalidated, never served stale.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.kernels import ops

from .buffer import UpdateLog


class LiveEngine:
    """Region queries over base ∪ delta − tombstones for one backend."""

    def __init__(self, log: UpdateLog, backend: str, backend_opts: dict):
        self.log = log
        self.backend = backend
        self.opts = dict(backend_opts)
        self._serve: Optional[Tuple[Tuple[int, str], object]] = None

    def bind_fault_plan(self, plan) -> None:
        """Thread a fault-injection plan into the live serve path."""
        self.opts["fault_plan"] = plan
        if self._serve is not None:
            self._serve[1].bind_fault_plan(plan)

    def drain_health(self) -> Optional[dict]:
        """Health-ladder counter deltas from the live server (None when
        this engine has no server — host/lax/pallas paths)."""
        if self._serve is None:
            return None
        return self._serve[1].drain_health()

    def region(self, queries: np.ndarray, base_region=None):
        """Returns ``(hits (Q, id_capacity), visits (Q, L+D), launches)``.

        ``base_region`` is the pristine backend's positional region
        callable — required for the composed ``host``/``lax`` paths,
        ignored by the fused device paths.
        """
        if self.backend in ("host", "lax"):
            hits_pos, visits, launches = base_region(queries)
            hits, visits = self.log.compose(
                np.asarray(hits_pos), np.asarray(visits), queries
            )
            return hits, visits, launches
        if self.backend == "pallas":
            return self._pallas(queries)
        if self.backend == "serve":
            return self._serve_region(queries)
        raise ValueError(f"no live engine for backend {self.backend!r}")

    # ------------------------------------------------------------------
    def _pallas(self, queries):
        precision = self.opts.get("precision", "float32")
        # compact8 normalizes to compact under mutation: delta rows ride
        # the fine uint16 grid, so the live launch is the compact twin
        # (hit sets are bit-identical either way; DESIGN.md §12).  The
        # live sweep is likewise always the VMEM-resident kernel — the
        # streamed path serves frozen-base indexes.
        if precision == "compact8":
            precision = "compact"
        aug = self.log.augmented(precision)
        kwargs = dict(
            block_w=self.opts.get("block_w") or 128,
            interpret=self.opts.get("interpret"),
            **aug.statics,
        )
        if precision == "compact":
            hits, visits = ops.fused_search_compact_live(
                jnp.asarray(queries, jnp.float32), *aug.arrays, **kwargs
            )
        else:
            hits, visits = ops.fused_search_live(
                jnp.asarray(queries, jnp.float32), *aug.arrays, **kwargs
            )
        return np.asarray(hits), np.asarray(visits), 1

    def _serve_region(self, queries):
        from repro.launch.spatial_serve import SpatialServer

        log = self.log
        precision = self.opts.get("precision", "float32")
        if precision == "compact8":  # same normalization as _pallas
            precision = "compact"
        key = (log.base_epoch, precision)
        if self._serve is None or self._serve[0] != key:
            # Fresh server per merge: a flush changes array shapes
            # (id capacity, level count), so the vmapped program differs.
            from repro.launch.spatial_serve import LADDER

            aug = log.augmented(precision)
            server = SpatialServer(
                log.base.schedule,
                query_block=self.opts.get("query_block") or 16,
                cache_size=self.opts.get("cache_size", 4096),
                block_w=self.opts.get("block_w") or 128,
                interpret=self.opts.get("interpret"),
                precision=precision,
                live=aug,
                ladder=self.opts.get("ladder") or LADDER,
                max_retries=self.opts.get("max_retries", 2),
                backoff=self.opts.get("backoff", 0.05),
                fault_plan=self.opts.get("fault_plan"),
            )
            server.rebind(aug.arrays, epoch=log.epoch)
            self._serve = (key, server)
        server = self._serve[1]
        if server.epoch != log.epoch:
            # Same shapes, new delta contents: swap device arrays and
            # advance the epoch tag (stale LRU entries stop matching).
            server.rebind(log.augmented(precision).arrays, epoch=log.epoch)
        before = server.stats.kernel_launches
        hits, visits = server.search(queries)
        return hits, visits, server.stats.kernel_launches - before
