"""Versioned snapshots of a :class:`repro.index.SpatialIndex` (DESIGN.md §9).

One snapshot is one directory, published atomically::

    <path>/
      meta.json     format version, structure, build opts, schedule statics,
                    merge-policy fields, admission mode, array manifest
      arrays.npz    base object table + LevelSchedule arrays
                    (+ quantized tile arrays when they were materialized)
                    (+ the UpdateLog's delta/tombstone/id-space arrays when
                    live-update state exists)

The write goes to ``<path>.tmp-<pid>`` and lands with ``os.replace`` — a
crash mid-save leaves either the previous snapshot or none, never a torn
one.  Loading installs the saved :class:`LevelSchedule` directly (via
:meth:`BuildArtifacts.restore`): restore never re-runs a device build, so
an index saved from a healthy accelerator reopens even on a degraded box,
on ANY backend, with bit-identical region/point/knn/count answers.

The snapshot captures *state*, not *history*: pair it with the mutation
WAL (:mod:`repro.update.wal` via :class:`repro.checkpoint.DurableIndex`)
for crash consistency between snapshots.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
from typing import Optional, Tuple

import numpy as np

from repro.obs import trace as _obs_trace

FORMAT_VERSION = 1

_SCHED_KEYS = (
    "mbr_cm", "parent", "n_real", "obj_mbr", "obj_level", "obj_slot", "obj_id",
)
_QUANT_KEYS = ("mbr_q", "parent_q", "origin", "inv_cell", "confirm_mbr")


class SnapshotError(RuntimeError):
    """The snapshot is unreadable or from an unknown format version."""


def _json_safe(d: dict) -> dict:
    out = {}
    for k, v in (d or {}).items():
        if isinstance(v, tuple):
            v = list(v)
        try:
            json.dumps(v)
        except TypeError:
            continue  # non-serializable opt (e.g. a FaultPlan): not state
        out[k] = v
    return out


def index_state(idx) -> Tuple[dict, dict]:
    """``(meta, arrays)`` snapshot content for ``idx`` — the CURRENT base
    build plus any live-update state (shared by :func:`save_index` and
    the DurableIndex's rotating generation snapshots)."""
    art = idx.artifacts  # current base: post-merge artifacts once mutated
    sched = art.schedule
    meta = {
        "format_version": FORMAT_VERSION,
        "structure": art.structure,
        "build_opts": _json_safe(art.build_opts),
        "backend": idx.backend,
        "backend_opts": _json_safe(idx._backend_opts),
        "admission": idx._admission,
        "schedule": {
            "n_objects": int(sched.n_objects),
            "root_unconditional": bool(sched.root_unconditional),
            "test_object_mbr": bool(sched.test_object_mbr),
        },
        "has_quantized": art._quantized is not None,
        "has_updates": idx._updates is not None,
    }
    arrays = {"mbrs": art.mbrs}
    for k in _SCHED_KEYS:
        arrays[f"sched/{k}"] = getattr(sched, k)
    if art._quantized is not None:
        qs = art._quantized
        meta["quantized"] = {"cells": int(qs.cells)}
        for k in _QUANT_KEYS:
            arrays[f"quant/{k}"] = getattr(qs, k)
    if idx._policy is not None or idx._updates is not None:
        import dataclasses

        from repro.update import MergePolicy

        policy = (
            idx._updates.policy if idx._updates is not None
            else (idx._policy or MergePolicy())
        )
        meta["policy"] = dataclasses.asdict(policy)
    if idx._updates is not None:
        log = idx._updates
        meta["log"] = log.state_scalars()
        for k, v in log.state_arrays().items():
            arrays[f"log/{k}"] = v
    return meta, arrays


def write_state(dirpath, meta: dict, arrays: dict) -> None:
    """Write snapshot content into an (existing) directory and fsync it."""
    dirpath = pathlib.Path(dirpath)
    np.savez(dirpath / "arrays.npz", **arrays)
    with open(dirpath / "meta.json", "w") as f:
        json.dump(meta, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(dirpath)


def _fsync_dir(path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_index(idx, path, *, extra_meta: Optional[dict] = None) -> None:
    """Atomically snapshot ``idx`` at ``path`` (a directory).

    Writes beside the target and publishes with ``os.replace``; an
    existing snapshot at ``path`` is superseded only after the new one is
    fully on disk.  ``extra_meta`` entries ride along in meta.json (the
    DurableIndex stores its op counter and generation there).
    """
    path = pathlib.Path(path)
    with _obs_trace.span("checkpoint.save", path=str(path)):
        path.parent.mkdir(parents=True, exist_ok=True)
        meta, arrays = index_state(idx)
        if extra_meta:
            meta.update(extra_meta)
        tmp = path.parent / f"{path.name}.tmp-{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        write_state(tmp, meta, arrays)
        if path.exists():
            shutil.rmtree(path)
        os.replace(tmp, path)
        _fsync_dir(path.parent)


def read_state(path) -> Tuple[dict, dict]:
    """Read ``(meta, arrays)`` back; validates presence and version."""
    path = pathlib.Path(path)
    meta_p, npz_p = path / "meta.json", path / "arrays.npz"
    if not meta_p.exists() or not npz_p.exists():
        raise SnapshotError(f"{path}: not a spatial-index snapshot")
    with open(meta_p) as f:
        meta = json.load(f)
    version = meta.get("format_version")
    if version != FORMAT_VERSION:
        raise SnapshotError(
            f"{path}: snapshot format {version!r} not supported "
            f"(this build reads version {FORMAT_VERSION})"
        )
    with np.load(npz_p) as z:
        arrays = {k: z[k] for k in z.files}
    return meta, arrays


def restore_index(meta: dict, arrays: dict, *, backend: str,
                  policy_override=None, **backend_opts):
    """Rehydrate a :class:`SpatialIndex` from snapshot content."""
    from repro.core.flat import LevelSchedule, QuantizedSchedule
    from repro.index.api import BuildArtifacts, SpatialIndex
    from repro.index.registry import get_backend

    s = meta["schedule"]
    sched = LevelSchedule(
        *(arrays[f"sched/{k}"] for k in _SCHED_KEYS),
        n_objects=int(s["n_objects"]),
        root_unconditional=bool(s["root_unconditional"]),
        test_object_mbr=bool(s["test_object_mbr"]),
    )
    quantized = None
    if meta.get("has_quantized"):
        quantized = QuantizedSchedule(
            sched,
            *(arrays[f"quant/{k}"] for k in _QUANT_KEYS),
            cells=int(meta["quantized"]["cells"]),
        )
    artifacts = BuildArtifacts.restore(
        meta["structure"], arrays["mbrs"], meta.get("build_opts"),
        sched, quantized,
    )
    idx = SpatialIndex(artifacts, get_backend(backend), **backend_opts)
    idx._admission = meta.get("admission", "merge")
    policy = policy_override
    if policy is None and "policy" in meta:
        from repro.update import MergePolicy

        policy = MergePolicy(**meta["policy"])
    if policy is not None:
        idx._policy = policy
    if meta.get("has_updates"):
        from repro.update import MergePolicy, UpdateLog

        structure = artifacts.structure
        build_opts = dict(artifacts.build_opts)
        log = UpdateLog.restore(
            artifacts,
            policy if policy is not None else MergePolicy(),
            rebuild=lambda mbrs: BuildArtifacts(structure, mbrs, **build_opts),
            arrays={
                k[len("log/"):]: v
                for k, v in arrays.items() if k.startswith("log/")
            },
            scalars=meta["log"],
        )
        idx._updates = log
        idx._backend_base_epoch = log.base_epoch
    return idx


def load_index(path, *, backend: str = "pallas", **backend_opts):
    """Load a snapshot written by :func:`save_index` onto any backend."""
    with _obs_trace.span("checkpoint.load", path=str(path),
                         backend=backend):
        meta, arrays = read_state(path)
        return restore_index(meta, arrays, backend=backend, **backend_opts)


def snapshot_meta(path) -> Optional[dict]:
    """The snapshot's meta.json, or None if ``path`` holds no snapshot."""
    try:
        return read_state(pathlib.Path(path))[0]
    except (SnapshotError, json.JSONDecodeError):
        return None
