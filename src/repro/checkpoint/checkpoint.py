"""Sharded npz checkpointing: atomic, async, keep-last-k, auto-resume.

No orbax offline — this is the from-scratch implementation:

* every leaf is saved under a flattened path key (np.savez per shard),
* writes go to ``<dir>/tmp.<step>`` then os.replace() -> ``step_<n>``
  (atomic on POSIX: a crash mid-write never corrupts a restorable step),
* an optional background thread makes saves non-blocking (the train loop
  keeps stepping while the previous checkpoint flushes),
* ``latest_step`` + ``restore`` implement crash auto-resume,
* ``keep`` bounds disk: older steps are deleted after a successful write.

On a multi-host deployment each host writes its own process shard
(``shard{process_index}.npz``) — the same layout works 1..N hosts.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        arr = np.asarray(leaf)
        # np.savez cannot serialize ml_dtypes (bfloat16, f8): store as f32
        # (exact widening) and cast back to the template dtype on restore.
        if arr.dtype not in (
            np.float64, np.float32, np.float16, np.int64, np.int32, np.int16,
            np.int8, np.uint8, np.uint16, np.uint32, np.uint64, np.bool_,
        ):
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _unflatten(template, flat: Dict[str, np.ndarray]):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    new = []
    for path, leaf in leaves_p:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        new.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None) -> None:
        host = jax.process_index() if jax.process_count() > 1 else 0
        flat = _flatten(tree)  # materialize on host BEFORE async handoff
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, metadata or {}, host)
            )
            self._thread.start()
        else:
            self._write(step, flat, metadata or {}, host)

    def _write(self, step: int, flat, metadata, host: int) -> None:
        tmp = self.dir / f"tmp.{step}.{host}"
        final = self.dir / f"step_{step:08d}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / f"shard{host}.npz", **flat)
        with open(tmp / "meta.json", "w") as f:
            json.dump({"step": step, **metadata}, f)
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def all_steps(self):
        return [
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if (p / "meta.json").exists()
        ]

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, step: int, template: Any):
        host = jax.process_index() if jax.process_count() > 1 else 0
        path = self.dir / f"step_{step:08d}" / f"shard{host}.npz"
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten(template, flat)

    def metadata(self, step: int) -> dict:
        with open(self.dir / f"step_{step:08d}" / "meta.json") as f:
            return json.load(f)
