"""Crash-consistent spatial serving: snapshot + mutation WAL (DESIGN.md §9).

A :class:`DurableIndex` wraps a live :class:`repro.index.SpatialIndex`
with the classic recovery pair:

* **snapshots** — generation-numbered, atomically published copies of the
  full index state (``snap_<g>/``, :mod:`repro.checkpoint.spatial`);
* **a write-ahead log per generation** (``wal_<g>.log``,
  :mod:`repro.update.wal`): every ``insert`` / ``delete`` / ``flush`` is
  fsync'd to the WAL *before* it touches index state.

``recover(root)`` = latest complete snapshot + deterministic replay of
its WAL tail.  Because global ids, merge triggers, and rebuilds are pure
functions of the op sequence, replay reconstructs the pre-crash live set
exactly — a kill at ANY point (before the append, after it, mid-merge,
or tearing the record itself) recovers to the last durable op, verified
op-index-by-op-index against the host oracle in tests/test_durability.py.

Directory layout::

    root/
      snap_<g>/      snapshot at generation g  (atomic os.replace publish)
      wal_<g>.log    mutations since snap_<g>  (fsync'd, checksummed)

:meth:`checkpoint` rotates: publish ``snap_<g+1>``, start ``wal_<g+1>``,
garbage-collect older generations.  The crash windows are safe by
ordering — a kill after the snapshot publish but before the new WAL
exists reads as "new snapshot + empty log"; a kill mid-publish leaves
the previous generation intact.

Admission control (the serving-side backpressure story): when the delta
buffer cannot absorb a batch, ``admission="merge"`` folds it into a
compaction (flush-then-insert; works even with ``auto=False`` policies),
``"shed"`` drops it, and ``"queue"`` parks it host-side — queued batches
reach the WAL only when they are actually applied, so recovery never
replays a mutation that was still pending.
"""

from __future__ import annotations

import dataclasses
import pathlib
import re
import shutil
from typing import List, Optional, Tuple

import numpy as np

from repro.obs import trace as _obs_trace
from repro.update.wal import WriteAheadLog, recover_wal

from .spatial import load_index, save_index, snapshot_meta

ADMISSION_MODES = ("merge", "shed", "queue")

_SNAP_RE = re.compile(r"^snap_(\d+)$")


@dataclasses.dataclass(frozen=True)
class MutationResult:
    """Outcome of one durable mutation.

    status: ``applied`` (durable in the WAL and visible to queries),
            ``shed`` (dropped by admission control), or ``queued``
            (parked host-side; durable only once drained).
    ids:    global ids of applied inserts (empty for deletes/flushes and
            for non-applied batches).
    """

    status: str
    ids: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), np.int64)
    )

    @property
    def applied(self) -> bool:
        return self.status == "applied"


class DurableIndex:
    """A SpatialIndex with WAL-backed crash consistency.

    Construct via :meth:`create` (fresh directory) or :meth:`recover`
    (reopen after a crash or clean shutdown — same call either way).
    Query methods (``region``/``point``/``count``/``knn``) delegate to
    the wrapped index; mutations go WAL-first.
    """

    def __init__(self, index, root, wal: WriteAheadLog, *,
                 generation: int, ops_total: int, admission: str = "merge",
                 fault_plan=None, sync: bool = True, keep: int = 1):
        if admission not in ADMISSION_MODES:
            raise ValueError(
                f"unknown admission {admission!r}; expected one of "
                f"{ADMISSION_MODES}"
            )
        self.index = index
        self.root = pathlib.Path(root)
        self.wal = wal
        self.generation = int(generation)
        self.ops_total = int(ops_total)  # durable ops since create()
        self.admission = admission
        self.sync = bool(sync)
        self.keep = int(keep)            # extra old generations retained
        self._pending: List[np.ndarray] = []  # queued insert batches
        self.fault_plan = None
        if fault_plan is not None:
            self.bind_fault_plan(fault_plan)

    # -- construction ---------------------------------------------------
    @classmethod
    def create(cls, mbrs, root, *, structure: str = "mqr",
               backend: str = "pallas", admission: str = "merge",
               sync: bool = True, keep: int = 1, fault_plan=None,
               **opts) -> "DurableIndex":
        """Build a fresh index at ``root``: snapshot generation 0 is
        published before this returns, so the build itself is durable."""
        from repro.index.api import SpatialIndex

        root = pathlib.Path(root)
        root.mkdir(parents=True, exist_ok=True)
        index = SpatialIndex.build(
            mbrs, structure=structure, backend=backend, **opts
        )
        save_index(
            index, root / "snap_0",
            extra_meta={"durable": {"generation": 0, "ops_total": 0}},
        )
        wal = WriteAheadLog(root / "wal_0.log", sync=sync)
        return cls(index, root, wal, generation=0, ops_total=0,
                   admission=admission, fault_plan=fault_plan, sync=sync,
                   keep=keep)

    @classmethod
    def recover(cls, root, *, backend: str = "pallas",
                admission: str = "merge", sync: bool = True, keep: int = 1,
                fault_plan=None, **opts) -> "DurableIndex":
        """Reopen ``root``: latest complete snapshot + WAL tail replay.

        Torn WAL tails are detected (checksum / sequence break), dropped,
        and the file repaired; the surviving op prefix is replayed in
        order through the same code paths that applied it originally, so
        the recovered live set is bit-identical to the pre-crash state at
        the last durable op.  The fault plan is bound only AFTER replay —
        recovery itself never re-triggers the fault that killed us.
        """
        root = pathlib.Path(root)
        gen = cls._latest_generation(root)
        if gen is None:
            raise FileNotFoundError(
                f"{root}: no complete snapshot generation to recover from"
            )
        index = load_index(root / f"snap_{gen}", backend=backend, **opts)
        wal, records, torn = recover_wal(
            root / f"wal_{gen}.log", sync=sync
        )
        base_ops = int(
            (snapshot_meta(root / f"snap_{gen}") or {})
            .get("durable", {}).get("ops_total", 0)
        )
        self = cls(index, root, wal, generation=gen,
                   ops_total=base_ops + len(records), admission=admission,
                   sync=sync, keep=keep)
        self.recovered_ops = len(records)
        self.recovered_torn = torn
        for op, arr in records:
            self._apply(op, arr)
        if fault_plan is not None:
            self.bind_fault_plan(fault_plan)
        return self

    @classmethod
    def open(cls, root, mbrs=None, *, structure: str = "mqr",
             backend: str = "pallas", admission: str = "merge",
             sync: bool = True, keep: int = 1, fault_plan=None,
             **opts) -> "DurableIndex":
        """Recover ``root`` if it holds a complete snapshot generation,
        else create it fresh from ``mbrs``.

        This is the serving front end's restart path: a tenant declared
        with ``durable_root`` comes back with its last durable live set
        on every process start, and bootstraps from its dataset only the
        first time.  ``structure`` applies only to the create path — on
        recovery the structure is whatever the snapshot recorded.
        """
        root = pathlib.Path(root)
        if cls._latest_generation(root) is not None:
            # build-time options (structure shape, delta capacity, merge
            # policy) are recorded IN the snapshot — only backend options
            # may pass through to recovery
            build_only = ("capacity", "merge", "levels", "max_entries",
                          "build")
            backend_opts = {
                k: v for k, v in opts.items() if k not in build_only
            }
            return cls.recover(root, backend=backend, admission=admission,
                               sync=sync, keep=keep, fault_plan=fault_plan,
                               **backend_opts)
        if mbrs is None:
            raise FileNotFoundError(
                f"{root}: nothing to recover and no mbrs to create from"
            )
        return cls.create(mbrs, root, structure=structure, backend=backend,
                          admission=admission, sync=sync, keep=keep,
                          fault_plan=fault_plan, **opts)

    @staticmethod
    def _latest_generation(root: pathlib.Path) -> Optional[int]:
        gens = []
        for p in root.iterdir() if root.exists() else []:
            m = _SNAP_RE.match(p.name)
            if m and snapshot_meta(p) is not None:
                gens.append(int(m.group(1)))
        return max(gens) if gens else None

    # -- fault injection ------------------------------------------------
    def bind_fault_plan(self, plan) -> None:
        """Thread one :class:`repro.ft.FaultPlan` through every layer:
        WAL appends (torn writes), the update log (mid-merge kills), the
        serving ladder (launch failures), and this op loop (kill sites).
        """
        self.fault_plan = plan
        self.wal.fault_plan = plan
        self.index.bind_fault_plan(plan)

    def _op_event(self, site: str) -> None:
        if self.fault_plan is not None:
            self.fault_plan.op_event(site, self.ops_total)

    # -- mutations (WAL-first) ------------------------------------------
    def insert(self, new_mbrs) -> MutationResult:
        """Durably insert a batch; admission control may shed or queue it
        when the delta buffer (or its id headroom) cannot absorb it."""
        from repro.index.api import validate_mbrs

        new_mbrs = validate_mbrs(new_mbrs, what="insert batch")
        n = new_mbrs.shape[0]
        if n == 0:
            return MutationResult("applied")
        if not self._admit(n):
            if self.admission == "shed":
                self.index.stats.shed_mutations += n
                return MutationResult("shed")
            self._pending.append(new_mbrs)
            self.index.stats.queued_mutations += n
            return MutationResult("queued")
        log = self.index._ensure_log()
        if (
            not log.policy.auto
            and n <= log.capacity
            and not log.can_buffer(n)
        ):
            # admission="merge" backpressure under a manual (auto=False)
            # policy: compact DURABLY first — the façade would otherwise
            # raise BufferFullError after the WAL append, poisoning
            # replay with a record that can never apply.
            self._commit("flush", None)
        gids = self._commit("insert", new_mbrs)
        return MutationResult("applied", ids=gids)

    def delete(self, ids) -> MutationResult:
        """Durably tombstone live objects by global id."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        if ids.size == 0:
            return MutationResult("applied")
        self._check_deletable(ids)  # KeyError BEFORE the WAL sees it
        self._commit("delete", ids)
        return MutationResult("applied")

    def flush(self) -> MutationResult:
        """Durably compact (merge buffer + tombstones into a fresh base),
        then drain any queued batches into the room it made."""
        self._commit("flush", None)
        self.drain_queue()
        return MutationResult("applied")

    def _commit(self, op: str, arr):
        """The WAL-before-apply discipline, with kill sites around every
        boundary: the record is durable before index state changes, so
        the surviving prefix is exactly what replay reconstructs."""
        with _obs_trace.span("durable.commit", op=op, seq=self.ops_total):
            self._op_event("pre-append")   # kill here: op lost, state clean
            self.wal.append(op, arr)       # torn-write kills land inside
            self._op_event("post-append")  # kill here: op durable, unapplied
            out = self._apply(op, arr)     # mid-merge kills land inside
            self._op_event("post-apply")   # kill here: op durable + applied
            self.ops_total += 1
            return out

    def _apply(self, op: str, arr):
        if op == "insert":
            return self.index.insert(arr)
        if op == "delete":
            self.index.delete(arr)
            return None
        self.index.flush()
        return None

    # -- admission ------------------------------------------------------
    def _admit(self, n: int) -> bool:
        """Can the delta buffer absorb ``n`` inserts right now?  With
        ``admission="merge"`` the answer is always yes — an unbufferable
        batch folds into a compaction (the façade's documented path)."""
        if self.admission == "merge":
            return True
        log = self.index._ensure_log()
        return n <= log.capacity and log.can_buffer(n)

    def drain_queue(self) -> int:
        """Apply queued batches that now fit (in arrival order, stopping
        at the first that still doesn't); returns objects drained."""
        drained = 0
        while self._pending and self._admit(self._pending[0].shape[0]):
            batch = self._pending.pop(0)
            self._commit("insert", batch)
            drained += batch.shape[0]
        return drained

    @property
    def pending(self) -> int:
        """Objects parked by ``admission="queue"``, not yet durable."""
        return int(sum(b.shape[0] for b in self._pending))

    def _check_deletable(self, ids: np.ndarray) -> None:
        log = self.index._ensure_log()
        bad = ids[(ids < 0) | (ids >= log.id_capacity)]
        if bad.size == 0:
            bad = ids[~log.alive[ids]]
        if bad.size:
            raise KeyError(
                f"id {int(bad[0])} is not a live object (dead or unknown)"
            )

    # -- checkpoint rotation --------------------------------------------
    def checkpoint(self) -> int:
        """Publish a new snapshot generation and rotate the WAL.

        Ordering makes every kill window safe: (1) drain the queue, (2)
        atomically publish ``snap_<g+1>``, (3) start ``wal_<g+1>``, (4)
        close the old log and GC stale generations.  A kill between (2)
        and (3) recovers as "new snapshot + empty log"; earlier kills
        leave the previous generation authoritative.  Returns the new
        generation number.
        """
        self.drain_queue()
        g = self.generation + 1
        with _obs_trace.span("durable.checkpoint", generation=g,
                             ops_total=self.ops_total):
            save_index(
                self.index, self.root / f"snap_{g}",
                extra_meta={
                    "durable": {"generation": g, "ops_total": self.ops_total}
                },
            )
            new_wal = WriteAheadLog(self.root / f"wal_{g}.log", sync=self.sync)
            new_wal.fault_plan = self.fault_plan
            old = self.wal
            self.wal, self.generation = new_wal, g
            old.close()
            self._gc()
        return g

    def _gc(self) -> None:
        floor = self.generation - self.keep
        for p in self.root.iterdir():
            m = _SNAP_RE.match(p.name)
            if m and int(m.group(1)) < floor:
                shutil.rmtree(p, ignore_errors=True)
                (self.root / f"wal_{m.group(1)}.log").unlink(missing_ok=True)
            elif p.name.startswith("snap_") and ".tmp-" in p.name:
                shutil.rmtree(p, ignore_errors=True)  # crashed mid-save

    def close(self) -> None:
        self.wal.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- query delegation -----------------------------------------------
    @property
    def stats(self):
        return self.index.stats

    @property
    def n_objects(self) -> int:
        return self.index.n_objects

    @property
    def id_space(self) -> int:
        return self.index.id_space

    def region(self, queries):
        return self.index.region(queries)

    def point(self, points):
        return self.index.point(points)

    def count(self, queries):
        return self.index.count(queries)

    def knn(self, points, k: int):
        return self.index.knn(points, k)

    def join(self, other, predicate: str = "intersects"):
        """Tree-vs-tree join of the durable live set against another
        index (DESIGN.md §10); joins are read-only, so no WAL traffic —
        the other side may be a plain or durable index."""
        return self.index.join(
            getattr(other, "index", other), predicate=predicate
        )


def live_ids(d: "DurableIndex") -> np.ndarray:
    """Global ids of the durable live set (sorted) — the unit the crash
    tests compare against the host oracle."""
    log = d.index._updates
    if log is None:
        return np.arange(d.index.n_objects, dtype=np.int64)
    return np.nonzero(log.alive)[0].astype(np.int64)


def mutation_workload(n_ops: int, *, seed: int = 0,
                      base_n: int = 64) -> Tuple[np.ndarray, list]:
    """A deterministic mixed mutation workload for the fault harness:
    ``(base_mbrs, ops)`` where ops are ``("insert", (n,4) mbrs)``,
    ``("delete", k)`` (delete k live ids, chosen by the runner), or
    ``("flush", None)`` — weighted toward inserts so the live set grows
    and merges trigger organically."""
    from repro.core import datasets

    rng = np.random.default_rng(seed)
    base = datasets.uniform_squares(base_n, seed=seed)
    ops: list = []
    for i in range(n_ops):
        r = rng.random()
        if r < 0.62:
            k = int(rng.integers(1, 5))
            ops.append(("insert", datasets.uniform_squares(
                k, seed=int(rng.integers(0, 2**31))
            )))
        elif r < 0.9:
            ops.append(("delete", int(rng.integers(1, 4))))
        else:
            ops.append(("flush", None))
    return base, ops
