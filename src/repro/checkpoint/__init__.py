from .checkpoint import CheckpointManager
from .durable import DurableIndex, MutationResult, live_ids, mutation_workload
from .spatial import (
    FORMAT_VERSION,
    SnapshotError,
    load_index,
    save_index,
    snapshot_meta,
)

__all__ = [
    "CheckpointManager",
    "DurableIndex",
    "MutationResult",
    "live_ids",
    "mutation_workload",
    "FORMAT_VERSION",
    "SnapshotError",
    "load_index",
    "save_index",
    "snapshot_meta",
]
