"""`SpatialIndex` — the one façade over every tree × backend path.

The paper's contract is a single access method: build an index over MBRs,
run a region search, count the disk accesses.  The repro grew four entry
points (pointer trees, the levelized ``lax`` sweep, the fused Pallas
kernel, the batching server) and three build paths; this module folds them
back into one config-driven surface (DESIGN.md §6):

    idx = SpatialIndex.build(mbrs, structure="mqr", backend="pallas")
    res = idx.region(queries)        # RegionResult(hits, visits_per_level)
    res = idx.point(points)          # degenerate-rectangle fast path
    cnt = idx.count(queries)         # hits per query, no mask materialized
    knn = idx.knn(points, k=8)       # k-NN as a first-class query

``structure`` picks the build path (``mqr`` | ``rtree`` | ``pyramid``),
``backend`` the query engine (``host`` | ``lax`` | ``pallas`` | ``serve``)
via the registry in :mod:`repro.index.registry`.  Every backend reports
the paper's disk-access accounting through the same :class:`AccessStats`
shape, and every advertised (structure × backend) pair returns bit-identical
hits and per-level access counts (tests/test_index_api.py).

Two orthogonal throughput options (DESIGN.md §7): ``build="device"`` runs
the pyramid's bulk fixed point on-accelerator, emitting the
``LevelSchedule`` in one launch (no host pointer tree — and
:meth:`SpatialIndex.extend` makes batch insertion one more such launch);
``precision="compact"`` streams conservatively quantized uint16 MBR tiles
through the fused sweep at half the bytes/query, with an exact float32
confirming pass keeping hit sets bit-identical.

Online mutation (DESIGN.md §8): :meth:`SpatialIndex.insert` /
:meth:`delete` / :meth:`flush` route through the live-update subsystem
(:mod:`repro.update`) — inserts land in a device-resident delta buffer
swept by the same fused launch, deletes tombstone ids masked in the scan
epilogue, and a merge policy decides when to compact into a fresh base
build.  Object ids are global and append-only, so hit masks stay
comparable (and bit-identical to the host mqr-insertion oracle) across
mutations and merges.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import bulk, mqrtree, rtree
from repro.core.flat import FlatTree, LevelSchedule, flatten, level_schedule, pyramid_schedule
from repro.obs import counters as _obs_counters
from repro.obs import trace as _obs_trace

from . import knn as _knn
from .registry import BackendSpec, get_backend

STRUCTURES = ("mqr", "rtree", "pyramid")

# Build-time options; everything else in **opts goes to the backend factory.
_BUILD_OPTS = ("levels", "max_entries", "build", "order")
# Live-update / durability options (structure-agnostic, façade-consumed).
_UPDATE_OPTS = ("capacity", "merge", "admission", "fault_plan")

# Admission policies for mutations that cannot be buffered (DESIGN.md §9).
ADMISSION_MODES = ("merge", "shed")


class InvalidQueryError(ValueError):
    """A query rectangle/point rejected at the serving boundary —
    NaN/±inf coordinates or an inverted rectangle (DESIGN.md §11).
    Typed so the front end can refuse one bad arrival without poisoning
    the coalesced batch it would have joined."""


def validate_queries(queries, *, what: str = "queries") -> np.ndarray:
    """Boundary hardening for QUERY rectangles: same finite/non-inverted
    rules as :func:`validate_mbrs`, but raising the typed
    :class:`InvalidQueryError` and returning the kernels' (Q, 4) float32
    form.  Degenerate-but-valid points (lo == hi) pass."""
    try:
        arr = validate_mbrs(queries, what=what)
    except ValueError as e:
        raise InvalidQueryError(str(e)) from None
    return np.ascontiguousarray(arr, np.float32)


def validate_mbrs(mbrs, *, what: str = "mbrs") -> np.ndarray:
    """Input hardening shared by build and insert (DESIGN.md §9).

    Rejects NaN / ±inf coordinates and inverted rectangles (lo > hi on
    either axis) with a clear ``ValueError`` — degenerate geometry would
    otherwise flow silently through every comparison-based sweep and
    poison hit sets, quantized tiles, and the WAL.  Degenerate-but-valid
    points (lo == hi) pass.  Returns the validated (n, 4) float64 array.
    """
    arr = np.asarray(mbrs, np.float64)
    if arr.size % 4 != 0:
        raise ValueError(
            f"{what} must be (n, 4) [xlo, ylo, xhi, yhi]; got shape "
            f"{arr.shape}"
        )
    arr = arr.reshape(-1, 4)
    if not np.isfinite(arr).all():
        bad = int(np.nonzero(~np.isfinite(arr).all(axis=1))[0][0])
        raise ValueError(
            f"{what}[{bad}] has a non-finite coordinate "
            f"({arr[bad].tolist()}); NaN/±inf MBRs are rejected"
        )
    inverted = (arr[:, 0] > arr[:, 2]) | (arr[:, 1] > arr[:, 3])
    if inverted.any():
        bad = int(np.nonzero(inverted)[0][0])
        raise ValueError(
            f"{what}[{bad}] is inverted (lo > hi): {arr[bad].tolist()}"
        )
    return arr


# ---------------------------------------------------------------------------
# Results and the shared access-accounting protocol
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RegionResult:
    """Result of a batched region (or point) search.

    hits:             (Q, id_space) bool object-overlap mask — columns are
                      GLOBAL object ids (equal to build positions until
                      live updates begin; append-only afterwards, §8).
    visits_per_level: (Q, L) int32 — node accesses by tree level, the
                      paper's "disk accesses" broken down by depth.  Every
                      backend reports the identical numbers (DESIGN.md §6).
                      Once live updates begin, columns past ``base_levels``
                      are the delta buffer's flat-scan accesses.
    base_levels:      levels belonging to the frozen base build; None for
                      an index with no live-update state.
    launch_report:    merged :class:`repro.obs.LaunchReport` byte/tile
                      ledger for this batch's kernel launches — populated
                      only while ``repro.obs.collect_launch_reports(True)``
                      is armed and the backend path runs eagerly
                      (DESIGN.md §13); None otherwise.
    """

    hits: np.ndarray
    visits_per_level: np.ndarray
    base_levels: Optional[int] = None
    launch_report: Optional[object] = None

    @property
    def visits(self) -> np.ndarray:
        """(Q,) total accesses per query."""
        return self.visits_per_level.sum(axis=1)

    @property
    def counts(self) -> np.ndarray:
        """(Q,) number of objects found per query."""
        return self.hits.sum(axis=1)

    def ids(self, i: int) -> np.ndarray:
        """Object ids found by query ``i`` (ascending)."""
        return np.nonzero(self.hits[i])[0]

    @property
    def delta_visits(self) -> np.ndarray:
        """(Q,) delta-buffer accesses per query (all zero when the index
        has no live-update state)."""
        if self.base_levels is None:
            return np.zeros((self.visits_per_level.shape[0],), np.int64)
        return self.visits_per_level[:, self.base_levels:].sum(
            axis=1, dtype=np.int64
        )


@dataclasses.dataclass(frozen=True)
class KNNResult:
    """Result of a batched k-nearest-neighbour query.

    ids:    (Q, k) int32 object ids, nearest first.
    dists:  (Q, k) float32 Euclidean MBR min-distances, ascending.
    visits: (Q,) int64 node accesses spent answering each query (for the
            device path: summed over every expanding-radius round).
    """

    ids: np.ndarray
    dists: np.ndarray
    visits: np.ndarray


@dataclasses.dataclass
class AccessStats:
    """The paper's disk-access accounting, identical across backends.

    One instance accumulates over the lifetime of a :class:`SpatialIndex`;
    backends feed it through :meth:`record` so the ledger has the same
    meaning whether the query ran on host pointers, the ``lax`` sweep, the
    fused Pallas kernel, or the batching server.
    """

    queries: int = 0
    node_accesses: int = 0
    launches: int = 0        # device dispatches (0 for the host backend)
    knn_queries: int = 0
    knn_rounds: int = 0      # expanding-radius region rounds issued
    joins: int = 0           # tree-vs-tree join calls (DESIGN.md §10)
    # live-update ledger (DESIGN.md §8)
    inserts: int = 0
    deletes: int = 0
    flushes: int = 0         # merges (manual, policy, or overflow)
    delta_accesses: int = 0  # node_accesses spent on delta-buffer levels
    # durability / degradation ledger (DESIGN.md §9)
    launch_failures: int = 0   # rung dispatch attempts that raised
    retries: int = 0           # same-rung retries after a failure
    degraded_batches: int = 0  # batches answered below the top rung
    shed_mutations: int = 0    # objects dropped by admission="shed"
    queued_mutations: int = 0  # objects parked by DurableIndex queueing
    rung_dispatches: dict = dataclasses.field(default_factory=dict)
    # serving-front-end ledger (DESIGN.md §11)
    shed_queries: int = 0      # requests dropped by SLO admission control
    queued_queries: int = 0    # requests parked past max_queue (best-effort)
    # kernel byte/tile ledger (DESIGN.md §13); accumulates only while
    # repro.obs.collect_launch_reports(True) is armed
    bytes_streamed: float = 0.0   # mbr+parent tile HBM traffic
    mask_bytes: float = 0.0       # streamed-sweep survivor-window traffic
    tiles_fetched: int = 0
    tiles_skipped: int = 0        # dead-window DMA skips (streamed sweep)
    launch_reports: int = 0       # batches with a ledger attached

    def record(self, n_queries: int, accesses: int, launches: int) -> None:
        self.queries += int(n_queries)
        self.node_accesses += int(accesses)
        self.launches += int(launches)

    def absorb_health(self, health: Optional[dict]) -> None:
        """Fold one :meth:`SpatialServer.drain_health` delta into the
        ledger (no-op for backends without a degradation ladder)."""
        if not health:
            return
        self.retries += int(health.get("retries", 0))
        self.degraded_batches += int(health.get("degraded_batches", 0))
        self.launch_failures += sum(
            int(v) for v in health.get("rung_failures", {}).values()
        )
        for rung, n in health.get("rung_dispatches", {}).items():
            if n:
                self.rung_dispatches[rung] = (
                    self.rung_dispatches.get(rung, 0) + int(n)
                )

    def absorb_launch_report(self, report) -> None:
        """Fold one merged :class:`repro.obs.LaunchReport` into the
        ledger (DESIGN.md §13)."""
        if report is None:
            return
        self.bytes_streamed += float(report.bytes_streamed)
        self.mask_bytes += float(report.mask_bytes)
        self.tiles_fetched += int(report.tiles_fetched)
        self.tiles_skipped += int(report.tiles_skipped)
        self.launch_reports += 1

    def to_dict(self) -> dict:
        """Flat snapshot of every counter (``rung_dispatches`` stays a
        nested dict) — the canonical form for metrics export and for
        windowed deltas via :meth:`diff`."""
        out = dataclasses.asdict(self)
        out["rung_dispatches"] = dict(self.rung_dispatches)
        return out

    def diff(self, prev) -> dict:
        """Counter deltas since ``prev`` (an :class:`AccessStats` or a
        previous :meth:`to_dict` snapshot) — per-window accounting
        instead of lifetime totals.  Zero rung entries are dropped."""
        prev_d = prev.to_dict() if isinstance(prev, AccessStats) else dict(prev)
        out = {}
        for k, v in self.to_dict().items():
            if isinstance(v, dict):
                pv = prev_d.get(k) or {}
                d = {r: n - pv.get(r, 0) for r, n in v.items()}
                out[k] = {r: n for r, n in d.items() if n}
            else:
                out[k] = v - prev_d.get(k, 0)
        return out

    @property
    def degraded(self) -> bool:
        """True once any batch was answered below the top rung."""
        return self.degraded_batches > 0

    @property
    def accesses_per_query(self) -> float:
        return self.node_accesses / max(self.queries, 1)


# ---------------------------------------------------------------------------
# Build artifacts: what the registry lowers a structure to, lazily
# ---------------------------------------------------------------------------


def _reject_opts(structure: str, **opts) -> None:
    """A build option the chosen structure does not use fails loudly —
    same strictness contract as the backend options."""
    bad = [k for k, v in opts.items() if v is not None]
    if bad:
        raise TypeError(
            f"structure {structure!r} does not accept option(s) {bad}"
        )


class BuildArtifacts:
    """One built structure plus its lazily lowered forms.

    A backend declares which artifact it consumes — the pointer tree, the
    :class:`FlatTree`, or the :class:`LevelSchedule` — and pulls it from
    here; each lowering is computed once and cached, so switching backends
    over the same build (``SpatialIndex.with_backend``) is cheap.
    """

    def __init__(self, structure: str, mbrs: np.ndarray, *, levels=None,
                 max_entries=None, build=None, order=None):
        self.structure = structure
        self.mbrs = validate_mbrs(mbrs)
        self.n_objects = self.mbrs.shape[0]
        if order not in (None, "none", "hilbert"):
            raise ValueError(
                f"unknown order {order!r}; expected 'hilbert' (or None)"
            )
        # original user options, so extend() can re-run the same build
        self.build_opts = dict(levels=levels, max_entries=max_entries,
                               build=build, order=order)
        self.pointer_tree = None
        self.pyramid = None
        self._flat: Optional[FlatTree] = None
        self._schedule: Optional[LevelSchedule] = None
        self._ordered = False  # Hilbert permutation applied to _schedule?
        self._quantized = None
        self._quantized8 = None
        # Autotuned TileConfig winners keyed by kernels.autotune.shape_key,
        # shared by every backend over these artifacts (DESIGN.md §12).
        self.tuned: dict = {}
        if structure == "mqr":
            _reject_opts(structure, levels=levels, max_entries=max_entries,
                         build=build)
            self.pointer_tree = mqrtree.build(self.mbrs)
        elif structure == "rtree":
            _reject_opts(structure, levels=levels, build=build)
            self.pointer_tree = rtree.build(
                self.mbrs,
                max_entries=rtree.DEFAULT_M if max_entries is None else max_entries,
            )
        elif structure == "pyramid":
            _reject_opts(structure, max_entries=max_entries)
            if build not in (None, "host", "device"):
                raise ValueError(
                    f"unknown build {build!r}; expected 'host' or 'device'"
                )
            if levels is None:
                levels = bulk.default_levels(self.n_objects)
            if build == "device":
                # Device-resident bulk build: the level fixed point runs
                # on-accelerator and emits the LevelSchedule directly —
                # no host pointer tree, no flatten() (DESIGN.md §7).
                from repro.kernels import ops

                self._schedule = ops.device_schedule(
                    np.asarray(self.mbrs, np.float32), levels=levels
                )
            else:
                self.pyramid = bulk.build_pyramid(
                    np.asarray(self.mbrs, np.float32), levels=levels
                )
        else:
            raise ValueError(
                f"unknown structure {structure!r}; expected one of {STRUCTURES}"
            )

    @classmethod
    def restore(cls, structure: str, mbrs: np.ndarray, build_opts: dict,
                schedule: LevelSchedule, quantized=None) -> "BuildArtifacts":
        """Rehydrate artifacts from a checkpoint (DESIGN.md §9).

        The saved :class:`LevelSchedule` (and quantized tile form, when
        it was materialized at save time) is installed directly — load
        NEVER re-runs a device build, so an index restores even when the
        accelerator path that built it is degraded.  The host pointer
        tree (mqr/rtree only; needed by the host backend and pointer
        k-NN) is rebuilt deterministically from the object table.
        """
        self = cls.__new__(cls)
        self.structure = structure
        self.mbrs = np.asarray(mbrs, np.float64).reshape(-1, 4)
        self.n_objects = self.mbrs.shape[0]
        self.build_opts = dict(levels=None, max_entries=None, build=None,
                               order=None)
        self.build_opts.update(build_opts or {})
        self.pointer_tree = None
        self.pyramid = None
        self._flat = None
        self._schedule = schedule
        # The saved schedule was captured AFTER any build-time slot
        # ordering, so restore never re-permutes.
        self._ordered = True
        self._quantized = quantized
        self._quantized8 = None
        self.tuned = {}
        if structure == "mqr":
            self.pointer_tree = mqrtree.build(self.mbrs)
        elif structure == "rtree":
            me = self.build_opts.get("max_entries")
            self.pointer_tree = rtree.build(
                self.mbrs,
                max_entries=rtree.DEFAULT_M if me is None else me,
            )
        return self

    @property
    def flat(self) -> FlatTree:
        if self._flat is None:
            if self.pointer_tree is None:
                raise ValueError(
                    "structure 'pyramid' has no pointer tree / FlatTree form"
                )
            self._flat = flatten(self.pointer_tree)
        return self._flat

    @property
    def schedule(self) -> LevelSchedule:
        if self._schedule is None:
            if self.pyramid is not None:
                self._schedule = pyramid_schedule(self.pyramid, self.mbrs)
            else:
                self._schedule = level_schedule(self.flat)
        if not self._ordered:
            self._ordered = True
            if self.build_opts.get("order") == "hilbert":
                # Build-time locality pass (DESIGN.md §12): permute every
                # level's real slots into Hilbert order of their MBR
                # centers.  Hits, visits and ids are bit-identical; only
                # which slots share a tile changes.
                from repro.kernels import ops

                self._schedule = ops.hilbert_permute(self._schedule)
        return self._schedule

    @property
    def quantized(self):
        """Compact uint16 tile form of :attr:`schedule` (DESIGN.md §7),
        quantized once and shared by every ``precision="compact"``
        backend over these artifacts."""
        if self._quantized is None:
            from repro.kernels import ops

            self._quantized = ops.quantize_schedule(self.schedule)
        return self._quantized

    @property
    def quantized8(self):
        """Hierarchical uint8-upper/uint16-lower tile form of
        :attr:`schedule` (DESIGN.md §12) for ``precision="compact8"``
        backends, quantized once and shared like :attr:`quantized`."""
        if self._quantized8 is None:
            from repro.kernels import ops

            self._quantized8 = ops.quantize_schedule(
                self.schedule, upper8=True
            )
        return self._quantized8


# ---------------------------------------------------------------------------
# The façade
# ---------------------------------------------------------------------------


class SpatialIndex:
    """Unified build/query surface over every structure × backend path."""

    def __init__(self, artifacts: BuildArtifacts, spec: BackendSpec, **backend_opts):
        if artifacts.structure not in spec.structures:
            raise ValueError(
                f"backend {spec.name!r} does not serve structure "
                f"{artifacts.structure!r} (serves: {sorted(spec.structures)})"
            )
        self._artifacts = artifacts
        self.spec = spec
        self.stats = AccessStats()
        self._backend_opts = dict(backend_opts)
        self._backend = spec.factory(artifacts, **backend_opts)
        # live-update state (DESIGN.md §8); created on first insert/delete.
        # The log lives in a shared one-slot cell so `with_backend` twins
        # observe mutations regardless of whether the first mutation
        # happens before or after the twin is created.
        self._policy = None            # MergePolicy override from build()
        self._updates_cell = {"log": None}
        self._live_engine = None
        self._backend_base_epoch = 0   # base epoch self._backend was built at
        # durability knobs (DESIGN.md §9)
        self._admission = "merge"      # what to do with unbufferable batches
        self._fault_plan = None        # repro.ft.FaultPlan, threaded everywhere

    @property
    def _updates(self):
        return self._updates_cell["log"]

    @_updates.setter
    def _updates(self, log):
        self._updates_cell["log"] = log

    # -- construction --------------------------------------------------
    @classmethod
    def build(cls, mbrs, *, structure: str = "mqr", backend: str = "pallas",
              backend_opts: Optional[dict] = None, **opts) -> "SpatialIndex":
        """Build a spatial index over ``mbrs`` (n, 4).

        structure: ``mqr`` (paper pointer tree) | ``rtree`` (Guttman
            baseline) | ``pyramid`` (bulk bottom-up fixed point).
        backend:   ``host`` (pointer/numpy oracle) | ``lax`` (jit'd level
            sweep) | ``pallas`` (fused single-launch kernel) | ``serve``
            (batching server: LRU cache + dedupe + vmap/pmap fan-out).
        opts: build options (``levels`` and ``build="host"|"device"`` for
            pyramid — ``"device"`` runs the bulk fixed point on-device and
            emits the ``LevelSchedule`` directly, no host pointer tree;
            ``max_entries`` for rtree) plus backend options
            (``block_w``/``interpret``/``precision="float32"|"compact"``
            for pallas and serve — ``"compact"`` streams conservatively
            quantized uint16 MBR tiles with an exact confirming pass, see
            DESIGN.md §7 — plus ``query_block``/``cache_size`` for
            serve), routed by key; an option the chosen structure or
            backend does not support raises ``TypeError`` rather than
            being silently dropped.  Live-update options (DESIGN.md §8):
            ``capacity`` (delta-buffer slots) and ``merge`` (a
            ``repro.update.MergePolicy`` or kwargs dict) configure how
            :meth:`insert`/:meth:`delete` buffer and when they compact.
            Durability options (DESIGN.md §9): ``admission`` — what to do
            with a batch the delta buffer cannot absorb: ``"merge"``
            (default: fold it into a compaction; raises
            ``repro.update.BufferFullError`` instead when the merge
            policy has ``auto=False``) or ``"shed"`` (drop the batch,
            count it in ``stats.shed_mutations``); ``fault_plan`` — a
            ``repro.ft.FaultPlan`` threaded through the update engine
            and serving ladder for fault-injection tests.
        backend_opts: an explicit dict of backend-only options (e.g.
            tile/stream overrides ``{"block_w": 256, "stream": True,
            "autotune": "off"}``), merged with the backend options routed
            out of ``opts``.  Keys are strict: a key also given in
            ``opts`` raises ``TypeError`` (no silent precedence), and an
            option the backend factory does not accept raises
            ``TypeError`` from its signature.
        """
        explicit = dict(backend_opts or {})
        update_opts = {k: opts.pop(k) for k in list(opts) if k in _UPDATE_OPTS}
        build_opts = {k: v for k, v in opts.items() if k in _BUILD_OPTS}
        backend_opts = {k: v for k, v in opts.items() if k not in _BUILD_OPTS}
        for k, v in explicit.items():
            if k in backend_opts or k in build_opts or k in update_opts:
                raise TypeError(
                    f"backend_opts duplicates option {k!r} also passed "
                    f"directly"
                )
            if k in _BUILD_OPTS or k in _UPDATE_OPTS:
                raise TypeError(
                    f"backend_opts key {k!r} is a "
                    f"{'build' if k in _BUILD_OPTS else 'update'} option; "
                    f"pass it directly"
                )
            backend_opts[k] = v
        artifacts = BuildArtifacts(structure, mbrs, **build_opts)
        idx = cls(artifacts, get_backend(backend), **backend_opts)
        if "capacity" in update_opts or "merge" in update_opts:
            from repro.update import as_policy

            # validated eagerly so a bad option fails at build time
            idx._policy = as_policy(
                update_opts.get("merge"), update_opts.get("capacity")
            )
        admission = update_opts.get("admission")
        if admission is not None:
            if admission not in ADMISSION_MODES:
                raise ValueError(
                    f"unknown admission {admission!r}; expected one of "
                    f"{ADMISSION_MODES} (queueing lives in "
                    f"repro.checkpoint.DurableIndex)"
                )
            idx._admission = admission
        if update_opts.get("fault_plan") is not None:
            idx.bind_fault_plan(update_opts["fault_plan"])
        return idx

    def with_backend(self, backend: str, **backend_opts) -> "SpatialIndex":
        """A new index answering from the SAME build artifacts on another
        backend (build once, serve anywhere; lowerings are shared).  Live
        mutation state is shared too: the twin answers over the same
        base ∪ delta − tombstones, and mutations through either index are
        visible to both."""
        new = SpatialIndex(self.artifacts, get_backend(backend), **backend_opts)
        new._policy = self._policy
        new._admission = self._admission
        new._updates_cell = self._updates_cell
        if self._updates is not None:
            new._backend_base_epoch = self._updates.base_epoch
        if self._fault_plan is not None:
            new.bind_fault_plan(self._fault_plan)
        return new

    def extend(self, new_mbrs, *, flush: str = "auto") -> "SpatialIndex":
        """Batch insertion: a new index whose live set adds ``new_mbrs``.

        Routed through the live-update subsystem (DESIGN.md §8): the
        batch lands in the NEW index's delta buffer and merges by policy
        — no unconditional rebuild — while this index stays untouched.
        ``flush="always"`` restores the old eager behavior (compact
        immediately; on a never-mutated index that is exactly the legacy
        full re-build over the concatenated arrays, one device launch for
        ``build="device"``).  Batches larger than the buffer capacity
        merge directly either way.
        """
        if flush not in ("auto", "always"):
            raise ValueError(
                f"unknown flush {flush!r}; expected 'auto' or 'always'"
            )
        new_mbrs = np.asarray(new_mbrs, np.float64).reshape(-1, 4)
        if flush == "always" and self._updates is None:
            # Legacy path, bit-for-bit: a pristine re-build over the
            # concatenated object set, no live-update state attached.
            mbrs = np.concatenate([self.artifacts.mbrs, new_mbrs], axis=0)
            artifacts = BuildArtifacts(
                self.structure, mbrs, **self.artifacts.build_opts
            )
            clone = SpatialIndex(artifacts, self.spec, **self._backend_opts)
            clone._policy = self._policy
            return clone
        clone = self._snapshot()
        clone.insert(new_mbrs)
        if flush == "always":
            clone.flush()
        return clone

    def _snapshot(self) -> "SpatialIndex":
        """A new index over the same (current) base with an independent
        copy of any live-update state."""
        clone = SpatialIndex(self.artifacts, self.spec, **self._backend_opts)
        clone._policy = self._policy
        clone._admission = self._admission
        if self._updates is not None:
            clone._updates = self._updates.snapshot()
            clone._backend_base_epoch = clone._updates.base_epoch
        return clone

    # -- introspection -------------------------------------------------
    @property
    def artifacts(self) -> BuildArtifacts:
        """The CURRENT frozen base build (replaced at every merge)."""
        if self._updates is not None:
            return self._updates.base
        return self._artifacts

    @property
    def structure(self) -> str:
        return self.artifacts.structure

    @property
    def backend(self) -> str:
        return self.spec.name

    @property
    def n_objects(self) -> int:
        """Number of LIVE objects (base survivors + buffered inserts)."""
        if self._updates is not None:
            return self._updates.n_live
        return self.artifacts.n_objects

    @property
    def id_space(self) -> int:
        """Width of ``RegionResult.hits``: the dense global-id space
        ``[0, id_space)``.  Equals ``n_objects`` until live updates
        begin; append-only afterwards (deleted ids never recycle, §8)."""
        if self._updates is not None:
            return self._updates.id_capacity
        return self.artifacts.n_objects

    @property
    def schedule(self) -> LevelSchedule:
        return self.artifacts.schedule

    # -- durability / fault injection (DESIGN.md §9) -------------------
    def bind_fault_plan(self, plan) -> None:
        """Thread a :class:`repro.ft.FaultPlan` (or ``None`` to detach)
        through every layer that honors injection hooks: the update log
        (mid-merge kills, slow merges) and the serving ladder (forced
        launch failures)."""
        self._fault_plan = plan
        if self._updates is not None:
            self._updates.fault_plan = plan
        if hasattr(self._backend, "bind_fault_plan"):
            self._backend.bind_fault_plan(plan)
        if self._live_engine is not None:
            self._live_engine.bind_fault_plan(plan)

    def _drain_health(self, source) -> None:
        drain = getattr(source, "drain_health", None)
        if drain is not None:
            self.stats.absorb_health(drain())

    # -- live updates (DESIGN.md §8) -----------------------------------
    def _ensure_log(self):
        if self._updates is None:
            from repro.update import MergePolicy, UpdateLog

            structure = self._artifacts.structure
            build_opts = dict(self._artifacts.build_opts)
            self._updates = UpdateLog(
                self._artifacts,
                self._policy if self._policy is not None else MergePolicy(),
                rebuild=lambda mbrs: BuildArtifacts(
                    structure, mbrs, **build_opts
                ),
            )
            self._backend_base_epoch = self._updates.base_epoch
        if self._fault_plan is not None:
            self._updates.fault_plan = self._fault_plan
        return self._updates

    def _live(self):
        from repro.update.engine import LiveEngine

        if self._live_engine is None or self._live_engine.log is not self._updates:
            self._live_engine = LiveEngine(
                self._updates, self.spec.name, self._backend_opts
            )
            if self._fault_plan is not None:
                self._live_engine.bind_fault_plan(self._fault_plan)
        return self._live_engine

    def _current_backend(self):
        """The pristine backend adapter over the CURRENT base build,
        re-lowered lazily after a merge (possibly initiated through a
        ``with_backend`` twin sharing the same update log)."""
        if (
            self._updates is not None
            and self._backend_base_epoch != self._updates.base_epoch
        ):
            self._backend = self.spec.factory(
                self.artifacts, **self._backend_opts
            )
            self._backend_base_epoch = self._updates.base_epoch
        return self._backend

    def insert(self, new_mbrs) -> np.ndarray:
        """Insert objects ONLINE; returns their global ids.

        The batch lands in the device-resident delta buffer (O(1), no
        rebuild) and is immediately visible to every query path; the
        merge policy — or a full buffer — folds it into a fresh base
        build later.  Batches larger than the buffer capacity merge
        directly (one bulk rebuild over the live set, the §7 path).
        """
        new_mbrs = validate_mbrs(new_mbrs, what="insert batch")
        n = new_mbrs.shape[0]
        if n == 0:  # no-op: leave pristine state and epochs untouched
            return np.zeros((0,), np.int64)
        with _obs_trace.span("index.insert", n=n):
            log = self._ensure_log()
            if n > log.capacity:
                # Oversized batch: never bufferable, folds straight into
                # one merge — the documented bulk path, regardless of
                # admission.
                gids = log.merge_insert(new_mbrs)
                self.stats.flushes += 1
            elif not log.can_buffer(n):
                # Full buffer (free slots / id headroom exhausted):
                # admission control decides (DESIGN.md §9).
                if self._admission == "shed":
                    self.stats.shed_mutations += n
                    return np.zeros((0,), np.int64)
                if not log.policy.auto:
                    from repro.update import BufferFullError

                    raise BufferFullError(
                        f"delta buffer cannot absorb {n} insert(s) "
                        f"(fill {log.fill:.0%}) and the merge policy has "
                        f"auto=False; call flush() or enable auto merging"
                    )
                gids = log.merge_insert(new_mbrs)
                self.stats.flushes += 1
            else:
                gids = log.buffer_insert(new_mbrs)
                if log.policy.should_flush(
                    fill=log.fill, tombstone_ratio=log.tombstone_ratio
                ):
                    log.flush()
                    self.stats.flushes += 1
            self.stats.inserts += n
        return gids

    def delete(self, ids) -> None:
        """Delete live objects by global id (tombstone semantics, §8).

        Base objects stay physically in the frozen build, masked out of
        every hit set from this call on; buffered inserts free their
        delta slot.  Unknown or already-dead ids raise ``KeyError``.
        """
        ids = np.asarray(ids, np.int64).reshape(-1)
        if ids.size == 0:  # no-op: leave pristine state and epochs untouched
            return
        with _obs_trace.span("index.delete", n=ids.size):
            log = self._ensure_log()
            gids = log.delete(ids)
            self.stats.deletes += int(gids.shape[0])
            if (
                log.n_live > 0
                and log.policy.should_flush(
                    fill=log.fill, tombstone_ratio=log.tombstone_ratio
                )
            ):
                log.flush()
                self.stats.flushes += 1

    def flush(self) -> bool:
        """Manually merge buffer + tombstones into a fresh base build.

        Hit sets are bit-identical before and after (global ids are
        preserved); returns True if a merge actually ran.
        """
        if self._updates is None:
            return False
        with _obs_trace.span("index.flush"):
            if self._updates.flush():
                self.stats.flushes += 1
                return True
        return False

    def live_metrics(self):
        """Paper §5.2 structure-quality metrics (overlap, overcoverage,
        …) of the CURRENT live object set, evaluated on the mqr
        insertion-rule oracle tree — how the zero-overlap property is
        monitored under mutation (DESIGN.md §8)."""
        from repro.core import metrics as _metrics
        from repro.update.oracle import live_tree

        return _metrics.compute_metrics(live_tree(self))

    # -- observability (DESIGN.md §13) ---------------------------------
    def metrics(self, *, tenant: Optional[str] = None):
        """Snapshot :attr:`stats` into a :class:`repro.obs.MetricsRegistry`
        (render with ``.to_prometheus()`` or ``.to_json()``); ``tenant``
        adds a label to every sample."""
        from repro.obs import metrics as _obs_metrics

        reg = _obs_metrics.MetricsRegistry()
        labels = {"tenant": tenant} if tenant else None
        _obs_metrics.stats_into(reg, self.stats, labels=labels)
        return reg

    # -- durability (DESIGN.md §9) -------------------------------------
    def save(self, path) -> None:
        """Write a versioned on-disk snapshot of the full index state —
        base build (object table + level schedule + quantized tiles if
        materialized), delta buffer, tombstones, id space, and merge
        policy — atomically (tmp + rename).  :meth:`load` restores
        bit-identical region/point/knn/count answers on every backend.
        """
        from repro.checkpoint.spatial import save_index

        save_index(self, path)

    @classmethod
    def load(cls, path, *, backend: str = "pallas", **backend_opts
             ) -> "SpatialIndex":
        """Restore an index saved by :meth:`save` onto any backend.

        The snapshot is backend-agnostic; the level schedule is installed
        directly (no device rebuild runs at load time), so restore works
        even when the accelerator path that built the index is down.
        """
        from repro.checkpoint.spatial import load_index

        return load_index(path, backend=backend, **backend_opts)

    # -- queries -------------------------------------------------------
    def _region_raw(self, queries: np.ndarray):
        """Route a region batch: pristine backend, or the live engine
        once update state exists.  Returns
        ``(hits, visits, launches, base_levels-or-None)``."""
        if self._updates is None:
            hits, visits, launches = self._backend.region(queries)
            self._drain_health(self._backend)
            return hits, visits, launches, None
        live = self._live()
        hits, visits, launches = live.region(
            queries,
            base_region=lambda qs: self._current_backend().region(qs),
        )
        self._drain_health(live)
        return hits, visits, launches, self._updates.base.schedule.levels

    def _drain_launch_report(self, visits=None):
        """Drain + merge the kernel side channel for one logical batch;
        fills survivor counts from the sweep's own visits when the
        emitting path didn't compute them (DESIGN.md §13)."""
        if not _obs_counters.collecting():
            return None
        report = _obs_counters.merge_reports(_obs_counters.drain())
        if report is not None:
            if report.survivors_per_level is None and visits is not None:
                report.survivors_per_level = tuple(
                    int(x) for x in np.asarray(visits).sum(axis=0)
                )
            if report.backend is None:
                report.backend = self.spec.name
            self.stats.absorb_launch_report(report)
        return report

    def region(self, queries) -> RegionResult:
        """Batched region search over (Q, 4) query rectangles."""
        queries = np.asarray(queries, np.float32).reshape(-1, 4)
        with _obs_trace.span("index.region", backend=self.spec.name,
                             structure=self.structure,
                             queries=queries.shape[0]):
            hits, visits, launches, base_levels = self._region_raw(queries)
        self.stats.record(queries.shape[0], visits.sum(), launches)
        if base_levels is not None:
            self.stats.delta_accesses += int(visits[:, base_levels:].sum())
        return RegionResult(
            hits=hits, visits_per_level=visits, base_levels=base_levels,
            launch_report=self._drain_launch_report(visits),
        )

    def point(self, points) -> RegionResult:
        """Point queries (Q, 2) as degenerate rectangles.

        For point data the paper's zero-overlap property (§4) makes this a
        one-path search on the mqr-tree (§5.5); all backends inherit that
        access count through the same level sweep.
        """
        points = np.asarray(points, np.float32).reshape(-1, 2)
        return self.region(np.concatenate([points, points], axis=1))

    def count(self, queries) -> np.ndarray:
        """(Q,) number of objects overlapping each query rectangle."""
        return self.region(queries).counts

    def join(self, other: "SpatialIndex", predicate: str = "intersects"):
        """Batch spatial join against another index (DESIGN.md §10).

        Sweeps both indexes' level schedules against each other in one
        launch (this index's backend/precision picks the engine; the
        ``serve`` backend walks its degradation ladder) and returns a
        :class:`repro.index.join.JoinResult` whose pair-set is
        bit-identical to the brute-force nested-loop oracle over the two
        live object sets — including mid-buffer live state and
        tombstones on either side.  Only ``predicate="intersects"``
        (closed-boundary overlap, the paper's region semantics) is
        defined.
        """
        from .join import join_impl

        with _obs_trace.span("index.join", backend=self.spec.name,
                             other_backend=other.spec.name,
                             predicate=predicate):
            result, launches = join_impl(self, other, predicate)
        self.stats.joins += 1
        self.stats.record(1, result.pair_visits.sum(), launches)
        self.stats.delta_accesses += int(result.delta_tests.sum())
        return result

    def knn(self, points, k: int) -> KNNResult:
        """k nearest neighbours of each (Q, 2) point, by MBR min-distance.

        Host backend: exact branch-and-bound over the pointer tree (brute
        force for the pyramid, which has no pointer form).  Device
        backends: expanding-radius region schedule driven through the
        backend's fused sweep until ≥k survivors, one √2-margin confirming
        round, then a top-k distance epilogue in jnp (DESIGN.md §6).
        """
        points = np.asarray(points, np.float64).reshape(-1, 2)
        if not 1 <= k <= self.n_objects:
            raise ValueError(f"k={k} outside [1, {self.n_objects}]")
        live = self._updates
        with _obs_trace.span("index.knn", backend=self.spec.name, k=k,
                             queries=points.shape[0]):
            if self.spec.name == "host":
                if live is not None:
                    # Under mutation the base pointer tree is stale; the
                    # host oracle answers exactly from the live id-space
                    # table.
                    ids, dists, visits = _knn.knn_brute_masked(
                        live.mbr_table, live.alive, points, k
                    )
                elif self.artifacts.pointer_tree is not None:
                    ids, dists, visits = _knn.knn_pointer(
                        self.artifacts.pointer_tree, points, k
                    )
                else:
                    ids, dists, visits = _knn.knn_brute(
                        self.artifacts.mbrs, points, k
                    )
                self.stats.knn_queries += points.shape[0]
                self.stats.record(points.shape[0], visits.sum(), 0)
            else:
                def region_fn(qs):
                    hits, visits, launches, base_levels = self._region_raw(qs)
                    self.stats.record(0, visits.sum(), launches)
                    if base_levels is not None:
                        self.stats.delta_accesses += int(
                            visits[:, base_levels:].sum()
                        )
                    return hits, visits

                # Live indexes rank candidates over the id-space MBR table
                # (hits already exclude tombstones, so stale rows never
                # rank).
                obj_mbrs = (live.mbr_table if live is not None
                            else self.artifacts.mbrs)
                ids, dists, visits, rounds = _knn.knn_expanding(
                    region_fn, obj_mbrs, points, k
                )
                self.stats.knn_queries += points.shape[0]
                self.stats.knn_rounds += rounds
                self.stats.queries += points.shape[0]
                # fold every expanding-radius round's kernel ledger
                self._drain_launch_report()
        return KNNResult(ids=ids, dists=dists, visits=visits)
