"""`SpatialIndex` — the one façade over every tree × backend path.

The paper's contract is a single access method: build an index over MBRs,
run a region search, count the disk accesses.  The repro grew four entry
points (pointer trees, the levelized ``lax`` sweep, the fused Pallas
kernel, the batching server) and three build paths; this module folds them
back into one config-driven surface (DESIGN.md §6):

    idx = SpatialIndex.build(mbrs, structure="mqr", backend="pallas")
    res = idx.region(queries)        # RegionResult(hits, visits_per_level)
    res = idx.point(points)          # degenerate-rectangle fast path
    cnt = idx.count(queries)         # hits per query, no mask materialized
    knn = idx.knn(points, k=8)       # k-NN as a first-class query

``structure`` picks the build path (``mqr`` | ``rtree`` | ``pyramid``),
``backend`` the query engine (``host`` | ``lax`` | ``pallas`` | ``serve``)
via the registry in :mod:`repro.index.registry`.  Every backend reports
the paper's disk-access accounting through the same :class:`AccessStats`
shape, and every advertised (structure × backend) pair returns bit-identical
hits and per-level access counts (tests/test_index_api.py).

Two orthogonal throughput options (DESIGN.md §7): ``build="device"`` runs
the pyramid's bulk fixed point on-accelerator, emitting the
``LevelSchedule`` in one launch (no host pointer tree — and
:meth:`SpatialIndex.extend` makes batch insertion one more such launch);
``precision="compact"`` streams conservatively quantized uint16 MBR tiles
through the fused sweep at half the bytes/query, with an exact float32
confirming pass keeping hit sets bit-identical.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import bulk, mqrtree, rtree
from repro.core.flat import FlatTree, LevelSchedule, flatten, level_schedule, pyramid_schedule

from . import knn as _knn
from .registry import BackendSpec, get_backend

STRUCTURES = ("mqr", "rtree", "pyramid")

# Build-time options; everything else in **opts goes to the backend factory.
_BUILD_OPTS = ("levels", "max_entries", "build")


# ---------------------------------------------------------------------------
# Results and the shared access-accounting protocol
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RegionResult:
    """Result of a batched region (or point) search.

    hits:             (Q, n_objects) bool object-overlap mask.
    visits_per_level: (Q, L) int32 — node accesses by tree level, the
                      paper's "disk accesses" broken down by depth.  Every
                      backend reports the identical numbers (DESIGN.md §6).
    """

    hits: np.ndarray
    visits_per_level: np.ndarray

    @property
    def visits(self) -> np.ndarray:
        """(Q,) total accesses per query."""
        return self.visits_per_level.sum(axis=1)

    @property
    def counts(self) -> np.ndarray:
        """(Q,) number of objects found per query."""
        return self.hits.sum(axis=1)

    def ids(self, i: int) -> np.ndarray:
        """Object ids found by query ``i`` (ascending)."""
        return np.nonzero(self.hits[i])[0]


@dataclasses.dataclass(frozen=True)
class KNNResult:
    """Result of a batched k-nearest-neighbour query.

    ids:    (Q, k) int32 object ids, nearest first.
    dists:  (Q, k) float32 Euclidean MBR min-distances, ascending.
    visits: (Q,) int64 node accesses spent answering each query (for the
            device path: summed over every expanding-radius round).
    """

    ids: np.ndarray
    dists: np.ndarray
    visits: np.ndarray


@dataclasses.dataclass
class AccessStats:
    """The paper's disk-access accounting, identical across backends.

    One instance accumulates over the lifetime of a :class:`SpatialIndex`;
    backends feed it through :meth:`record` so the ledger has the same
    meaning whether the query ran on host pointers, the ``lax`` sweep, the
    fused Pallas kernel, or the batching server.
    """

    queries: int = 0
    node_accesses: int = 0
    launches: int = 0        # device dispatches (0 for the host backend)
    knn_queries: int = 0
    knn_rounds: int = 0      # expanding-radius region rounds issued

    def record(self, n_queries: int, accesses: int, launches: int) -> None:
        self.queries += int(n_queries)
        self.node_accesses += int(accesses)
        self.launches += int(launches)

    @property
    def accesses_per_query(self) -> float:
        return self.node_accesses / max(self.queries, 1)


# ---------------------------------------------------------------------------
# Build artifacts: what the registry lowers a structure to, lazily
# ---------------------------------------------------------------------------


def _reject_opts(structure: str, **opts) -> None:
    """A build option the chosen structure does not use fails loudly —
    same strictness contract as the backend options."""
    bad = [k for k, v in opts.items() if v is not None]
    if bad:
        raise TypeError(
            f"structure {structure!r} does not accept option(s) {bad}"
        )


class BuildArtifacts:
    """One built structure plus its lazily lowered forms.

    A backend declares which artifact it consumes — the pointer tree, the
    :class:`FlatTree`, or the :class:`LevelSchedule` — and pulls it from
    here; each lowering is computed once and cached, so switching backends
    over the same build (``SpatialIndex.with_backend``) is cheap.
    """

    def __init__(self, structure: str, mbrs: np.ndarray, *, levels=None,
                 max_entries=None, build=None):
        self.structure = structure
        self.mbrs = np.asarray(mbrs, np.float64).reshape(-1, 4)
        self.n_objects = self.mbrs.shape[0]
        # original user options, so extend() can re-run the same build
        self.build_opts = dict(levels=levels, max_entries=max_entries,
                               build=build)
        self.pointer_tree = None
        self.pyramid = None
        self._flat: Optional[FlatTree] = None
        self._schedule: Optional[LevelSchedule] = None
        self._quantized = None
        if structure == "mqr":
            _reject_opts(structure, levels=levels, max_entries=max_entries,
                         build=build)
            self.pointer_tree = mqrtree.build(self.mbrs)
        elif structure == "rtree":
            _reject_opts(structure, levels=levels, build=build)
            self.pointer_tree = rtree.build(
                self.mbrs,
                max_entries=rtree.DEFAULT_M if max_entries is None else max_entries,
            )
        elif structure == "pyramid":
            _reject_opts(structure, max_entries=max_entries)
            if build not in (None, "host", "device"):
                raise ValueError(
                    f"unknown build {build!r}; expected 'host' or 'device'"
                )
            if levels is None:
                levels = bulk.default_levels(self.n_objects)
            if build == "device":
                # Device-resident bulk build: the level fixed point runs
                # on-accelerator and emits the LevelSchedule directly —
                # no host pointer tree, no flatten() (DESIGN.md §7).
                from repro.kernels import ops

                self._schedule = ops.device_schedule(
                    np.asarray(self.mbrs, np.float32), levels=levels
                )
            else:
                self.pyramid = bulk.build_pyramid(
                    np.asarray(self.mbrs, np.float32), levels=levels
                )
        else:
            raise ValueError(
                f"unknown structure {structure!r}; expected one of {STRUCTURES}"
            )

    @property
    def flat(self) -> FlatTree:
        if self._flat is None:
            if self.pointer_tree is None:
                raise ValueError(
                    "structure 'pyramid' has no pointer tree / FlatTree form"
                )
            self._flat = flatten(self.pointer_tree)
        return self._flat

    @property
    def schedule(self) -> LevelSchedule:
        if self._schedule is None:
            if self.pyramid is not None:
                self._schedule = pyramid_schedule(self.pyramid, self.mbrs)
            else:
                self._schedule = level_schedule(self.flat)
        return self._schedule

    @property
    def quantized(self):
        """Compact uint16 tile form of :attr:`schedule` (DESIGN.md §7),
        quantized once and shared by every ``precision="compact"``
        backend over these artifacts."""
        if self._quantized is None:
            from repro.kernels import ops

            self._quantized = ops.quantize_schedule(self.schedule)
        return self._quantized


# ---------------------------------------------------------------------------
# The façade
# ---------------------------------------------------------------------------


class SpatialIndex:
    """Unified build/query surface over every structure × backend path."""

    def __init__(self, artifacts: BuildArtifacts, spec: BackendSpec, **backend_opts):
        if artifacts.structure not in spec.structures:
            raise ValueError(
                f"backend {spec.name!r} does not serve structure "
                f"{artifacts.structure!r} (serves: {sorted(spec.structures)})"
            )
        self.artifacts = artifacts
        self.spec = spec
        self.stats = AccessStats()
        self._backend_opts = dict(backend_opts)
        self._backend = spec.factory(artifacts, **backend_opts)

    # -- construction --------------------------------------------------
    @classmethod
    def build(cls, mbrs, *, structure: str = "mqr", backend: str = "pallas",
              **opts) -> "SpatialIndex":
        """Build a spatial index over ``mbrs`` (n, 4).

        structure: ``mqr`` (paper pointer tree) | ``rtree`` (Guttman
            baseline) | ``pyramid`` (bulk bottom-up fixed point).
        backend:   ``host`` (pointer/numpy oracle) | ``lax`` (jit'd level
            sweep) | ``pallas`` (fused single-launch kernel) | ``serve``
            (batching server: LRU cache + dedupe + vmap/pmap fan-out).
        opts: build options (``levels`` and ``build="host"|"device"`` for
            pyramid — ``"device"`` runs the bulk fixed point on-device and
            emits the ``LevelSchedule`` directly, no host pointer tree;
            ``max_entries`` for rtree) plus backend options
            (``block_w``/``interpret``/``precision="float32"|"compact"``
            for pallas and serve — ``"compact"`` streams conservatively
            quantized uint16 MBR tiles with an exact confirming pass, see
            DESIGN.md §7 — plus ``query_block``/``cache_size`` for
            serve), routed by key; an option the chosen structure or
            backend does not support raises ``TypeError`` rather than
            being silently dropped.
        """
        build_opts = {k: v for k, v in opts.items() if k in _BUILD_OPTS}
        backend_opts = {k: v for k, v in opts.items() if k not in _BUILD_OPTS}
        artifacts = BuildArtifacts(structure, mbrs, **build_opts)
        return cls(artifacts, get_backend(backend), **backend_opts)

    def with_backend(self, backend: str, **backend_opts) -> "SpatialIndex":
        """A new index answering from the SAME build artifacts on another
        backend (build once, serve anywhere; lowerings are shared)."""
        return SpatialIndex(self.artifacts, get_backend(backend), **backend_opts)

    def extend(self, new_mbrs) -> "SpatialIndex":
        """Batch insertion: a new index over ``mbrs + new_mbrs``.

        The paper inserts one object at a time; the array pipeline instead
        re-runs the (bulk) build over the concatenated object set — for
        ``build="device"`` that is one device launch, which at bulk sizes
        is far cheaper than per-object host insertion (DESIGN.md §7).
        Build options (``levels`` re-derived if it was auto) and backend
        options are inherited; the original index is untouched.
        """
        new_mbrs = np.asarray(new_mbrs, np.float64).reshape(-1, 4)
        mbrs = np.concatenate([self.artifacts.mbrs, new_mbrs], axis=0)
        artifacts = BuildArtifacts(
            self.structure, mbrs, **self.artifacts.build_opts
        )
        return SpatialIndex(artifacts, self.spec, **self._backend_opts)

    # -- introspection -------------------------------------------------
    @property
    def structure(self) -> str:
        return self.artifacts.structure

    @property
    def backend(self) -> str:
        return self.spec.name

    @property
    def n_objects(self) -> int:
        return self.artifacts.n_objects

    @property
    def schedule(self) -> LevelSchedule:
        return self.artifacts.schedule

    # -- queries -------------------------------------------------------
    def region(self, queries) -> RegionResult:
        """Batched region search over (Q, 4) query rectangles."""
        queries = np.asarray(queries, np.float32).reshape(-1, 4)
        hits, visits, launches = self._backend.region(queries)
        self.stats.record(queries.shape[0], visits.sum(), launches)
        return RegionResult(hits=hits, visits_per_level=visits)

    def point(self, points) -> RegionResult:
        """Point queries (Q, 2) as degenerate rectangles.

        For point data the paper's zero-overlap property (§4) makes this a
        one-path search on the mqr-tree (§5.5); all backends inherit that
        access count through the same level sweep.
        """
        points = np.asarray(points, np.float32).reshape(-1, 2)
        return self.region(np.concatenate([points, points], axis=1))

    def count(self, queries) -> np.ndarray:
        """(Q,) number of objects overlapping each query rectangle."""
        return self.region(queries).counts

    def knn(self, points, k: int) -> KNNResult:
        """k nearest neighbours of each (Q, 2) point, by MBR min-distance.

        Host backend: exact branch-and-bound over the pointer tree (brute
        force for the pyramid, which has no pointer form).  Device
        backends: expanding-radius region schedule driven through the
        backend's fused sweep until ≥k survivors, one √2-margin confirming
        round, then a top-k distance epilogue in jnp (DESIGN.md §6).
        """
        points = np.asarray(points, np.float64).reshape(-1, 2)
        if not 1 <= k <= self.n_objects:
            raise ValueError(f"k={k} outside [1, {self.n_objects}]")
        if self.spec.name == "host":
            if self.artifacts.pointer_tree is not None:
                ids, dists, visits = _knn.knn_pointer(
                    self.artifacts.pointer_tree, points, k
                )
            else:
                ids, dists, visits = _knn.knn_brute(self.artifacts.mbrs, points, k)
            self.stats.knn_queries += points.shape[0]
            self.stats.record(points.shape[0], visits.sum(), 0)
        else:
            def region_fn(qs):
                hits, visits, launches = self._backend.region(qs)
                self.stats.record(0, visits.sum(), launches)
                return hits, visits

            ids, dists, visits, rounds = _knn.knn_expanding(
                region_fn, self.artifacts.mbrs, points, k
            )
            self.stats.knn_queries += points.shape[0]
            self.stats.knn_rounds += rounds
            self.stats.queries += points.shape[0]
        return KNNResult(ids=ids, dists=dists, visits=visits)
