"""Backend registry for the :class:`SpatialIndex` façade.

Mirrors the ``configs/registry.py`` idiom: backends self-register with a
declaration of (a) which structures they serve and (b) which build
artifact they lower — the pointer tree, the ``FlatTree``, or the
``LevelSchedule``.  The façade consults :func:`get_backend` at build time
and :func:`advertised_pairs` is the single source of truth the parity
matrix test sweeps (tests/test_index_api.py).

Backends that accept ``precision="compact"`` (pallas, serve) additionally
pull the QUANTIZED lowering — the conservative uint16 tile form of the
schedule (DESIGN.md §7) — via ``BuildArtifacts.quantized``; like every
lowering it is computed once and cached, so float32 and compact engines
over the same build share one quantization.

The backend name also selects the join engine: ``SpatialIndex.join``
routes on the LEFT index's spec (``index/join.py`` — host/lax/pallas
pair-sweep twins, serve walking the degradation ladder), so registering
a backend here serves region/point/knn AND tree-vs-tree joins
(DESIGN.md §10) with one name.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Tuple

ARTIFACTS = ("pointer", "flat", "schedule")


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    structures: frozenset
    artifact: str           # which lowering of the build the backend consumes
    factory: Callable       # (BuildArtifacts, **opts) -> adapter with .region()
    doc: str = ""


_REGISTRY: Dict[str, BackendSpec] = {}
_BUILTINS_LOADED = False


def register_backend(name: str, *, structures: Iterable[str], artifact: str,
                     doc: str = ""):
    """Class/function decorator: declare a query backend.

    The factory is called as ``factory(artifacts, **backend_opts)`` and
    must return an adapter exposing ``region(queries) -> (hits (Q, n_obj)
    bool, visits (Q, L) int32, launches int)``.
    """
    if artifact not in ARTIFACTS:
        raise ValueError(f"artifact {artifact!r} not in {ARTIFACTS}")

    def deco(factory):
        _REGISTRY[name] = BackendSpec(
            name=name,
            structures=frozenset(structures),
            artifact=artifact,
            factory=factory,
            doc=doc,
        )
        return factory

    return deco


def get_backend(name: str) -> BackendSpec:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {backend_names()}"
        ) from None


def backend_names() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def advertised_pairs() -> List[Tuple[str, str]]:
    """Every (structure, backend) combination the registry serves."""
    _ensure_loaded()
    return sorted(
        (structure, spec.name)
        for spec in _REGISTRY.values()
        for structure in spec.structures
    )


def _ensure_loaded() -> None:
    # The built-in backends live in repro.index.backends and register on
    # import; imported lazily so registry.py stays import-cycle-free.  A
    # dedicated flag (not `if not _REGISTRY`) so user-registered backends
    # never mask the built-ins; set only after the import succeeds so a
    # transient import failure re-raises the real error on retry instead
    # of an empty-registry "unknown backend".
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        from . import backends  # noqa: F401

        _BUILTINS_LOADED = True
