"""Built-in query backends of the :class:`SpatialIndex` registry.

Four engines over the same search semantics (DESIGN.md §6):

* ``host``   — the oracle: per-level pointer search over the built tree
               (numpy level sweep for the pyramid, which has no pointers);
* ``lax``    — the whole level sweep as one jit'd ``lax.scan`` (pure XLA,
               no Pallas; runs anywhere JAX does);
* ``pallas`` — the fused single-launch kernel (``kernels.ops.pyramid_scan``);
* ``serve``  — the batching :class:`SpatialServer` (LRU cache, dedupe,
               vmap/pmap fan-out) as a backend adapter.

Every adapter returns ``(hits (Q, n_obj) bool, visits (Q, L) int32,
launches int)`` with bit-identical hits and per-level access counts, so
the façade's :class:`AccessStats` ledger means the same thing everywhere.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import mbr as M
from repro.core.flat import LevelSchedule
from repro.kernels import ops
from repro.obs import counters as _obs_counters
from repro.obs import trace as _obs_trace

from .registry import register_backend
from .trees import node_children, node_mbr, tree_height

ALL_STRUCTURES = ("mqr", "rtree", "pyramid")


def _overlap_np(a, b):
    """Closed-boundary rectangle intersection, broadcasting.

    Pure indexing/comparison ops, so the same function serves numpy arrays
    (host sweep) and traced jnp arrays (the jitted lax sweep) — ONE copy of
    the boundary semantics every backend's parity depends on."""
    return (
        (a[..., 0] <= b[..., 2])
        & (b[..., 0] <= a[..., 2])
        & (a[..., 1] <= b[..., 3])
        & (b[..., 1] <= a[..., 3])
    )


# ---------------------------------------------------------------------------
# host
# ---------------------------------------------------------------------------


@register_backend(
    "host",
    structures=ALL_STRUCTURES,
    artifact="pointer",
    doc="per-level pointer search (numpy sweep for the pyramid); the oracle",
)
class HostBackend:
    def __init__(self, artifacts):
        self.artifacts = artifacts
        self.tree = artifacts.pointer_tree
        if self.tree is not None:
            self.levels = tree_height(self.tree)
        else:
            self.schedule = artifacts.schedule
            self.levels = self.schedule.levels

    def region(self, queries: np.ndarray):
        with _obs_trace.span("backend.host", queries=queries.shape[0]):
            return self._region(queries)

    def _region(self, queries: np.ndarray):
        if self.tree is None:
            hits, visits = schedule_region_numpy(self.schedule, queries)
            return hits, visits, 0
        nq = queries.shape[0]
        hits = np.zeros((nq, max(self.artifacts.n_objects, 1)), bool)
        visits = np.zeros((nq, self.levels), np.int32)
        for i, q in enumerate(queries):
            qq = np.asarray(q, np.float64)
            stack = [(self.tree.root, 0)]
            while stack:
                node, d = stack.pop()
                if node_mbr(node) is None:
                    continue
                visits[i, d] += 1
                for embr, child, obj in node_children(node):
                    if not M.overlaps(embr, qq):
                        continue
                    if child is not None:
                        stack.append((child, d + 1))
                    else:
                        hits[i, obj] = True
        return hits, visits, 0


def schedule_region_numpy(schedule: LevelSchedule, queries: np.ndarray):
    """Reference level sweep over a :class:`LevelSchedule`, pure numpy.

    Same recurrence as the fused kernel: ``active[l] = active[l-1][parent]
    & overlaps`` (level 0 unconditional at the root slot for tree
    schedules).  Returns ``(hits, visits (Q, L))``.
    """
    queries = np.asarray(queries, np.float32)
    nq = queries.shape[0]
    levels, _, w = schedule.mbr_cm.shape
    mbr = schedule.mbr_cm.transpose(0, 2, 1)  # (L, W, 4)
    acts = np.zeros((levels, nq, w), bool)
    for l in range(levels):
        ov = _overlap_np(mbr[l][None, :, :], queries[:, None, :])
        if l == 0:
            if schedule.root_unconditional:
                act = np.zeros((nq, w), bool)
                act[:, 0] = True
            else:
                act = ov
        else:
            act = ov & acts[l - 1][:, schedule.parent[l]]
        acts[l] = act
    visits = acts.sum(axis=2).T.astype(np.int32)
    entry_act = acts[schedule.obj_level, :, schedule.obj_slot].T  # (Q, E)
    if schedule.test_object_mbr:
        entry_act = entry_act & _overlap_np(
            schedule.obj_mbr[None, :, :], queries[:, None, :]
        )
    hits = np.zeros((nq, max(schedule.n_objects, 1)), bool)
    np.maximum.at(hits, (slice(None), schedule.obj_id), entry_act)
    return hits, visits


# ---------------------------------------------------------------------------
# lax
# ---------------------------------------------------------------------------


@register_backend(
    "lax",
    structures=ALL_STRUCTURES,
    artifact="schedule",
    doc="whole level sweep as one jit'd lax.scan (pure XLA, no Pallas)",
)
class LaxBackend:
    def __init__(self, artifacts):
        sched = artifacts.schedule
        self._run = _make_lax_sweep(sched)

    def region(self, queries: np.ndarray):
        with _obs_trace.span("backend.lax", queries=queries.shape[0]):
            hits, visits = self._run(jnp.asarray(queries, jnp.float32))
            return np.asarray(hits), np.asarray(visits), 1


def _make_lax_sweep(schedule: LevelSchedule):
    mbr_rm = jnp.asarray(schedule.mbr_cm.transpose(0, 2, 1))  # (L, W, 4)
    parent = jnp.asarray(schedule.parent)
    obj_mbr = jnp.asarray(schedule.obj_mbr)
    obj_level = jnp.asarray(schedule.obj_level)
    obj_slot = jnp.asarray(schedule.obj_slot)
    obj_id = jnp.asarray(schedule.obj_id)
    levels, width, _ = mbr_rm.shape
    root_unconditional = schedule.root_unconditional
    test_object_mbr = schedule.test_object_mbr
    n_obj = schedule.n_objects

    @jax.jit
    def run(queries):
        nq = queries.shape[0]

        def step(prev, xs):
            mbr_l, parent_l, l = xs
            ov = _overlap_np(mbr_l[None, :, :], queries[:, None, :])  # (Q, W)
            pa = jnp.take(prev, parent_l, axis=1)
            if root_unconditional:
                act0 = jnp.zeros((nq, width), bool).at[:, 0].set(True)
            else:
                act0 = ov
            act = jnp.where(l == 0, act0, pa & ov)
            return act, act

        init = jnp.zeros((nq, width), bool)
        _, acts = jax.lax.scan(
            step, init, (mbr_rm, parent, jnp.arange(levels))
        )  # acts: (L, Q, W)
        visits = jnp.transpose(acts.sum(axis=2, dtype=jnp.int32))
        hit = jnp.transpose(acts[obj_level, :, obj_slot])  # (Q, E)
        if test_object_mbr:
            hit = hit & _overlap_np(obj_mbr[None, :, :], queries[:, None, :])
        hits = jnp.zeros((nq, max(n_obj, 1)), jnp.bool_)
        hits = hits.at[:, obj_id].max(hit)
        return hits, visits

    return run


# ---------------------------------------------------------------------------
# pallas
# ---------------------------------------------------------------------------


def _check_precision(precision: str) -> None:
    if precision not in ("float32", "compact", "compact8"):
        raise ValueError(
            f"unknown precision {precision!r}; expected 'float32', "
            f"'compact' or 'compact8'"
        )


@register_backend(
    "pallas",
    structures=ALL_STRUCTURES,
    artifact="schedule",
    doc="fused single-launch Pallas sweep (kernels.ops.pyramid_scan); "
        "precision='compact' streams conservative uint16 tiles, "
        "'compact8' adds coarse uint8 upper-level tiles; stream=True "
        "double-buffers MBR tiles from HBM; block_w=None autotunes",
)
class PallasBackend:
    """Fused-kernel adapter with autotuned tiling (DESIGN.md §12).

    ``block_w=None`` (the default) leaves the tile width to the
    autotuner: ``autotune="auto"`` times the candidate grid of
    :mod:`repro.kernels.autotune` on the first query batch once the slot
    grid is wide enough to matter, ``"on"`` always does, ``"off"`` (or
    any explicit ``block_w``/``query_block``) pins the fixed
    configuration.  Winners are cached in ``BuildArtifacts.tuned`` keyed
    by shape, so ``with_backend`` twins reuse the measurement.
    """

    def __init__(self, artifacts, *, block_w: int | None = None,
                 interpret=None, precision: str = "float32",
                 stream: bool = False, autotune: str = "auto",
                 query_block: int | None = None):
        _check_precision(precision)
        if autotune not in ("auto", "on", "off"):
            raise ValueError(
                f"unknown autotune {autotune!r}; expected 'auto', 'on' or "
                f"'off'"
            )
        if stream and precision == "compact8":
            raise ValueError(
                "stream=True is not supported with precision='compact8' "
                "(the hierarchical sweep is VMEM-resident; DESIGN.md §12)"
            )
        self.precision = precision
        self.schedule = artifacts.schedule
        # Quantized once per BuildArtifacts, shared across backends.
        if precision == "compact":
            self.qschedule = artifacts.quantized
        elif precision == "compact8":
            self.qschedule = artifacts.quantized8
        else:
            self.qschedule = None
        self.block_w = block_w
        self.query_block = query_block
        self.stream = stream
        self.autotune = autotune
        self.interpret = interpret
        # Shape -> TileConfig winners, shared across backends over the
        # same artifacts (restore()'d artifacts start empty).
        self._tuned = getattr(artifacts, "tuned", None)
        if self._tuned is None:
            self._tuned = {}

    def _config(self, queries: np.ndarray):
        from repro.kernels.autotune import (
            AUTO_MIN_WIDTH,
            PROBE_QUERIES,
            TileConfig,
            candidates,
            shape_key,
            tune,
        )

        fixed = TileConfig(
            128 if self.block_w is None else self.block_w,
            self.query_block, True,
        )
        if (
            self.autotune == "off"
            or self.block_w is not None
            or self.query_block is not None
        ):
            return fixed
        width = self.schedule.width
        if self.autotune == "auto" and width < AUTO_MIN_WIDTH:
            return fixed
        nq = queries.shape[0]
        key = shape_key(
            width, self.schedule.levels, nq, self.precision, self.stream
        )
        cfg = self._tuned.get(key)
        if cfg is None:
            probe = queries[:PROBE_QUERIES]
            cands = candidates(
                width, nq, precision=self.precision, stream=self.stream
            )
            cfg, _ = tune(
                lambda c: lambda: np.asarray(self._run(probe, c)[0]), cands
            )
            self._tuned[key] = cfg
        return cfg

    def _run_one(self, queries: np.ndarray, cfg):
        if not cfg.levels_in_grid:
            # Per-level launch plan — float32 non-streamed only (the
            # candidate grid never proposes it elsewhere); hits and
            # visits are bit-identical to the fused sweep.
            hits, visits, n_launches = ops.per_level_region_search(
                self.schedule, queries, block_w=cfg.block_w
            )
            return hits, visits, n_launches
        if self.precision == "compact":
            hits, visits = ops.pyramid_scan_compact(
                self.qschedule, queries, block_w=cfg.block_w,
                interpret=self.interpret, stream=self.stream,
            )
        elif self.precision == "compact8":
            hits, visits = ops.pyramid_scan_compact8(
                self.qschedule, queries, block_w=cfg.block_w,
                interpret=self.interpret,
            )
        else:
            hits, visits = ops.pyramid_scan(
                self.schedule, queries, block_w=cfg.block_w,
                interpret=self.interpret, stream=self.stream,
            )
        return hits, visits, 1

    def _run(self, queries: np.ndarray, cfg):
        qb = cfg.query_block
        if qb and queries.shape[0] > qb:
            hs, vs, launches = [], [], 0
            for i in range(0, queries.shape[0], qb):
                h, v, n = self._run_one(queries[i:i + qb], cfg)
                hs.append(np.asarray(h))
                vs.append(np.asarray(v))
                launches += n
            return np.concatenate(hs), np.concatenate(vs), launches
        return self._run_one(queries, cfg)

    def region(self, queries: np.ndarray):
        queries = np.asarray(queries, np.float32)
        with _obs_trace.span("backend.pallas", queries=queries.shape[0],
                             precision=self.precision, stream=self.stream):
            cfg = self._config(queries)
            if _obs_counters.collecting():
                _obs_counters.drain()  # discard autotune-probe emissions
            hits, visits, launches = self._run(queries, cfg)
            hits, visits = np.asarray(hits), np.asarray(visits)
        if _obs_counters.collecting():
            # The query_block chunking above emits one report per chunk;
            # re-emit them merged, stamped with the tiling actually used
            # (the façade drains this into RegionResult.launch_report).
            report = _obs_counters.merge_reports(_obs_counters.drain())
            if report is not None:
                report.query_block = cfg.query_block
                report.block_w = cfg.block_w
                report.backend = "pallas"
                if report.survivors_per_level is None:
                    report.survivors_per_level = tuple(
                        int(x) for x in visits.sum(axis=0)
                    )
                _obs_counters.emit(report)
        return hits, visits, launches


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------


@register_backend(
    "serve",
    structures=ALL_STRUCTURES,
    artifact="schedule",
    doc="batching SpatialServer: LRU cache + dedupe + vmap/pmap fan-out; "
        "precision='compact' serves the quantized tile form",
)
class ServeBackend:
    def __init__(self, artifacts, *, query_block: int = 16,
                 cache_size: int = 4096, block_w: int = 128,
                 interpret=None, precision: str = "float32",
                 ladder=None, max_retries: int = 2, backoff: float = 0.05,
                 fault_plan=None):
        _check_precision(precision)
        # Imported here: launch.spatial_serve itself builds on the index
        # package's kernel API, keep the layers acyclic at import time.
        from repro.launch.spatial_serve import LADDER, SpatialServer

        if precision == "compact":
            quantized = artifacts.quantized
        elif precision == "compact8":
            quantized = artifacts.quantized8
        else:
            quantized = None
        self.server = SpatialServer(
            artifacts.schedule,
            query_block=query_block,
            cache_size=cache_size,
            block_w=block_w,
            interpret=interpret,
            precision=precision,
            quantized=quantized,
            ladder=LADDER if ladder is None else ladder,
            max_retries=max_retries,
            backoff=backoff,
            fault_plan=fault_plan,
        )

    def region(self, queries: np.ndarray):
        with _obs_trace.span("backend.serve", queries=queries.shape[0]):
            before = self.server.stats.kernel_launches
            hits, visits = self.server.search(queries)
            return hits, visits, self.server.stats.kernel_launches - before

    def bind_fault_plan(self, plan) -> None:
        self.server.bind_fault_plan(plan)

    def drain_health(self) -> dict:
        return self.server.drain_health()
