"""k-nearest-neighbour engines behind ``SpatialIndex.knn``.

The Symmetric M-tree line of related work treats k-NN as the peer of
region search; here it is first-class on every backend (DESIGN.md §6):

* :func:`knn_pointer` — exact best-first branch-and-bound over the pointer
  tree (the host oracle), MBR min-distance priority queue; generalizes
  ``mqrtree.knn_search`` to both pointer structures.
* :func:`knn_brute` — exact scan over object MBRs (host path for the
  pyramid structure, which has no pointer form).
* :func:`knn_expanding` — the device path: an expanding-radius *region
  schedule* drives the backend's fused level sweep until every point has
  ≥k survivors, one √2-margin confirming round closes the corner gap of
  the square probe, and a top-k distance epilogue in jnp ranks the
  survivors.  Exactness: survivors of an L∞ ball of radius r all lie
  within Euclidean distance r·√2, so the kth distance d_k ≤ r·√2, and the
  confirming round's L∞ ball of radius r·√2 ⊇ the Euclidean d_k-ball —
  no true neighbour can be outside the final candidate set.

All engines report distances as Euclidean point-to-MBR min-distances
(0 inside the rectangle) and the paper's access counts.
"""

from __future__ import annotations

from typing import Tuple

import heapq

import numpy as np

from .trees import node_children as _node_children
from .trees import node_mbr as _node_mbr

import jax.numpy as jnp
from jax import lax

# > sqrt(2): covers the square-vs-circle corner gap with float slack.
_CONFIRM_MARGIN = 1.5


def _mindist_np(points: np.ndarray, mbrs: np.ndarray) -> np.ndarray:
    """Euclidean min-distance point→MBR, (Q, 2) × (N, 4) -> (Q, N)."""
    px = points[:, 0][:, None]
    py = points[:, 1][:, None]
    dx = np.maximum(np.maximum(mbrs[None, :, 0] - px, px - mbrs[None, :, 2]), 0.0)
    dy = np.maximum(np.maximum(mbrs[None, :, 1] - py, py - mbrs[None, :, 3]), 0.0)
    return np.sqrt(dx * dx + dy * dy)


def _mindist_point(p: np.ndarray, mbr) -> float:
    dx = max(mbr[0] - p[0], 0.0, p[0] - mbr[2])
    dy = max(mbr[1] - p[1], 0.0, p[1] - mbr[3])
    return float(np.sqrt(dx * dx + dy * dy))


def knn_pointer(tree, points: np.ndarray, k: int):
    """Exact best-first k-NN over an ``MQRTree`` or ``RTree``.

    Returns ``(ids (Q, k) int32, dists (Q, k) float32, visits (Q,) int64)``
    — visits counts expanded nodes, the paper's disk accesses.

    Equal distances resolve by lowest object id — the same rule as the
    brute-force scan (stable argsort) and the device top-k (``lax.top_k``
    prefers the lower index): heap keys order nodes *before* objects at
    the same distance, so every object at distance ≤ d is enqueued before
    any object at distance d is emitted, and among equal-distance objects
    the id is the tiebreak.
    """
    nq = points.shape[0]
    ids = np.zeros((nq, k), np.int32)
    dists = np.zeros((nq, k), np.float32)
    visits = np.zeros((nq,), np.int64)
    for i in range(nq):
        p = points[i]
        # key: (dist, kind, id) — kind 0 = node (expand first), 1 = object.
        heap = [(0.0, 0, 0, tree.root)]
        counter = 1
        got = 0
        while heap and got < k:
            d, kind, key, item = heapq.heappop(heap)
            if kind == 0:
                node = item
                if _node_mbr(node) is None:
                    continue
                visits[i] += 1
                for embr, child, obj in _node_children(node):
                    if child is not None:
                        counter += 1
                        heapq.heappush(
                            heap, (_mindist_point(p, embr), 0, counter, child)
                        )
                    else:
                        heapq.heappush(
                            heap, (_mindist_point(p, embr), 1, obj, None)
                        )
            else:
                ids[i, got] = key
                dists[i, got] = d
                got += 1
    return ids, dists, visits


def knn_brute(obj_mbrs: np.ndarray, points: np.ndarray, k: int):
    """Exact k-NN by scanning every object MBR (pyramid host path)."""
    obj_mbrs = np.asarray(obj_mbrs)
    return knn_brute_masked(
        obj_mbrs, np.ones((obj_mbrs.shape[0],), bool), points, k
    )


def knn_brute_masked(mbr_table: np.ndarray, alive: np.ndarray,
                     points: np.ndarray, k: int):
    """Exact k-NN over the LIVE rows of an id-space MBR table — the host
    path once live updates begin (DESIGN.md §8).  Dead and unallocated
    rows are masked to +inf distance, so ids and tie-breaks (lowest
    global id first, stable argsort) resolve exactly as
    :func:`knn_brute` would on the compacted live set."""
    d = _mindist_np(
        np.asarray(points, np.float64), np.asarray(mbr_table, np.float64)
    )
    d = np.where(alive[None, :], d, np.inf)
    order = np.argsort(d, axis=1, kind="stable")[:, :k]
    dists = np.take_along_axis(d, order, axis=1).astype(np.float32)
    visits = np.full((points.shape[0],), int(alive.sum()), np.int64)
    return order.astype(np.int32), dists, visits


def knn_expanding(
    region_fn,
    obj_mbrs: np.ndarray,
    points: np.ndarray,
    k: int,
    *,
    max_rounds: int = 40,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Device k-NN: expanding-radius region schedule + jnp top-k epilogue.

    ``region_fn(queries (Q, 4)) -> (hits (Q, n_obj), visits (Q, L))`` is
    the backend's batched region search (the fused sweep for ``pallas`` /
    ``serve``).  Query shape is constant across rounds, so the device
    function compiles once.  Ties resolve by lowest object id
    (``lax.top_k`` prefers the lower index), matching :func:`knn_pointer`
    and :func:`knn_brute`.

    Returns ``(ids (Q, k), dists (Q, k), visits (Q,), rounds)``.
    """
    obj_mbrs = np.asarray(obj_mbrs, np.float64)
    points = np.asarray(points, np.float64)
    nq = points.shape[0]
    n = obj_mbrs.shape[0]

    # Initial radius from the density estimate: a square expected to hold
    # ~k objects under a uniform spread of n objects over the data extent.
    extent = max(
        obj_mbrs[:, 2].max() - obj_mbrs[:, 0].min(),
        obj_mbrs[:, 3].max() - obj_mbrs[:, 1].min(),
        1e-6,
    )
    r = np.full((nq,), 0.5 * extent * np.sqrt(k / max(n, 1)) + 1e-6)

    total_visits = np.zeros((nq,), np.int64)
    rounds = 0
    satisfied = np.zeros((nq,), bool)
    for _ in range(max_rounds):
        queries = np.stack(
            [points[:, 0] - r, points[:, 1] - r,
             points[:, 0] + r, points[:, 1] + r],
            axis=1,
        ).astype(np.float32)
        hits, visits = region_fn(queries)
        rounds += 1
        total_visits += np.asarray(visits).sum(axis=1)
        satisfied = np.asarray(hits).sum(axis=1) >= k
        if satisfied.all():
            break
        # double only the radii still short of k survivors; satisfied
        # points keep their radius (their result is already final-bound)
        r = np.where(satisfied, r, r * 2.0)
    if not satisfied.all():
        raise RuntimeError(
            f"knn radius expansion did not reach k={k} survivors "
            f"in {max_rounds} rounds"
        )

    # Confirming round: the square of radius r·√2 covers the Euclidean
    # d_k-ball (see module docstring), making the candidate set exact.
    rf = r * _CONFIRM_MARGIN
    queries = np.stack(
        [points[:, 0] - rf, points[:, 1] - rf,
         points[:, 0] + rf, points[:, 1] + rf],
        axis=1,
    ).astype(np.float32)
    hits, visits = region_fn(queries)
    rounds += 1
    total_visits += np.asarray(visits).sum(axis=1)

    # Top-k distance epilogue in jnp over the surviving candidates.
    pts = jnp.asarray(points, jnp.float32)
    mb = jnp.asarray(obj_mbrs, jnp.float32)
    px, py = pts[:, 0][:, None], pts[:, 1][:, None]
    dx = jnp.maximum(jnp.maximum(mb[None, :, 0] - px, px - mb[None, :, 2]), 0.0)
    dy = jnp.maximum(jnp.maximum(mb[None, :, 1] - py, py - mb[None, :, 3]), 0.0)
    d = jnp.sqrt(dx * dx + dy * dy)
    d = jnp.where(jnp.asarray(hits), d, jnp.inf)
    neg_top, ids = lax.top_k(-d, k)
    return (
        np.asarray(ids, np.int32),
        np.asarray(-neg_top, np.float32),
        total_visits,
        rounds,
    )
