"""Unified spatial index façade (DESIGN.md §6).

One build/query contract over every structure × backend path:

    from repro.index import SpatialIndex
    idx = SpatialIndex.build(mbrs, structure="mqr", backend="pallas")
    idx.region(queries)   # RegionResult(hits, visits_per_level)
    idx.knn(points, k=8)  # KNNResult(ids, dists, visits)
"""

from .api import (
    STRUCTURES,
    AccessStats,
    BuildArtifacts,
    KNNResult,
    RegionResult,
    SpatialIndex,
)
from .registry import (
    BackendSpec,
    advertised_pairs,
    backend_names,
    get_backend,
    register_backend,
)

__all__ = [
    "STRUCTURES",
    "AccessStats",
    "BackendSpec",
    "BuildArtifacts",
    "KNNResult",
    "RegionResult",
    "SpatialIndex",
    "advertised_pairs",
    "backend_names",
    "get_backend",
    "register_backend",
]
