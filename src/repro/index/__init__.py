"""Unified spatial index façade (DESIGN.md §6).

One build/query contract over every structure × backend path:

    from repro.index import SpatialIndex
    idx = SpatialIndex.build(mbrs, structure="mqr", backend="pallas")
    idx.region(queries)   # RegionResult(hits, visits_per_level)
    idx.knn(points, k=8)  # KNNResult(ids, dists, visits)
    gids = idx.insert(more_mbrs)   # live updates: delta buffer + merge
    idx.delete(gids[:2])           # tombstones (DESIGN.md §8)
"""

from repro.update import MergePolicy

from .api import (
    STRUCTURES,
    AccessStats,
    BuildArtifacts,
    InvalidQueryError,
    KNNResult,
    RegionResult,
    SpatialIndex,
    validate_queries,
)
from .join import JoinResult
from .registry import (
    BackendSpec,
    advertised_pairs,
    backend_names,
    get_backend,
    register_backend,
)

__all__ = [
    "STRUCTURES",
    "AccessStats",
    "BackendSpec",
    "BuildArtifacts",
    "InvalidQueryError",
    "JoinResult",
    "KNNResult",
    "MergePolicy",
    "RegionResult",
    "SpatialIndex",
    "advertised_pairs",
    "backend_names",
    "get_backend",
    "register_backend",
    "validate_queries",
]
