"""Shared pointer-tree adapters: one structure dispatch for both the host
region search (`backends.HostBackend`) and the host k-NN (`knn.knn_pointer`),
so a new pointer node shape is wired up in exactly one place."""

from __future__ import annotations


def node_children(node):
    """(mbr, child, obj) triples of one node — mqr and R nodes unified."""
    if hasattr(node, "locs"):  # mqr Node
        return [(e.mbr, e.node if e.is_node else None, e.obj)
                for _, e in node.entries()]
    return [(e.mbr, e.child, e.obj) for e in node.entries]  # RNode


def node_mbr(node):
    """Node MBR — attribute on mqr nodes, method on R nodes."""
    return node.mbr if not callable(node.mbr) else node.mbr()


def tree_height(tree) -> int:
    height = 0
    for _, depth in tree.iter_nodes():
        height = max(height, depth)
    return height
