"""Batch tree-vs-tree spatial join behind the ``SpatialIndex`` façade.

``left.join(right)`` pairs two indexes — any structure × any structure,
live or pristine — through one levelized pair sweep (DESIGN.md §10):

* both sides' :class:`~repro.core.flat.LevelSchedule`s are trimmed to
  their common depth ``K = min(levels_a, levels_b)`` and swept
  level-synchronized (the fused Pallas kernel
  :func:`repro.kernels.ops.fused_join`, its plain-XLA ``lax`` twin, or
  the pure-numpy ``host`` twin — the LEFT index's backend picks);
* ``precision="compact"`` (on the left index) quantizes BOTH sides'
  tiles outward onto one JOINT uint16 grid spanning the union of the two
  live object sets — integer overlap is only meaningful on a shared
  grid; node boxes of stale (tombstoned) base objects may poke past the
  joint domain, which the clip-monotone argument of
  :func:`repro.kernels.quantize.quantize_rows` covers;
* live state rides along exactly like ``fused_search_live``: the frozen
  base×base structure goes through the sweep, delta-buffer rows on
  either side become unconditional candidate rows (a flat cross-scan —
  the buffer is O(capacity), so structural pruning buys nothing the
  exact pass doesn't), and tombstones are masked in the epilogue;
* every engine ends with the same exact float32 object-MBR confirming
  pass, so the returned pair-set is bit-identical to the brute-force
  O(n·m) nested-loop oracle on every structure × backend × precision
  (tests/test_join.py) — precision and pruning quality only move the
  pair-visit ledger.

The ``serve`` backend walks the degradation ladder (pallas → lax →
host) per join call, honouring any bound :class:`repro.ft.FaultPlan`,
and records rung dispatches / degraded calls in the index's
:class:`~repro.index.api.AccessStats` — the same health ledger the
region path uses.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import flat
from repro.core.flat import CELLS

PREDICATES = ("intersects",)

#: degradation-ladder rung order for serve-backend joins
JOIN_LADDER = ("pallas", "lax", "host")


@dataclasses.dataclass(frozen=True)
class JoinResult:
    """Result of ``left.join(right)``.

    pairs:       (id_space_left, id_space_right) bool — pair (i, j) is
                 True iff live object ``i`` of the left index and live
                 object ``j`` of the right index overlap (closed
                 boundaries, the paper's region semantics).
    pair_visits: (K + 2,) int64 — tile-pair tests per synchronized sweep
                 level (the join analogue of the paper's disk accesses),
                 then one column per side counting the delta-buffer
                 cross-scan's exact tests.
    base_levels: K, the synchronized sweep depth (== min of the two
                 schedules' level counts).
    """

    pairs: np.ndarray
    pair_visits: np.ndarray
    base_levels: int

    @property
    def n_pairs(self) -> int:
        return int(self.pairs.sum())

    @property
    def sweep_visits(self) -> np.ndarray:
        """Per-level tile-pair tests of the structure sweep alone."""
        return self.pair_visits[: self.base_levels]

    @property
    def delta_tests(self) -> np.ndarray:
        """(2,) exact tests spent on (left, right) delta-buffer rows."""
        return self.pair_visits[self.base_levels:]

    def pair_list(self) -> np.ndarray:
        """(P, 2) int64 (left_id, right_id) pairs, lexicographic."""
        return np.argwhere(self.pairs)


@dataclasses.dataclass(frozen=True)
class _Side:
    """One join operand lowered to the kernel's view of it."""

    sched: flat.LevelSchedule
    table: np.ndarray      # (N, 4) float32 global-id MBR table
    alive: np.ndarray      # (N,) bool
    delta: np.ndarray      # (N,) bool — ids in the delta buffer
    entry_gid: np.ndarray  # (E,) int32 — schedule entries -> global ids


def _side_state(idx) -> _Side:
    """Lower one index (pristine or live) to its join-side arrays.

    Live indexes expose the frozen base schedule for the structure sweep
    (delta rows become unconditional candidates), the full global-id MBR
    table, the tombstone mask, and the base-entry -> global-id remap —
    the same decomposition ``UpdateLog.augmented`` feeds the live region
    sweep.
    """
    log = idx._updates
    sched = idx.artifacts.schedule
    if log is None:
        table = np.asarray(idx.artifacts.mbrs, np.float32)
        n = table.shape[0]
        return _Side(
            sched=sched,
            table=table,
            alive=np.ones((n,), bool),
            delta=np.zeros((n,), bool),
            entry_gid=np.asarray(sched.obj_id, np.int32),
        )
    return _Side(
        sched=sched,
        table=log.mbr_table.astype(np.float32),
        alive=log.alive.copy(),
        delta=log.delta_id_mask(),
        entry_gid=log.base_gids[sched.obj_id].astype(np.int32),
    )


def _joint_grid(side_a: _Side, side_b: _Side):
    """Shared uint16 grid over the union of both LIVE object sets —
    coordinate-major (origin, inv_cell) exactly like
    :func:`repro.kernels.quantize.grid_params`, but spanning two
    indexes.  Integer pair overlap is only conservative when both sides
    round outward onto the SAME grid."""
    rows = np.concatenate(
        [side_a.table[side_a.alive], side_b.table[side_b.alive]], axis=0
    ).astype(np.float64)
    if rows.shape[0] == 0:  # both sides fully tombstoned: any grid works
        return (np.zeros((4,), np.float32), np.ones((4,), np.float32))
    lo = rows[:, :2].min(axis=0)
    hi = rows[:, 2:].max(axis=0)
    with np.errstate(divide="ignore"):
        inv = np.minimum(CELLS / np.maximum(hi - lo, 0.0), 1e30)
    origin = np.concatenate([lo, lo]).astype(np.float32)
    inv_cell = np.concatenate([inv, inv]).astype(np.float32)
    return origin, inv_cell


def _quantize_cm(mbr_cm: np.ndarray, origin, inv_cell) -> np.ndarray:
    """(K, 4, W) float32 level tiles -> uint16 on the joint grid, via the
    row quantizer (identical float32 arithmetic to the schedule path)."""
    from repro.kernels import ops

    k, _, w = mbr_cm.shape
    rows = mbr_cm.transpose(0, 2, 1).reshape(-1, 4)
    q = ops.quantize_rows(rows, origin, inv_cell)
    return np.ascontiguousarray(q.reshape(k, w, 4).transpose(0, 2, 1))


def _dispatch(rung: str, args, *, block_w: int, interpret,
              symmetric: bool = False):
    """Run one ladder rung over the prepared join arrays.

    Returns ``(pairs, visits, launches)`` as numpy."""
    if rung == "pallas":
        from repro.kernels import ops

        pairs, visits = ops.fused_join(
            *args, block_a=block_w, block_b=block_w, interpret=interpret,
            symmetric=symmetric,
        )
        launches = 1
    elif rung == "lax":
        from repro.kernels import fallback

        pairs, visits = fallback.fused_join_lax(*args, symmetric=symmetric)
        launches = 0
    elif rung == "host":
        from repro.kernels import fallback

        pairs, visits = fallback.fused_join_np(*args, symmetric=symmetric)
        launches = 0
    else:  # pragma: no cover
        raise ValueError(f"unknown join rung {rung!r}")
    return np.asarray(pairs), np.asarray(visits, np.int64), launches


def join_impl(left, right, predicate: str = "intersects"):
    """Execute ``left.join(right)``; returns ``(JoinResult, launches)``.

    The left index picks the engine (backend, precision, block size,
    fault plan); both sides contribute structure + live state.
    """
    if predicate not in PREDICATES:
        raise ValueError(
            f"unknown join predicate {predicate!r}; expected one of "
            f"{PREDICATES}"
        )
    side_a = _side_state(left)
    side_b = _side_state(right)
    k = min(side_a.sched.levels, side_b.sched.levels)

    a_cm = side_a.sched.mbr_cm[:k]
    b_cm = side_b.sched.mbr_cm[:k]
    precision = left._backend_opts.get("precision", "float32")
    if precision == "compact":
        origin, inv_cell = _joint_grid(side_a, side_b)
        a_cm = _quantize_cm(a_cm, origin, inv_cell)
        b_cm = _quantize_cm(b_cm, origin, inv_cell)

    args = (
        a_cm, side_a.sched.parent[:k],
        flat.ancestor_chains(side_a.sched, k),
        side_a.sched.obj_level, side_a.entry_gid,
        b_cm, side_b.sched.parent[:k],
        flat.ancestor_chains(side_b.sched, k),
        side_b.sched.obj_level, side_b.entry_gid,
        side_a.table, side_b.table,
        side_a.alive, side_b.alive,
        side_a.delta, side_b.delta,
    )
    block_w = int(left._backend_opts.get("block_w", 128))
    interpret = left._backend_opts.get("interpret")
    # Self-join fast path: both sides are the SAME index object, so the
    # pair mask is symmetric at every level — sweep only the upper
    # triangle (half the tile-pair work), mirror in the epilogue.  Pairs
    # stay bit-identical to the full sweep; only the visit ledger shrinks.
    symmetric = right is left

    backend = left.spec.name
    if backend != "serve":
        rung = backend if backend in JOIN_LADDER else "host"
        pairs, visits, launches = _dispatch(
            rung, args, block_w=block_w, interpret=interpret,
            symmetric=symmetric,
        )
        return JoinResult(pairs, visits, base_levels=k), launches

    # serve: walk the degradation ladder, same health ledger as region
    plan = left._fault_plan
    last_err = None
    for i, rung in enumerate(JOIN_LADDER):
        try:
            if plan is not None:
                plan.launch(rung)
            pairs, visits, launches = _dispatch(
                rung, args, block_w=block_w, interpret=interpret,
                symmetric=symmetric,
            )
        except Exception as e:  # noqa: BLE001 — any rung failure degrades
            left.stats.launch_failures += 1
            last_err = e
            continue
        left.stats.rung_dispatches[rung] = (
            left.stats.rung_dispatches.get(rung, 0) + 1
        )
        if i > 0:
            left.stats.degraded_batches += 1
        return JoinResult(pairs, visits, base_levels=k), launches
    raise RuntimeError(
        f"every join ladder rung failed; last error: {last_err!r}"
    )
