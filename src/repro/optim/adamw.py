"""AdamW + gradient clipping + schedules, pure JAX (no optax offline).

Optimizer state shards exactly like the parameters (ZeRO: the same
PartitionSpec tree is applied to ``m``/``v``), so memory scales 1/N with
the data axes.  ``moments_dtype`` lets very large models (DeepSeek-671B)
keep moments in bf16 — recorded in EXPERIMENTS.md as the memory-fit choice.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moments_dtype: str = "float32"


def init_state(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.moments_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def lr_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def apply_updates(params, grads, state: AdamWState, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    mdt = jnp.dtype(cfg.moments_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / (1 - b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m_new.astype(mdt),
            v_new.astype(mdt),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return (
        new_p,
        AdamWState(step=step, m=new_m, v=new_v),
        {"grad_norm": gnorm, "lr": lr},
    )
