from .adamw import AdamWConfig, AdamWState, init_state, apply_updates, lr_schedule, global_norm  # noqa: F401
from .compress import ef_int8_compress, ef_int8_state  # noqa: F401
