"""Error-feedback int8 gradient compression (distributed-optimization trick).

Before the data-axis all-reduce, gradients are quantized to int8 with a
per-tensor scale; the quantization residual is carried in an error-feedback
buffer so the compression is unbiased over time (1-bit Adam / EF-SGD
lineage).  4x reduction of the gradient all-reduce bytes — the collective
roofline term shrinks accordingly (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def ef_int8_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_int8_compress(grads, ef_state):
    """Returns (quantized_grads_as_float, new_ef_state).

    The returned gradients are the dequantized int8 values; callers sum them
    across data shards (the all-reduce then moves int8-precision values).
    """

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quant(gf)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in out]),
        jax.tree.unflatten(treedef, [o[1] for o in out]),
    )
