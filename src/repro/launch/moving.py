"""Moving-object workload: continuous queries over a churning index.

The skip-quadtree paper (PAPERS.md) frames the dynamic workload the
static benchmarks miss: objects move every tick, and the index must keep
answering a CONTINUOUS query set while absorbing the churn.  This module
drives exactly that against the live-update subsystem (DESIGN.md §8) and
the join kernel (DESIGN.md §10):

* every tick a batch of movers advances (constant velocity, bouncing off
  the ``[0, extent]²`` walls) and re-indexes as one batch **delete** +
  one batch **insert** through the ``UpdateLog`` — tombstone + delta
  buffer, no rebuild; the merge policy (or a full buffer) compacts
  mid-workload, which must not move any answer (tests/test_moving.py);
* every ``query_every`` ticks the continuous query set runs: a fixed
  batch of region rectangles plus a spatial join of the moving set
  against a static ZONE index (``SpatialIndex.join``), both honouring
  the delta buffer and tombstones mid-tick.

The workload drives any index-like with ``insert/delete/region/join`` —
a plain :class:`~repro.index.SpatialIndex` or a
:class:`~repro.checkpoint.DurableIndex` (whose ``FaultPlan`` kills then
land mid-tick; recovery resumes from the last durable mutation).
``rebuild_per_tick=True`` is the naive baseline the benchmark compares
against: every tick rebuilds the whole index from scratch instead of
going through the delta buffer.

    PYTHONPATH=src python -m repro.launch.moving --ticks 200
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.datasets import EXTENT
from repro.index import SpatialIndex


def _f32_exact(a):
    """float32-exact float64 coordinates: device (f32) and host oracle
    (f64) paths see bit-identical geometry."""
    return np.float64(np.float32(a))


@dataclasses.dataclass(frozen=True)
class MovingConfig:
    """Shape of the moving-object scenario (all coordinates in the
    ``[0, extent]²`` world of ``core.datasets``)."""

    n_objects: int = 128
    n_zones: int = 12
    moves_per_tick: int = 8
    half_side: float = 5.0      # object half-extent (0 -> point objects)
    zone_side: float = 150.0
    speed: float = 11.0         # max |velocity component| per tick
    extent: float = EXTENT
    n_queries: int = 4
    query_side: float = 120.0
    query_every: int = 1        # run the continuous query set every k ticks
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class TickResult:
    """What one tick did: which objects moved (old and new global ids)
    and — on query ticks — the continuous query answers."""

    tick: int
    moved: np.ndarray            # (m,) object slots that moved
    old_gids: np.ndarray         # (m,) ids tombstoned this tick
    new_gids: np.ndarray         # (m,) ids inserted this tick
    region: Optional[object]     # RegionResult | None (non-query tick)
    join: Optional[object]       # JoinResult | None


class MovingWorkload:
    """Seeded, replayable moving-object scenario over a live index."""

    def __init__(
        self,
        config: MovingConfig = MovingConfig(),
        *,
        index=None,
        structure: str = "mqr",
        backend: str = "pallas",
        capacity: int = 128,
        rebuild_per_tick: bool = False,
        **build_opts,
    ):
        self.config = config
        self.rebuild_per_tick = rebuild_per_tick
        rng = np.random.default_rng(config.seed)
        n, h, ext = config.n_objects, config.half_side, config.extent
        self.pos = rng.uniform(h, ext - h, size=(n, 2))
        self.vel = rng.uniform(-config.speed, config.speed, size=(n, 2))
        self._rng = rng
        self._structure = structure
        self._backend = backend
        self._build_opts = dict(build_opts)

        if index is not None:
            self.index = index
        elif rebuild_per_tick:
            self.index = SpatialIndex.build(
                self.boxes(), structure=structure, backend=backend,
                **build_opts,
            )
        else:
            self.index = SpatialIndex.build(
                self.boxes(), structure=structure, backend=backend,
                capacity=capacity, **build_opts,
            )
        # current global id of each object slot
        self.gid = np.arange(n, dtype=np.int64)
        self.dead_gids: list = []   # every id ever tombstoned by a move

        # static zone index: the join's right-hand side
        zones_ll = rng.uniform(
            0.0, ext - config.zone_side, size=(config.n_zones, 2)
        )
        self.zone_mbrs = _f32_exact(
            np.concatenate([zones_ll, zones_ll + config.zone_side], axis=1)
        )
        self.zones = SpatialIndex.build(
            self.zone_mbrs, structure="mqr", backend="host"
        )
        # continuous region query set, fixed for the whole run
        qc = rng.uniform(0.0, ext - config.query_side,
                         size=(config.n_queries, 2))
        self.queries = np.concatenate(
            [qc, qc + config.query_side], axis=1
        ).astype(np.float32)
        self.t = 0

    # -- geometry ------------------------------------------------------
    def boxes(self, slots=None) -> np.ndarray:
        """float32-exact MBRs of the (chosen) objects' current positions."""
        p = self.pos if slots is None else self.pos[slots]
        h = self.config.half_side
        return _f32_exact(np.concatenate([p - h, p + h], axis=1))

    def _advance(self, slots) -> None:
        """Constant-velocity motion with wall bounce, objects ``slots``."""
        h, ext = self.config.half_side, self.config.extent
        p = self.pos[slots] + self.vel[slots]
        v = self.vel[slots]
        lo, hi = h, ext - h
        over_lo, over_hi = p < lo, p > hi
        p = np.where(over_lo, 2 * lo - p, p)
        p = np.where(over_hi, 2 * hi - p, p)
        v = np.where(over_lo | over_hi, -v, v)
        self.pos[slots] = np.clip(p, lo, hi)
        self.vel[slots] = v

    # -- index-protocol shims (SpatialIndex | DurableIndex) ------------
    @staticmethod
    def _ids(result) -> np.ndarray:
        """Unwrap ``DurableIndex.MutationResult.ids`` / pass gid arrays."""
        return np.asarray(getattr(result, "ids", result), np.int64)

    @property
    def query_index(self) -> SpatialIndex:
        """The underlying ``SpatialIndex`` (unwraps ``DurableIndex``)."""
        return getattr(self.index, "index", self.index)

    # -- the tick ------------------------------------------------------
    def tick(self) -> TickResult:
        """One step: move a batch, re-index it, answer the continuous
        query set (on query ticks)."""
        cfg = self.config
        self.t += 1
        m = min(cfg.moves_per_tick, cfg.n_objects)
        moved = np.sort(self._rng.choice(cfg.n_objects, size=m,
                                         replace=False))
        self._advance(moved)
        old = self.gid[moved].copy()
        if self.rebuild_per_tick:
            # naive baseline: full rebuild instead of delta-buffer churn
            self.index = SpatialIndex.build(
                self.boxes(), structure=self._structure,
                backend=self._backend, **self._build_opts,
            )
            self.gid = np.arange(cfg.n_objects, dtype=np.int64)
            new = self.gid[moved]  # rebuild renumbers from zero
        else:
            self.index.delete(old)
            new = self._ids(self.index.insert(self.boxes(moved)))
            self.dead_gids.extend(old.tolist())
        self.gid[moved] = new

        region = join = None
        if self.t % cfg.query_every == 0:
            region = self.index.region(self.queries)
            join = self.index.join(self.zones)
        return TickResult(
            tick=self.t, moved=moved, old_gids=old, new_gids=new,
            region=region, join=join,
        )

    def run(self, ticks: int) -> TickResult:
        """Run ``ticks`` ticks; returns the last tick's result."""
        last = None
        for _ in range(ticks):
            last = self.tick()
        return last


def main(argv=None):  # pragma: no cover - CLI demo
    import argparse
    import time

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ticks", type=int, default=100)
    ap.add_argument("--objects", type=int, default=128)
    ap.add_argument("--backend", default="pallas")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = MovingConfig(n_objects=args.objects, seed=args.seed,
                       query_every=10)
    w = MovingWorkload(cfg, backend=args.backend)
    t0 = time.time()
    last = w.run(args.ticks)
    dt = time.time() - t0
    idx = w.query_index
    print(
        f"{args.ticks} ticks in {dt:.2f}s ({args.ticks / dt:.1f} ticks/s) "
        f"on backend={args.backend}: {idx.stats.inserts} inserts, "
        f"{idx.stats.deletes} deletes, {idx.stats.flushes} merges, "
        f"{idx.stats.joins} joins"
    )
    if last.join is not None:
        print(
            f"final continuous answers: {last.region.counts.sum()} region "
            f"hits, {last.join.n_pairs} object×zone pairs "
            f"({int(last.join.pair_visits.sum())} pair tests)"
        )


if __name__ == "__main__":  # pragma: no cover
    main()
