import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count at first init).

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.launch import hlo_cost  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.sharding import rules  # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
record memory/cost/collective analyses for EXPERIMENTS.md §Dry-run and
§Roofline.  No arrays are ever materialized (ShapeDtypeStruct only).
"""

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def parse_collectives(hlo: str):
    """Sum operand bytes of every collective in post-SPMD HLO (per device),
    plus a ring-model estimate of wire bytes (DESIGN.md §4.2)."""
    defs = {}
    instr = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?)([a-z0-9]+)\[([0-9,]*)\]")
    tuple_instr = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for line in hlo.splitlines():
        m = instr.match(line)
        if not m:
            continue
        name, is_tuple, dt, dims = m.groups()
        if is_tuple:
            total = 0
            # tuple type text up to the op name
            tup = line.split("=", 1)[1]
            tup = tup[: tup.find(")") + 1]
            for dt2, dims2 in tuple_instr.findall(tup):
                nb = _DTYPE_BYTES.get(dt2, 4)
                n = 1
                for d in dims2.split(","):
                    if d:
                        n *= int(d)
                total += n * nb
            defs[name] = total
        else:
            nb = _DTYPE_BYTES.get(dt, 4)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            defs[name] = n * nb

    out = {op: {"count": 0, "operand_bytes": 0, "wire_bytes": 0} for op in _COLL_OPS}
    coll_re = re.compile(
        r"=\s*\(?[a-z0-9]+\[[0-9,]*\][^(]*?\b(" + "|".join(_COLL_OPS) + r")(-start)?\("
    )
    group_re = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
    group_re2 = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
    for line in hlo.splitlines():
        m = coll_re.search(line)
        if not m:
            continue
        op = m.group(1)
        # operands: %names inside the call parens
        call = line[m.end():]
        call = call[: call.find(")")] if ")" in call else call
        operands = re.findall(r"%([\w\.\-]+)", call)
        ob = sum(defs.get(o, 0) for o in operands)
        gm = group_re.search(line)
        if gm:
            gsize = int(gm.group(2))
        else:
            gm2 = group_re2.search(line)
            gsize = len(gm2.group(1).split(",")) if gm2 else 2
        n = max(gsize, 2)
        factor = {
            "all-reduce": 2.0 * (n - 1) / n,
            "all-gather": float(n - 1),
            "reduce-scatter": (n - 1) / n,
            "all-to-all": (n - 1) / n,
            "collective-permute": 1.0,
        }[op]
        out[op]["count"] += 1
        out[op]["operand_bytes"] += ob
        out[op]["wire_bytes"] += int(ob * factor)
    out["total_operand_bytes"] = sum(v["operand_bytes"] for v in out.values() if isinstance(v, dict))
    out["total_wire_bytes"] = sum(v["wire_bytes"] for v in out.values() if isinstance(v, dict))
    return out


def _apply_overrides(cfg, overrides):
    if not overrides:
        return cfg
    kw = {}
    for ov in overrides:
        k, v = ov.split("=", 1)
        field = {f.name: f for f in dataclasses.fields(cfg)}[k]
        if field.type in ("int", int):
            v = int(v)
        elif field.type in ("float", float):
            v = float(v)
        elif field.type in ("bool", bool):
            v = v.lower() in ("1", "true")
        kw[k] = v
    return dataclasses.replace(cfg, **kw)


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: pathlib.Path,
             overrides=None, tag: str = "", force: bool = False):
    mesh_name = "multi" if multi_pod else "single"
    suffix = f"__{tag}" if tag else ""
    out_path = out_dir / f"{arch}__{shape}__{mesh_name}{suffix}.json"
    if out_path.exists() and not force:
        print(f"[skip] {out_path.name}")
        return json.loads(out_path.read_text())

    cfg = registry.get_config(arch)
    cfg = _apply_overrides(cfg, overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    kind = registry.SHAPES[shape]["kind"]
    seq = registry.SHAPES[shape]["seq_len"]
    gbatch = registry.SHAPES[shape]["global_batch"]

    record = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "tag": tag,
        "n_devices": n_dev, "kind": kind, "seq_len": seq, "global_batch": gbatch,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "overrides": list(overrides or []),
    }
    t0 = time.time()

    params_abs = steps.abstract_params(cfg)
    params_sh = rules.param_shardings(params_abs, mesh)
    specs = registry.input_specs(cfg, shape)

    with mesh:
        if kind == "train":
            # bf16 moments for >100B models: the recorded memory-fit choice.
            opt_cfg = adamw.AdamWConfig(
                moments_dtype="bfloat16" if cfg.param_count() > 100e9 else "float32"
            )
            record["moments_dtype"] = opt_cfg.moments_dtype
            opt_abs = steps.abstract_opt_state(params_abs, opt_cfg)
            opt_sh = jax.tree.map(
                lambda s: s,
                adamw.AdamWState(
                    step=NamedSharding(mesh, P()),
                    m=rules.param_shardings(params_abs, mesh),
                    v=rules.param_shardings(params_abs, mesh),
                ),
            )
            batch_abs = specs["batch"]
            batch_sh = rules.batch_shardings(batch_abs, mesh)
            fn = steps.make_train_step(cfg, opt_cfg)
            jitted = jax.jit(
                fn,
                in_shardings=(params_sh, opt_sh, batch_sh),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
            # model flops: 6 * N_active * tokens
            tokens = gbatch * seq
            record["model_flops"] = 6 * cfg.active_param_count() * tokens
        elif kind == "prefill":
            batch_abs = specs["batch"]
            batch_sh = rules.batch_shardings(batch_abs, mesh)
            fn = steps.make_prefill_step(cfg)
            jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params_abs, batch_abs)
            record["model_flops"] = 2 * cfg.active_param_count() * gbatch * seq
        else:  # decode
            has_kv_attn = any(
                k in ("attn", "mla") for k in cfg.block_pattern + cfg.tail_pattern
            )
            mqr = shape == "long_500k" and has_kv_attn
            if "dense" in tag:
                mqr = False  # full-attention baseline for §Perf comparison
            record["mqr_sparse"] = bool(mqr)
            caches_abs = specs["caches"]
            caches_sh = rules.cache_shardings(caches_abs, mesh)
            tok_sh = NamedSharding(mesh, rules.batch_spec(specs["tokens"].shape, mesh))
            fn = steps.make_serve_step(cfg, mqr_sparse=mqr)
            jitted = jax.jit(
                fn,
                in_shardings=(params_sh, tok_sh, caches_sh, NamedSharding(mesh, P())),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(
                params_abs, specs["tokens"], caches_abs, specs["pos"]
            )
            # per-step decode flops: 2 * N_active * batch (+ KV read is memory)
            record["model_flops"] = 2 * cfg.active_param_count() * gbatch

        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

    mem = compiled.memory_analysis()
    record["memory"] = {
        "argument_bytes_per_device": int(mem.argument_size_in_bytes),
        "output_bytes_per_device": int(mem.output_size_in_bytes),
        "temp_bytes_per_device": int(mem.temp_size_in_bytes),
        "alias_bytes_per_device": int(mem.alias_size_in_bytes),
        "peak_bytes_per_device": int(
            mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes
        ),
    }
    ca = compiled.cost_analysis() or {}
    # Loop-aware correction: XLA cost analysis counts while bodies once;
    # hlo_cost multiplies by trip counts (layer scans, kv-chunk scans...).
    hlo_txt = compiled.as_text()
    corr = hlo_cost.corrected_costs(
        hlo_txt, float(ca.get("flops", 0)), float(ca.get("bytes accessed", 0))
    )
    record["cost"] = {
        "flops_per_device": corr["flops_per_device"],
        "bytes_accessed_per_device": corr["bytes_accessed_per_device"],
        "flops_per_device_xla_raw": float(ca.get("flops", -1)),
        "bytes_per_device_xla_raw": float(ca.get("bytes accessed", -1)),
        "loop_flops_ratio": corr["flops_ratio"],
        "loop_bytes_ratio": corr["bytes_ratio"],
        "transcendentals": float(ca.get("transcendentals", 0)),
    }
    record["collectives"] = corr["collectives"]
    record["lower_s"] = round(t_lower - t0, 2)
    record["compile_s"] = round(t_compile - t_lower, 2)

    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=1))
    pk = record["memory"]["peak_bytes_per_device"] / 2**30
    print(
        f"[ok] {out_path.name}: peak={pk:.2f} GiB/dev "
        f"flops/dev={record['cost']['flops_per_device']:.3e} "
        f"coll_wire={record['collectives']['total_wire_bytes']/2**30:.3f} GiB "
        f"(lower {record['lower_s']}s, compile {record['compile_s']}s)"
    )
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--override", action="append", default=[],
                    help="ModelConfig field=value (perf iterations)")
    ap.add_argument("--tag", default="", help="suffix for the output JSON")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(registry.ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(registry.SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = pathlib.Path(args.out)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, mp, out_dir, args.override, args.tag,
                             args.force)
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[FAIL] {arch} {shape} {'multi' if mp else 'single'}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()
