"""Batched spatial query serving over the fused region-search kernel.

Production shape of the paper's region search (DESIGN.md §3.3): a
:class:`SpatialServer` holds one immutable :class:`repro.core.flat.
LevelSchedule` on device and answers streams of query rectangles with

* an LRU result cache — repeated regions (hot map tiles, dashboard
  refreshes) are answered without touching the device at all;
* query batching — cache misses are deduplicated, padded to fixed-size
  blocks, and dispatched as ONE fused kernel launch per block batch;
* ``vmap`` over query blocks within a device, and ``pmap`` fan-out across
  devices when more than one is attached (single-device falls back to the
  vmapped path transparently).

  PYTHONPATH=src python -m repro.launch.spatial_serve --n 2000 --queries 256

Where this sits in the serving stack (one entry point per layer):

* THIS module is the low-level single-index serving ENGINE — cache,
  dedupe, padding, ladder.  It is what ``backend="serve"`` builds under
  a :class:`repro.index.SpatialIndex`.
* :mod:`repro.serve` is the user-facing serving FRONT END — continuous
  batching of single arrivals, SLO admission control, the multi-tenant
  registry.  New serving features land there, on top of this engine.
* :mod:`repro.launch.serve` is the UNRELATED transformer decode driver
  (same repo, different paper track); it serves tokens, not rectangles.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import time
import warnings
from collections import OrderedDict
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.flat import NEVER_MBR, LevelSchedule
from repro.kernels import fallback, ops
from repro.obs import trace as _obs_trace

LADDER = ("pallas", "lax", "host")


@dataclasses.dataclass
class ServeStats:
    queries_served: int = 0
    cache_hits: int = 0           # answered from the LRU of a previous call
    dedup_hits: int = 0           # duplicates within one batch, computed once
    batches_dispatched: int = 0
    kernel_launches: int = 0      # one fused launch per dispatched block
    node_accesses: int = 0        # sum of per-level visit counts ("disk accesses")
    retries: int = 0              # failed launches retried on the same rung
    degraded_batches: int = 0     # batches answered below the top rung
    rung_dispatches: dict = dataclasses.field(
        default_factory=lambda: {r: 0 for r in LADDER}
    )
    rung_failures: dict = dataclasses.field(
        default_factory=lambda: {r: 0 for r in LADDER}
    )

    @property
    def cache_hit_rate(self) -> float:
        return self.cache_hits / max(self.queries_served, 1)


class SpatialServer:
    """Serve batched region searches from one level schedule.

    Args:
      schedule: the tree/pyramid level schedule (see ``flat.level_schedule``).
      query_block: queries per kernel launch; misses are padded up to this.
      cache_size: LRU capacity in distinct query rectangles (0 disables).
      block_w: kernel lane-tile width.
      interpret: run the Pallas kernel in interpreter mode (None = auto:
        interpret off TPU, compile on TPU — same policy as ``kernels.ops``).
      precision: ``"float32"`` streams exact tiles; ``"compact"`` streams
        the conservatively quantized uint16 tile form at half the bytes
        per query with an exact confirming pass — hit sets are identical,
        visit counts are the compact sweep's own (DESIGN.md §7).
      quantized: optionally a pre-built ``QuantizedSchedule`` for
        ``precision="compact"`` (quantized here when omitted).
      live: optionally the live-update array bundle
        (``repro.update.AugmentedArrays``, DESIGN.md §8): the server then
        dispatches the LIVE fused sweep — base levels + delta-buffer flat
        levels + tombstone mask — and supports :meth:`rebind` to swap in
        a new mutation epoch's arrays; the LRU is epoch-tagged so entries
        cached under an older epoch are never served after a mutation.
      ladder: health ladder walked when a rung's launch fails (DESIGN.md
        §9).  Each rung answers with the identical sweep semantics —
        ``pallas`` is the fused kernel, ``lax`` the plain-XLA twin,
        ``host`` the numpy twin — so degradation changes latency, never
        answers.
      max_retries: failed launches retried per rung (with exponential
        backoff) before falling to the next rung.
      backoff: base retry sleep in seconds; attempt ``k`` waits
        ``backoff * 2**k``, capped at ``backoff_cap``.
      fault_plan: optional :class:`repro.ft.FaultPlan`; its
        :meth:`~repro.ft.FaultPlan.launch` hook fires before every rung
        dispatch so tests can force launch failures deterministically.
    """

    def __init__(
        self,
        schedule: LevelSchedule,
        *,
        query_block: int = 16,
        cache_size: int = 4096,
        block_w: int = 128,
        interpret: bool | None = None,
        precision: str = "float32",
        quantized=None,
        live=None,
        ladder: Tuple[str, ...] = LADDER,
        max_retries: int = 2,
        backoff: float = 0.05,
        backoff_cap: float = 1.0,
        fault_plan=None,
    ):
        if interpret is None:
            interpret = ops.interpret_default()
        if precision not in ("float32", "compact", "compact8"):
            raise ValueError(f"unknown precision {precision!r}")
        ladder = tuple(ladder)
        bad = [r for r in ladder if r not in LADDER]
        if not ladder or bad:
            raise ValueError(
                f"ladder rungs must be drawn from {LADDER}, got {ladder!r}"
            )
        self.schedule = schedule
        self.precision = precision
        self.query_block = int(query_block)
        self.cache_size = int(cache_size)
        self.ladder = ladder
        self.max_retries = int(max_retries)
        self.backoff = float(backoff)
        self.backoff_cap = float(backoff_cap)
        self.fault_plan = fault_plan
        self._rung_floor = 0   # sticky: index of the lowest healthy rung
        self.stats = ServeStats()
        self._health_mark = (0, 0, {r: 0 for r in LADDER}, {r: 0 for r in LADDER})
        self.epoch = 0
        self._cache: "OrderedDict[bytes, Tuple[int, Tuple[np.ndarray, np.ndarray]]]" = (
            OrderedDict()
        )
        self._n_out = schedule.n_objects
        self._levels_out = schedule.levels
        if live is not None:
            if live.precision != precision:
                raise ValueError(
                    f"live bundle is {live.precision!r}, server asked for "
                    f"{precision!r}"
                )
            self._n_out = live.n_objects
            self._levels_out = live.levels
            self._arrays = tuple(jnp.asarray(a) for a in live.arrays)
            fn = (
                ops.fused_search_compact_live
                if precision == "compact"
                else ops.fused_search_live
            )
            kwargs = dict(block_w=block_w, interpret=interpret, **live.statics)
        elif precision == "compact8":
            # Hierarchical uint8-upper/uint16-lower tile form (DESIGN.md
            # §12); hit sets bit-identical, upper-level bytes halved again.
            # Live mutation normalizes compact8 -> compact upstream (delta
            # levels ride the fine grid), so this branch is base-only.
            qs = quantized
            if qs is None:
                qs = ops.quantize_schedule(
                    schedule, interpret=interpret, upper8=True
                )
            if not qs.hierarchical and schedule.levels > 1:
                raise ValueError(
                    "precision='compact8' needs a hierarchical quantized "
                    "schedule (quantize_schedule(..., upper8=True))"
                )
            split = qs.split
            mbr_q8 = qs.mbr_q8
            inv_cell8 = qs.inv_cell8
            if mbr_q8 is None:  # single-level schedule: degenerate split=0
                mbr_q8 = np.zeros((0, 4, qs.width), np.uint8)
                inv_cell8 = qs.inv_cell
            self._arrays = (
                jnp.asarray(mbr_q8),
                jnp.asarray(qs.mbr_q[split:]),
                jnp.asarray(qs.parent_q),
                jnp.asarray(qs.confirm_mbr),
                jnp.asarray(schedule.obj_level),
                jnp.asarray(schedule.obj_slot),
                jnp.asarray(schedule.obj_id),
                jnp.asarray(qs.origin),
                jnp.asarray(qs.inv_cell),
                jnp.asarray(inv_cell8),
            )
            fn = ops.fused_search_compact8
            kwargs = dict(
                n_objects=schedule.n_objects,
                cells=qs.cells,
                cells8=qs.cells8,
                split=split,
                block_w=block_w,
                root_unconditional=schedule.root_unconditional,
                interpret=interpret,
            )
        elif precision == "compact":
            qs = quantized
            if qs is None:
                qs = ops.quantize_schedule(schedule, interpret=interpret)
            self._arrays = (
                jnp.asarray(qs.mbr_q),
                jnp.asarray(qs.parent_q),
                jnp.asarray(qs.confirm_mbr),
                jnp.asarray(schedule.obj_level),
                jnp.asarray(schedule.obj_slot),
                jnp.asarray(schedule.obj_id),
                jnp.asarray(qs.origin),
                jnp.asarray(qs.inv_cell),
            )
            fn = ops.fused_search_compact
            kwargs = dict(
                n_objects=schedule.n_objects,
                cells=qs.cells,
                block_w=block_w,
                root_unconditional=schedule.root_unconditional,
                interpret=interpret,
            )
        else:
            self._arrays = (
                jnp.asarray(schedule.mbr_cm),
                jnp.asarray(schedule.parent),
                jnp.asarray(schedule.obj_mbr),
                jnp.asarray(schedule.obj_level),
                jnp.asarray(schedule.obj_slot),
                jnp.asarray(schedule.obj_id),
            )
            fn = ops.fused_search
            kwargs = dict(
                n_objects=schedule.n_objects,
                block_w=block_w,
                root_unconditional=schedule.root_unconditional,
                test_object_mbr=schedule.test_object_mbr,
                interpret=interpret,
            )
        inner = functools.partial(fn, **kwargs)
        # Signature-compatible degradation twins: same statics, no pallas.
        fb_lax, fb_np = fallback.FALLBACKS[(precision, live is not None)]
        self._inner_lax = functools.partial(fb_lax, **kwargs)
        self._inner_np = functools.partial(fb_np, **kwargs)
        self._batch_axes = batch_axes = (0,) + (None,) * len(self._arrays)
        self._vmapped = jax.jit(jax.vmap(inner, in_axes=batch_axes))
        self._vmapped_lax = None   # jit'd lazily, on first lax-rung dispatch
        self._np_arrays = None     # host copies, materialized on first use
        self._pmapped = None
        if jax.device_count() > 1:
            self._pmapped = jax.pmap(
                jax.vmap(inner, in_axes=batch_axes), in_axes=batch_axes
            )

    # ------------------------------------------------------------------
    def rebind(self, arrays, *, epoch: int) -> None:
        """Swap the device-resident schedule arrays for a new mutation
        epoch (live-update servers only; DESIGN.md §8).

        The replacement must be shape-identical — delta contents and the
        alive mask change per mutation, the compiled program does not; a
        merge changes shapes and therefore needs a fresh server.  The
        epoch tag advances so LRU entries cached under older epochs stop
        matching (and are evicted on touch) instead of being served
        stale.
        """
        arrays = tuple(jnp.asarray(a) for a in arrays)
        if len(arrays) != len(self._arrays) or any(
            a.shape != b.shape or a.dtype != b.dtype
            for a, b in zip(arrays, self._arrays)
        ):
            raise ValueError(
                "rebind requires shape/dtype-identical arrays; a merge "
                "(base rebuild) needs a new SpatialServer"
            )
        self._arrays = arrays
        self._np_arrays = None
        self.epoch = int(epoch)

    def bind_fault_plan(self, plan) -> None:
        """Attach (or detach, with ``None``) a fault-injection plan."""
        self.fault_plan = plan

    def reset_health(self) -> None:
        """Forget sticky degradation: the next batch starts back at the
        top rung (call after the underlying fault is known fixed)."""
        self._rung_floor = 0

    @property
    def current_rung(self) -> str:
        return self.ladder[min(self._rung_floor, len(self.ladder) - 1)]

    def drain_health(self) -> dict:
        """Return health-ladder counter deltas since the previous drain
        (retries, degraded batches, per-rung dispatches/failures) — the
        façade folds these into ``AccessStats`` per query call."""
        s = self.stats
        m_ret, m_deg, m_disp, m_fail = self._health_mark
        out = {
            "retries": s.retries - m_ret,
            "degraded_batches": s.degraded_batches - m_deg,
            "rung_dispatches": {
                r: s.rung_dispatches.get(r, 0) - m_disp.get(r, 0)
                for r in LADDER
            },
            "rung_failures": {
                r: s.rung_failures.get(r, 0) - m_fail.get(r, 0)
                for r in LADDER
            },
            "rung": self.current_rung,
        }
        self._health_mark = (
            s.retries,
            s.degraded_batches,
            dict(s.rung_dispatches),
            dict(s.rung_failures),
        )
        return out

    # ------------------------------------------------------------------
    def search(self, queries) -> Tuple[np.ndarray, np.ndarray]:
        """Answer (Q, 4) query rectangles.

        Returns ``(hits, visits)`` exactly as :func:`repro.kernels.ops.
        pyramid_scan` would per query — the cache and batching are
        result-transparent.

        The boundary is hardened: NaN/±inf/inverted rectangles raise the
        typed :class:`repro.index.InvalidQueryError` BEFORE any of them
        can be cached or poison a padded batch's neighbours.
        """
        # lazy import: repro.index imports this module's backend wrapper,
        # so the validation helper is pulled at call time, not import time
        from repro.index.api import validate_queries

        queries = validate_queries(queries, what="served queries")
        nq = queries.shape[0]
        if nq == 0:
            return (
                np.zeros((0, max(self._n_out, 1)), bool),
                np.zeros((0, self._levels_out), np.int32),
            )
        self.stats.queries_served += nq

        keys = [queries[i].tobytes() for i in range(nq)]
        fresh: dict = {}   # results computed for THIS call; immune to LRU
        miss_rows: list[np.ndarray] = []
        for i, k in enumerate(keys):
            if k in fresh:  # duplicate within this batch: computed once
                self.stats.dedup_hits += 1
            elif k in self._cache:
                tag, value = self._cache[k]
                if tag == self.epoch:
                    fresh[k] = value
                    self._cache.move_to_end(k)
                    self.stats.cache_hits += 1
                else:
                    # cached under an older mutation epoch: stale — drop
                    # and recompute (epoch-tagged invalidation, §8)
                    del self._cache[k]
                    fresh[k] = None
                    miss_rows.append(queries[i])
            else:
                fresh[k] = None  # placeholder, filled after dispatch
                miss_rows.append(queries[i])

        if miss_rows:
            block_hits, block_visits = self._dispatch(np.stack(miss_rows))
            j = 0
            for k, v in fresh.items():
                if v is None:
                    fresh[k] = (block_hits[j], block_visits[j])
                    self._put(k, fresh[k])
                    j += 1

        hits = np.stack([fresh[k][0] for k in keys])
        visits = np.stack([fresh[k][1] for k in keys])
        return hits, visits

    # ------------------------------------------------------------------
    def _dispatch(self, miss: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        qb = self.query_block
        n = miss.shape[0]
        pad = (-n) % qb
        if pad:
            # pad with never-overlapping null queries (results discarded)
            miss = np.concatenate(
                [miss, np.broadcast_to(NEVER_MBR, (pad, 4))], axis=0
            )
        blocks = miss.reshape(-1, qb, 4)
        nb = blocks.shape[0]
        hits, visits, launches = self._run_ladder(blocks)
        self.stats.batches_dispatched += 1
        self.stats.kernel_launches += launches
        self.stats.node_accesses += int(visits[:n].sum())
        return hits[:n], visits[:n]

    def _run_ladder(
        self, blocks: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, int]:
        """Dispatch one padded block batch down the health ladder.

        Starts at the sticky rung floor (a rung that exhausted its retry
        budget earlier stays skipped until :meth:`reset_health`), retries
        each rung ``max_retries`` times with bounded exponential backoff,
        then degrades to the next rung.  A simulated SIGKILL
        (``repro.ft.KillPoint``) derives from ``BaseException`` so it is
        NOT absorbed as a rung failure.
        """
        last_exc: Exception | None = None
        start = min(self._rung_floor, len(self.ladder) - 1)
        for ri in range(start, len(self.ladder)):
            rung = self.ladder[ri]
            for attempt in range(self.max_retries + 1):
                try:
                    with _obs_trace.span("serve.rung", rung=rung,
                                         attempt=attempt,
                                         blocks=blocks.shape[0]):
                        if self.fault_plan is not None:
                            self.fault_plan.launch(rung)
                        out = self._dispatch_rung(rung, blocks)
                except Exception as exc:
                    last_exc = exc
                    self.stats.rung_failures[rung] += 1
                    _obs_trace.instant("serve.rung_failure", rung=rung,
                                       attempt=attempt,
                                       error=type(exc).__name__)
                    if attempt < self.max_retries:
                        self.stats.retries += 1
                        if self.backoff > 0:
                            time.sleep(
                                min(self.backoff * 2**attempt, self.backoff_cap)
                            )
                    continue
                self.stats.rung_dispatches[rung] += 1
                if ri > 0:
                    self.stats.degraded_batches += 1
                return out
            # Retry budget exhausted: degrade, and stay degraded (sticky
            # floor) so subsequent batches skip the broken rung.
            if ri + 1 < len(self.ladder):
                self._rung_floor = max(self._rung_floor, ri + 1)
                _obs_trace.instant(
                    "serve.degrade",
                    **{"from": rung, "to": self.ladder[ri + 1],
                       "failures": self.max_retries + 1},
                )
                warnings.warn(
                    f"SpatialServer: rung {rung!r} failed "
                    f"{self.max_retries + 1}x ({last_exc!r}); degrading to "
                    f"{self.ladder[ri + 1]!r}",
                    RuntimeWarning,
                    stacklevel=3,
                )
        raise RuntimeError(
            f"SpatialServer: every ladder rung {self.ladder!r} failed"
        ) from last_exc

    def _dispatch_rung(
        self, rung: str, blocks: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, int]:
        """One attempt on one rung; returns flat (hits, visits, launches)."""
        nb, qb, _ = blocks.shape
        if rung == "pallas":
            n_dev = jax.device_count()
            if self._pmapped is not None and nb % n_dev == 0:
                sharded = blocks.reshape(n_dev, nb // n_dev, qb, 4)
                hits, visits = self._pmapped(
                    jnp.asarray(sharded), *self._arrays
                )
            else:
                hits, visits = self._vmapped(
                    jnp.asarray(blocks), *self._arrays
                )
            return (
                np.asarray(hits).reshape(nb * qb, -1),
                np.asarray(visits).reshape(nb * qb, -1),
                nb,
            )
        if rung == "lax":
            if self._vmapped_lax is None:
                self._vmapped_lax = jax.jit(
                    jax.vmap(self._inner_lax, in_axes=self._batch_axes)
                )
            hits, visits = self._vmapped_lax(
                jnp.asarray(blocks), *self._arrays
            )
            return (
                np.asarray(hits).reshape(nb * qb, -1),
                np.asarray(visits).reshape(nb * qb, -1),
                nb,
            )
        # host: pure numpy, zero device launches
        if self._np_arrays is None:
            self._np_arrays = tuple(np.asarray(a) for a in self._arrays)
        hits, visits = self._inner_np(
            blocks.reshape(nb * qb, 4), *self._np_arrays
        )
        return np.asarray(hits), np.asarray(visits), 0

    def _put(self, key: bytes, value) -> None:
        if self.cache_size <= 0:  # caching disabled
            return
        self._cache[key] = (self.epoch, value)
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)


# ---------------------------------------------------------------------------


def main():
    from repro.core import datasets, flat, mqrtree

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--repeat-frac", type=float, default=0.5,
                    help="fraction of queries drawn from a small hot set")
    ap.add_argument("--query-block", type=int, default=16)
    args = ap.parse_args()

    data = datasets.uniform_squares(args.n, seed=0)
    tree = mqrtree.build(data)
    sched = flat.level_schedule(flat.flatten(tree))
    server = SpatialServer(sched, query_block=args.query_block)

    rng = np.random.default_rng(0)
    cold = datasets.region_queries(data, args.queries, seed=1)
    hot = datasets.region_queries(data, 8, seed=2)
    mask = rng.random(args.queries) < args.repeat_frac
    stream = np.where(mask[:, None], hot[rng.integers(0, 8, args.queries)], cold)

    t0 = time.time()
    chunks = [
        server.search(stream[i : i + args.query_block])
        for i in range(0, args.queries, args.query_block)
    ]
    hits = np.concatenate([h for h, _ in chunks])
    dt = time.time() - t0
    s = server.stats
    print(
        f"[spatial-serve] {args.queries} queries in {dt:.3f}s "
        f"({args.queries / dt:.0f} q/s) | cache hit rate "
        f"{s.cache_hit_rate:.0%} | {s.kernel_launches} fused launches | "
        f"{s.node_accesses} node accesses | "
        f"avg {hits.sum(1).mean():.1f} objects/query"
    )


if __name__ == "__main__":
    main()
