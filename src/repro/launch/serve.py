"""Serving driver: batched greedy decoding with KV caches; the long-context
path uses the mqr-KV sparse attention (the paper's technique).

  PYTHONPATH=src python -m repro.launch.serve --arch llama32_1b \
      --batch 4 --prompt-len 32 --gen 32

NOT the spatial serving front end: this module serves transformer
tokens.  Spatial query serving is :mod:`repro.serve` (front end:
batching / admission / tenants) over :mod:`repro.launch.spatial_serve`
(the per-index engine).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.launch import steps as step_lib
from repro.models import transformer as T


def serve(
    arch: str = "llama32_1b",
    smoke: bool = True,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 32,
    mqr_sparse: bool = False,
    seed: int = 0,
    params=None,
    prompts=None,
):
    cfg = registry.get_config(arch, smoke=smoke)
    if params is None:
        params = T.init_params(jax.random.PRNGKey(seed), cfg)
    max_len = prompt_len + gen
    if cfg.mqr_block and mqr_sparse:
        max_len = ((max_len + cfg.mqr_block - 1) // cfg.mqr_block) * cfg.mqr_block
    if prompts is None:
        shape = (
            (batch, prompt_len, cfg.n_codebooks)
            if cfg.frontend == "audio_codebooks"
            else (batch, prompt_len)
        )
        prompts = jax.random.randint(
            jax.random.PRNGKey(seed + 1), shape, 0, cfg.vocab_size, jnp.int32
        )

    serve_step = jax.jit(
        step_lib.make_serve_step(cfg, mqr_sparse=mqr_sparse),
        donate_argnums=(2,),
        static_argnames=(),
    )
    caches = T.init_caches(cfg, batch, max_len)

    # Prefill by streaming the prompt through decode steps (exact, cache-
    # building); a chunked prefill kernel is the production TPU path.
    t0 = time.time()
    for t in range(prompt_len):
        nxt, caches = serve_step(params, prompts[:, t : t + 1], caches, t)
    generated = [nxt]
    for t in range(prompt_len, prompt_len + gen - 1):
        nxt, caches = serve_step(params, generated[-1], caches, t)
        generated.append(nxt)
    out = jnp.concatenate(generated, axis=1)
    dt = time.time() - t0
    n_tok = batch * (prompt_len + gen)
    print(
        f"[serve] {arch} batch={batch} prompt={prompt_len} gen={gen} "
        f"mqr_sparse={mqr_sparse}: {n_tok / dt:.1f} tok/s ({dt:.2f}s)"
    )
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.vocab_size))
    return np.asarray(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32_1b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mqr-sparse", action="store_true")
    args = ap.parse_args()
    serve(
        arch=args.arch, smoke=not args.full, batch=args.batch,
        prompt_len=args.prompt_len, gen=args.gen, mqr_sparse=args.mqr_sparse,
    )


if __name__ == "__main__":
    main()
