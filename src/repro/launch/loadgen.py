"""Open-loop load-sweep CLI for the spatial serving front end.

THE serving entry point is :mod:`repro.serve` (ServingFrontEnd); this
driver just builds a demo tenant registry, sweeps offered QPS through
:mod:`repro.serve.loadgen`, prints the latency-vs-load curve, and
(``--write-bench``) merges the rows into ``BENCH_<date>.json``:

  PYTHONPATH=src python -m repro.launch.loadgen \
      --qps 50,150,400 --duration 2 --n 4096 --backend serve --write-bench

``REPRO_BENCH_TINY=1`` shrinks everything to CI-smoke sizes.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro.obs import counters as obs_counters
from repro.obs import trace as obs_trace
from repro.serve import ServerConfig, ServingFrontEnd
from repro.serve.loadgen import run_sweep, write_bench_rows

TINY = os.environ.get("REPRO_BENCH_TINY") == "1"


def demo_dataset(n: int, *, seed: int = 7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    c = rng.random((n, 2)).astype(np.float32) * 100.0
    wh = (rng.random((n, 2)).astype(np.float32) * 0.5 + 0.05)
    return np.concatenate([c, c + wh], axis=1)


def build_sweep(args, last_front=None):
    """``make_front`` factory for :func:`run_sweep`.

    ``last_front`` is an optional one-element list: run_sweep builds a
    FRESH front per QPS level, so the cell captures whichever front ran
    last — the one ``--metrics-out`` snapshots after the sweep.
    """
    data = {"demo": demo_dataset(args.n)}
    cfg = ServerConfig.from_dict({
        "tenants": [{
            "name": "demo",
            "structure": args.structure,
            "backend": args.backend,
        }],
        "query_block": args.query_block,
        "classes": [
            {"name": "interactive", "deadline_ms": args.deadline_ms,
             "overload": "shed", "max_queue": args.max_queue},
        ],
    })

    def make_front():
        front = ServingFrontEnd.build(cfg, data)
        if last_front is not None:
            last_front[0] = front
        return front, "demo"

    return make_front


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--qps", default="25,100,400" if TINY else "50,200,800")
    p.add_argument("--duration", type=float, default=0.4 if TINY else 2.0)
    p.add_argument("--n", type=int, default=256 if TINY else 8192)
    p.add_argument("--structure", default="mqr")
    p.add_argument("--backend", default="serve")
    p.add_argument("--query-block", type=int, default=8 if TINY else 16)
    p.add_argument("--deadline-ms", type=float, default=50.0)
    p.add_argument("--max-queue", type=int, default=64 if TINY else 1024)
    p.add_argument("--knn-every", type=int, default=0,
                   help="every n-th request becomes a knn query")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--write-bench", action="store_true",
                   help="merge rows into BENCH_<date>.json at the repo root")
    p.add_argument("--trace-out", default=None, metavar="PATH",
                   help="record spans and export a Chrome/Perfetto "
                        "trace.json of the sweep")
    p.add_argument("--metrics-out", default=None, metavar="PATH",
                   help="write the last front's Prometheus metrics "
                        "snapshot (PATH and PATH + '.json')")
    args = p.parse_args(argv)

    if args.trace_out:
        obs_trace.enable()
        obs_counters.collect_launch_reports(True)

    levels = [float(x) for x in args.qps.split(",")]
    last_front = [None]
    rows = run_sweep(build_sweep(args, last_front), levels,
                     duration=args.duration, seed=args.seed,
                     knn_every=args.knn_every)

    print("qps_offered,qps_achieved,p50_ms,p99_ms,p999_ms,shed,"
          "slo_violations,avg_batch")
    for row in rows:
        print(f"{row['qps_offered']:.1f},{row['qps_achieved']:.1f},"
              f"{row['p50_ms']:.3f},{row['p99_ms']:.3f},"
              f"{row['p999_ms']:.3f},{row['shed']},"
              f"{row['slo_violations']},{row['avg_batch']}")

    if args.trace_out:
        obs_trace.get_tracer().export_chrome_trace(args.trace_out)
        obs_counters.collect_launch_reports(False)
        obs_trace.disable()
        print(f"# wrote {args.trace_out}", file=sys.stderr)
    if args.metrics_out and last_front[0] is not None:
        reg = last_front[0].metrics()
        with open(args.metrics_out, "w") as f:
            f.write(reg.to_prometheus())
        reg.write_json(args.metrics_out + ".json")
        print(f"# wrote {args.metrics_out} (+.json)", file=sys.stderr)

    if args.write_bench:
        root = os.path.dirname(
            os.path.dirname(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__)
            )))
        )
        path = write_bench_rows(rows, root)
        print(f"# wrote {path}", file=sys.stderr)
    else:
        print(json.dumps(rows, indent=1, default=float), file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
