"""Roofline analysis over the dry-run JSONs (DESIGN.md §4.2).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs_global / (chips * peak)   [seconds/step]
  memory term     = HLO_bytes_global / (chips * HBM_bw)
  collective term = wire_bytes_per_device / link_bw
(cost_analysis returns PER-DEVICE post-SPMD numbers; global = x chips.
 wire bytes already include ring-cost factors per op — see dryrun.py.)

Also reports MODEL_FLOPS / HLO_FLOPs (useful-compute fraction: catches remat
recompute, dispatch overhead, masked-attention waste) and the bound term.
"""

from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
LINK_BW = 50e9          # bytes/s / link


def load_cells(dry_dir: str, tag: str = "") -> List[Dict]:
    out = []
    for p in sorted(pathlib.Path(dry_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if (rec.get("tag") or "") != tag:
            continue
        out.append(rec)
    return out


def analyze(rec: Dict) -> Dict:
    chips = rec["n_devices"]
    flops_dev = rec["cost"]["flops_per_device"]
    bytes_dev = rec["cost"]["bytes_accessed_per_device"]
    wire_dev = rec["collectives"]["total_wire_bytes"]
    t_compute = flops_dev * chips / (chips * PEAK_FLOPS)
    t_memory = bytes_dev * chips / (chips * HBM_BW)
    t_coll = wire_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bound = max(terms, key=terms.get)
    model_flops = rec.get("model_flops", 0)
    hlo_global = flops_dev * chips
    useful = model_flops / hlo_global if hlo_global > 0 else float("nan")
    # roofline fraction: useful model flops per chip-second at the bound
    step_time = max(terms.values())
    mfu = model_flops / (chips * PEAK_FLOPS * step_time) if step_time > 0 else 0.0
    return {
        **rec,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bound": bound,
        "useful_flops_ratio": useful,
        "roofline_mfu": mfu,
        "peak_gib": rec["memory"]["peak_bytes_per_device"] / 2**30,
    }


def table(cells: List[Dict]) -> str:
    rows = [
        "| arch | shape | mesh | compute s | memory s | collective s | bound "
        "| useful/HLO | roofline-MFU | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        a = analyze(c)
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['t_compute_s']:.3e} | {a['t_memory_s']:.3e} "
            f"| {a['t_collective_s']:.3e} | **{a['bound']}** "
            f"| {a['useful_flops_ratio']:.2f} | {a['roofline_mfu']:.3f} "
            f"| {a['peak_gib']:.2f} |"
        )
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    cells = load_cells(args.dir, args.tag)
    if args.csv:
        print("arch,shape,mesh,t_compute,t_memory,t_collective,bound,"
              "useful_ratio,roofline_mfu,peak_gib")
        for c in cells:
            a = analyze(c)
            print(
                f"{a['arch']},{a['shape']},{a['mesh']},{a['t_compute_s']:.4e},"
                f"{a['t_memory_s']:.4e},{a['t_collective_s']:.4e},{a['bound']},"
                f"{a['useful_flops_ratio']:.3f},{a['roofline_mfu']:.4f},"
                f"{a['peak_gib']:.2f}"
            )
    else:
        print(table(cells))


if __name__ == "__main__":
    main()
