"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces 512 host
devices while tests/benches must see 1.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
