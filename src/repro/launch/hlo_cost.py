"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE: the body
of a ``while`` loop (every ``lax.scan`` — our layer stacks, flash kv chunks,
SSD chunks) is counted a single time regardless of trip count, so FLOPs /
bytes / collective sizes are undercounted by up to the model depth.

This module statically parses post-SPMD HLO text:

* splits it into computations,
* finds ``while`` ops and derives the trip count from the loop condition
  (scan conditions compare the counter against a constant),
* attributes ``fusion``/``call``/``while`` edges to build execution
  multipliers per computation,
* counts dot FLOPs (2 * numel(out) * contracted) and per-instruction bytes
  per computation,
* reports corrected totals, plus correction RATIOS that can be applied to
  XLA's own (fusion-aware) aggregates:

    corrected_X ~= xla_X * (ours_weighted / ours_once)

* and re-weights collective operand/wire bytes by the multiplier of the
  computation they live in (FSDP all-gathers sit inside the layer scan!).
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?)([a-z0-9]+)\[([0-9,]*)\]\S*\s+"
    r"([a-z0-9\-]+)\("
)
_SHAPES_IN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _bytes_of(dt: str, dims: str) -> int:
    return _numel(dims) * _DTYPE_BYTES.get(dt, 4)


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry = None
        cur = None
        for line in text.splitlines():
            m = _COMP_HEADER.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                self.comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is not None:
                self.comps[cur].append(line)
        if self.entry is None and self.comps:
            self.entry = next(iter(self.comps))
        self._defs_cache: Dict[str, Dict[str, Tuple[str, str]]] = {}

    # ------------------------------------------------------------------
    def defs(self, comp: str) -> Dict[str, Tuple[str, str]]:
        """name -> (dtype, dims) within a computation (tuples keep 1st)."""
        if comp in self._defs_cache:
            return self._defs_cache[comp]
        out = {}
        for line in self.comps.get(comp, []):
            m = _INSTR.match(line)
            if m:
                name, is_tuple, dt, dims, _ = m.groups()
                out[name] = (dt, dims)
        self._defs_cache[comp] = out
        return out

    # ------------------------------------------------------------------
    def trip_count(self, cond_comp: str) -> int:
        """Scan conditions are `counter < constant`: take the largest s32/u32
        constant in the condition computation; default 1 when unknown."""
        best = 1
        for line in self.comps.get(cond_comp, []):
            m = re.search(r"=\s*[su]32\[\]\S*\s+constant\((\d+)\)", line)
            if m:
                best = max(best, int(m.group(1)))
        return best

    # ------------------------------------------------------------------
    def multipliers(self) -> Dict[str, float]:
        """Execution multiplier per computation (product of loop trips)."""
        mult: Dict[str, float] = {c: 0.0 for c in self.comps}
        mult[self.entry] = 1.0
        single_attr = re.compile(
            r"(?:condition|body|calls|to_apply)=%?([\w\.\-]+)"
        )
        braced_attr = re.compile(r"branch_computations=\{([^}]*)\}")
        known_tc = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
        import collections

        q = collections.deque([self.entry])
        while q:
            comp = q.popleft()
            m_here = mult.get(comp, 1.0)
            for line in self.comps.get(comp, []):
                if "=" not in line:
                    continue
                trips = 1
                if re.search(r"\bwhile\(", line):
                    mk = known_tc.search(line)
                    if mk:
                        trips = int(mk.group(1))
                    else:
                        mc = re.search(r"condition=%?([\w\.\-]+)", line)
                        if mc:
                            trips = self.trip_count(mc.group(1))
                callees = [m.group(1) for m in single_attr.finditer(line)]
                for m2 in braced_attr.finditer(line):
                    callees.extend(
                        c.strip().lstrip("%") for c in m2.group(1).split(",")
                    )
                for callee in callees:
                    if callee not in self.comps:
                        continue
                    new = m_here * trips
                    if new > mult.get(callee, 0.0):
                        mult[callee] = new
                        q.append(callee)
        return mult

    # ------------------------------------------------------------------
    def dot_flops(self, comp: str) -> float:
        total = 0.0
        defs = self.defs(comp)
        for line in self.comps.get(comp, []):
            m = _INSTR.match(line)
            if not m:
                continue
            name, is_tuple, dt, dims, op = m.groups()
            if op != "dot":
                continue
            out_n = _numel(dims)
            lhs_m = re.search(r"dot\(\s*%?([\w\.\-]+)", line)
            contr = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
            csize = 1
            if lhs_m and contr and lhs_m.group(1) in defs:
                ldims = defs[lhs_m.group(1)][1].split(",")
                for ci in contr.group(1).split(","):
                    if ci:
                        csize *= int(ldims[int(ci)])
            total += 2.0 * out_n * csize
        return total

    def inst_bytes(self, comp: str) -> float:
        """Rough per-computation bytes: result sizes of all instructions."""
        total = 0.0
        for line in self.comps.get(comp, []):
            m = _INSTR.match(line)
            if not m:
                continue
            _, is_tuple, dt, dims, op = m.groups()
            if op in ("parameter", "constant", "tuple", "get-tuple-element"):
                continue
            if is_tuple:
                for dt2, dims2 in _SHAPES_IN.findall(line.split("=", 1)[1][:200]):
                    total += _bytes_of(dt2, dims2)
            else:
                total += _bytes_of(dt, dims)
        return total

    # ------------------------------------------------------------------
    def collectives(self) -> Dict:
        mult = self.multipliers()
        out = {op: {"count": 0, "operand_bytes": 0.0, "wire_bytes": 0.0}
               for op in _COLL_OPS}
        coll_re = re.compile(
            r"=\s*\(?[a-z0-9]+\[[0-9,]*\][^(]*?\b("
            + "|".join(_COLL_OPS) + r")(-start)?\("
        )
        group_re = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
        group_re2 = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
        for comp, lines in self.comps.items():
            m_comp = mult.get(comp, 1.0)
            if m_comp == 0.0:
                m_comp = 1.0  # unreachable in our walk; count once
            defs = self.defs(comp)
            for line in lines:
                m = coll_re.search(line)
                if not m:
                    continue
                op = m.group(1)
                call = line[m.end():]
                call = call[: call.find(")")] if ")" in call else call
                operands = re.findall(r"%([\w\.\-]+)", call)
                ob = sum(
                    _bytes_of(*defs[o]) for o in operands if o in defs
                )
                gm = group_re.search(line)
                if gm:
                    gsize = int(gm.group(2))
                else:
                    gm2 = group_re2.search(line)
                    gsize = len(gm2.group(1).split(",")) if gm2 else 2
                n = max(gsize, 2)
                factor = {
                    "all-reduce": 2.0 * (n - 1) / n,
                    "all-gather": float(n - 1),
                    "reduce-scatter": (n - 1) / n,
                    "all-to-all": (n - 1) / n,
                    "collective-permute": 1.0,
                }[op]
                out[op]["count"] += 1
                out[op]["operand_bytes"] += ob * m_comp
                out[op]["wire_bytes"] += ob * factor * m_comp
        out["total_operand_bytes"] = sum(
            v["operand_bytes"] for v in out.values() if isinstance(v, dict)
        )
        out["total_wire_bytes"] = sum(
            v["wire_bytes"] for v in out.values() if isinstance(v, dict)
        )
        return out

    # ------------------------------------------------------------------
    def summary(self) -> Dict:
        mult = self.multipliers()
        flops_once = flops_weighted = 0.0
        bytes_once = bytes_weighted = 0.0
        for comp in self.comps:
            m = mult.get(comp, 1.0) or 1.0
            f = self.dot_flops(comp)
            b = self.inst_bytes(comp)
            flops_once += f
            flops_weighted += f * m
            bytes_once += b
            bytes_weighted += b * m
        return {
            "dot_flops_once": flops_once,
            "dot_flops_weighted": flops_weighted,
            "flops_ratio": (flops_weighted / flops_once) if flops_once else 1.0,
            "bytes_once": bytes_once,
            "bytes_weighted": bytes_weighted,
            "bytes_ratio": (bytes_weighted / bytes_once) if bytes_once else 1.0,
            "collectives": self.collectives(),
            "max_multiplier": max(mult.values() or [1.0]),
        }


def corrected_costs(hlo_text: str, xla_flops: float, xla_bytes: float) -> Dict:
    """Apply loop-aware correction ratios to XLA's fusion-aware totals."""
    mod = HloModule(hlo_text)
    s = mod.summary()
    return {
        "flops_per_device": xla_flops * s["flops_ratio"],
        "bytes_accessed_per_device": xla_bytes * s["bytes_ratio"],
        "flops_ratio": s["flops_ratio"],
        "bytes_ratio": s["bytes_ratio"],
        "collectives": s["collectives"],
        "raw": {k: v for k, v in s.items() if k != "collectives"},
    }
