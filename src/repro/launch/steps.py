"""Jit-able step functions shared by train.py / serve.py / dryrun.py."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.compress import ef_int8_compress


def make_train_step(cfg: T.ModelConfig, opt_cfg: adamw.AdamWConfig,
                    grad_compress: bool = False):
    """(params, opt_state, batch[, ef_state]) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch, ef_state=None):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: T.loss_and_aux(p, cfg, batch), has_aux=True
        )(params)
        if grad_compress:
            grads, ef_state = ef_int8_compress(grads, ef_state)
        params, opt_state, metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg
        )
        metrics = dict(metrics, loss=loss, expert_load_max=jnp.max(aux["expert_load"]))
        if grad_compress:
            return params, opt_state, ef_state, metrics
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: T.ModelConfig):
    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch)

    return prefill_step


def make_serve_step(cfg: T.ModelConfig, mqr_sparse: bool = False):
    """One decode step: greedy next token + updated caches."""

    def serve_step(params, tokens, caches, pos):
        logits, caches = T.decode_step(
            params, cfg, tokens, caches, pos, mqr_sparse=mqr_sparse
        )
        # mask vocab-padding ids (see ModelConfig.padded_vocab)
        vocab_ids = jnp.arange(logits.shape[-1])
        logits = jnp.where(vocab_ids < cfg.vocab_size, logits, -jnp.inf)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    return serve_step


def abstract_params(cfg: T.ModelConfig):
    """ShapeDtypeStruct tree of the model parameters (no allocation)."""
    return jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))


def abstract_opt_state(params_abs, opt_cfg: adamw.AdamWConfig):
    return jax.eval_shape(lambda: adamw.init_state(params_abs, opt_cfg))
