"""Training driver: data pipeline -> jit train_step -> checkpoint/restart,
straggler monitoring, failure injection, optional EF-int8 grad compression.

Runs anywhere: single CPU (smoke/examples) up to the production mesh (the
same step function is what dryrun.py lowers for 512 chips).

  PYTHONPATH=src python -m repro.launch.train --arch llama32_1b --smoke \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import registry
from repro.data import DataConfig, SyntheticLM
from repro.ft import FailureInjector, StragglerMonitor
from repro.launch import steps as step_lib
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw
from repro.optim.compress import ef_int8_state


def train(
    arch: str = "llama32_1b",
    smoke: bool = True,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    lr: float = 1e-3,
    ckpt_dir: str = "",
    ckpt_every: int = 50,
    log_every: int = 10,
    grad_compress: bool = False,
    fail_at_step: int = -1,
    seed: int = 0,
    d_model: int = 0,
    n_layers: int = 0,
):
    cfg = registry.get_config(arch, smoke=smoke)
    overrides = {}
    if d_model:
        overrides["d_model"] = d_model
    if n_layers:
        overrides["n_layers"] = n_layers
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    opt_cfg = AdamWConfig(lr=lr, warmup_steps=min(20, steps // 5 + 1),
                          total_steps=steps)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                  global_batch=batch, seed=seed))
    train_step = jax.jit(
        step_lib.make_train_step(cfg, opt_cfg, grad_compress=grad_compress),
        donate_argnums=(0, 1),
    )

    mgr = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None
    injector = FailureInjector(fail_at_step if fail_at_step >= 0 else None)
    monitor = StragglerMonitor()

    start = 0
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = adamw.init_state(params, opt_cfg)
    ef = ef_int8_state(params) if grad_compress else None
    if mgr is not None and mgr.latest_step() is not None:
        start = mgr.latest_step()
        state = mgr.restore(start, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        print(f"[resume] restored step {start} from {ckpt_dir}")

    losses = []
    for step in range(start, steps):
        injector.maybe_fail(step)
        t0 = time.time()
        b = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        if grad_compress:
            params, opt_state, ef, metrics = train_step(params, opt_state, b, ef)
        else:
            params, opt_state, metrics = train_step(params, opt_state, b)
        loss = float(metrics["loss"])
        losses.append(loss)
        monitor.observe(step, time.time() - t0)
        if log_every and step % log_every == 0:
            print(
                f"step {step:5d} loss {loss:7.4f} "
                f"gnorm {float(metrics['grad_norm']):8.3f} "
                f"lr {float(metrics['lr']):.2e} ({time.time()-t0:.2f}s)"
            )
        if mgr is not None and ckpt_every and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state},
                     {"loss": loss})
    if mgr is not None:
        mgr.save(steps, {"params": params, "opt": opt_state},
                 {"loss": losses[-1] if losses else float("nan")})
        mgr.wait()
    if monitor.events:
        print(f"[stragglers] {len(monitor.events)} flagged steps")
    return np.array(losses)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32_1b")
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=-1)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--n-layers", type=int, default=0)
    args = ap.parse_args()
    train(
        arch=args.arch, smoke=not args.full, steps=args.steps,
        batch=args.batch, seq=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, log_every=args.log_every,
        grad_compress=args.grad_compress, fail_at_step=args.fail_at_step,
        d_model=args.d_model, n_layers=args.n_layers,
    )


if __name__ == "__main__":
    main()
