"""deepseek-v3-671b [moe]: 61L d_model=7168 128H MLA, d_ff=2048(moe),
vocab=129280, 1 shared + 256 routed top-8, MTP [arXiv:2412.19437]."""
from repro.models.transformer import ModelConfig
from .registry import scale_for_smoke


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek_v3_671b",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=18432,          # dense layers' FFN (first 3)
        moe_d_ff=2048,
        ffn_kind="moe",
        n_experts=256,
        experts_per_tok=8,
        n_shared_experts=1,
        n_dense_layers=3,
        router_kind="sigmoid",
        vocab_size=129280,
        block_pattern=("mla",),
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        mtp_depth=1,
        tie_embeddings=False,
        attn_chunk=2048,
    )


def smoke_config() -> ModelConfig:
    return scale_for_smoke(config())
