"""mamba2-2.7b [ssm]: 64L d_model=2560, attention-free, vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models.transformer import ModelConfig
from .registry import scale_for_smoke


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2_2p7b",
        n_layers=64,
        d_model=2560,
        n_heads=80,          # d_inner(5120) / headdim(64)
        n_kv_heads=80,
        head_dim=64,
        d_ff=0,
        ffn_kind="none",
        vocab_size=50280,
        block_pattern=("mamba2",),
        ssm_state=128,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_ngroups=1,
        conv_kernel=4,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return scale_for_smoke(config())
