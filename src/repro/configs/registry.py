"""Architecture registry: exact assigned configs + reduced smoke variants +
per-shape input specs (ShapeDtypeStruct stand-ins, no allocation).

Shapes (assignment):
  train_4k     seq_len=4096   global_batch=256   -> train_step
  prefill_32k  seq_len=32768  global_batch=32    -> prefill forward
  decode_32k   seq_len=32768  global_batch=128   -> serve_step (1 new token)
  long_500k    seq_len=524288 global_batch=1     -> serve_step, sub-quadratic
               (SSM/hybrid: native state decode; dense attention archs run
                the mqr-KV sparse path — the paper's technique; DESIGN.md §3.2)
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig

ARCHS = (
    "mamba2_2p7b",
    "granite_moe_1b",
    "deepseek_v3_671b",
    "recurrentgemma_9b",
    "gemma_2b",
    "command_r_35b",
    "granite_8b",
    "llama32_1b",
    "musicgen_large",
    "internvl2_2b",
)

SHAPES: Dict[str, dict] = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke_config() if smoke else mod.config()


def scale_for_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Generic reduction: tiny widths/depths, same family/topology."""
    base = dict(
        n_layers=len(cfg.block_pattern) * 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_chunk=64,
        ssd_chunk=32,
        remat=False,
    )
    if cfg.ffn_kind == "moe":
        base.update(n_experts=4, experts_per_tok=2, moe_d_ff=32,
                    n_shared_experts=min(cfg.n_shared_experts, 1),
                    moe_capacity_factor=4.0)  # drop-free at smoke scale
        if cfg.n_dense_layers:
            base.update(n_layers=3, n_dense_layers=1)
    if cfg.ssm_state:
        base.update(ssm_state=16, ssm_headdim=16, d_model=64)
    if cfg.lru_width:
        base.update(lru_width=64, local_window=32)
    if cfg.use_mla:
        base.update(
            q_lora_rank=32, kv_lora_rank=32, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16,
        )
    if cfg.frontend == "vision_patches":
        base.update(n_patches=8)
    base.update(mqr_block=16, mqr_topk=4, mqr_levels=4)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)


def input_specs(cfg: ModelConfig, shape_name: str, global_batch=None, seq_len=None):
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    sh = SHAPES[shape_name]
    b = global_batch or sh["global_batch"]
    s = seq_len or sh["seq_len"]
    kind = sh["kind"]
    i32 = jnp.int32

    def tok_shape(seq):
        if cfg.frontend == "audio_codebooks":
            return (b, seq, cfg.n_codebooks)
        return (b, seq)

    if kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct(tok_shape(s), i32),
            "labels": jax.ShapeDtypeStruct(tok_shape(s), i32),
        }
        if cfg.frontend == "vision_patches":
            batch["tokens"] = jax.ShapeDtypeStruct(tok_shape(s - cfg.n_patches), i32)
            batch["labels"] = jax.ShapeDtypeStruct(tok_shape(s - cfg.n_patches), i32)
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), jnp.bfloat16
            )
        return {"batch": batch}

    if kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct(tok_shape(s), i32)}
        if cfg.frontend == "vision_patches":
            batch["tokens"] = jax.ShapeDtypeStruct(tok_shape(s - cfg.n_patches), i32)
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), jnp.bfloat16
            )
        return {"batch": batch}

    # decode: one new token against caches of length s
    from repro.models.transformer import init_caches

    caches = jax.eval_shape(lambda: init_caches(cfg, b, s))
    tok = jax.ShapeDtypeStruct(
        (b, 1, cfg.n_codebooks) if cfg.frontend == "audio_codebooks" else (b, 1), i32
    )
    return {
        "tokens": tok,
        "caches": caches,
        "pos": jax.ShapeDtypeStruct((), i32),
    }
