"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152
— llama-arch, code [arXiv:2405.04324]."""
from repro.models.transformer import ModelConfig
from .registry import scale_for_smoke


def config() -> ModelConfig:
    return ModelConfig(
        name="granite_8b",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        ffn_kind="swiglu",
        vocab_size=49152,
        block_pattern=("attn",),
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return scale_for_smoke(config())
