from .registry import ARCHS, SHAPES, get_config, input_specs  # noqa: F401
