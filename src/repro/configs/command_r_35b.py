"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.models.transformer import ModelConfig
from .registry import scale_for_smoke


def config() -> ModelConfig:
    return ModelConfig(
        name="command_r_35b",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22528,
        ffn_kind="swiglu",
        vocab_size=256000,
        block_pattern=("attn",),
        tie_embeddings=True,
        rope_theta=75e5,
    )


def smoke_config() -> ModelConfig:
    return scale_for_smoke(config())
