"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attn 1:2 [arXiv:2402.19427]."""
from repro.models.transformer import ModelConfig
from .registry import scale_for_smoke


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma_9b",
        n_layers=36,          # 38 in paper incl. in/out blocks; 36 pattern layers
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        ffn_kind="geglu",
        act="gelu",
        vocab_size=256000,
        block_pattern=("rglru", "rglru", "local"),
        lru_width=4096,
        local_window=2048,
        conv_kernel=4,
        tie_embeddings=True,
        rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return scale_for_smoke(config())
