"""musicgen-large [audio]: 48L d_model=2048 32H d_ff=8192 vocab=2048 —
decoder-only over EnCodec tokens (4 codebooks); the EnCodec frontend is a
STUB: inputs are codebook token ids [arXiv:2306.05284]."""
from repro.models.transformer import ModelConfig
from .registry import scale_for_smoke


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen_large",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        ffn_kind="mlp_gelu",
        act="gelu",
        vocab_size=2048,
        block_pattern=("attn",),
        frontend="audio_codebooks",
        n_codebooks=4,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return scale_for_smoke(config(), n_codebooks=2)
