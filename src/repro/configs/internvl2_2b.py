"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
— InternViT + InternLM2; the ViT frontend is a STUB: input_specs provide
precomputed patch embeddings [arXiv:2404.16821]."""
from repro.models.transformer import ModelConfig
from .registry import scale_for_smoke


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2_2b",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        ffn_kind="swiglu",
        vocab_size=92553,
        block_pattern=("attn",),
        frontend="vision_patches",
        n_patches=1024,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return scale_for_smoke(config())
