"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) moe_d_ff=512
vocab=49155, 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.models.transformer import ModelConfig
from .registry import scale_for_smoke


def config() -> ModelConfig:
    return ModelConfig(
        name="granite_moe_1b",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,
        moe_d_ff=512,
        ffn_kind="moe",
        n_experts=32,
        experts_per_tok=8,
        router_kind="softmax",
        vocab_size=49155,
        block_pattern=("attn",),
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return scale_for_smoke(config())
