"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 [hf:meta-llama/Llama-3.2-1B]."""
from repro.models.transformer import ModelConfig
from .registry import scale_for_smoke


def config() -> ModelConfig:
    return ModelConfig(
        name="llama32_1b",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        ffn_kind="swiglu",
        vocab_size=128256,
        block_pattern=("attn",),
        tie_embeddings=True,
        rope_theta=500000.0,
    )


def smoke_config() -> ModelConfig:
    return scale_for_smoke(config())
