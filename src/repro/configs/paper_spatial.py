"""The paper's own workload: spatial index construction + region search.

Not an LM arch — exposes dataset/query parameters for the paper benchmarks
(benchmarks/tables.py) and the mqr-KV defaults used by the LM integration.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SpatialConfig:
    dataset: str = "uniform_squares"
    n_objects: int = 1000
    n_trees: int = 5          # paper: 100 random orders; scaled for CPU
    n_queries: int = 20
    seed: int = 0
    rtree_max_entries: int = 5


def config() -> SpatialConfig:
    return SpatialConfig()
