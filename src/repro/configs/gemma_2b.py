"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000 —
GeGLU, head_dim=256 [arXiv:2403.08295]."""
from repro.models.transformer import ModelConfig
from .registry import scale_for_smoke


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma_2b",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        ffn_kind="geglu",
        act="gelu",
        vocab_size=256000,
        block_pattern=("attn",),
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return scale_for_smoke(config())
