"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mbr_scan_ref(mbrs: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """mbrs: (N, 4); queries: (Q, 4) -> (Q, N) bool overlap mask."""
    a = mbrs[None, :, :]
    b = queries[:, None, :]
    return (
        (a[..., 0] <= b[..., 2])
        & (b[..., 0] <= a[..., 2])
        & (a[..., 1] <= b[..., 3])
        & (b[..., 1] <= a[..., 3])
    )


def flash_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool = True
) -> jnp.ndarray:
    """q/k/v: (BH, S, D) -> (BH, S, D), fp32 softmax."""
    s = q.shape[1]
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    logits = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v).astype(q.dtype)


def mqr_sparse_attention_ref(
    q: jnp.ndarray,       # (BH, D)
    k_blocks: jnp.ndarray,  # (BH, nb, bs, D)
    v_blocks: jnp.ndarray,  # (BH, nb, bs, D)
    ids: jnp.ndarray,       # (BH, K) int32 selected blocks
    pos: jnp.ndarray,       # scalar int32 — causal limit (inclusive)
) -> jnp.ndarray:
    """Block-table decode attention -> (BH, D)."""
    bs = k_blocks.shape[2]
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)

    def per(qh, kb, vb, ih):
        kg = kb[ih]  # (K, bs, D)
        vg = vb[ih]
        logits = jnp.einsum("d,ksd->ks", qh, kg).astype(jnp.float32) * scale
        kpos = ih[:, None] * bs + jnp.arange(bs)[None, :]
        logits = jnp.where(kpos <= pos, logits, NEG_INF)
        p = jax.nn.softmax(logits.reshape(-1))
        return jnp.einsum("k,kd->d", p.astype(vg.dtype), vg.reshape(-1, vg.shape[-1]))

    return jax.vmap(per)(q, k_blocks, v_blocks, ids).astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
