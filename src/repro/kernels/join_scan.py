"""Pallas TPU kernel: levelized tree-vs-tree spatial join (one launch).

``pyramid_scan`` sweeps ONE schedule against a resident query batch; this
kernel sweeps TWO :class:`repro.core.flat.LevelSchedule`s against each
other (DESIGN.md §10).  Both sides advance level-synchronized through one
``pallas_call``:

* grid = (K, A-tiles, B-tiles) with ``K = min(levels_a, levels_b)`` —
  levels iterate in the outer grid dimension, so level ``k`` sees level
  ``k-1``'s surviving PAIRS;
* the per-level pair survivor masks live in two VMEM scratch buffers
  (``prev``/``cur``, each (Wa, Wb)) that persist across grid steps;
* both sides' MBR tiles stream coordinate-major (4, block) — one A-tile ×
  B-tile fetch = one tile-pair test, the join analogue of the paper's
  disk access;
* the pair recurrence

      P[k, a, b] = P[k-1, parent_a(a), parent_b(b)] & overlaps(A[k,a], B[k,b])

  prunes exactly like the single-index sweep: a node pair survives only
  if its parent pair did.  The double parent gather is expressed as two
  one-hot matmuls (``onehotA^T @ prev @ onehotB``) so it runs on the MXU;
* level 0 tests the root-pair MBR overlap for EVERY schedule flavour —
  root MBRs contain all their objects, so this is conservative for
  ``root_unconditional`` trees too, and padded sentinel slots can never
  activate.

The sweep is only required to be CONSERVATIVE: the epilogue looks up each
entry pair at the deepest level where both sides still have proper
ancestors (``k = min(entry_level_a, entry_level_b)``, via precomputed
ancestor-slot chains from :func:`repro.core.flat.ancestor_chains`) and
then runs an exact float32 object-MBR confirming pass over the candidate
set.  Any true object pair keeps all its synchronized ancestor pairs
overlapping (both ancestors contain the shared intersection point), so no
true pair is ever pruned, and the confirmed pair-set is bit-identical to
the brute-force O(n·m) nested-loop oracle by construction — for float32
AND uint16 tiles (tests/test_join.py).  Tile precision only moves the
pair-visit counts.

VMEM ceiling: the pair masks cost ``2 · Wa · Wb · 4`` bytes of scratch,
so both level widths together must fit (~2k × 2k at a 32 MB budget);
past that the mask itself needs block-pair tiling (ROADMAP item 5).
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flat import NEVER_MBR, Q_NEVER_MBR, _overlaps


def _pair_overlap_tile(a_tile, b_tile):
    """(4, BA) × (4, BB) coordinate-major tiles -> (BA, BB) closed-boundary
    pair overlap.  Tiles are cast to float32 after the VMEM load (uint16
    grid cells are exact in float32), so one comparison path serves the
    float32 and compact precisions and HBM only streams the narrow form."""
    a = a_tile.astype(jnp.float32)
    b = b_tile.astype(jnp.float32)
    alx, aly, ahx, ahy = a[0][:, None], a[1][:, None], a[2][:, None], a[3][:, None]
    blx, bly, bhx, bhy = b[0][None, :], b[1][None, :], b[2][None, :], b[3][None, :]
    return (alx <= bhx) & (blx <= ahx) & (aly <= bhy) & (bly <= ahy)


def _pair_sweep_kernel(
    a_ref,       # (1, 4, BA) tile of side A, level k
    pa_ref,      # (1, BA) parent slots of side A, level k
    b_ref,       # (1, 4, BB) tile of side B, level k
    pb_ref,      # (1, BB) parent slots of side B, level k
    act_ref,     # out (1, BA, BB) bool
    prev_ref,    # scratch (Wa, Wb) f32 — level k-1 surviving pairs
    cur_ref,     # scratch (Wa, Wb) f32 — level k surviving pairs
    *,
    block_a: int,
    block_b: int,
    width_a: int,
    width_b: int,
    onehot_gather: bool,
    symmetric: bool,
):
    k = pl.program_id(0)
    ta = pl.program_id(1)
    tb = pl.program_id(2)

    @pl.when((k > 0) & (ta == 0) & (tb == 0))
    def _roll():  # level finished: its pair survivors become the parent mask
        if symmetric:
            # Only the upper triangle was swept, but a child pair's
            # parent slots may land BELOW the diagonal — mirror the
            # survivors so the gather sees the full symmetric mask.
            c = cur_ref[...]
            prev_ref[...] = jnp.maximum(c, c.T)
        else:
            prev_ref[...] = cur_ref[...]

    def _tile_body():
        ov = _pair_overlap_tile(a_ref[0], b_ref[0])  # (BA, BB)

        pa_row = pa_ref[0].astype(jnp.int32)
        pb_row = pb_ref[0].astype(jnp.int32)
        if onehot_gather:
            # TPU path: prev[pa, pb] as onehotA^T @ prev @ onehotB — two
            # MXU matmuls instead of a two-axis lane gather.
            ia = jax.lax.broadcasted_iota(jnp.int32, (width_a, block_a), 0)
            oa = (ia == pa_row[None, :]).astype(jnp.float32)  # (Wa, BA)
            ib = jax.lax.broadcasted_iota(jnp.int32, (width_b, block_b), 0)
            ob = (ib == pb_row[None, :]).astype(jnp.float32)  # (Wb, BB)
            pp = jnp.dot(
                oa.T,
                jnp.dot(prev_ref[...], ob,
                        preferred_element_type=jnp.float32),
                preferred_element_type=jnp.float32,
            )
        else:
            # Interpreter path: O(BA·Wb + BA·BB) two-stage take.
            pp = jnp.take(
                jnp.take(prev_ref[...], pa_row, axis=0), pb_row, axis=1
            )
        parent_active = pp > 0.5

        act = jnp.where(k == 0, ov, parent_active & ov)
        if symmetric:
            # Self-join: the pair mask is symmetric at every level, so
            # only slot pairs with ga <= gb are swept.  The mask is at
            # SLOT granularity (not tile granularity) so the surviving
            # set is independent of block size — the lax/np twins apply
            # the identical triu and stay bit-compatible.
            ga = ta * block_a + jax.lax.broadcasted_iota(
                jnp.int32, (block_a, block_b), 0
            )
            gb = tb * block_b + jax.lax.broadcasted_iota(
                jnp.int32, (block_a, block_b), 1
            )
            act = act & (ga <= gb)
        cur_ref[
            pl.ds(ta * block_a, block_a), pl.ds(tb * block_b, block_b)
        ] = act.astype(jnp.float32)
        act_ref[0] = act

    if symmetric:
        # Tiles strictly below the diagonal hold no ga <= gb slot pair:
        # skip the overlap compute and parent gather entirely (this is
        # the ~half-work saving), but still zero their act/cur region so
        # the mirrored roll and the epilogue never read garbage.
        @pl.when(tb < ta)
        def _skip_lower():
            z = jnp.zeros((block_a, block_b), jnp.float32)
            cur_ref[
                pl.ds(ta * block_a, block_a), pl.ds(tb * block_b, block_b)
            ] = z
            act_ref[0] = z.astype(jnp.bool_)

        @pl.when(tb >= ta)
        def _upper():
            _tile_body()
    else:
        _tile_body()


def _pad_side(mbr_cm, parent, block):
    """Pad one side's level tiles to a block multiple with never-overlap
    sentinels (float32 or uint16 grid form) and zero parents."""
    levels, _, w = mbr_cm.shape
    pad = (-w) % block
    if pad:
        never = (
            NEVER_MBR
            if jnp.issubdtype(mbr_cm.dtype, jnp.floating)
            else Q_NEVER_MBR.astype(mbr_cm.dtype)
        )
        mbr_cm = jnp.concatenate(
            [mbr_cm,
             jnp.broadcast_to(jnp.asarray(never)[None, :, None],
                              (levels, 4, pad))],
            axis=2,
        )
        parent = jnp.concatenate(
            [parent, jnp.zeros((levels, pad), parent.dtype)], axis=1
        )
    return mbr_cm, parent, w + pad


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_a", "block_b", "interpret", "onehot_gather", "symmetric"
    ),
)
def pair_sweep(
    a_cm,      # (K, 4, Wa) level tiles of side A (f32 or uint16)
    a_parent,  # (K, Wa) int parent slots of side A
    b_cm,      # (K, 4, Wb) level tiles of side B
    b_parent,  # (K, Wb) int parent slots of side B
    *,
    block_a: int = 128,
    block_b: int = 128,
    interpret: bool = False,
    onehot_gather: bool | None = None,
    symmetric: bool = False,
):
    """Run the fused pair sweep; returns the (K, Wa, Wb) pair-active mask.

    ``symmetric=True`` is the self-join fast path: both sides MUST be the
    same schedule, the sweep tests only slot pairs with ``ga <= gb``
    (strict-lower tiles are skipped — half the tile-pair work), and the
    returned mask holds only the upper triangle per level.  Mirror with
    ``act | act.transpose(0, 2, 1)`` to recover the full mask (the
    epilogue does this when told the join is symmetric).
    """
    k_levels, _, wa = a_cm.shape
    kb, _, wb = b_cm.shape
    assert k_levels == kb, "both sides must be trimmed to the same K levels"
    if symmetric:
        assert wa == wb and block_a == block_b, (
            "symmetric sweep requires identical widths and blocks"
        )
    a_cm, a_parent, wa_p = _pad_side(a_cm, a_parent, block_a)
    b_cm, b_parent, wb_p = _pad_side(b_cm, b_parent, block_b)
    if onehot_gather is None:
        onehot_gather = not interpret
    kernel = functools.partial(
        _pair_sweep_kernel,
        block_a=block_a,
        block_b=block_b,
        width_a=wa_p,
        width_b=wb_p,
        onehot_gather=onehot_gather,
        symmetric=symmetric,
    )
    act = pl.pallas_call(
        kernel,
        grid=(k_levels, wa_p // block_a, wb_p // block_b),
        in_specs=[
            pl.BlockSpec((1, 4, block_a), lambda k, ta, tb: (k, 0, ta)),
            pl.BlockSpec((1, block_a), lambda k, ta, tb: (k, ta)),
            pl.BlockSpec((1, 4, block_b), lambda k, ta, tb: (k, 0, tb)),
            pl.BlockSpec((1, block_b), lambda k, ta, tb: (k, tb)),
        ],
        out_specs=pl.BlockSpec((1, block_a, block_b),
                               lambda k, ta, tb: (k, ta, tb)),
        out_shape=jax.ShapeDtypeStruct((k_levels, wa_p, wb_p), jnp.bool_),
        scratch_shapes=[
            pltpu.VMEM((wa_p, wb_p), jnp.float32),
            pltpu.VMEM((wa_p, wb_p), jnp.float32),
        ],
        interpret=interpret,
    )(a_cm, a_parent, b_cm, b_parent)
    return act[:, :wa, :wb]


def join_epilogue(
    act,                       # (K, Wa, Wb) pair-active mask
    a_anc, a_level, a_gid,     # (Ea, K) chains, (Ea,) levels, (Ea,) global ids
    b_anc, b_level, b_gid,
    table_a, table_b,          # (Na, 4) / (Nb, 4) f32 global-id MBR tables
    alive_a, alive_b,          # (Na,) / (Nb,) bool tombstone masks
    delta_a, delta_b,          # (Na,) / (Nb,) bool delta-buffer candidate rows
    *,
    symmetric: bool = False,   # act holds only the upper triangle per level
):
    """Candidate lookup + exact confirming pass, shared by every engine.

    Entry pair (ea, eb) is a candidate iff the pair mask is active at
    ``k = min(level_a, level_b)`` — the deepest synchronized level where
    both entries still have proper ancestors (their ancestor slots come
    from the precomputed chains).  Delta-buffer rows bypass the structure
    sweep entirely: every pair touching one is a candidate (the flat
    cross-scan of DESIGN.md §10 — the buffer is O(capacity) rows, so
    structural pruning buys nothing the exact pass doesn't).  The exact
    float32 overlap ∧ tombstone masks then make the result bit-identical
    to the brute-force oracle.  Runs under jit (jnp inputs) and as plain
    numpy (host rung) unchanged — index/compare ops only.
    """
    ea = a_level.shape[0]
    eb = b_level.shape[0]
    xp = np if isinstance(act, np.ndarray) else jnp
    sweep_act = act  # unmirrored: the ledger counts pairs actually TESTED
    if symmetric:
        # Upper-triangle sweep: entry pairs gather at arbitrary (sa, sb)
        # order, so mirror the mask for the candidate lookup.
        act = act | act.transpose(0, 2, 1)
    k_ab = xp.minimum(a_level[:, None], b_level[None, :])        # (Ea, Eb)
    sa = a_anc[xp.arange(ea)[:, None], k_ab]
    sb = b_anc[xp.arange(eb)[None, :], k_ab]
    cand = act[k_ab, sa, sb]                                     # (Ea, Eb)
    n_a = table_a.shape[0]
    n_b = table_b.shape[0]
    if xp is jnp:
        pairs = jnp.zeros((n_a, n_b), jnp.bool_)
        pairs = pairs.at[a_gid[:, None], b_gid[None, :]].max(cand)
    else:
        pairs = xp.zeros((n_a, n_b), bool)
        xp.maximum.at(pairs, (a_gid[:, None], b_gid[None, :]), cand)
    pairs = pairs | delta_a[:, None] | delta_b[None, :]
    exact = _overlaps(table_a[:, None, :], table_b[None, :, :])
    pairs = pairs & exact & alive_a[:, None] & alive_b[None, :]
    # Pair-test ledger: per-level tile-pair survivors from the sweep, then
    # one column per side for the delta cross-scan's exact tests.
    visits = xp.concatenate([
        sweep_act.sum(axis=(1, 2), dtype=xp.int32),
        xp.stack([
            delta_a.sum(dtype=xp.int32) * alive_b.sum(dtype=xp.int32),
            delta_b.sum(dtype=xp.int32) * alive_a.sum(dtype=xp.int32),
        ]),
    ])
    return pairs, visits


@functools.partial(
    jax.jit, static_argnames=("block_a", "block_b", "interpret", "symmetric")
)
def _fused_join(
    a_cm, a_parent, a_anc, a_level, a_gid,
    b_cm, b_parent, b_anc, b_level, b_gid,
    table_a, table_b, alive_a, alive_b, delta_a, delta_b,
    *,
    block_a: int,
    block_b: int,
    interpret: bool,
    symmetric: bool = False,
):
    """One jit program: pair sweep kernel + candidate/confirm epilogue.

    Returns ``(pairs (Na, Nb) bool, visits (K + 2,) int32)`` — the pair
    set in global-id space and the per-level pair-test ledger.  The same
    entry serves float32 and compact tiles: the caller just streams the
    uint16 joint-grid form for ``precision="compact"`` (the confirming
    pass is always exact float32, DESIGN.md §10).
    """
    act = pair_sweep(
        a_cm, a_parent, b_cm, b_parent,
        block_a=block_a, block_b=block_b, interpret=interpret,
        symmetric=symmetric,
    )
    return join_epilogue(
        act,
        a_anc, a_level, a_gid,
        b_anc, b_level, b_gid,
        table_a, table_b, alive_a, alive_b, delta_a, delta_b,
        symmetric=symmetric,
    )
