"""Autotuned tiling for the fused region-search sweep (DESIGN.md §12).

The fused kernels historically ran with a hardcoded ``block_w=128``.  The
best tile shape actually depends on the schedule: wide pyramid levels
amortize per-step overhead with bigger tiles, narrow tree grids waste
VMEM (and, interpreted, Python kernel-body invocations) on them, and for
some shapes the per-level launch plan beats the fused grid outright.
This module times a small candidate grid of

* ``block_w``        — slot-tile width of the sweep grid,
* ``query_block``    — split the query batch into chunks of this many
                       rows (``None`` = whole batch in one launch),
* ``levels_in_grid`` — the fused single-launch sweep (True) vs the
                       per-level launch baseline (False; float32
                       non-streamed paths only),

on a probe slice of the first real query batch and returns the winner as
a :class:`TileConfig`.  The caller (``repro.index.backends.PallasBackend``)
caches winners in ``BuildArtifacts.tuned`` keyed by :func:`shape_key`, so
every backend sharing the artifacts — ``with_backend`` twins included —
reuses the measurement instead of re-timing.

Timing is wall-clock over the backend's own runner, after one warm-up
call (so jit/lowering cost is excluded), best-of-``iters``.  A candidate
that raises (e.g. a tile shape the runtime rejects) is skipped, never
fatal.  The fixed default ``TileConfig()`` is always in the candidate
grid, so the tuned pick can only match or beat it.
"""

from __future__ import annotations

import dataclasses
import time

__all__ = [
    "TileConfig",
    "DEFAULT_BLOCK_WS",
    "AUTO_MIN_WIDTH",
    "shape_key",
    "candidates",
    "tune",
]

DEFAULT_BLOCK_WS = (64, 128, 256, 512)

# autotune="auto" only spends tuning time when the slot grid is at least
# this wide; narrower schedules sweep in microseconds at any tile shape.
AUTO_MIN_WIDTH = 1024

# Probe slice of the first query batch used for timing.
PROBE_QUERIES = 16


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One point of the tiling candidate grid (the default is the
    historical fixed configuration)."""

    block_w: int = 128
    query_block: int | None = None
    levels_in_grid: bool = True


def _bucket(v: int) -> int:
    """Next power of two ≥ v (≥ 1) — coarse enough that e.g. every query
    batch of 65..128 rows shares one cached measurement."""
    return 1 << max(int(v - 1).bit_length(), 0) if v > 1 else 1


def shape_key(width: int, levels: int, n_queries: int, precision: str,
              stream: bool):
    """Cache key of a tuning measurement in ``BuildArtifacts.tuned``.

    Width and query count are bucketed to the next power of two; levels,
    precision and the streaming flag are exact — those change the kernel
    being launched, not just its extent.
    """
    return (_bucket(width), int(levels), _bucket(n_queries), str(precision),
            bool(stream))


def candidates(width: int, n_queries: int, *, precision: str = "float32",
               stream: bool = False, live: bool = False,
               block_ws=DEFAULT_BLOCK_WS):
    """The candidate grid for one shape.  Always contains the fixed
    default :class:`TileConfig`, so tuning never loses to it."""
    bws = [bw for bw in block_ws if bw <= max(_bucket(width), 128)]
    if not bws:
        bws = [128]
    qbs = [None]
    if n_queries > 32:
        qbs.append(32)
    out = []
    for bw in bws:
        for qb in qbs:
            out.append(TileConfig(bw, qb, True))
            # The per-level launch plan only exists for the plain float32
            # sweep (no delta levels, no quantized tiles, no streaming).
            if precision == "float32" and not stream and not live:
                out.append(TileConfig(bw, qb, False))
    default = TileConfig()
    if default not in out:
        out.insert(0, default)
    return out


def tune(make_run, cands, *, iters: int = 2):
    """Time every candidate and return ``(best_cfg, {cfg: seconds})``.

    ``make_run(cfg)`` returns a zero-argument callable executing the
    search under that configuration (the caller blocks on the result so
    the measurement covers real work).  One warm-up call per candidate
    excludes jit/lowering cost; the score is the best of ``iters`` timed
    calls.  Candidates that raise are skipped; if all do, the fixed
    default wins by fiat.
    """
    timings: dict[TileConfig, float] = {}
    best = None
    for cfg in cands:
        try:
            fn = make_run(cfg)
            fn()  # warm-up: compile/lower outside the measurement
            t = min(
                _timed(fn) for _ in range(max(iters, 1))
            )
        except Exception:
            continue
        timings[cfg] = t
        if best is None or t < timings[best]:
            best = cfg
    if best is None:
        best = TileConfig()
    return best, timings


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
