"""Public jit'd wrappers for the Pallas kernels.

On this CPU container the kernels execute with ``interpret=True`` (the
Pallas interpreter runs the kernel body in Python for correctness); on a
real TPU runtime set ``REPRO_PALLAS_COMPILE=1`` to lower them natively.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import ref
from .build import build_levels_jnp as build_levels_jnp  # noqa: F401
from .build import build_levels_pallas as build_levels_pallas  # noqa: F401
from .build import device_schedule as _device_schedule
from .build import hilbert_keys as hilbert_keys  # noqa: F401 (re-export)
from .build import hilbert_permute as hilbert_permute  # noqa: F401
from .flash_attention import flash_attention as _flash
from .join_scan import _fused_join
from .join_scan import pair_sweep as _pair_sweep
from .mbr_scan import mbr_scan as _mbr_scan
from .mqr_sparse_attention import mqr_sparse_attention as _sparse
from .pyramid_scan import (
    _fused_search,
    _fused_search_compact,
    _fused_search_compact8,
    _fused_search_compact_live,
    _fused_search_live,
)
from .pyramid_scan import level_sweep as level_sweep  # noqa: F401
from .pyramid_scan import level_sweep_hier as level_sweep_hier  # noqa: F401
from .pyramid_scan import parent_windows as parent_windows  # noqa: F401
from .pyramid_scan import per_level_region_search as _per_level
from .pyramid_scan import pyramid_scan as _pyramid_scan
from .pyramid_scan import pyramid_scan_compact as _pyramid_scan_compact
from .pyramid_scan import pyramid_scan_compact8 as _pyramid_scan_compact8
from .quantize import grid_params as grid_params  # noqa: F401 (re-export)
from .quantize import quantize_rows as quantize_rows  # noqa: F401 (re-export)
from .quantize import quantize_schedule as _quantize_schedule
from .rmsnorm import rmsnorm as _rmsnorm


def interpret_default() -> bool:
    """Default Pallas execution policy: interpret off TPU, compile on TPU
    (``REPRO_PALLAS_COMPILE=1`` forces native lowering).  This is the ONE
    public source of that policy — callers outside ``kernels/`` must not
    reach for private module state."""
    if os.environ.get("REPRO_PALLAS_COMPILE", "0") == "1":
        return False
    return jax.default_backend() != "tpu"


# Internal alias kept for the kernel wrappers below.
_interpret = interpret_default


def fused_search(
    queries,
    mbr_cm,
    parent,
    obj_mbr,
    obj_level,
    obj_slot,
    obj_id,
    *,
    n_objects: int,
    block_w: int = 128,
    root_unconditional: bool = True,
    test_object_mbr: bool = True,
    interpret: bool | None = None,
    stream: bool = False,
    win_off=None,
    win_w: int | None = None,
):
    """Array-level public entry of the fused sweep (DESIGN.md §3.3).

    Same computation as :func:`pyramid_scan` but over the unpacked
    ``LevelSchedule`` arrays, so callers (e.g. the spatial server) can
    ``vmap``/``pmap`` it over query blocks with the schedule arrays held
    constant.  Returns ``(hits (Q, n_objects), visits (Q, L))``.

    ``stream=True`` runs the HBM-streaming double-buffered sweep
    (DESIGN.md §12); pass the ``(win_off, win_w)`` parent windows from
    :func:`parent_windows` alongside.
    """
    if interpret is None:
        interpret = interpret_default()
    return _fused_search(
        queries, mbr_cm, parent, obj_mbr, obj_level, obj_slot, obj_id,
        n_objects=n_objects,
        block_w=block_w,
        root_unconditional=root_unconditional,
        test_object_mbr=test_object_mbr,
        interpret=interpret,
        stream=stream,
        win_off=win_off,
        win_w=win_w,
    )


def fused_search_live(
    queries, mbr_cm, parent, obj_mbr, obj_level, obj_slot, obj_id, alive,
    *,
    n_objects: int,
    base_levels: int,
    block_w: int = 128,
    root_unconditional: bool = True,
    test_object_mbr: bool = True,
    interpret: bool | None = None,
    stream: bool = False,
    win_off=None,
    win_w: int | None = None,
):
    """Live-update variant of :func:`fused_search` (DESIGN.md §8): the
    level grid carries ``base_levels`` hierarchical levels plus appended
    FLAT delta-buffer levels (swept unconditionally in the same launch),
    object ids are global, and ``alive`` masks tombstoned ids out of the
    hit set.  Returns ``(hits (Q, n_objects), visits (Q, L+D))``."""
    if interpret is None:
        interpret = interpret_default()
    return _fused_search_live(
        queries, mbr_cm, parent, obj_mbr, obj_level, obj_slot, obj_id, alive,
        n_objects=n_objects,
        base_levels=base_levels,
        block_w=block_w,
        root_unconditional=root_unconditional,
        test_object_mbr=test_object_mbr,
        interpret=interpret,
        stream=stream,
        win_off=win_off,
        win_w=win_w,
    )


def fused_search_compact_live(
    queries, mbr_q, parent_q, confirm_mbr, obj_level, obj_slot, obj_id,
    origin, inv_cell, alive,
    *,
    n_objects: int,
    cells: int,
    base_levels: int,
    block_w: int = 128,
    root_unconditional: bool = True,
    interpret: bool | None = None,
    stream: bool = False,
    win_off=None,
    win_w: int | None = None,
):
    """Live-update variant of :func:`fused_search_compact`: uint16 base
    tiles + quantized flat delta levels in one integer sweep, exact
    confirming pass, tombstones masked via ``alive`` (DESIGN.md §8)."""
    if interpret is None:
        interpret = interpret_default()
    return _fused_search_compact_live(
        queries, mbr_q, parent_q, confirm_mbr, obj_level, obj_slot, obj_id,
        origin, inv_cell, alive,
        n_objects=n_objects,
        cells=cells,
        base_levels=base_levels,
        block_w=block_w,
        root_unconditional=root_unconditional,
        interpret=interpret,
        stream=stream,
        win_off=win_off,
        win_w=win_w,
    )


def fused_join(
    a_cm, a_parent, a_anc, a_level, a_gid,
    b_cm, b_parent, b_anc, b_level, b_gid,
    table_a, table_b, alive_a, alive_b, delta_a, delta_b,
    *,
    block_a: int = 128,
    block_b: int = 128,
    interpret: bool | None = None,
    symmetric: bool = False,
):
    """Tree-vs-tree spatial join: one fused pair-sweep launch + exact
    confirming epilogue (DESIGN.md §10).

    Both sides arrive as their first ``K = min(levels_a, levels_b)``
    schedule levels (float32 tiles, or uint16 tiles quantized onto one
    JOINT grid for ``precision="compact"``), per-entry ancestor chains
    from :func:`repro.core.flat.ancestor_chains`, global-id float32 MBR
    tables, tombstone ``alive`` masks, and delta-buffer candidate row
    masks.  Returns ``(pairs (Na, Nb) bool, visits (K + 2,) int32)`` —
    the pair set is bit-identical to the brute-force nested-loop oracle
    on every precision; only ``visits`` (tile-pair tests per level, plus
    one delta cross-scan column per side) depends on tile precision.

    ``symmetric=True`` (self-join: both sides the same schedule + live
    state) sweeps only the upper pair triangle — half the tile-pair
    work — and mirrors in the epilogue; the pair set is unchanged.
    """
    if interpret is None:
        interpret = interpret_default()
    return _fused_join(
        a_cm, a_parent, a_anc, a_level, a_gid,
        b_cm, b_parent, b_anc, b_level, b_gid,
        table_a, table_b, alive_a, alive_b, delta_a, delta_b,
        block_a=block_a,
        block_b=block_b,
        interpret=interpret,
        symmetric=symmetric,
    )


def pair_sweep(a_cm, a_parent, b_cm, b_parent, *, block_a: int = 128,
               block_b: int = 128, interpret: bool | None = None,
               symmetric: bool = False):
    """Raw (K, Wa, Wb) pair-active mask of the synchronized level sweep —
    the join kernel without its epilogue, for tests and benches."""
    if interpret is None:
        interpret = interpret_default()
    return _pair_sweep(
        a_cm, a_parent, b_cm, b_parent,
        block_a=block_a, block_b=block_b, interpret=interpret,
        symmetric=symmetric,
    )


def device_schedule(mbrs, *, levels=None, engine: str = "auto",
                    block_n: int = 128, interpret: bool | None = None,
                    order: str | None = None):
    """Device-resident bulk build straight to a ``LevelSchedule`` — no
    host pointer tree, no ``flatten()`` (DESIGN.md §7).  ``engine="auto"``
    picks the one-launch Pallas build kernel when compiling natively and
    the object set fits its VMEM residency, the jit'd jnp fixed point
    otherwise; both are bit-identical to the host
    ``flat.pyramid_schedule`` lowering.  ``order="hilbert"`` permutes the
    real slots of every level into Hilbert-curve order of their MBR
    centers after the build (DESIGN.md §12) — hit sets, visit counts and
    reported ids are unchanged; only tile locality improves."""
    if interpret is None:
        interpret = interpret_default()
    return _device_schedule(
        mbrs, levels=levels, engine=engine, block_n=block_n,
        interpret=interpret, order=order,
    )


def quantize_schedule(schedule, *, engine: str = "auto", block_w: int = 128,
                      interpret: bool | None = None, upper8: bool = False,
                      split: int | None = None):
    """Lower a ``LevelSchedule`` to its conservative uint16 tile form
    (``QuantizedSchedule``, DESIGN.md §7) for the compact fused scan.
    ``upper8=True`` adds coarse uint8 tiles for levels ``[0, split)`` on
    a 254-cell grid — the hierarchical form :func:`pyramid_scan_compact8`
    sweeps (DESIGN.md §12)."""
    if interpret is None:
        interpret = interpret_default()
    return _quantize_schedule(
        schedule, engine=engine, block_w=block_w, interpret=interpret,
        upper8=upper8, split=split,
    )


def pyramid_scan_compact(qsched, queries, *, block_w: int = 128,
                         interpret: bool | None = None,
                         stream: bool = False):
    """Fused region search over uint16 tiles + exact float32 confirming
    pass: hit sets bit-identical to :func:`pyramid_scan` at ~half the
    streamed bytes per query; ``visits`` reports the compact sweep's own
    conservative access counts (DESIGN.md §7).  ``stream=True`` runs the
    HBM-streaming sweep (DESIGN.md §12)."""
    if interpret is None:
        interpret = interpret_default()
    return _pyramid_scan_compact(
        qsched, queries, block_w=block_w, interpret=interpret, stream=stream
    )


def pyramid_scan_compact8(qsched, queries, *, block_w: int = 128,
                          interpret: bool | None = None):
    """Hierarchical compact region search (DESIGN.md §12): coarse uint8
    tiles gate the upper levels, uint16 tiles the lower, and the exact
    float32 confirming pass keeps hit sets bit-identical to
    :func:`pyramid_scan`.  Needs ``quantize_schedule(..., upper8=True)``;
    upper-level streamed bytes drop ~2x vs the uint16 form."""
    if interpret is None:
        interpret = interpret_default()
    return _pyramid_scan_compact8(
        qsched, queries, block_w=block_w, interpret=interpret
    )


def fused_search_compact(
    queries, mbr_q, parent_q, confirm_mbr, obj_level, obj_slot, obj_id,
    origin, inv_cell,
    *,
    n_objects: int,
    cells: int,
    block_w: int = 128,
    root_unconditional: bool = True,
    interpret: bool | None = None,
    stream: bool = False,
    win_off=None,
    win_w: int | None = None,
):
    """Array-level public entry of the compact sweep (the ``precision=
    "compact"`` analogue of :func:`fused_search`), ``vmap``/``pmap``-able
    over query blocks with the quantized schedule arrays held constant."""
    if interpret is None:
        interpret = interpret_default()
    return _fused_search_compact(
        queries, mbr_q, parent_q, confirm_mbr, obj_level, obj_slot, obj_id,
        origin, inv_cell,
        n_objects=n_objects,
        cells=cells,
        block_w=block_w,
        root_unconditional=root_unconditional,
        interpret=interpret,
        stream=stream,
        win_off=win_off,
        win_w=win_w,
    )


def fused_search_compact8(
    queries, mbr_q8, mbr_q16, parent_q, confirm_mbr, obj_level, obj_slot,
    obj_id, origin, inv_cell, inv_cell8,
    *,
    n_objects: int,
    cells: int,
    cells8: int,
    split: int,
    block_w: int = 128,
    root_unconditional: bool = True,
    interpret: bool | None = None,
):
    """Array-level public entry of the hierarchical uint8/uint16 sweep
    (the ``precision="compact8"`` analogue of :func:`fused_search_compact`,
    DESIGN.md §12): ``mbr_q8`` carries the coarse tiles of levels
    ``[0, split)``, ``mbr_q16`` the fine tiles of levels ``[split, L)``."""
    if interpret is None:
        interpret = interpret_default()
    return _fused_search_compact8(
        queries, mbr_q8, mbr_q16, parent_q, confirm_mbr, obj_level, obj_slot,
        obj_id, origin, inv_cell, inv_cell8,
        n_objects=n_objects,
        cells=cells,
        cells8=cells8,
        split=split,
        block_w=block_w,
        root_unconditional=root_unconditional,
        interpret=interpret,
    )


def mbr_scan(mbrs, queries, *, block_n: int = 512):
    """(N,4) x (Q,4) -> (Q,N) overlap mask via the Pallas level-scan."""
    return _mbr_scan(
        jnp.asarray(mbrs, jnp.float32),
        jnp.asarray(queries, jnp.float32),
        block_n=block_n,
        interpret=_interpret(),
    )


def pyramid_scan(schedule, queries, *, block_w: int = 128,
                 interpret: bool | None = None, stream: bool = False):
    """Fused multi-level region search: one launch for the whole levelized
    sweep (DESIGN.md §3.3).  Returns (hits (Q, n_obj), visits (Q, L)).
    ``interpret=None`` follows :func:`interpret_default`.  ``stream=True``
    runs the HBM-streaming double-buffered sweep (DESIGN.md §12): MBR
    tiles stay in HBM and are DMA'd through a two-slot VMEM buffer, so
    VMEM residency no longer bounds the schedule width."""
    if interpret is None:
        interpret = interpret_default()
    return _pyramid_scan(
        schedule, queries, block_w=block_w, interpret=interpret, stream=stream
    )


def per_level_region_search(schedule, queries, *, block_w: int = 128):
    """Baseline: one mbr_scan launch per level, host-combined frontier.
    Returns (hits, visits, n_launches)."""
    return _per_level(
        schedule, queries, block_w=block_w, interpret=_interpret()
    )


def flash_attention(q, k, v, *, block_q: int = 128, block_k: int = 128):
    """Causal flash attention, (BH, S, D). kv heads must be pre-broadcast."""
    return _flash(q, k, v, block_q=block_q, block_k=block_k,
                  interpret=_interpret())


def mqr_sparse_attention(q, k_blocks, v_blocks, ids, pos):
    """Block-table decode attention over mqr-selected blocks."""
    return _sparse(q, k_blocks, v_blocks, ids, jnp.asarray(pos, jnp.int32),
                   interpret=_interpret())


def rmsnorm(x, scale, eps: float = 1e-6):
    return _rmsnorm(x, scale, eps, interpret=_interpret())


# re-export oracles for tests/benches
mbr_scan_ref = ref.mbr_scan_ref
flash_attention_ref = ref.flash_attention_ref
mqr_sparse_attention_ref = ref.mqr_sparse_attention_ref
rmsnorm_ref = ref.rmsnorm_ref
