"""Public jit'd wrappers for the Pallas kernels.

On this CPU container the kernels execute with ``interpret=True`` (the
Pallas interpreter runs the kernel body in Python for correctness); on a
real TPU runtime set ``REPRO_PALLAS_COMPILE=1`` to lower them natively.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _flash
from .mbr_scan import mbr_scan as _mbr_scan
from .mqr_sparse_attention import mqr_sparse_attention as _sparse
from .pyramid_scan import per_level_region_search as _per_level
from .pyramid_scan import pyramid_scan as _pyramid_scan
from .rmsnorm import rmsnorm as _rmsnorm


def _interpret() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE", "0") == "1":
        return False
    return jax.default_backend() != "tpu"


def mbr_scan(mbrs, queries, *, block_n: int = 512):
    """(N,4) x (Q,4) -> (Q,N) overlap mask via the Pallas level-scan."""
    return _mbr_scan(
        jnp.asarray(mbrs, jnp.float32),
        jnp.asarray(queries, jnp.float32),
        block_n=block_n,
        interpret=_interpret(),
    )


def pyramid_scan(schedule, queries, *, block_w: int = 128):
    """Fused multi-level region search: one launch for the whole levelized
    sweep (DESIGN.md §3.3).  Returns (hits (Q, n_obj), visits (Q, L))."""
    return _pyramid_scan(
        schedule, queries, block_w=block_w, interpret=_interpret()
    )


def per_level_region_search(schedule, queries, *, block_w: int = 128):
    """Baseline: one mbr_scan launch per level, host-combined frontier.
    Returns (hits, visits, n_launches)."""
    return _per_level(
        schedule, queries, block_w=block_w, interpret=_interpret()
    )


def flash_attention(q, k, v, *, block_q: int = 128, block_k: int = 128):
    """Causal flash attention, (BH, S, D). kv heads must be pre-broadcast."""
    return _flash(q, k, v, block_q=block_q, block_k=block_k,
                  interpret=_interpret())


def mqr_sparse_attention(q, k_blocks, v_blocks, ids, pos):
    """Block-table decode attention over mqr-selected blocks."""
    return _sparse(q, k_blocks, v_blocks, ids, jnp.asarray(pos, jnp.int32),
                   interpret=_interpret())


def rmsnorm(x, scale, eps: float = 1e-6):
    return _rmsnorm(x, scale, eps, interpret=_interpret())


# re-export oracles for tests/benches
mbr_scan_ref = ref.mbr_scan_ref
flash_attention_ref = ref.flash_attention_ref
mqr_sparse_attention_ref = ref.mqr_sparse_attention_ref
rmsnorm_ref = ref.rmsnorm_ref
