"""Pallas TPU kernel: mqr-KV block-table decode attention.

The consumer of the paper's region search: given the top-K block ids chosen
by the mqr index (repro.core.kvindex), attend over ONLY those KV blocks.
Block ids are scalar-prefetched (PrefetchScalarGridSpec) so the BlockSpec
index_map can chase the block table — the TPU equivalent of the paper's
pointer dereference, resolved at tile-fetch granularity.  Zero-overlap
sibling MBRs (paper §4) mean no block is fetched twice: HBM traffic is
exactly K·bs·D·2 bytes per (batch, head).

Shapes: q (BH, D); k/v blocks (BH, nb, bs, D); ids (BH, K) int32.
Grid = (BH, K), K innermost/sequential; softmax state in VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(ids_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
            acc_scr, *, block_size, scale):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)
    bh = pl.program_id(0)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...]        # (1, D) — row vector
    k = k_ref[0, 0]       # (bs, D)
    v = v_ref[0, 0]
    logits = (
        jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * scale
    )  # (1, bs)
    block_id = ids_ref[bh, ki]
    kpos = block_id * block_size + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1
    )
    logits = jnp.where(kpos <= pos_ref[0], logits, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(jax.jit, static_argnames=("interpret",))
def mqr_sparse_attention(
    q: jnp.ndarray,        # (BH, D)
    k_blocks: jnp.ndarray,  # (BH, nb, bs, D)
    v_blocks: jnp.ndarray,  # (BH, nb, bs, D)
    ids: jnp.ndarray,       # (BH, K) int32
    pos: jnp.ndarray,       # scalar int32 causal limit (inclusive)
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, nb, bs, d = k_blocks.shape
    kk = ids.shape[1]
    scale = 1.0 / (d ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # ids, pos
        grid=(bh, kk),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, k, ids_ref, pos_ref: (b, 0)),
            pl.BlockSpec(
                (1, 1, bs, d),
                lambda b, k, ids_ref, pos_ref: (b, ids_ref[b, k], 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, bs, d),
                lambda b, k, ids_ref, pos_ref: (b, ids_ref[b, k], 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, k, ids_ref, pos_ref: (b, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_size=bs, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, d), q.dtype),
        interpret=interpret,
    )(ids, pos.reshape(1), q, k_blocks, v_blocks)
