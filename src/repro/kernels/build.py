"""Pallas TPU kernel: device-resident bulk build of the mqr group pyramid.

The host build path (``core/mqrtree.py`` insertion, then ``flatten`` +
``level_schedule``) is per-object Python and dominates end-to-end time for
large n; ``core/bulk.py`` already phrases the canonical mqr tree as a
level-by-level centroid-quadrant fixed point in pure jnp.  This module
computes that same fixed point ON DEVICE and emits the
:class:`repro.core.flat.LevelSchedule` arrays the fused region-search
kernel consumes directly — no host pointer tree, no ``flatten()`` on the
hot build path (DESIGN.md §7).

Two engines, bit-identical outputs:

* ``engine="pallas"`` — ONE ``pallas_call`` with ``grid=(levels,)``.  The
  object MBRs stay VMEM-resident coordinate-major for the whole build; per
  level the kernel (a) subdivides each multi-member group by the
  branch-free Fig. 2 quadrant select of ``bulk.quad_code``, (b) densifies
  the new ``parent*5+quad`` keys with a presence-mask + prefix-sum rank
  (identical numbering to ``bulk._densify``'s sort-based ranks, because
  both assign dense ids in ascending key order), and (c) computes each
  group's enclosing MBR as a segment min/max over ``block_n``-object tiles
  (one-hot select + tile reduce).  Group-of / slot-MBR / parent rows are
  emitted level by level straight into the schedule layout.
* ``engine="jnp"`` — ``bulk.build_pyramid`` (the parity oracle) plus a
  vectorized scatter for the parent map, all jit'd; this is also the
  large-n path, since the kernel holds the whole object set in VMEM and is
  therefore sized for VMEM-scale n (DESIGN.md §7).

Both produce a schedule bit-identical to the host
``flat.pyramid_schedule(bulk.build_pyramid(...))`` lowering
(tests/test_device_build.py), so the fused scan's hit sets and per-level
access counts are unchanged — only where the build runs moves.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bulk
from repro.core.flat import LevelSchedule

# Above this the whole-set VMEM residency of the build kernel stops making
# sense (objects, bounds, and the 5x key space all live on chip); the
# ``auto`` engine falls back to the jit'd jnp fixed point.
PALLAS_BUILD_MAX_N = 4096


def _build_kernel(
    mbr_ref,      # (4, W) f32 — object MBRs coordinate-major, resident
    gof_ref,      # out (1, W) i32 — group id per object at this level
    mbr_out_ref,  # out (1, 4, W) f32 — slot MBRs of this level
    par_out_ref,  # out (1, W) i32 — parent slot of each slot
    gid_ref,      # scratch (1, W) i32 — current-level group ids
    prev_ref,     # scratch (1, W) i32 — previous-level group ids
    bounds_ref,   # scratch (4, W) f32 — per-slot MBRs (segment min/max)
    counts_ref,   # scratch (1, W) f32 — per-slot member counts
    *,
    n: int,
    width: int,
    block_n: int,
    onehot_gather: bool,
):
    l = pl.program_id(0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, width), 1)[0]  # (W,)
    valid = lane < n
    n_tiles = width // block_n

    cx = (mbr_ref[0, :] + mbr_ref[2, :]) * 0.5  # (W,) object centroids
    cy = (mbr_ref[1, :] + mbr_ref[3, :]) * 0.5

    @pl.when(l == 0)
    def _root():
        gid_ref[...] = jnp.zeros((1, width), jnp.int32)
        prev_ref[...] = jnp.zeros((1, width), jnp.int32)

    @pl.when(l > 0)
    def _subdivide():
        # Level l-1 state is still in scratch: derive level-l group ids.
        gid = gid_ref[0, :]
        # Empty slots carry +/-inf sentinels; members only ever gather
        # their own (non-empty, finite) group, so zero the empties to keep
        # 0*inf NaNs out of the one-hot matmul.
        safe = jnp.where(counts_ref[...] > 0.0, bounds_ref[...], 0.0)
        if onehot_gather:
            # MXU path: per-object group box/count via one-hot matmuls
            # over block_n-object tiles.
            gb_tiles, cnt_tiles = [], []
            for t in range(n_tiles):
                sl = slice(t * block_n, (t + 1) * block_n)
                oh = (
                    jax.lax.broadcasted_iota(jnp.int32, (block_n, width), 1)
                    == gid[sl][:, None]
                ).astype(jnp.float32)
                gb_tiles.append(
                    jnp.dot(oh, safe.T, preferred_element_type=jnp.float32).T
                )
                cnt_tiles.append(jnp.dot(oh, counts_ref[0, :]))
            gb = jnp.concatenate(gb_tiles, axis=1)    # (4, W)
            cnt = jnp.concatenate(cnt_tiles)          # (W,)
        else:
            gb = jnp.take(safe, gid, axis=1)          # (4, W)
            cnt = jnp.take(counts_ref[0, :], gid)     # (W,)
        gcx = (gb[0] + gb[2]) * 0.5
        gcy = (gb[1] + gb[3]) * 0.5
        quad = bulk.quad_code(cx, cy, gcx, gcy)
        # Same key rule as bulk.build_pyramid: singletons keep their slot
        # ("quad 0" of their own group); keys stay unique per group.
        key = jnp.where(cnt > 1.5, gid * 5 + quad, gid * 5)
        key = jnp.where(valid, key, 0)
        # Densify: presence mask over the 5W key space, then prefix-sum
        # ranks — ascending-key numbering, exactly bulk._densify's.
        kspace = 5 * width
        pres = jnp.zeros((kspace,), jnp.float32)
        for t in range(n_tiles):
            sl = slice(t * block_n, (t + 1) * block_n)
            oh5 = (
                jax.lax.broadcasted_iota(jnp.int32, (block_n, kspace), 1)
                == key[sl][:, None]
            ) & valid[sl][:, None]
            pres = jnp.maximum(pres, oh5.astype(jnp.float32).max(axis=0))
        rank = jnp.cumsum(pres) - 1.0  # (5W,) f32; exact for n < 2**24
        if onehot_gather:
            gid_tiles = []
            for t in range(n_tiles):
                sl = slice(t * block_n, (t + 1) * block_n)
                oh5 = (
                    jax.lax.broadcasted_iota(jnp.int32, (block_n, kspace), 1)
                    == key[sl][:, None]
                ).astype(jnp.float32)
                gid_tiles.append(jnp.dot(oh5, rank).astype(jnp.int32))
            new_gid = jnp.concatenate(gid_tiles)
        else:
            new_gid = jnp.take(rank, key).astype(jnp.int32)
        prev_ref[...] = gid_ref[...]
        gid_ref[0, :] = jnp.where(valid, new_gid, 0)

    # Segment min/max for the CURRENT level's groups, block_n objects at a
    # time (the "VMEM-resident tiles" of the level fixed point).
    bounds_ref[0, :] = jnp.full((width,), jnp.inf, jnp.float32)
    bounds_ref[1, :] = jnp.full((width,), jnp.inf, jnp.float32)
    bounds_ref[2, :] = jnp.full((width,), -jnp.inf, jnp.float32)
    bounds_ref[3, :] = jnp.full((width,), -jnp.inf, jnp.float32)
    counts_ref[...] = jnp.zeros((1, width), jnp.float32)
    par_acc = jnp.zeros((width,), jnp.float32)
    gid = gid_ref[0, :]
    prev = prev_ref[0, :]
    for t in range(n_tiles):
        sl = slice(t * block_n, (t + 1) * block_n)
        oh = (
            jax.lax.broadcasted_iota(jnp.int32, (block_n, width), 1)
            == gid[sl][:, None]
        ) & valid[sl][:, None]
        for c, red, fill in ((0, jnp.min, jnp.inf), (1, jnp.min, jnp.inf),
                             (2, jnp.max, -jnp.inf), (3, jnp.max, -jnp.inf)):
            part = red(
                jnp.where(oh, mbr_ref[c, sl][:, None], fill), axis=0
            )
            bounds_ref[c, :] = (
                jnp.minimum(bounds_ref[c, :], part)
                if red is jnp.min
                else jnp.maximum(bounds_ref[c, :], part)
            )
        counts_ref[0, :] = counts_ref[0, :] + oh.astype(jnp.float32).sum(axis=0)
        # parent[slot of member] = member's previous-level gid (groups
        # nest, so every member agrees); max-reduce the (prev+1) tags.
        par_acc = jnp.maximum(
            par_acc,
            jnp.where(oh, (prev[sl] + 1).astype(jnp.float32)[:, None],
                      0.0).max(axis=0),
        )

    gof_ref[0, :] = gid
    mbr_out_ref[0] = bounds_ref[...]
    parent = jnp.maximum(par_acc, 1.0).astype(jnp.int32) - 1
    par_out_ref[0, :] = jnp.where(l > 0, parent, 0)


@functools.partial(
    jax.jit, static_argnames=("levels", "block_n", "interpret", "onehot_gather")
)
def build_levels_pallas(
    mbrs: jnp.ndarray,  # (n, 4) f32
    *,
    levels: int,
    block_n: int = 128,
    interpret: bool = False,
    onehot_gather: bool | None = None,
):
    """One-launch device build.  Returns ``(group_of (L, n) i32,
    mbr_cm (L, 4, n) f32, parent (L, n) i32, n_real (L,) i32)`` — exactly
    the level arrays of ``flat.pyramid_schedule``."""
    mbrs = jnp.asarray(mbrs, jnp.float32)
    n = mbrs.shape[0]
    width = max(((n + block_n - 1) // block_n) * block_n, block_n)
    if onehot_gather is None:
        onehot_gather = not interpret  # same policy as pyramid_scan
    mbr_cm_in = jnp.concatenate(
        [mbrs.T, jnp.zeros((4, width - n), jnp.float32)], axis=1
    )  # (4, W); padding is masked out of every reduction by `valid`
    kernel = functools.partial(
        _build_kernel,
        n=n,
        width=width,
        block_n=block_n,
        onehot_gather=onehot_gather,
    )
    group_of, mbr_cm, parent = pl.pallas_call(
        kernel,
        grid=(levels,),
        in_specs=[pl.BlockSpec((4, width), lambda l: (0, 0))],
        out_specs=[
            pl.BlockSpec((1, width), lambda l: (l, 0)),
            pl.BlockSpec((1, 4, width), lambda l: (l, 0, 0)),
            pl.BlockSpec((1, width), lambda l: (l, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((levels, width), jnp.int32),
            jax.ShapeDtypeStruct((levels, 4, width), jnp.float32),
            jax.ShapeDtypeStruct((levels, width), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, width), jnp.int32),
            pltpu.VMEM((1, width), jnp.int32),
            pltpu.VMEM((4, width), jnp.float32),
            pltpu.VMEM((1, width), jnp.float32),
        ],
        interpret=interpret,
    )(mbr_cm_in)
    group_of = group_of[:, :n]
    n_real = group_of.max(axis=1) + 1
    return group_of, mbr_cm[:, :, :n], parent[:, :n], n_real


@functools.partial(jax.jit, static_argnames=("levels",))
def build_levels_jnp(mbrs: jnp.ndarray, *, levels: int):
    """Pure-jnp device build (large-n engine; parity oracle wiring): the
    ``bulk.build_pyramid`` fixed point plus a vectorized parent scatter.
    Same return contract as :func:`build_levels_pallas`."""
    mbrs = jnp.asarray(mbrs, jnp.float32)
    pyr = bulk.build_pyramid(mbrs, levels)
    group_of = pyr.group_of                          # (L, n)
    n = group_of.shape[1]
    mbr_cm = jnp.transpose(pyr.group_mbr, (0, 2, 1))  # (L, 4, n)
    parent = jnp.zeros((levels, n), jnp.int32)
    if levels > 1:
        rows = jnp.broadcast_to(
            jnp.arange(1, levels)[:, None], (levels - 1, n)
        )
        parent = parent.at[rows, group_of[1:]].set(group_of[:-1])
    n_real = group_of.max(axis=1) + 1
    return group_of, mbr_cm, parent, n_real


def device_schedule(
    mbrs,
    *,
    levels: int | None = None,
    engine: str = "auto",
    block_n: int = 128,
    interpret: bool | None = None,
    order: str | None = None,
) -> LevelSchedule:
    """Device-resident bulk build straight to a :class:`LevelSchedule`.

    ``engine="auto"`` uses the Pallas kernel when it would compile natively
    (on-TPU) and the object set fits its VMEM residency
    (:data:`PALLAS_BUILD_MAX_N`), the jit'd jnp fixed point otherwise —
    both emit bit-identical schedules.  The returned schedule is the same
    object the host ``flat.pyramid_schedule`` path produces, so every
    backend (host/lax/pallas/serve) serves it unchanged.

    ``order="hilbert"`` additionally renumbers every level's slots along
    the Hilbert curve of the slot-MBR centers (:func:`hilbert_permute`) —
    hit sets, ids, and per-level access counts are invariant under the
    within-level bijection; only which *tiles* the visited slots cluster
    into changes (DESIGN.md §12).
    """
    from . import ops  # runtime import: ops imports this module at load

    mbrs_f32 = np.asarray(mbrs, np.float32).reshape(-1, 4)
    n = mbrs_f32.shape[0]
    if n == 0:
        raise ValueError("device_schedule needs at least one MBR")
    if levels is None:
        levels = bulk.default_levels(n)
    if interpret is None:
        interpret = ops.interpret_default()
    if engine == "auto":
        engine = "pallas" if (not interpret and n <= PALLAS_BUILD_MAX_N) else "jnp"
    if engine == "pallas":
        group_of, mbr_cm, parent, n_real = build_levels_pallas(
            jnp.asarray(mbrs_f32), levels=levels, block_n=block_n,
            interpret=interpret,
        )
    elif engine == "jnp":
        group_of, mbr_cm, parent, n_real = build_levels_jnp(
            jnp.asarray(mbrs_f32), levels=levels
        )
    else:
        raise ValueError(f"unknown build engine {engine!r}")
    group_of = np.asarray(group_of)
    schedule = LevelSchedule(
        mbr_cm=np.ascontiguousarray(np.asarray(mbr_cm)),
        parent=np.asarray(parent),
        n_real=np.asarray(n_real, np.int32),
        obj_mbr=mbrs_f32,
        obj_level=np.full((n,), levels - 1, np.int32),
        obj_slot=group_of[levels - 1].astype(np.int32),
        obj_id=np.arange(n, dtype=np.int32),
        n_objects=n,
        root_unconditional=False,
        test_object_mbr=False,
    )
    if order not in (None, "none", "hilbert"):
        raise ValueError(f"unknown slot order {order!r}")
    if order == "hilbert":
        schedule = hilbert_permute(schedule)
    return schedule


# ---------------------------------------------------------------------------
# Build-time Hilbert slot ordering (DESIGN.md §12)
# ---------------------------------------------------------------------------


def hilbert_keys(x, y, order: int = 16) -> np.ndarray:
    """Vectorized Hilbert-curve index of points normalized to [0, 1].

    Standard bitwise xy→d walk over ``order`` bits (rotate/reflect per
    quadrant), evaluated with numpy array ops so a whole level keys in one
    pass.  Ties (identical centers) are broken by the stable argsort of
    the caller, keeping the permutation deterministic."""
    n = 1 << order
    x = np.clip((np.asarray(x, np.float64) * n).astype(np.int64), 0, n - 1)
    y = np.clip((np.asarray(y, np.float64) * n).astype(np.int64), 0, n - 1)
    d = np.zeros_like(x)
    s = n >> 1
    while s > 0:
        rx = ((x & s) > 0).astype(np.int64)
        ry = ((y & s) > 0).astype(np.int64)
        d += s * s * ((3 * rx) ^ ry)
        # rotate the quadrant: reflect when rx==1, then swap axes (ry==0)
        swap = ry == 0
        refl = swap & (rx == 1)
        xr = np.where(refl, s - 1 - x, x)
        yr = np.where(refl, s - 1 - y, y)
        x = np.where(swap, yr, xr)
        y = np.where(swap, xr, yr)
        s >>= 1
    return d


def hilbert_permute(schedule: LevelSchedule, order: int = 16) -> LevelSchedule:
    """Renumber every level's real slots along the Hilbert curve of their
    MBR centers (a within-level bijection; padded slots stay in place).

    Parent references are remapped through the previous level's
    permutation and object entry slots through their own level's, so the
    sweep recurrence computes the *same* per-level active sets under new
    slot numbers: hit sets, ``AccessStats`` ids, and per-level visit
    counts are all bit-identical (tests/test_hilbert.py).  What changes
    is tile locality — a small query's survivors cluster into few
    ``block_w`` tiles instead of scattering across the level, which is
    what the visited-tile bytes/query metric of DESIGN.md §12 measures.
    """
    obj = np.asarray(schedule.obj_mbr, np.float64)
    lo = obj[:, :2].min(axis=0)
    span = np.maximum(obj[:, 2:].max(axis=0) - lo, 1e-30)
    mbr = np.array(schedule.mbr_cm, copy=True)
    parent = np.array(schedule.parent, copy=True)
    obj_slot = np.array(schedule.obj_slot, copy=True)
    obj_level = np.asarray(schedule.obj_level)
    levels = schedule.levels
    prev_perm = None  # old slot -> new slot, previous level
    for l in range(levels):
        nr = int(schedule.n_real[l])
        cx = (schedule.mbr_cm[l, 0, :nr] + schedule.mbr_cm[l, 2, :nr]) / 2.0
        cy = (schedule.mbr_cm[l, 1, :nr] + schedule.mbr_cm[l, 3, :nr]) / 2.0
        keys = hilbert_keys((cx - lo[0]) / span[0], (cy - lo[1]) / span[1],
                            order=order)
        by_key = np.argsort(keys, kind="stable")  # new slot -> old slot
        perm = np.empty(nr, np.int64)
        perm[by_key] = np.arange(nr)              # old slot -> new slot
        mbr[l, :, :nr] = schedule.mbr_cm[l][:, by_key]
        if l > 0:
            old_parent = np.asarray(schedule.parent[l, :nr], np.int64)
            parent[l, :nr] = prev_perm[old_parent[by_key]].astype(
                schedule.parent.dtype
            )
        mask = obj_level == l
        if mask.any():
            obj_slot[mask] = perm[
                np.asarray(schedule.obj_slot)[mask].astype(np.int64)
            ].astype(obj_slot.dtype)
        prev_perm = perm
    return dataclasses.replace(
        schedule, mbr_cm=mbr, parent=parent, obj_slot=obj_slot
    )
