"""Pallas TPU kernel: batched MBR overlap scan (one mqr level per call).

The TPU form of the paper's region search inner loop: a level of the
levelized mqr-tree is a dense (N, 4) array of MBRs; each grid step streams
one VMEM tile of MBRs and tests it against the resident query rectangles on
the VPU.  One tile fetch = one "disk access" of the paper, so the kernel's
HBM traffic is exactly the quantity the mqr-tree minimizes (DESIGN.md §3).

Layout: MBRs are stored coordinate-major as (4, N) so each coordinate is a
contiguous lane vector; N is tiled in ``block_n`` lanes.  Queries (Q, 4) are
small and stay resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, mbr_ref, out_ref):
    # q_ref: (Q, 4) resident; mbr_ref: (4, BN) tile; out_ref: (Q, BN)
    lx = mbr_ref[0, :]
    ly = mbr_ref[1, :]
    hx = mbr_ref[2, :]
    hy = mbr_ref[3, :]
    qlx = q_ref[:, 0][:, None]
    qly = q_ref[:, 1][:, None]
    qhx = q_ref[:, 2][:, None]
    qhy = q_ref[:, 3][:, None]
    out_ref[...] = (
        (lx[None, :] <= qhx)
        & (qlx <= hx[None, :])
        & (ly[None, :] <= qhy)
        & (qly <= hy[None, :])
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def mbr_scan(
    mbrs: jnp.ndarray,      # (N, 4) float32
    queries: jnp.ndarray,   # (Q, 4) float32
    *,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns (Q, N) bool overlap mask."""
    n = mbrs.shape[0]
    q = queries.shape[0]
    pad = (-n) % block_n
    # pad with never-overlapping sentinels
    mbrs_p = jnp.concatenate(
        [mbrs, jnp.full((pad, 4), jnp.inf, mbrs.dtype)], axis=0
    ) if pad else mbrs
    mt = mbrs_p.T  # (4, N_pad) coordinate-major
    n_pad = mt.shape[1]
    grid = (n_pad // block_n,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q, 4), lambda i: (0, 0)),
            pl.BlockSpec((4, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((q, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((q, n_pad), jnp.bool_),
        interpret=interpret,
    )(queries, mt)
    return out[:, :n]
