"""Pallas TPU kernel: causal flash attention forward (MaxText-style).

Grid = (batch*heads, n_q_blocks, n_kv_blocks); the kv dim is innermost and
sequential on TPU, so the running-softmax accumulators live in VMEM scratch
and persist across kv steps.  Causal blocks above the diagonal are skipped
via ``pl.when`` (their tiles are still indexed but not computed — the
block-level equivalent of the paper's pruned subtrees).

Shapes: q/k/v are (BH, S, D) with kv heads pre-broadcast to full heads by
ops.py.  block sizes default to the MXU-native 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, block_q,
            block_k, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: kv block strictly after the q block contributes nothing
    @pl.when(ki * block_k <= qi * block_q + block_q - 1)
    def _compute():
        q = q_ref[0]  # (block_q, D)
        k = k_ref[0]  # (block_k, D)
        v = v_ref[0]
        logits = (
            jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )
            * scale
        )
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(qpos >= kpos, logits, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,  # (BH, S, D)
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    scale = 1.0 / (d ** 0.5)
    grid = (bh, s // block_q, s // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
