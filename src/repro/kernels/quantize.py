"""Pallas TPU kernel: conservative uint16 quantization of MBR tile grids.

GP-Tree-style grid discretization (PAPERS.md) for the fused level sweep:
the schedule's float32 node MBRs are snapped to a ``CELLS``-cell uint16
grid with OUTWARD rounding — lo coordinates floor, hi coordinates ceil —
so every quantized box contains its exact box.  Queries are quantized
outward the same way at scan time, which makes the quantized overlap test
a conservative superset of the exact one: true hits are never dropped,
and the (rare, one-grid-cell-wide) false positives are removed by the
exact float32 confirming pass of
:func:`repro.kernels.pyramid_scan.pyramid_scan_compact` (DESIGN.md §7).

The grid derives from the root bounding box (the union of the object
MBRs), per axis: ``cell = clip(round((v - origin) * cells / extent))``.
Padded slots (lo=+inf / hi=-inf sentinels) map to the integer
never-overlap sentinel ``Q_NEVER_MBR`` (lo = cells+1 > any query hi).
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.flat import (
    CELLS,
    CELLS8,
    Q_NEVER_MBR,
    LevelSchedule,
    QuantizedSchedule,
)


def grid_params(schedule: LevelSchedule, cells: int = CELLS):
    """Derive the per-axis grid from the object-MBR union (== root box).

    Returns ``(origin (4,) f32, inv_cell (4,) f32)`` laid out
    coordinate-major (x, y, x, y) so they broadcast against the
    ``(lx, ly, hx, hy)`` coordinate rows directly.  ``cells`` picks the
    grid resolution — ``CELLS`` for the uint16 form, ``CELLS8`` for the
    coarse uint8 upper-level form (same origin either way).
    """
    obj = np.asarray(schedule.obj_mbr, np.float64)
    lo = obj[:, :2].min(axis=0)
    hi = obj[:, 2:].max(axis=0)
    # Cap the scale well inside float32: a degenerate (zero-extent) axis
    # must not produce an inf scale, or quantizing a query AT the origin
    # hits 0*inf=NaN.  With a capped scale the axis still quantizes
    # conservatively (everything lands in cells [0, 1]).
    with np.errstate(divide="ignore"):
        inv = np.minimum(cells / np.maximum(hi - lo, 0.0), 1e30)
    origin = np.concatenate([lo, lo]).astype(np.float32)
    inv_cell = np.concatenate([inv, inv]).astype(np.float32)
    return origin, inv_cell


def quantize_cm_jnp(mbr_cm, origin, inv_cell, *, cells: int = CELLS,
                    dtype=jnp.uint16):
    """Reference (and large-array) quantizer: (L, 4, W) f32 -> ``dtype``
    grid cells on a ``cells``-cell outward-rounded grid."""
    mbr_cm = jnp.asarray(mbr_cm, jnp.float32)
    t = (mbr_cm - origin[None, :, None]) * inv_cell[None, :, None]
    is_lo = (jnp.arange(4) < 2)[None, :, None]
    cell = jnp.where(is_lo, jnp.floor(t), jnp.ceil(t))
    cell = jnp.clip(cell, 0.0, float(cells))
    # lo=+inf sentinel (padded slot) -> integer never-overlap sentinel
    cell = jnp.where(is_lo & (mbr_cm == jnp.inf), float(cells + 1), cell)
    return cell.astype(dtype)


def quantize_rows(mbrs: np.ndarray, origin: np.ndarray,
                  inv_cell: np.ndarray) -> np.ndarray:
    """Conservative uint16 quantization of row-major (N, 4) MBRs onto an
    EXISTING schedule grid — the delta-buffer lowering (DESIGN.md §8).

    Unlike node boxes, delta rows may extend past the grid domain (inserts
    land anywhere).  Clipping lo-after-floor and hi-after-ceil into
    ``[0, CELLS]`` preserves the conservative-superset property because
    scan-time queries are clipped into the same range and clip is
    monotone: real-interval intersection still implies clipped-integer
    intersection on every axis; the exact confirming pass removes the
    extra boundary candidates.  Same float32 arithmetic as
    :func:`quantize_cm_jnp`, so delta tiles behave exactly like base
    tiles.  Rows with ``lo == +inf`` (empty slots) map to ``Q_NEVER_MBR``.
    """
    m = np.asarray(mbrs, np.float32)
    origin = np.asarray(origin, np.float32)
    inv_cell = np.asarray(inv_cell, np.float32)
    with np.errstate(invalid="ignore", over="ignore"):
        t = (m - origin[None, :]) * inv_cell[None, :]
        cell = np.concatenate(
            [np.floor(t[:, :2]), np.ceil(t[:, 2:])], axis=1
        )
    cell = np.clip(cell, 0.0, float(CELLS))
    out = cell.astype(np.uint16)
    out[np.isposinf(m[:, 0])] = Q_NEVER_MBR
    return out


def _quantize_kernel(mbr_ref, org_ref, inv_ref, out_ref, *, block_w: int):
    v = mbr_ref[0]                       # (4, BW) f32
    org = org_ref[0][:, None]            # (4, 1)
    inv = inv_ref[0][:, None]
    t = (v - org) * inv
    is_lo = jax.lax.broadcasted_iota(jnp.int32, (4, block_w), 0) < 2
    cell = jnp.where(is_lo, jnp.floor(t), jnp.ceil(t))
    cell = jnp.clip(cell, 0.0, float(CELLS))
    cell = jnp.where(is_lo & (v == jnp.inf), float(CELLS + 1), cell)
    out_ref[0] = cell.astype(jnp.uint16)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def quantize_cm_pallas(mbr_cm, origin, inv_cell, *, block_w: int = 128,
                       interpret: bool = False):
    """Device quantizer: one elementwise Pallas pass over the level grid."""
    mbr_cm = jnp.asarray(mbr_cm, jnp.float32)
    levels, _, w = mbr_cm.shape
    pad = (-w) % block_w
    if pad:
        # pad with the float never-sentinel; quantizes to Q_NEVER_MBR
        sent = jnp.asarray(
            [jnp.inf, jnp.inf, -jnp.inf, -jnp.inf], jnp.float32
        )
        mbr_cm = jnp.concatenate(
            [mbr_cm, jnp.broadcast_to(sent[None, :, None], (levels, 4, pad))],
            axis=2,
        )
    wp = w + pad
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, block_w=block_w),
        grid=(levels, wp // block_w),
        in_specs=[
            pl.BlockSpec((1, 4, block_w), lambda l, t: (l, 0, t)),
            pl.BlockSpec((1, 4), lambda l, t: (0, 0)),
            pl.BlockSpec((1, 4), lambda l, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 4, block_w), lambda l, t: (l, 0, t)),
        out_shape=jax.ShapeDtypeStruct((levels, 4, wp), jnp.uint16),
        interpret=interpret,
    )(mbr_cm, origin[None, :], inv_cell[None, :])
    return out[:, :, :w]


def quantize_schedule(
    schedule: LevelSchedule,
    *,
    engine: str = "auto",
    block_w: int = 128,
    interpret: bool | None = None,
    upper8: bool = False,
    split: int | None = None,
) -> QuantizedSchedule:
    """Lower a :class:`LevelSchedule` to its compact uint16 tile form.

    ``upper8=True`` additionally materializes coarse uint8 tiles for the
    upper levels (``[0, split)``, default all but the deepest level) on a
    254-cell grid sharing the same origin — the hierarchical form
    :func:`repro.kernels.ops.pyramid_scan_compact8` sweeps (DESIGN.md
    §12).  Outward rounding is resolution-independent, so the confirming
    pass keeps hit sets bit-identical at any split.
    """
    from . import ops  # runtime import: ops imports this module at load

    if interpret is None:
        interpret = ops.interpret_default()
    if engine == "auto":
        engine = "jnp" if interpret else "pallas"
    origin, inv_cell = grid_params(schedule)
    if engine == "pallas":
        mbr_q = quantize_cm_pallas(
            schedule.mbr_cm, jnp.asarray(origin), jnp.asarray(inv_cell),
            block_w=block_w, interpret=interpret,
        )
    elif engine == "jnp":
        mbr_q = quantize_cm_jnp(
            schedule.mbr_cm, jnp.asarray(origin), jnp.asarray(inv_cell)
        )
    else:
        raise ValueError(f"unknown quantize engine {engine!r}")
    # Parent slots stream as uint16 while the level width fits; wider
    # schedules (pyramid width == n > 65535) fall back to int32 parents,
    # keeping the MBR tiles uint16 (bytes ratio 0.6 instead of 0.5).
    pdtype = (
        np.uint16 if schedule.width <= np.iinfo(np.uint16).max else np.int32
    )
    if schedule.test_object_mbr:
        confirm = np.asarray(schedule.obj_mbr, np.float32)
    else:
        # Pyramid schedules: the entry's deepest group MBR is the exact
        # membership box (nested inside every ancestor, DESIGN.md §7).
        confirm = np.ascontiguousarray(
            schedule.mbr_cm[schedule.obj_level, :, schedule.obj_slot]
        ).astype(np.float32)
    mbr_q8 = None
    inv_cell8 = None
    if split is None:
        split = max(schedule.levels - 1, 0) if upper8 else 0
    if upper8 and split > 0:
        _, inv_cell8 = grid_params(schedule, cells=CELLS8)
        mbr_q8 = np.asarray(
            quantize_cm_jnp(
                schedule.mbr_cm[:split], jnp.asarray(origin),
                jnp.asarray(inv_cell8), cells=CELLS8, dtype=jnp.uint8,
            )
        )
    return QuantizedSchedule(
        base=schedule,
        mbr_q=np.asarray(mbr_q),
        parent_q=schedule.parent.astype(pdtype),
        origin=origin,
        inv_cell=inv_cell,
        confirm_mbr=confirm,
        cells=CELLS,
        mbr_q8=mbr_q8,
        split=split if upper8 else 0,
        cells8=CELLS8,
        inv_cell8=inv_cell8,
    )
