"""Pallas TPU kernel: fused multi-level region search (one launch per sweep).

``mbr_scan`` scans ONE tree level per kernel call, so a height-``L`` search
pays ``L`` dispatches and the survivor frontier round-trips through host
Python between levels.  This kernel fuses the whole levelized sweep of a
:class:`repro.core.flat.LevelSchedule` into a single ``pallas_call``
(DESIGN.md §3.3):

* grid = (levels, width tiles) — levels iterate in the outer grid dimension,
  and TPU grid execution is sequential, so level ``l`` sees level ``l-1``'s
  results;
* the per-level survivor masks live in two VMEM scratch buffers
  (``prev``/``cur``, each (Q, W)) that persist across grid steps;
* the Q query rectangles stay resident in VMEM for the entire sweep;
* node-MBR tiles are streamed coordinate-major (4, block_w) — one tile fetch
  = one "disk access" of the paper (DESIGN.md §3);
* the parent gather ``prev[:, parent[j]]`` is expressed as a one-hot matmul
  (broadcasted-iota compare + ``jnp.dot``) so it runs on the MXU instead of
  a lane gather.

The kernel emits the full per-level active mask; a thin jnp epilogue (still
one kernel launch) reduces it to object hits and per-level access counts
that are bit-identical to the host pointer search / ``bulk.pyramid_search``
(tests/test_pyramid_scan.py).
"""

from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flat import (
    NEVER_MBR,
    Q_NEVER_MBR,
    LevelSchedule,
    QuantizedSchedule,
    _overlaps,
)


def _overlap_tile(q_ref, mbr_tile):
    """(Q, 4) resident queries vs (4, BW) coordinate-major tile -> (Q, BW).

    Works for float32 tiles and for uint16 compact tiles (tiles are cast
    to the query dtype — int32 for quantized sweeps — after the VMEM
    load, so HBM only ever streams the narrow form)."""
    if mbr_tile.dtype != q_ref.dtype:
        mbr_tile = mbr_tile.astype(q_ref.dtype)
    lx, ly, hx, hy = mbr_tile[0, :], mbr_tile[1, :], mbr_tile[2, :], mbr_tile[3, :]
    qlx = q_ref[:, 0][:, None]
    qly = q_ref[:, 1][:, None]
    qhx = q_ref[:, 2][:, None]
    qhy = q_ref[:, 3][:, None]
    return (
        (lx[None, :] <= qhx)
        & (qlx <= hx[None, :])
        & (ly[None, :] <= qhy)
        & (qly <= hy[None, :])
    )


def _sweep_kernel(
    q_ref,       # (Q, 4) f32, resident
    mbr_ref,     # (1, 4, BW) f32 tile of level l
    parent_ref,  # (1, BW) i32 tile of level l
    act_ref,     # out (1, Q, BW) bool
    prev_ref,    # scratch (Q, W) f32 — level l-1 survivors
    cur_ref,     # scratch (Q, W) f32 — level l survivors
    *,
    block_w: int,
    width: int,
    root_unconditional: bool,
    onehot_gather: bool,
    uncond_from: int,
):
    l = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when((t == 0) & (l > 0))
    def _roll():  # level finished: its survivors become the parent mask
        prev_ref[...] = cur_ref[...]

    ov = _overlap_tile(q_ref, mbr_ref[0])  # (Q, BW)

    parent_row = parent_ref[0].astype(jnp.int32)  # uint16 on the compact path
    if onehot_gather:
        # TPU path: parent gather as a one-hot matmul on the MXU,
        # onehot[p, j] = (p == parent[j]) — no lane gather needed.
        iota = jax.lax.broadcasted_iota(jnp.int32, (width, block_w), 0)
        onehot = (iota == parent_row[None, :]).astype(jnp.float32)
        pa = jnp.dot(prev_ref[...], onehot, preferred_element_type=jnp.float32)
    else:
        # Interpreter path: O(Q·BW) column gather instead of O(Q·W·BW).
        pa = jnp.take(prev_ref[...], parent_row, axis=1)
    parent_active = pa > 0.5

    if root_unconditional:
        # The pointer search always examines the root node (slot 0).
        col = jax.lax.broadcasted_iota(jnp.int32, (1, block_w), 1)[0]
        root = (t * block_w + col) == 0
        act0 = jnp.broadcast_to(root[None, :], ov.shape)
    else:
        act0 = ov
    # Levels at or past ``uncond_from`` are FLAT appendices (the live-update
    # delta buffer, DESIGN.md §8): every slot is tested against the query
    # directly, with no parent gating — a linear scan fused into the same
    # launch as the hierarchical sweep.
    act = jnp.where(
        l == 0, act0, jnp.where(l >= uncond_from, ov, parent_active & ov)
    )

    cur_ref[:, pl.ds(t * block_w, block_w)] = act.astype(jnp.float32)
    act_ref[0] = act


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_w", "root_unconditional", "interpret", "onehot_gather",
        "uncond_from",
    ),
)
def level_sweep(
    queries: jnp.ndarray,   # (Q, 4) f32
    mbr_cm: jnp.ndarray,    # (L, 4, W) f32
    parent: jnp.ndarray,    # (L, W) i32
    *,
    block_w: int = 128,
    root_unconditional: bool = True,
    interpret: bool = False,
    onehot_gather: bool | None = None,
    uncond_from: int | None = None,
) -> jnp.ndarray:
    """Run the fused sweep; returns the (L, Q, W) per-level active mask.

    ``uncond_from`` marks the first FLAT level: levels ``>= uncond_from``
    skip the parent gate and test every slot against the query directly —
    how the live-update delta buffer rides the same launch (DESIGN.md §8).
    ``None`` (the default) keeps the whole sweep hierarchical.
    """
    levels, _, w = mbr_cm.shape
    q = queries.shape[0]
    pad = (-w) % block_w
    if pad:
        never = (
            NEVER_MBR
            if jnp.issubdtype(mbr_cm.dtype, jnp.floating)
            else Q_NEVER_MBR.astype(mbr_cm.dtype)
        )
        mbr_cm = jnp.concatenate(
            [mbr_cm,
             jnp.broadcast_to(jnp.asarray(never)[None, :, None],
                              (levels, 4, pad))],
            axis=2,
        )
        parent = jnp.concatenate(
            [parent, jnp.zeros((levels, pad), parent.dtype)], axis=1
        )
    wp = w + pad
    grid = (levels, wp // block_w)
    if onehot_gather is None:
        # The MXU one-hot matmul is the native TPU lowering; the column
        # gather is cheaper (O(Q·W) vs O(Q·W²/BW)) where gathers are free.
        onehot_gather = not interpret
    kernel = functools.partial(
        _sweep_kernel,
        block_w=block_w,
        width=wp,
        root_unconditional=root_unconditional,
        onehot_gather=onehot_gather,
        uncond_from=levels if uncond_from is None else uncond_from,
    )
    act = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q, 4), lambda l, t: (0, 0)),
            pl.BlockSpec((1, 4, block_w), lambda l, t: (l, 0, t)),
            pl.BlockSpec((1, block_w), lambda l, t: (l, t)),
        ],
        out_specs=pl.BlockSpec((1, q, block_w), lambda l, t: (l, 0, t)),
        out_shape=jax.ShapeDtypeStruct((levels, q, wp), jnp.bool_),
        scratch_shapes=[
            pltpu.VMEM((q, wp), jnp.float32),
            pltpu.VMEM((q, wp), jnp.float32),
        ],
        interpret=interpret,
    )(queries, mbr_cm, parent)
    return act[:, :, :w]


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_objects", "block_w", "root_unconditional", "test_object_mbr",
        "interpret",
    ),
)
def _fused_search(
    queries, mbr_cm, parent, obj_mbr, obj_level, obj_slot, obj_id,
    *,
    n_objects: int,
    block_w: int,
    root_unconditional: bool,
    test_object_mbr: bool,
    interpret: bool,
):
    act = level_sweep(
        queries, mbr_cm, parent,
        block_w=block_w,
        root_unconditional=root_unconditional,
        interpret=interpret,
    )  # (L, Q, W)
    # Per-level access counts: padded slots carry sentinel MBRs and are
    # never active, so a plain sum counts exactly the visited real nodes.
    visits = jnp.transpose(act.sum(axis=2, dtype=jnp.int32))  # (Q, L)
    # Object-hit epilogue: entry e hits iff its holding node is active
    # (and, for tree schedules, its own MBR overlaps the query).
    entry_act = act[obj_level, :, obj_slot]  # (E, Q)
    hit = jnp.transpose(entry_act)           # (Q, E)
    if test_object_mbr:
        hit = hit & _overlaps(obj_mbr[None, :, :], queries[:, None, :])
    q = queries.shape[0]
    hits = jnp.zeros((q, max(n_objects, 1)), jnp.bool_)
    hits = hits.at[:, obj_id].max(hit)
    return hits, visits


def pyramid_scan(
    schedule: LevelSchedule,
    queries,
    *,
    block_w: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused region search over a :class:`LevelSchedule`.

    Returns ``(hits, visits)``: hits (Q, n_objects) bool object mask and
    visits (Q, L) int32 per-level access counts — both identical to the
    host pointer search (tree schedules) / ``bulk.pyramid_search``
    (pyramid schedules).  ONE kernel launch regardless of tree height.
    """
    return _fused_search(
        jnp.asarray(queries, jnp.float32),
        jnp.asarray(schedule.mbr_cm),
        jnp.asarray(schedule.parent),
        jnp.asarray(schedule.obj_mbr),
        jnp.asarray(schedule.obj_level),
        jnp.asarray(schedule.obj_slot),
        jnp.asarray(schedule.obj_id),
        n_objects=schedule.n_objects,
        block_w=block_w,
        root_unconditional=schedule.root_unconditional,
        test_object_mbr=schedule.test_object_mbr,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_objects", "cells", "block_w", "root_unconditional", "interpret",
    ),
)
def _fused_search_compact(
    queries, mbr_q, parent_q, confirm_mbr, obj_level, obj_slot, obj_id,
    origin, inv_cell,
    *,
    n_objects: int,
    cells: int,
    block_w: int,
    root_unconditional: bool,
    interpret: bool,
):
    """Fused sweep over uint16 tiles + exact float32 confirming pass.

    Queries are quantized OUTWARD onto the schedule's grid (lo floor, hi
    ceil, clipped to the domain — node boxes never extend past it), so
    the integer sweep's survivors are a superset of the exact sweep's.
    The confirming pass intersects them with the exact ``confirm_mbr``
    overlap, which by MBR nesting implies the full exact ancestor chain:
    hit sets come out bit-identical to :func:`_fused_search`
    (tests/test_quantized.py).  ``visits`` counts the accesses this path
    actually performed — the conservative sweep may touch slightly more
    nodes per level than the exact one (DESIGN.md §7).
    """
    t = (queries - origin[None, :]) * inv_cell[None, :]
    qq = jnp.concatenate([jnp.floor(t[:, :2]), jnp.ceil(t[:, 2:])], axis=1)
    qq = jnp.clip(qq, 0.0, float(cells)).astype(jnp.int32)
    act = level_sweep(
        qq, mbr_q, parent_q,
        block_w=block_w,
        root_unconditional=root_unconditional,
        interpret=interpret,
    )  # (L, Q, W) candidate mask, superset of the exact active mask
    visits = jnp.transpose(act.sum(axis=2, dtype=jnp.int32))  # (Q, L)
    cand = jnp.transpose(act[obj_level, :, obj_slot])          # (Q, E)
    hit = cand & _overlaps(confirm_mbr[None, :, :], queries[:, None, :])
    q = queries.shape[0]
    hits = jnp.zeros((q, max(n_objects, 1)), jnp.bool_)
    hits = hits.at[:, obj_id].max(hit)
    return hits, visits


def pyramid_scan_compact(
    qsched: QuantizedSchedule,
    queries,
    *,
    block_w: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused region search over a :class:`QuantizedSchedule`: half the
    streamed bytes per tile, hit sets bit-identical to the float32 path;
    ``visits`` reports the compact sweep's own (conservative) accesses."""
    return _fused_search_compact(
        jnp.asarray(queries, jnp.float32),
        jnp.asarray(qsched.mbr_q),
        jnp.asarray(qsched.parent_q),
        jnp.asarray(qsched.confirm_mbr),
        jnp.asarray(qsched.base.obj_level),
        jnp.asarray(qsched.base.obj_slot),
        jnp.asarray(qsched.base.obj_id),
        jnp.asarray(qsched.origin),
        jnp.asarray(qsched.inv_cell),
        n_objects=qsched.n_objects,
        cells=qsched.cells,
        block_w=block_w,
        root_unconditional=qsched.base.root_unconditional,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_objects", "base_levels", "block_w", "root_unconditional",
        "test_object_mbr", "interpret",
    ),
)
def _fused_search_live(
    queries, mbr_cm, parent, obj_mbr, obj_level, obj_slot, obj_id, alive,
    *,
    n_objects: int,
    base_levels: int,
    block_w: int,
    root_unconditional: bool,
    test_object_mbr: bool,
    interpret: bool,
):
    """Fused sweep over base levels + appended flat delta levels.

    The live-update subsystem (DESIGN.md §8) appends the delta buffer as
    ``uncond_from = base_levels`` flat levels: one launch still sweeps
    everything, and the epilogue scatters base entries and delta slots
    into the same global-id hit mask, then masks tombstoned ids with
    ``alive``.  ``visits`` keeps the per-level layout — columns past
    ``base_levels`` are delta-side accesses.
    """
    act = level_sweep(
        queries, mbr_cm, parent,
        block_w=block_w,
        root_unconditional=root_unconditional,
        interpret=interpret,
        uncond_from=base_levels,
    )  # (L_base + D, Q, W)
    visits = jnp.transpose(act.sum(axis=2, dtype=jnp.int32))  # (Q, L+D)
    entry_act = act[obj_level, :, obj_slot]  # (E + C, Q)
    hit = jnp.transpose(entry_act)           # (Q, E + C)
    if test_object_mbr:
        hit = hit & _overlaps(obj_mbr[None, :, :], queries[:, None, :])
    q = queries.shape[0]
    hits = jnp.zeros((q, max(n_objects, 1)), jnp.bool_)
    hits = hits.at[:, obj_id].max(hit)
    # Tombstone mask: deleted ids drop out here, in the same jit program.
    hits = hits & alive[None, :]
    return hits, visits


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_objects", "cells", "base_levels", "block_w",
        "root_unconditional", "interpret",
    ),
)
def _fused_search_compact_live(
    queries, mbr_q, parent_q, confirm_mbr, obj_level, obj_slot, obj_id,
    origin, inv_cell, alive,
    *,
    n_objects: int,
    cells: int,
    base_levels: int,
    block_w: int,
    root_unconditional: bool,
    interpret: bool,
):
    """Compact (uint16-tile) twin of :func:`_fused_search_live`.

    Delta rows are quantized outward onto the base grid (clipped — see
    ``kernels.quantize.quantize_rows``), swept as flat levels in the same
    integer launch, and confirmed exactly against their float32 MBRs, so
    the tombstone-masked hit sets stay bit-identical to the float32 live
    path (DESIGN.md §8).
    """
    t = (queries - origin[None, :]) * inv_cell[None, :]
    qq = jnp.concatenate([jnp.floor(t[:, :2]), jnp.ceil(t[:, 2:])], axis=1)
    qq = jnp.clip(qq, 0.0, float(cells)).astype(jnp.int32)
    act = level_sweep(
        qq, mbr_q, parent_q,
        block_w=block_w,
        root_unconditional=root_unconditional,
        interpret=interpret,
        uncond_from=base_levels,
    )
    visits = jnp.transpose(act.sum(axis=2, dtype=jnp.int32))
    cand = jnp.transpose(act[obj_level, :, obj_slot])
    hit = cand & _overlaps(confirm_mbr[None, :, :], queries[:, None, :])
    q = queries.shape[0]
    hits = jnp.zeros((q, max(n_objects, 1)), jnp.bool_)
    hits = hits.at[:, obj_id].max(hit)
    hits = hits & alive[None, :]
    return hits, visits


def per_level_region_search(
    schedule: LevelSchedule,
    queries,
    *,
    block_w: int = 128,
    interpret: bool = False,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Status-quo baseline: ONE ``mbr_scan`` launch per level, survivor
    frontier combined in host Python between launches.  Returns
    ``(hits, visits, n_launches)`` with hits/visits matching
    :func:`pyramid_scan`; exists so the benchmark can measure what fusing
    the sweep saves (DESIGN.md §3.3).
    """
    from .mbr_scan import mbr_scan

    q = np.asarray(queries, np.float32)
    nq = q.shape[0]
    levels, _, w = schedule.mbr_cm.shape
    launches = 0
    active = None
    acts = []
    for l in range(levels):
        mbrs = np.ascontiguousarray(schedule.mbr_cm[l].T)  # (W, 4) row-major
        # Sentinel-padded rows contain inf; mbr_scan pads with inf itself,
        # so the scan is well defined and padded slots never overlap.
        ov = np.asarray(
            mbr_scan(jnp.asarray(mbrs), jnp.asarray(q),
                     block_n=block_w, interpret=interpret)
        )
        launches += 1
        if l == 0:
            if schedule.root_unconditional:
                act = np.zeros((nq, w), bool)
                act[:, 0] = True
            else:
                act = ov
        else:
            act = ov & active[:, schedule.parent[l]]
        active = act
        acts.append(act)
    act = np.stack(acts)  # (L, Q, W)
    visits = act.sum(axis=2).T.astype(np.int32)
    entry_act = act[schedule.obj_level, :, schedule.obj_slot].T  # (Q, E)
    if schedule.test_object_mbr:
        entry_act = entry_act & _overlaps(
            schedule.obj_mbr[None, :, :], q[:, None, :]
        )
    hits = np.zeros((nq, max(schedule.n_objects, 1)), bool)
    np.maximum.at(hits, (slice(None), schedule.obj_id), entry_act)
    return hits, visits, launches
