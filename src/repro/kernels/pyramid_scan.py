"""Pallas TPU kernel: fused multi-level region search (one launch per sweep).

``mbr_scan`` scans ONE tree level per kernel call, so a height-``L`` search
pays ``L`` dispatches and the survivor frontier round-trips through host
Python between levels.  This kernel fuses the whole levelized sweep of a
:class:`repro.core.flat.LevelSchedule` into a single ``pallas_call``
(DESIGN.md §3.3):

* grid = (levels, width tiles) — levels iterate in the outer grid dimension,
  and TPU grid execution is sequential, so level ``l`` sees level ``l-1``'s
  results;
* the per-level survivor masks live in two VMEM scratch buffers
  (``prev``/``cur``, each (Q, W)) that persist across grid steps;
* the Q query rectangles stay resident in VMEM for the entire sweep;
* node-MBR tiles are streamed coordinate-major (4, block_w) — one tile fetch
  = one "disk access" of the paper (DESIGN.md §3);
* the parent gather ``prev[:, parent[j]]`` is expressed as a one-hot matmul
  (broadcasted-iota compare + ``jnp.dot``) so it runs on the MXU instead of
  a lane gather.

The VMEM-resident layout above caps single-chip width: the two survivor
masks alone cost ``2·Q·W·4`` bytes of VMEM.  ``stream=True`` switches to
the HBM-streaming variant (DESIGN.md §12): MBR/parent tiles live in HBM
(``memory_space=ANY``) and are double-buffered into VMEM with explicit
async copies (copy of tile ``t+1`` overlaps compute of tile ``t``,
``emit_pipeline``-style), and the survivor masks ping-pong through an HBM
scratch — each grid step only reads back the narrow *parent window*
actually referenced by its tile (``parent_windows``).  Per-step VMEM then
scales with ``Q·(win_w + O(block_w))`` instead of ``Q·W``, which is what
lets one chip sweep 1e7+ objects.

The kernel emits the full per-level active mask; a thin jnp epilogue (still
one kernel launch) reduces it to object hits and per-level access counts
that are bit-identical to the host pointer search / ``bulk.pyramid_search``
(tests/test_pyramid_scan.py, tests/test_stream_scan.py).
"""

from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flat import (
    NEVER_MBR,
    Q8_NEVER_MBR,
    Q_NEVER_MBR,
    LevelSchedule,
    QuantizedSchedule,
    _overlaps,
)
from repro.obs import counters as _obs_counters


def _overlap_tile(q_ref, mbr_tile):
    """(Q, 4) resident queries vs (4, BW) coordinate-major tile -> (Q, BW).

    Works for float32 tiles and for uint16 compact tiles (tiles are cast
    to the query dtype — int32 for quantized sweeps — after the VMEM
    load, so HBM only ever streams the narrow form)."""
    if mbr_tile.dtype != q_ref.dtype:
        mbr_tile = mbr_tile.astype(q_ref.dtype)
    lx, ly, hx, hy = mbr_tile[0, :], mbr_tile[1, :], mbr_tile[2, :], mbr_tile[3, :]
    qlx = q_ref[:, 0][:, None]
    qly = q_ref[:, 1][:, None]
    qhx = q_ref[:, 2][:, None]
    qhy = q_ref[:, 3][:, None]
    return (
        (lx[None, :] <= qhx)
        & (qlx <= hx[None, :])
        & (ly[None, :] <= qhy)
        & (qly <= hy[None, :])
    )


def _act_formula(ov, parent_active, *, l, t, block_w, root_unconditional,
                 uncond_from):
    """The shared per-tile active-mask recurrence of every sweep kernel."""
    if root_unconditional:
        # The pointer search always examines the root node (slot 0).
        col = jax.lax.broadcasted_iota(jnp.int32, (1, block_w), 1)[0]
        root = (t * block_w + col) == 0
        act0 = jnp.broadcast_to(root[None, :], ov.shape)
    else:
        act0 = ov
    # Levels at or past ``uncond_from`` are FLAT appendices (the live-update
    # delta buffer, DESIGN.md §8): every slot is tested against the query
    # directly, with no parent gating — a linear scan fused into the same
    # launch as the hierarchical sweep.
    return jnp.where(
        l == 0, act0, jnp.where(l >= uncond_from, ov, parent_active & ov)
    )


def _sweep_kernel(
    q_ref,       # (Q, 4) f32, resident
    mbr_ref,     # (1, 4, BW) f32 tile of level l
    parent_ref,  # (1, BW) i32 tile of level l
    act_ref,     # out (1, Q, BW) bool
    prev_ref,    # scratch (Q, W) f32 — level l-1 survivors
    cur_ref,     # scratch (Q, W) f32 — level l survivors
    *,
    block_w: int,
    width: int,
    root_unconditional: bool,
    onehot_gather: bool,
    uncond_from: int,
):
    l = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when((t == 0) & (l > 0))
    def _roll():  # level finished: its survivors become the parent mask
        prev_ref[...] = cur_ref[...]

    ov = _overlap_tile(q_ref, mbr_ref[0])  # (Q, BW)

    parent_row = parent_ref[0].astype(jnp.int32)  # uint16 on the compact path
    if onehot_gather:
        # TPU path: parent gather as a one-hot matmul on the MXU,
        # onehot[p, j] = (p == parent[j]) — no lane gather needed.
        iota = jax.lax.broadcasted_iota(jnp.int32, (width, block_w), 0)
        onehot = (iota == parent_row[None, :]).astype(jnp.float32)
        pa = jnp.dot(prev_ref[...], onehot, preferred_element_type=jnp.float32)
    else:
        # Interpreter path: O(Q·BW) column gather instead of O(Q·W·BW).
        pa = jnp.take(prev_ref[...], parent_row, axis=1)
    parent_active = pa > 0.5

    act = _act_formula(
        ov, parent_active, l=l, t=t, block_w=block_w,
        root_unconditional=root_unconditional, uncond_from=uncond_from,
    )

    cur_ref[:, pl.ds(t * block_w, block_w)] = act.astype(jnp.float32)
    act_ref[0] = act


def _hier_sweep_kernel(
    q8_ref,      # (Q, 4) i32 — queries on the coarse uint8 grid
    q16_ref,     # (Q, 4) i32 — queries on the fine uint16 grid
    mbr8_ref,    # (1, 4, BW) u8 tile (level index clamped to < split)
    mbr16_ref,   # (1, 4, BW) u16 tile (level index clamped to >= split)
    parent_ref,  # (1, BW)
    act_ref,     # out (1, Q, BW) bool
    prev_ref,    # scratch (Q, W) f32
    cur_ref,     # scratch (Q, W) f32
    *,
    block_w: int,
    width: int,
    split: int,
    root_unconditional: bool,
    onehot_gather: bool,
    uncond_from: int,
):
    """Two-segment sweep: coarse uint8 tiles for levels < ``split``, fine
    uint16 tiles after (DESIGN.md §12).  Both BlockSpec index maps clamp
    into their own segment, so each step fetches one narrow tile and the
    level selects which overlap result feeds the shared recurrence."""
    l = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when((t == 0) & (l > 0))
    def _roll():
        prev_ref[...] = cur_ref[...]

    ov8 = _overlap_tile(q8_ref, mbr8_ref[0])
    ov16 = _overlap_tile(q16_ref, mbr16_ref[0])
    ov = jnp.where(l < split, ov8, ov16)

    parent_row = parent_ref[0].astype(jnp.int32)
    if onehot_gather:
        iota = jax.lax.broadcasted_iota(jnp.int32, (width, block_w), 0)
        onehot = (iota == parent_row[None, :]).astype(jnp.float32)
        pa = jnp.dot(prev_ref[...], onehot, preferred_element_type=jnp.float32)
    else:
        pa = jnp.take(prev_ref[...], parent_row, axis=1)
    parent_active = pa > 0.5

    act = _act_formula(
        ov, parent_active, l=l, t=t, block_w=block_w,
        root_unconditional=root_unconditional, uncond_from=uncond_from,
    )

    cur_ref[:, pl.ds(t * block_w, block_w)] = act.astype(jnp.float32)
    act_ref[0] = act


def _stream_sweep_kernel(
    winoff_ref,  # (L, T) SMEM i32 — parent-window start of every tile
    q_ref,       # (Q, 4) VMEM, resident
    mbr_hbm,     # (L, 4, Wp) ANY (HBM) — streamed, never VMEM-resident
    par_hbm,     # (L, Wp) ANY (HBM)
    act_ref,     # out (1, Q, BW) bool
    mbr_buf,     # VMEM (2, 4, BW) — double-buffered tile landing slots
    par_buf,     # VMEM (2, BW)
    win_buf,     # VMEM (2, Q, win_w) f32 — double-buffered parent windows
    cur_buf,     # VMEM (1, Q, BW) f32 — this tile's survivors, staged out
    mask_hbm,    # ANY (2, Q, Wp) f32 — ping-pong survivor masks (by level)
    sem_in,      # DMA sems (2 slots × {mbr, parent})
    sem_win,     # DMA sem — level-boundary window read
    sem_pre,     # DMA sem — next-step window prefetch
    sem_out,     # DMA sem — survivor write-back
    *,
    block_w: int,
    win_w: int,
    n_tiles: int,
    n_steps: int,
    root_unconditional: bool,
    onehot_gather: bool,
    uncond_from: int,
):
    """HBM-streaming twin of :func:`_sweep_kernel` (DESIGN.md §12).

    Copy/compute overlap: at linear step ``s = l·T + t`` the tile for step
    ``s+1`` is prefetched into VMEM slot ``(s+1) % 2`` while slot ``s % 2``
    is consumed — the double-buffer recurrence ``emit_pipeline`` would
    generate, written out so the survivor masks can ride an HBM scratch.
    Level ``l`` writes its survivors to ``mask_hbm[l % 2]`` and reads its
    parents from ``mask_hbm[(l+1) % 2]`` (= parity of ``l-1``), but only
    the ``win_w``-wide window starting at ``winoff[l, t]`` that this
    tile's parent slots actually span, so VMEM never holds a full-width
    mask.

    Dead-window skip: the window for step ``s+1`` is fetched (into the
    other ``win_buf`` slot) before step ``s+1``'s tile copies are issued.
    If no parent slot in it survived for ANY query, every activation in
    tile ``s+1`` would gather a zero — the tile is provably all-dead, so
    its MBR/parent DMA is skipped outright and only the zero write-back
    happens. Root, flat-delta, and level-boundary tiles are always
    fetched (the first tile of a level cannot read its window a step
    early: the previous level's last write-back may still be in flight)."""
    l = pl.program_id(0)
    t = pl.program_id(1)
    step = l * n_tiles + t
    slot = jax.lax.rem(step, 2)

    def tile_copies(li, ti, s):
        return (
            pltpu.make_async_copy(
                mbr_hbm.at[pl.ds(li, 1), :, pl.ds(ti * block_w, block_w)],
                mbr_buf.at[pl.ds(s, 1)],
                sem_in.at[s, 0],
            ),
            pltpu.make_async_copy(
                par_hbm.at[pl.ds(li, 1), pl.ds(ti * block_w, block_w)],
                par_buf.at[pl.ds(s, 1)],
                sem_in.at[s, 1],
            ),
        )

    def win_copy(li, ti, s, sem):
        # off < 0 marks a statically-empty tile; the copy is never
        # started for one, the clamp only keeps the descriptor in range.
        off = jnp.maximum(winoff_ref[li, ti], 0)
        return pltpu.make_async_copy(
            mask_hbm.at[pl.ds(jax.lax.rem(li + 1, 2), 1), :,
                        pl.ds(off, win_w)],
            win_buf.at[pl.ds(s, 1)],
            sem,
        )

    def gated_at(li):
        # Only hierarchical, non-root levels gate on the previous level's
        # survivors; flat delta levels and level 0 test unconditionally.
        return (li > 0) & (li < uncond_from)

    gated = gated_at(l)
    boundary = t == 0
    empty = winoff_ref[l, t] < 0

    @pl.when(step == 0)
    def _warmup():  # first tile has no previous step to prefetch it
        for c in tile_copies(l, t, slot):
            c.start()

    # Level-boundary window: read synchronously at this step (the mask of
    # level l-1 is complete once level l starts, but was not yet at the
    # previous step, when the boundary tile's copies were issued).
    bwin = win_copy(l, t, slot, sem_win)

    @pl.when(gated & boundary & ~empty)
    def _boundary_win_start():
        bwin.start()

    @pl.when(gated & boundary & ~empty)
    def _boundary_win_wait():
        bwin.wait()

    # Prefetch for step s+1 with dead-window skip: fetch the next tile's
    # parent window first; tile copies are only issued if some parent
    # slot in it is still alive for some query (and never for
    # statically-empty tiles, at any level).
    nxt = step + 1
    l1 = jax.lax.div(nxt, n_tiles)
    t1 = jax.lax.rem(nxt, n_tiles)
    s1 = jax.lax.rem(nxt, 2)
    empty1 = (nxt < n_steps) & (winoff_ref[jnp.minimum(l1, n_steps // n_tiles - 1), t1] < 0)
    skippable1 = gated_at(l1) & (t1 != 0)
    pwin = win_copy(jnp.minimum(l1, n_steps // n_tiles - 1), t1, s1, sem_pre)

    @pl.when((nxt < n_steps) & skippable1 & ~empty1)
    def _prefetch_win():
        pwin.start()
        pwin.wait()

    live1 = jnp.max(win_buf[pl.ds(s1, 1)]) > 0.5

    @pl.when((nxt < n_steps) & ~empty1 & (live1 | ~skippable1))
    def _prefetch():  # overlap: next tile's copy rides this tile's compute
        for c in tile_copies(l1, t1, s1):
            c.start()

    # Wait for our own tile — unless the previous step skipped its DMA.
    # ``live`` re-reads the same window slot the skip decision used (it
    # is untouched in between), so the predicate matches exactly.
    live = jnp.max(win_buf[pl.ds(slot, 1)]) > 0.5
    fetched = ~empty & (live | ~gated | boundary)

    @pl.when(fetched)
    def _tile_wait():
        for c in tile_copies(l, t, slot):
            c.wait()

    ov = _overlap_tile(q_ref, mbr_buf[pl.ds(slot, 1)][0])  # (Q, BW)

    parent_row = par_buf[pl.ds(slot, 1)][0].astype(jnp.int32)
    # Window-local parent slot.  Real slots are guaranteed in-window by
    # ``parent_windows``; padded slots may clamp to a garbage column, but
    # their sentinel MBRs make ``ov`` False so the AND discards it.  At
    # gated=False steps win_buf is stale/uninitialized — same argument:
    # the selected branch of ``_act_formula`` never reads parent_active.
    loc = jnp.clip(parent_row - winoff_ref[l, t], 0, win_w - 1)
    win = win_buf[pl.ds(slot, 1)][0]  # (Q, win_w)
    if onehot_gather:
        iota = jax.lax.broadcasted_iota(jnp.int32, (win_w, block_w), 0)
        onehot = (iota == loc[None, :]).astype(jnp.float32)
        pa = jnp.dot(win, onehot, preferred_element_type=jnp.float32)
    else:
        pa = jnp.take(win, loc, axis=1)
    parent_active = pa > 0.5

    act = _act_formula(
        ov, parent_active, l=l, t=t, block_w=block_w,
        root_unconditional=root_unconditional, uncond_from=uncond_from,
    )
    # A skipped statically-empty tile never DMA'd its buffers, so ``ov``
    # is stale garbage there — but its true activations are provably all
    # zero (sentinel MBRs; the root mask is slot 0 of tile 0), so force
    # exactly that.
    act = act & ~empty

    cur_buf[0] = act.astype(jnp.float32)
    out_copy = pltpu.make_async_copy(
        cur_buf,
        mask_hbm.at[pl.ds(jax.lax.rem(l, 2), 1), :,
                    pl.ds(t * block_w, block_w)],
        sem_out,
    )
    out_copy.start()
    out_copy.wait()
    act_ref[0] = act


def parent_windows(
    parent,
    n_real,
    *,
    block_w: int,
    uncond_from: int | None = None,
    levels: int | None = None,
    win_unit: int = 128,
) -> Tuple[np.ndarray, int]:
    """Per-tile parent-window metadata for the streaming sweep.

    For every (level, tile) of the padded grid, the window
    ``[off, off + win_w)`` must cover the parent slots of the tile's real
    entries.  Computed on the host from the concrete schedule arrays
    (outside jit — the offsets feed the kernel through SMEM), with ONE
    static ``win_w`` (the max span over all tiles, rounded up to
    ``win_unit`` lanes and capped at the padded width, so adversarial
    orderings degrade to a full-width window rather than a wrong answer).

    Returns ``(win_off (levels, T) int32, win_w int)``.
    """
    parent = np.asarray(parent)
    n_real = np.asarray(n_real)
    n_levels, w = parent.shape
    if levels is None:
        levels = n_levels
    if uncond_from is None:
        uncond_from = n_levels
    pad = (-w) % block_w
    wp = w + pad
    n_tiles = wp // block_w
    big = np.iinfo(np.int64).max
    tmin = np.full((levels, n_tiles), big, np.int64)
    tmax = np.full((levels, n_tiles), -1, np.int64)
    gate_top = min(n_levels, uncond_from, len(n_real), levels)
    for l in range(1, gate_top):
        nr = int(n_real[l])
        p = parent[l].astype(np.int64)
        valid = np.arange(w) < nr
        lo = np.concatenate([np.where(valid, p, big), np.full(pad, big)])
        hi = np.concatenate([np.where(valid, p, -1), np.full(pad, -1)])
        tmin[l] = lo.reshape(n_tiles, block_w).min(axis=1)
        tmax[l] = hi.reshape(n_tiles, block_w).max(axis=1)
    spans = np.where(tmax >= tmin, tmax - tmin + 1, 1)
    span = max(1, int(spans.max()))
    win_w = min(wp, int(-(-span // win_unit)) * win_unit)
    win_w = max(win_w, min(wp, win_unit))
    off = np.where(tmin == big, 0, np.minimum(tmin, wp - win_w))
    off = np.clip(off, 0, max(wp - win_w, 0)).astype(np.int32)
    # Statically-empty tiles (every slot past n_real[l]) can never
    # activate — sentinel MBRs overlap nothing and the root mask is slot
    # 0 only — so mark them with off = -1: the streaming kernel skips
    # their DMA outright, at every level including root and flat ones.
    tidx = np.arange(n_tiles) * block_w
    for l in range(min(levels, n_levels, len(n_real))):
        off[l, tidx >= int(n_real[l])] = -1
    return np.ascontiguousarray(off), win_w


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_w", "root_unconditional", "interpret", "onehot_gather",
        "uncond_from", "stream", "win_w",
    ),
)
def level_sweep(
    queries: jnp.ndarray,   # (Q, 4) f32
    mbr_cm: jnp.ndarray,    # (L, 4, W) f32
    parent: jnp.ndarray,    # (L, W) i32
    *,
    block_w: int = 128,
    root_unconditional: bool = True,
    interpret: bool = False,
    onehot_gather: bool | None = None,
    uncond_from: int | None = None,
    stream: bool = False,
    win_off: jnp.ndarray | None = None,   # (L, T) i32, see parent_windows
    win_w: int | None = None,
) -> jnp.ndarray:
    """Run the fused sweep; returns the (L, Q, W) per-level active mask.

    ``uncond_from`` marks the first FLAT level: levels ``>= uncond_from``
    skip the parent gate and test every slot against the query directly —
    how the live-update delta buffer rides the same launch (DESIGN.md §8).
    ``None`` (the default) keeps the whole sweep hierarchical.

    ``stream=True`` runs the HBM-streaming kernel instead of the
    VMEM-resident one (bit-identical masks, DESIGN.md §12); it requires
    the ``(win_off, win_w)`` pair from :func:`parent_windows` computed
    with the same ``block_w`` and ``uncond_from``.
    """
    levels, _, w = mbr_cm.shape
    q = queries.shape[0]
    pad = (-w) % block_w
    if pad:
        never = (
            NEVER_MBR
            if jnp.issubdtype(mbr_cm.dtype, jnp.floating)
            else Q_NEVER_MBR.astype(mbr_cm.dtype)
        )
        mbr_cm = jnp.concatenate(
            [mbr_cm,
             jnp.broadcast_to(jnp.asarray(never)[None, :, None],
                              (levels, 4, pad))],
            axis=2,
        )
        parent = jnp.concatenate(
            [parent, jnp.zeros((levels, pad), parent.dtype)], axis=1
        )
    wp = w + pad
    n_tiles = wp // block_w
    grid = (levels, n_tiles)
    if onehot_gather is None:
        # The MXU one-hot matmul is the native TPU lowering; the column
        # gather is cheaper (O(Q·W) vs O(Q·W²/BW)) where gathers are free.
        onehot_gather = not interpret
    uncond = levels if uncond_from is None else uncond_from
    if not stream:
        kernel = functools.partial(
            _sweep_kernel,
            block_w=block_w,
            width=wp,
            root_unconditional=root_unconditional,
            onehot_gather=onehot_gather,
            uncond_from=uncond,
        )
        act = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((q, 4), lambda l, t: (0, 0)),
                pl.BlockSpec((1, 4, block_w), lambda l, t: (l, 0, t)),
                pl.BlockSpec((1, block_w), lambda l, t: (l, t)),
            ],
            out_specs=pl.BlockSpec((1, q, block_w), lambda l, t: (l, 0, t)),
            out_shape=jax.ShapeDtypeStruct((levels, q, wp), jnp.bool_),
            scratch_shapes=[
                pltpu.VMEM((q, wp), jnp.float32),
                pltpu.VMEM((q, wp), jnp.float32),
            ],
            interpret=interpret,
        )(queries, mbr_cm, parent)
        return act[:, :, :w]
    if win_off is None or win_w is None:
        raise ValueError(
            "stream=True needs (win_off, win_w) from parent_windows()"
        )
    win_w = min(win_w, wp)
    kernel = functools.partial(
        _stream_sweep_kernel,
        block_w=block_w,
        win_w=win_w,
        n_tiles=n_tiles,
        n_steps=levels * n_tiles,
        root_unconditional=root_unconditional,
        onehot_gather=onehot_gather,
        uncond_from=uncond,
    )
    act = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((levels, n_tiles), lambda l, t: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((q, 4), lambda l, t: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, q, block_w), lambda l, t: (l, 0, t)),
        out_shape=jax.ShapeDtypeStruct((levels, q, wp), jnp.bool_),
        scratch_shapes=[
            pltpu.VMEM((2, 4, block_w), mbr_cm.dtype),
            pltpu.VMEM((2, block_w), parent.dtype),
            pltpu.VMEM((2, q, win_w), jnp.float32),
            pltpu.VMEM((1, q, block_w), jnp.float32),
            pltpu.ANY((2, q, wp), jnp.float32),
            pltpu.SemaphoreType.DMA((2, 2)),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(jnp.asarray(win_off, jnp.int32), queries, mbr_cm, parent)
    return act[:, :, :w]


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_w", "split", "root_unconditional", "interpret",
        "onehot_gather", "uncond_from",
    ),
)
def level_sweep_hier(
    q8: jnp.ndarray,      # (Q, 4) i32 — coarse-grid queries
    q16: jnp.ndarray,     # (Q, 4) i32 — fine-grid queries
    mbr8: jnp.ndarray,    # (split, 4, W) u8
    mbr16: jnp.ndarray,   # (L - split, 4, W) u16
    parent: jnp.ndarray,  # (L, W)
    *,
    split: int,
    block_w: int = 128,
    root_unconditional: bool = True,
    interpret: bool = False,
    onehot_gather: bool | None = None,
    uncond_from: int | None = None,
) -> jnp.ndarray:
    """Hierarchical two-grid sweep: uint8 tiles for levels < ``split``,
    uint16 after; returns the (L, Q, W) active mask (DESIGN.md §12)."""
    l8 = mbr8.shape[0]
    l16 = mbr16.shape[0]
    levels = l8 + l16
    assert split == l8 and split >= 1
    w = mbr16.shape[2]
    q = q16.shape[0]
    pad = (-w) % block_w
    if pad:
        mbr8 = jnp.concatenate(
            [mbr8,
             jnp.broadcast_to(jnp.asarray(Q8_NEVER_MBR)[None, :, None],
                              (l8, 4, pad))],
            axis=2,
        )
        mbr16 = jnp.concatenate(
            [mbr16,
             jnp.broadcast_to(jnp.asarray(Q_NEVER_MBR)[None, :, None],
                              (l16, 4, pad))],
            axis=2,
        )
        parent = jnp.concatenate(
            [parent, jnp.zeros((levels, pad), parent.dtype)], axis=1
        )
    wp = w + pad
    grid = (levels, wp // block_w)
    if onehot_gather is None:
        onehot_gather = not interpret
    kernel = functools.partial(
        _hier_sweep_kernel,
        block_w=block_w,
        width=wp,
        split=split,
        root_unconditional=root_unconditional,
        onehot_gather=onehot_gather,
        uncond_from=levels if uncond_from is None else uncond_from,
    )
    act = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q, 4), lambda l, t: (0, 0)),
            pl.BlockSpec((q, 4), lambda l, t: (0, 0)),
            # Each segment's index map clamps into its own level range, so
            # out-of-segment steps fetch a (discarded) boundary tile
            # instead of reading past the array.
            pl.BlockSpec(
                (1, 4, block_w),
                lambda l, t: (jnp.minimum(l, split - 1), 0, t),
            ),
            pl.BlockSpec(
                (1, 4, block_w),
                lambda l, t: (jnp.maximum(l - split, 0), 0, t),
            ),
            pl.BlockSpec((1, block_w), lambda l, t: (l, t)),
        ],
        out_specs=pl.BlockSpec((1, q, block_w), lambda l, t: (l, 0, t)),
        out_shape=jax.ShapeDtypeStruct((levels, q, wp), jnp.bool_),
        scratch_shapes=[
            pltpu.VMEM((q, wp), jnp.float32),
            pltpu.VMEM((q, wp), jnp.float32),
        ],
        interpret=interpret,
    )(q8, q16, mbr8, mbr16, parent)
    return act[:, :, :w]


def _quantize_queries(queries, origin, inv_cell, cells: int):
    """Outward query quantization onto a schedule grid (floor lo, ceil hi,
    clip into the domain) — shared by the compact and hier sweeps."""
    t = (queries - origin[None, :]) * inv_cell[None, :]
    qq = jnp.concatenate([jnp.floor(t[:, :2]), jnp.ceil(t[:, 2:])], axis=1)
    return jnp.clip(qq, 0.0, float(cells)).astype(jnp.int32)


def _hits_epilogue(act, queries, gate_mbr, obj_level, obj_slot, obj_id,
                   n_objects: int, alive=None):
    """Shared jnp epilogue: (L, Q, W) active mask -> (hits, visits).

    Per-level access counts: padded slots carry sentinel MBRs and are
    never active, so a plain sum counts exactly the visited real nodes.
    Entry e hits iff its holding node is active and (when ``gate_mbr`` is
    given) its exact float32 MBR overlaps the query — the confirming pass
    of the quantized paths and the object-MBR test of tree schedules are
    the same operation."""
    visits = jnp.transpose(act.sum(axis=2, dtype=jnp.int32))  # (Q, L)
    hit = jnp.transpose(act[obj_level, :, obj_slot])           # (Q, E)
    if gate_mbr is not None:
        hit = hit & _overlaps(gate_mbr[None, :, :], queries[:, None, :])
    q = queries.shape[0]
    hits = jnp.zeros((q, max(n_objects, 1)), jnp.bool_)
    hits = hits.at[:, obj_id].max(hit)
    if alive is not None:
        # Tombstone mask: deleted ids drop out here, in the same jit program.
        hits = hits & alive[None, :]
    return hits, visits


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_objects", "block_w", "root_unconditional", "test_object_mbr",
        "interpret", "stream", "win_w",
    ),
)
def _fused_search(
    queries, mbr_cm, parent, obj_mbr, obj_level, obj_slot, obj_id,
    *,
    n_objects: int,
    block_w: int,
    root_unconditional: bool,
    test_object_mbr: bool,
    interpret: bool,
    stream: bool = False,
    win_off=None,
    win_w: int | None = None,
):
    act = level_sweep(
        queries, mbr_cm, parent,
        block_w=block_w,
        root_unconditional=root_unconditional,
        interpret=interpret,
        stream=stream,
        win_off=win_off,
        win_w=win_w,
    )  # (L, Q, W)
    return _hits_epilogue(
        act, queries, obj_mbr if test_object_mbr else None,
        obj_level, obj_slot, obj_id, n_objects,
    )


def pyramid_scan(
    schedule: LevelSchedule,
    queries,
    *,
    block_w: int = 128,
    interpret: bool = False,
    stream: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused region search over a :class:`LevelSchedule`.

    Returns ``(hits, visits)``: hits (Q, n_objects) bool object mask and
    visits (Q, L) int32 per-level access counts — both identical to the
    host pointer search (tree schedules) / ``bulk.pyramid_search``
    (pyramid schedules).  ONE kernel launch regardless of tree height.
    ``stream=True`` uses the HBM-streaming kernel (DESIGN.md §12) —
    bit-identical results, VMEM bounded by the tile/window working set.
    """
    win_off, win_w = (None, None)
    if stream:
        win_off, win_w = parent_windows(
            schedule.parent, schedule.n_real, block_w=block_w
        )
    if _obs_counters.collecting():  # side channel: eager wrappers only
        _obs_counters.emit(_obs_counters.scan_report_float32(
            schedule, queries, block_w=block_w, stream=stream,
            win_off=win_off, win_w=win_w))
    if stream:
        win_off = jnp.asarray(win_off)
    return _fused_search(
        jnp.asarray(queries, jnp.float32),
        jnp.asarray(schedule.mbr_cm),
        jnp.asarray(schedule.parent),
        jnp.asarray(schedule.obj_mbr),
        jnp.asarray(schedule.obj_level),
        jnp.asarray(schedule.obj_slot),
        jnp.asarray(schedule.obj_id),
        n_objects=schedule.n_objects,
        block_w=block_w,
        root_unconditional=schedule.root_unconditional,
        test_object_mbr=schedule.test_object_mbr,
        interpret=interpret,
        stream=stream,
        win_off=win_off,
        win_w=win_w,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_objects", "cells", "block_w", "root_unconditional", "interpret",
        "stream", "win_w",
    ),
)
def _fused_search_compact(
    queries, mbr_q, parent_q, confirm_mbr, obj_level, obj_slot, obj_id,
    origin, inv_cell,
    *,
    n_objects: int,
    cells: int,
    block_w: int,
    root_unconditional: bool,
    interpret: bool,
    stream: bool = False,
    win_off=None,
    win_w: int | None = None,
):
    """Fused sweep over uint16 tiles + exact float32 confirming pass.

    Queries are quantized OUTWARD onto the schedule's grid (lo floor, hi
    ceil, clipped to the domain — node boxes never extend past it), so
    the integer sweep's survivors are a superset of the exact sweep's.
    The confirming pass intersects them with the exact ``confirm_mbr``
    overlap, which by MBR nesting implies the full exact ancestor chain:
    hit sets come out bit-identical to :func:`_fused_search`
    (tests/test_quantized.py).  ``visits`` counts the accesses this path
    actually performed — the conservative sweep may touch slightly more
    nodes per level than the exact one (DESIGN.md §7).
    """
    qq = _quantize_queries(queries, origin, inv_cell, cells)
    act = level_sweep(
        qq, mbr_q, parent_q,
        block_w=block_w,
        root_unconditional=root_unconditional,
        interpret=interpret,
        stream=stream,
        win_off=win_off,
        win_w=win_w,
    )  # (L, Q, W) candidate mask, superset of the exact active mask
    return _hits_epilogue(
        act, queries, confirm_mbr, obj_level, obj_slot, obj_id, n_objects
    )


def pyramid_scan_compact(
    qsched: QuantizedSchedule,
    queries,
    *,
    block_w: int = 128,
    interpret: bool = False,
    stream: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused region search over a :class:`QuantizedSchedule`: half the
    streamed bytes per tile, hit sets bit-identical to the float32 path;
    ``visits`` reports the compact sweep's own (conservative) accesses."""
    win_off, win_w = (None, None)
    if stream:
        win_off, win_w = parent_windows(
            qsched.parent_q, qsched.base.n_real, block_w=block_w
        )
    if _obs_counters.collecting():  # side channel: eager wrappers only
        _obs_counters.emit(_obs_counters.scan_report_compact(
            qsched, queries, block_w=block_w, stream=stream,
            win_off=win_off, win_w=win_w))
    if stream:
        win_off = jnp.asarray(win_off)
    return _fused_search_compact(
        jnp.asarray(queries, jnp.float32),
        jnp.asarray(qsched.mbr_q),
        jnp.asarray(qsched.parent_q),
        jnp.asarray(qsched.confirm_mbr),
        jnp.asarray(qsched.base.obj_level),
        jnp.asarray(qsched.base.obj_slot),
        jnp.asarray(qsched.base.obj_id),
        jnp.asarray(qsched.origin),
        jnp.asarray(qsched.inv_cell),
        n_objects=qsched.n_objects,
        cells=qsched.cells,
        block_w=block_w,
        root_unconditional=qsched.base.root_unconditional,
        interpret=interpret,
        stream=stream,
        win_off=win_off,
        win_w=win_w,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_objects", "cells", "cells8", "split", "block_w",
        "root_unconditional", "interpret",
    ),
)
def _fused_search_compact8(
    queries, mbr_q8, mbr_q16, parent_q, confirm_mbr, obj_level, obj_slot,
    obj_id, origin, inv_cell, inv_cell8,
    *,
    n_objects: int,
    cells: int,
    cells8: int,
    split: int,
    block_w: int,
    root_unconditional: bool,
    interpret: bool,
):
    """Hierarchically quantized sweep: uint8 coarse tiles for the upper
    ``split`` levels, uint16 fine tiles below, one launch (DESIGN.md §12).

    Conservativity is per-level and grid-independent: both grids round
    node boxes AND queries outward, so each level's candidate mask is a
    superset of the exact sweep's regardless of cell size, and the exact
    confirming pass keeps hit sets bit-identical.  Only ``visits`` may
    inflate on the coarse levels (those are exactly the levels whose fat
    MBRs make extra candidates cheap — the skip-quadtree intuition)."""
    qq16 = _quantize_queries(queries, origin, inv_cell, cells)
    if split == 0:  # degenerate (single-level) schedule: plain compact
        act = level_sweep(
            qq16, mbr_q16, parent_q,
            block_w=block_w,
            root_unconditional=root_unconditional,
            interpret=interpret,
        )
    else:
        qq8 = _quantize_queries(queries, origin, inv_cell8, cells8)
        act = level_sweep_hier(
            qq8, qq16, mbr_q8, mbr_q16, parent_q,
            split=split,
            block_w=block_w,
            root_unconditional=root_unconditional,
            interpret=interpret,
        )
    return _hits_epilogue(
        act, queries, confirm_mbr, obj_level, obj_slot, obj_id, n_objects
    )


def pyramid_scan_compact8(
    qsched: QuantizedSchedule,
    queries,
    *,
    block_w: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused region search over the hierarchical (uint8 upper-level) form
    of a :class:`QuantizedSchedule` — ``quantize_schedule(..., upper8=
    True)``.  Hit sets bit-identical to every other precision; upper-level
    tiles stream at 1 byte per coordinate (DESIGN.md §12)."""
    if not qsched.hierarchical and qsched.levels > 1:
        raise ValueError(
            "pyramid_scan_compact8 needs quantize_schedule(..., upper8=True)"
        )
    if _obs_counters.collecting():  # side channel: eager wrappers only
        _obs_counters.emit(_obs_counters.scan_report_compact8(
            qsched, queries, block_w=block_w))
    split = qsched.split
    return _fused_search_compact8(
        jnp.asarray(queries, jnp.float32),
        jnp.asarray(
            qsched.mbr_q8
            if qsched.mbr_q8 is not None
            else np.zeros((0, 4, qsched.width), np.uint8)
        ),
        jnp.asarray(qsched.mbr_q[split:]),
        jnp.asarray(qsched.parent_q),
        jnp.asarray(qsched.confirm_mbr),
        jnp.asarray(qsched.base.obj_level),
        jnp.asarray(qsched.base.obj_slot),
        jnp.asarray(qsched.base.obj_id),
        jnp.asarray(qsched.origin),
        jnp.asarray(qsched.inv_cell),
        jnp.asarray(
            qsched.inv_cell8
            if qsched.inv_cell8 is not None
            else qsched.inv_cell
        ),
        n_objects=qsched.n_objects,
        cells=qsched.cells,
        cells8=qsched.cells8,
        split=split,
        block_w=block_w,
        root_unconditional=qsched.base.root_unconditional,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_objects", "base_levels", "block_w", "root_unconditional",
        "test_object_mbr", "interpret", "stream", "win_w",
    ),
)
def _fused_search_live(
    queries, mbr_cm, parent, obj_mbr, obj_level, obj_slot, obj_id, alive,
    *,
    n_objects: int,
    base_levels: int,
    block_w: int,
    root_unconditional: bool,
    test_object_mbr: bool,
    interpret: bool,
    stream: bool = False,
    win_off=None,
    win_w: int | None = None,
):
    """Fused sweep over base levels + appended flat delta levels.

    The live-update subsystem (DESIGN.md §8) appends the delta buffer as
    ``uncond_from = base_levels`` flat levels: one launch still sweeps
    everything, and the epilogue scatters base entries and delta slots
    into the same global-id hit mask, then masks tombstoned ids with
    ``alive``.  ``visits`` keeps the per-level layout — columns past
    ``base_levels`` are delta-side accesses.
    """
    act = level_sweep(
        queries, mbr_cm, parent,
        block_w=block_w,
        root_unconditional=root_unconditional,
        interpret=interpret,
        uncond_from=base_levels,
        stream=stream,
        win_off=win_off,
        win_w=win_w,
    )  # (L_base + D, Q, W)
    return _hits_epilogue(
        act, queries, obj_mbr if test_object_mbr else None,
        obj_level, obj_slot, obj_id, n_objects, alive=alive,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_objects", "cells", "base_levels", "block_w",
        "root_unconditional", "interpret", "stream", "win_w",
    ),
)
def _fused_search_compact_live(
    queries, mbr_q, parent_q, confirm_mbr, obj_level, obj_slot, obj_id,
    origin, inv_cell, alive,
    *,
    n_objects: int,
    cells: int,
    base_levels: int,
    block_w: int,
    root_unconditional: bool,
    interpret: bool,
    stream: bool = False,
    win_off=None,
    win_w: int | None = None,
):
    """Compact (uint16-tile) twin of :func:`_fused_search_live`.

    Delta rows are quantized outward onto the base grid (clipped — see
    ``kernels.quantize.quantize_rows``), swept as flat levels in the same
    integer launch, and confirmed exactly against their float32 MBRs, so
    the tombstone-masked hit sets stay bit-identical to the float32 live
    path (DESIGN.md §8).
    """
    qq = _quantize_queries(queries, origin, inv_cell, cells)
    act = level_sweep(
        qq, mbr_q, parent_q,
        block_w=block_w,
        root_unconditional=root_unconditional,
        interpret=interpret,
        uncond_from=base_levels,
        stream=stream,
        win_off=win_off,
        win_w=win_w,
    )
    return _hits_epilogue(
        act, queries, confirm_mbr, obj_level, obj_slot, obj_id, n_objects,
        alive=alive,
    )


def per_level_region_search(
    schedule: LevelSchedule,
    queries,
    *,
    block_w: int = 128,
    interpret: bool = False,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Status-quo baseline: ONE ``mbr_scan`` launch per level, survivor
    frontier combined in host Python between launches.  Returns
    ``(hits, visits, n_launches)`` with hits/visits matching
    :func:`pyramid_scan`; exists so the benchmark can measure what fusing
    the sweep saves (DESIGN.md §3.3).
    """
    from .mbr_scan import mbr_scan

    q = np.asarray(queries, np.float32)
    nq = q.shape[0]
    levels, _, w = schedule.mbr_cm.shape
    launches = 0
    active = None
    acts = []
    for l in range(levels):
        mbrs = np.ascontiguousarray(schedule.mbr_cm[l].T)  # (W, 4) row-major
        # Sentinel-padded rows contain inf; mbr_scan pads with inf itself,
        # so the scan is well defined and padded slots never overlap.
        ov = np.asarray(
            mbr_scan(jnp.asarray(mbrs), jnp.asarray(q),
                     block_n=block_w, interpret=interpret)
        )
        launches += 1
        if l == 0:
            if schedule.root_unconditional:
                act = np.zeros((nq, w), bool)
                act[:, 0] = True
            else:
                act = ov
        else:
            act = ov & active[:, schedule.parent[l]]
        active = act
        acts.append(act)
    act = np.stack(acts)  # (L, Q, W)
    visits = act.sum(axis=2).T.astype(np.int32)
    entry_act = act[schedule.obj_level, :, schedule.obj_slot].T  # (Q, E)
    if schedule.test_object_mbr:
        entry_act = entry_act & _overlaps(
            schedule.obj_mbr[None, :, :], q[:, None, :]
        )
    hits = np.zeros((nq, max(schedule.n_objects, 1)), bool)
    np.maximum.at(hits, (slice(None), schedule.obj_id), entry_act)
    return hits, visits, launches
