"""Degradation-ladder twins of the fused Pallas sweeps (DESIGN.md §9).

When a Pallas lowering or launch fails at serving time, the
:class:`repro.launch.spatial_serve.SpatialServer` retries the query batch
on the next rung of its health ladder:

* **lax rung** — the same level sweep in plain ``jnp`` ops (jit'd XLA, no
  ``pallas_call``), signature-compatible with the fused entry points of
  :mod:`repro.kernels.ops` so the server's vmap/pmap plumbing is reused
  unchanged;
* **host rung** — the same sweep in pure numpy, the last resort when the
  device runtime itself is unavailable.

Every twin reproduces the kernel's recurrence exactly — root slot
unconditional (tree schedules), parent-gated overlap per level, flat
unconditional delta levels from ``uncond_from``, per-object confirming
pass, tombstone mask — so degraded answers are *bit-identical* to the
healthy path's hit sets and per-level visit counts (tests/
test_degradation.py); only latency degrades.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp


def _overlap(a, b):
    """Closed-boundary rectangle intersection, broadcasting; index/compare
    ops only, so one definition serves numpy and traced jnp arrays (and
    the integer grid of the compact path, where <=/& mean the same)."""
    return (
        (a[..., 0] <= b[..., 2])
        & (b[..., 0] <= a[..., 2])
        & (a[..., 1] <= b[..., 3])
        & (b[..., 1] <= a[..., 3])
    )


def _quantize_queries(xp, queries, origin, inv_cell, cells):
    """Outward query quantization of the compact sweep — identical to
    ``pyramid_scan._fused_search_compact`` (floor lo, ceil hi, clip)."""
    t = (queries - origin[None, :]) * inv_cell[None, :]
    qq = xp.concatenate([xp.floor(t[:, :2]), xp.ceil(t[:, 2:])], axis=1)
    return xp.clip(qq, 0.0, float(cells)).astype(xp.int32)


# ---------------------------------------------------------------------------
# lax rung: jnp level sweep, jit/vmap-able, no pallas_call
# ---------------------------------------------------------------------------


def _sweep_jnp(queries, mbr_cm, parent, *, root_unconditional, uncond_from):
    """(L, Q, W) active mask — the jnp twin of ``pyramid_scan.level_sweep``."""
    levels, _, w = mbr_cm.shape
    mbr_rm = jnp.transpose(mbr_cm, (0, 2, 1))  # (L, W, 4)
    nq = queries.shape[0]
    uncond_from = levels if uncond_from is None else uncond_from
    acts = []
    prev = None
    for l in range(levels):
        ov = _overlap(mbr_rm[l][None, :, :], queries[:, None, :])  # (Q, W)
        if l == 0:
            if root_unconditional and uncond_from > 0:
                act = jnp.zeros((nq, w), bool).at[:, 0].set(True)
            else:
                act = ov
        elif l >= uncond_from:
            act = ov  # flat delta level: no parent gate
        else:
            act = ov & jnp.take(prev, parent[l], axis=1)
        acts.append(act)
        prev = act
    return jnp.stack(acts)  # (L, Q, W)


def fused_search_lax(
    queries, mbr_cm, parent, obj_mbr, obj_level, obj_slot, obj_id,
    *, n_objects, block_w=128, root_unconditional=True,
    test_object_mbr=True, interpret=None,
):
    del block_w, interpret  # kernel-only tuning knobs
    act = _sweep_jnp(
        queries, mbr_cm, parent,
        root_unconditional=root_unconditional, uncond_from=None,
    )
    visits = jnp.transpose(act.sum(axis=2, dtype=jnp.int32))
    hit = jnp.transpose(act[obj_level, :, obj_slot])
    if test_object_mbr:
        hit = hit & _overlap(obj_mbr[None, :, :], queries[:, None, :])
    hits = jnp.zeros((queries.shape[0], max(n_objects, 1)), jnp.bool_)
    hits = hits.at[:, obj_id].max(hit)
    return hits, visits


def fused_search_live_lax(
    queries, mbr_cm, parent, obj_mbr, obj_level, obj_slot, obj_id, alive,
    *, n_objects, base_levels, block_w=128, root_unconditional=True,
    test_object_mbr=True, interpret=None,
):
    del block_w, interpret
    act = _sweep_jnp(
        queries, mbr_cm, parent,
        root_unconditional=root_unconditional, uncond_from=base_levels,
    )
    visits = jnp.transpose(act.sum(axis=2, dtype=jnp.int32))
    hit = jnp.transpose(act[obj_level, :, obj_slot])
    if test_object_mbr:
        hit = hit & _overlap(obj_mbr[None, :, :], queries[:, None, :])
    hits = jnp.zeros((queries.shape[0], max(n_objects, 1)), jnp.bool_)
    hits = hits.at[:, obj_id].max(hit)
    return hits & alive[None, :], visits


def fused_search_compact_lax(
    queries, mbr_q, parent_q, confirm_mbr, obj_level, obj_slot, obj_id,
    origin, inv_cell,
    *, n_objects, cells, block_w=128, root_unconditional=True,
    interpret=None,
):
    del block_w, interpret
    qq = _quantize_queries(jnp, queries, origin, inv_cell, cells)
    act = _sweep_jnp(
        qq, mbr_q.astype(jnp.int32), parent_q.astype(jnp.int32),
        root_unconditional=root_unconditional, uncond_from=None,
    )
    visits = jnp.transpose(act.sum(axis=2, dtype=jnp.int32))
    cand = jnp.transpose(act[obj_level, :, obj_slot])
    hit = cand & _overlap(confirm_mbr[None, :, :], queries[:, None, :])
    hits = jnp.zeros((queries.shape[0], max(n_objects, 1)), jnp.bool_)
    hits = hits.at[:, obj_id].max(hit)
    return hits, visits


def fused_search_compact_live_lax(
    queries, mbr_q, parent_q, confirm_mbr, obj_level, obj_slot, obj_id,
    origin, inv_cell, alive,
    *, n_objects, cells, base_levels, block_w=128, root_unconditional=True,
    interpret=None,
):
    del block_w, interpret
    qq = _quantize_queries(jnp, queries, origin, inv_cell, cells)
    act = _sweep_jnp(
        qq, mbr_q.astype(jnp.int32), parent_q.astype(jnp.int32),
        root_unconditional=root_unconditional, uncond_from=base_levels,
    )
    visits = jnp.transpose(act.sum(axis=2, dtype=jnp.int32))
    cand = jnp.transpose(act[obj_level, :, obj_slot])
    hit = cand & _overlap(confirm_mbr[None, :, :], queries[:, None, :])
    hits = jnp.zeros((queries.shape[0], max(n_objects, 1)), jnp.bool_)
    hits = hits.at[:, obj_id].max(hit)
    return hits & alive[None, :], visits


# ---------------------------------------------------------------------------
# host rung: the same sweep in pure numpy (no device runtime at all)
# ---------------------------------------------------------------------------


def _sweep_np(queries, mbr_cm, parent, *, root_unconditional, uncond_from):
    levels, _, w = mbr_cm.shape
    mbr_rm = mbr_cm.transpose(0, 2, 1)  # (L, W, 4)
    nq = queries.shape[0]
    uncond_from = levels if uncond_from is None else uncond_from
    acts = np.zeros((levels, nq, w), bool)
    for l in range(levels):
        ov = _overlap(mbr_rm[l][None, :, :], queries[:, None, :])
        if l == 0:
            if root_unconditional and uncond_from > 0:
                act = np.zeros((nq, w), bool)
                act[:, 0] = True
            else:
                act = ov
        elif l >= uncond_from:
            act = ov
        else:
            act = ov & acts[l - 1][:, parent[l]]
        acts[l] = act
    return acts


def _scatter_hits_np(queries, act, obj_level, obj_slot, obj_id, n_objects,
                     entry_gate):
    visits = act.sum(axis=2).T.astype(np.int32)
    hit = act[obj_level, :, obj_slot].T  # (Q, E)
    if entry_gate is not None:
        hit = hit & entry_gate
    hits = np.zeros((queries.shape[0], max(n_objects, 1)), bool)
    np.maximum.at(hits, (slice(None), obj_id), hit)
    return hits, visits


def fused_search_np(
    queries, mbr_cm, parent, obj_mbr, obj_level, obj_slot, obj_id,
    *, n_objects, block_w=128, root_unconditional=True,
    test_object_mbr=True, interpret=None,
):
    del block_w, interpret
    queries = np.asarray(queries, np.float32)
    act = _sweep_np(
        queries, np.asarray(mbr_cm), np.asarray(parent),
        root_unconditional=root_unconditional, uncond_from=None,
    )
    gate = (
        _overlap(np.asarray(obj_mbr)[None, :, :], queries[:, None, :])
        if test_object_mbr else None
    )
    return _scatter_hits_np(
        queries, act, np.asarray(obj_level), np.asarray(obj_slot),
        np.asarray(obj_id), n_objects, gate,
    )


def fused_search_live_np(
    queries, mbr_cm, parent, obj_mbr, obj_level, obj_slot, obj_id, alive,
    *, n_objects, base_levels, block_w=128, root_unconditional=True,
    test_object_mbr=True, interpret=None,
):
    del block_w, interpret
    queries = np.asarray(queries, np.float32)
    act = _sweep_np(
        queries, np.asarray(mbr_cm), np.asarray(parent),
        root_unconditional=root_unconditional, uncond_from=base_levels,
    )
    gate = (
        _overlap(np.asarray(obj_mbr)[None, :, :], queries[:, None, :])
        if test_object_mbr else None
    )
    hits, visits = _scatter_hits_np(
        queries, act, np.asarray(obj_level), np.asarray(obj_slot),
        np.asarray(obj_id), n_objects, gate,
    )
    return hits & np.asarray(alive, bool)[None, :], visits


def fused_search_compact_np(
    queries, mbr_q, parent_q, confirm_mbr, obj_level, obj_slot, obj_id,
    origin, inv_cell,
    *, n_objects, cells, block_w=128, root_unconditional=True,
    interpret=None,
):
    del block_w, interpret
    queries = np.asarray(queries, np.float32)
    qq = _quantize_queries(
        np, queries, np.asarray(origin), np.asarray(inv_cell), cells
    )
    act = _sweep_np(
        qq, np.asarray(mbr_q, np.int32), np.asarray(parent_q, np.int32),
        root_unconditional=root_unconditional, uncond_from=None,
    )
    gate = _overlap(np.asarray(confirm_mbr)[None, :, :], queries[:, None, :])
    return _scatter_hits_np(
        queries, act, np.asarray(obj_level), np.asarray(obj_slot),
        np.asarray(obj_id), n_objects, gate,
    )


def fused_search_compact_live_np(
    queries, mbr_q, parent_q, confirm_mbr, obj_level, obj_slot, obj_id,
    origin, inv_cell, alive,
    *, n_objects, cells, base_levels, block_w=128, root_unconditional=True,
    interpret=None,
):
    del block_w, interpret
    queries = np.asarray(queries, np.float32)
    qq = _quantize_queries(
        np, queries, np.asarray(origin), np.asarray(inv_cell), cells
    )
    act = _sweep_np(
        qq, np.asarray(mbr_q, np.int32), np.asarray(parent_q, np.int32),
        root_unconditional=root_unconditional, uncond_from=base_levels,
    )
    gate = _overlap(np.asarray(confirm_mbr)[None, :, :], queries[:, None, :])
    hits, visits = _scatter_hits_np(
        queries, act, np.asarray(obj_level), np.asarray(obj_slot),
        np.asarray(obj_id), n_objects, gate,
    )
    return hits & np.asarray(alive, bool)[None, :], visits


# ---------------------------------------------------------------------------
# tree-vs-tree join twins (DESIGN.md §10): same rungs for SpatialIndex.join
# ---------------------------------------------------------------------------


def _pair_sweep_jnp(a_cm, a_parent, b_cm, b_parent, symmetric=False):
    """(K, Wa, Wb) pair-active mask — jnp twin of ``join_scan.pair_sweep``.

    Same recurrence: a node pair survives level ``k`` iff its parent pair
    survived ``k-1`` and the two level-``k`` MBRs overlap (level 0 tests
    the root-pair overlap directly — conservative for every schedule
    flavour).  Tiles cast to float32 so uint16 joint-grid tiles take the
    identical path.  ``symmetric`` is the self-join fast path: only slot
    pairs with ``ga <= gb`` are kept per level (the same slot-granularity
    triu the kernel applies — bit-compatible regardless of block size),
    and the parent gather reads the mirrored previous level."""
    k_levels = a_cm.shape[0]
    a = jnp.asarray(a_cm).astype(jnp.float32)
    b = jnp.asarray(b_cm).astype(jnp.float32)
    wa, wb = a.shape[2], b.shape[2]
    triu = None
    if symmetric:
        triu = (
            jnp.arange(wa)[:, None] <= jnp.arange(wb)[None, :]
        )
    acts = []
    prev = None
    for k in range(k_levels):
        al, bl = a[k], b[k]  # (4, Wa) / (4, Wb)
        ov = (
            (al[0][:, None] <= bl[2][None, :])
            & (bl[0][None, :] <= al[2][:, None])
            & (al[1][:, None] <= bl[3][None, :])
            & (bl[1][None, :] <= al[3][:, None])
        )
        if k == 0:
            act = ov
        else:
            gather = prev | prev.T if symmetric else prev
            act = ov & jnp.take(
                jnp.take(gather, a_parent[k], axis=0), b_parent[k], axis=1
            )
        if symmetric:
            act = act & triu
        acts.append(act)
        prev = act
    return jnp.stack(acts)


def _pair_sweep_np(a_cm, a_parent, b_cm, b_parent, symmetric=False):
    k_levels, _, wa = a_cm.shape
    wb = b_cm.shape[2]
    a = np.asarray(a_cm, np.float32)
    b = np.asarray(b_cm, np.float32)
    triu = (
        np.arange(wa)[:, None] <= np.arange(wb)[None, :]
        if symmetric else None
    )
    acts = np.zeros((k_levels, wa, wb), bool)
    for k in range(k_levels):
        al, bl = a[k], b[k]
        ov = (
            (al[0][:, None] <= bl[2][None, :])
            & (bl[0][None, :] <= al[2][:, None])
            & (al[1][:, None] <= bl[3][None, :])
            & (bl[1][None, :] <= al[3][:, None])
        )
        if k == 0:
            acts[k] = ov
        else:
            prev = acts[k - 1]
            if symmetric:
                prev = prev | prev.T
            acts[k] = ov & prev[a_parent[k]][:, b_parent[k]]
        if symmetric:
            acts[k] &= triu
    return acts


def fused_join_lax(
    a_cm, a_parent, a_anc, a_level, a_gid,
    b_cm, b_parent, b_anc, b_level, b_gid,
    table_a, table_b, alive_a, alive_b, delta_a, delta_b,
    *, block_a=128, block_b=128, interpret=None, symmetric=False,
):
    """lax rung of :func:`repro.kernels.ops.fused_join`: plain-XLA pair
    sweep + the shared candidate/confirm epilogue — pair sets AND pair-
    visit ledger bit-identical to the fused kernel."""
    del block_a, block_b, interpret  # kernel-only tuning knobs
    from .join_scan import join_epilogue

    act = _pair_sweep_jnp(a_cm, a_parent, b_cm, b_parent, symmetric)
    return join_epilogue(
        act,
        jnp.asarray(a_anc), jnp.asarray(a_level), jnp.asarray(a_gid),
        jnp.asarray(b_anc), jnp.asarray(b_level), jnp.asarray(b_gid),
        jnp.asarray(table_a), jnp.asarray(table_b),
        jnp.asarray(alive_a), jnp.asarray(alive_b),
        jnp.asarray(delta_a), jnp.asarray(delta_b),
        symmetric=symmetric,
    )


def fused_join_np(
    a_cm, a_parent, a_anc, a_level, a_gid,
    b_cm, b_parent, b_anc, b_level, b_gid,
    table_a, table_b, alive_a, alive_b, delta_a, delta_b,
    *, block_a=128, block_b=128, interpret=None, symmetric=False,
):
    """host rung: the same join in pure numpy (no device runtime)."""
    del block_a, block_b, interpret
    from .join_scan import join_epilogue

    act = _pair_sweep_np(
        np.asarray(a_cm), np.asarray(a_parent),
        np.asarray(b_cm), np.asarray(b_parent), symmetric,
    )
    return join_epilogue(
        act,
        np.asarray(a_anc), np.asarray(a_level), np.asarray(a_gid),
        np.asarray(b_anc), np.asarray(b_level), np.asarray(b_gid),
        np.asarray(table_a, np.float32), np.asarray(table_b, np.float32),
        np.asarray(alive_a, bool), np.asarray(alive_b, bool),
        np.asarray(delta_a, bool), np.asarray(delta_b, bool),
        symmetric=symmetric,
    )


# degradation-ladder rung -> join twin; the pallas rung is
# ``repro.kernels.ops.fused_join`` itself.
JOIN_FALLBACKS = {"lax": fused_join_lax, "host": fused_join_np}


# variant key -> (lax rung fn, host rung fn); the server picks by the
# same (precision, live) pair it used to choose the fused kernel.
FALLBACKS = {
    ("float32", False): (fused_search_lax, fused_search_np),
    ("float32", True): (fused_search_live_lax, fused_search_live_np),
    ("compact", False): (fused_search_compact_lax, fused_search_compact_np),
    ("compact", True): (
        fused_search_compact_live_lax, fused_search_compact_live_np,
    ),
}
